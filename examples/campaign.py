"""Run-farm campaign walkthrough (src/repro/runfarm/).

Shards a seeded register-protocol fuzz campaign into work units, runs it
through the sequential in-process oracle and then a 2-worker spawned
pool, and shows the determinism bar holding: identical merged coverage
and identical final digest at both worker counts, and again after a
resume from the JSONL result store.  Finishes by harvesting a planted
interpret-backend bug: the failing unit ships a shrunk repro bundle.

Every number below is a digest, count, or modeled quantity (no wall
time), so the transcript is deterministic; docs/runfarm.md reproduces it
verbatim, pinned by tests/test_docs.py::test_runfarm_docs_transcript.

    PYTHONPATH=src python examples/campaign.py
"""
import json
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.runfarm import CampaignManager, fuzz_units


def main(argv=None):
    tmp = Path(tempfile.mkdtemp(prefix="campaign_"))
    try:
        units = fuzz_units(seed=42, n_scenarios=600, batch=150)
        print("run-farm campaign: 600 register-protocol fuzz scenarios")
        print(f"  gen 0: {len(units)} units x 150 scenarios, "
              "coverage-guided mutation after each generation")

        oracle = CampaignManager(tmp / "oracle", units, seed=42,
                                 workers=0, generations=3).run()
        det = oracle.report["deterministic"]
        print("\nsequential oracle (workers=0):")
        print(f"  units {det['units']}  scenarios {det['scenarios']}  "
              f"final digest {oracle.digest[:16]}")
        for t in det["trajectory"]:
            print(f"  gen {t['generation']}: {t['units']} units, "
                  f"+{t['new_bins']} new bins -> {t['covered']} covered")
        print("  protocol coverage "
              f"{oracle.coverage.percent('protocol'):.1f}%")

        pool = CampaignManager(tmp / "pool", units, seed=42,
                               workers=2, generations=3).run()
        same_digest = pool.digest == oracle.digest
        same_cov = pool.coverage.counts == oracle.coverage.counts
        print("\n2-worker spawned pool:")
        print(f"  final digest {pool.digest[:16]}  "
              f"({'identical' if same_digest else 'DIVERGED'})")
        print(f"  merged coverage identical: {same_cov}")

        resumed = CampaignManager(tmp / "pool", units, seed=42,
                                  workers=2, generations=3).run()
        n_skip = resumed.report["timing"]["units_resumed_from_store"]
        print(f"  resume from store: {n_skip} units skipped, digest "
              f"{'identical' if resumed.digest == oracle.digest else 'DIVERGED'}")

        bug = fuzz_units(seed=5, n_scenarios=2, batch=2,
                         layers=("bridge",), bridge_ops=[2, 4],
                         mm_bug=(1, 2, 1.0))
        res = CampaignManager(tmp / "bug", bug, seed=5).run()
        h = json.loads(res.bundles[0].read_text())["harvest"]
        print("\nplanted interpret-backend bug (c[1,2] += 1.0), "
              "2 bridge scenarios:")
        print(f"  campaign passed: {res.passed}; harvested bundle: "
              f"bundles/{res.bundles[0].name}")
        print(f"  scenario {h['scenario']} shrunk: {h['full_ops']} -> "
              f"{h['shrunk_ops']} launches")
        return 0 if same_digest and same_cov and not res.passed else 1
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
