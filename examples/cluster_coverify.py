"""Multi-device co-verification walkthrough (core/fabric.py): the same
sweep cell at 1/2/4 devices, cross-scale equivalence, modeled link
stalls, same-seed digest reproducibility, fabric coverage, and (with
--serve) the cluster serving engine under a request storm.

    PYTHONPATH=src python examples/cluster_coverify.py
    PYTHONPATH=src python examples/cluster_coverify.py --devices 1,2,4 --size 128
    PYTHONPATH=src python examples/cluster_coverify.py --serve
"""
import argparse
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (FABRIC_LINK, CoVerifySession, CoverageModel,
                        FabricCluster, FaultPlan)
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_fabric_firmware,
                                                 matmul_firmware)

LINK = FABRIC_LINK


def devices_sweep(devices, size, backends):
    print(f"== devices sweep: systolic matmul {size}x{size} across "
          f"{devices} device(s) x {backends} ==")
    sess = CoVerifySession(matmul_firmware,
                           fabric_firmware=matmul_fabric_firmware,
                           link_config=LINK)
    sess.register_op("mm", **matmul_backends(tile=32))
    sess.add_sweep("mm", backends, [{"size": size}], devices=devices)
    report = sess.run(max_workers=4)
    s = report.summary()
    print(f"  {s['cells']} cells, {s['groups']} equivalence group(s), "
          f"{s['wall_seconds']:.2f}s wall -> "
          f"{'PASS' if report.passed else 'FAIL: ' + str(s['failures'])}")
    for line in report.scaling():
        print(f"  {line}")
    (eq,) = report.equivalence.values()
    print(f"  cross-scale equivalence: {eq}")
    by = {r.cell.group_member: r for r in report.cells}
    for be in backends:
        for n in devices:
            if n == 1:
                continue
            same = np.array_equal(by[be].outputs["c"],
                                  by[f"{be}@{n}dev"].outputs["c"])
            print(f"  {be}: {n}-device gather bit-identical to "
                  f"single-device: {same}")
    return report


def digest_reproducibility(size, seed):
    def one():
        fab = FabricCluster(4, link_config=LINK,
                            fault_plan=FaultPlan(seed))
        fab.register_op("mm", **matmul_backends(tile=32, jit=False))
        matmul_fabric_firmware(fab, "mm", "oracle", size=size, tile=32)
        fab.all_reduce("c")     # exercise the collective too
        return fab

    a, b = one(), one()
    print(f"\n== same-seed reproducibility (seed {seed}) ==")
    print(f"  run 1 fabric digest: {a.digest()[:16]}")
    print(f"  run 2 fabric digest: {b.digest()[:16]}")
    if a.digest() != b.digest():
        sys.exit("fabric digest reproducibility broken")
    print(f"  IDENTICAL ({len(a.log.txs)} fabric transactions, "
          f"{len(a.log.faults)} injected faults audited, "
          f"{a.total_link_stall():.0f} link stall cycles)")


def fabric_coverage(size):
    cov = CoverageModel()
    fab = FabricCluster(4, link_config=LINK, coverage=cov)
    fab.register_op("mm", **matmul_backends(tile=32, jit=False))
    matmul_fabric_firmware(fab, "mm", "oracle", size=size, tile=32)
    fab.all_reduce("c")
    fab.dev_copy(0, 1, "b", dst_name="b_copy")
    print("\n== fabric coverage ==")
    print(cov.report(groups=["fabric", "burst_size", "congestion"]))


def serving_storm():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke
    from repro.models import init_params
    from repro.models.transformer import RunFlags
    from repro.serving import ClusterServingEngine, ServingEngine

    print("\n== cluster serving storm (2 devices, one CSR front-end) ==")
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    flags = RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16)
    single = ServingEngine(cfg, params, max_slots=4, max_len=64,
                           flags=flags)
    clu = ClusterServingEngine(cfg, params, n_devices=2, max_slots=2,
                               max_len=64, flags=flags)
    rng = np.random.default_rng(0)
    prompts = {rid: rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(5, 30)))
               for rid in range(8)}

    def storm(e):
        for rid, p in prompts.items():
            e.mem.buffers["prompt_in"].array[:len(p)] = p
            e.csr.fb_write_32(e.csr.addr_of("SUBMIT_ID"), rid)
            e.csr.fb_write_32(e.csr.addr_of("SUBMIT_LEN"), len(p))
            e.csr.fb_write_32(e.csr.addr_of("SUBMIT_MAXNEW"), 6)
            e.csr.fb_write_32(e.csr.addr_of("DOORBELL"), 1)
        e.run_until_done()

    storm(single)
    storm(clu)
    parity = all(single.requests[r].out_tokens == clu.requests[r].out_tokens
                 for r in prompts)
    st = clu.fabric_stats()
    print(f"  completed: single {single.completed}, "
          f"cluster {clu.completed} (placement "
          f"{dict(sorted(clu.placement.items()))})")
    print(f"  token parity vs single engine: {parity}")
    print(f"  host-channel stalls: "
          f"{ {k: round(v) for k, v in sorted(st.per_engine_stall.items())} }")
    if not parity or clu.completed != len(prompts):
        sys.exit("cluster serving diverged from the single engine")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", default="1,2,4")
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backends", default="oracle,interpret,compiled")
    ap.add_argument("--serve", action="store_true",
                    help="also run the cluster serving storm (builds a "
                         "smoke model; slower)")
    args = ap.parse_args()
    devices = tuple(int(d) for d in args.devices.split(","))
    backends = tuple(b for b in args.backends.split(",") if b)

    report = devices_sweep(devices, args.size, backends)
    digest_reproducibility(args.size, args.seed)
    fabric_coverage(args.size)
    if args.serve:
        serving_storm()
    if not report.passed:
        sys.exit(1)


if __name__ == "__main__":
    main()
