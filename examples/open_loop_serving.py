"""Open-loop serving under load: continuous batching + KV paging + SLO.

Closed-loop demos (examples/serve_registers.py) submit a burst and wait;
the engine sets the pace.  Open-loop load keeps arriving on its own
schedule — the traffic shape where queueing delay, deferred admission,
and latency-SLO percentiles become visible.  This walkthrough:

1. builds a seeded bursty arrival trace (pure function of the seed),
2. drives it through a continuously-batched `ServingEngine` whose KV
   cache is a paged pool smaller than the burst's aggregate demand,
3. reads back the per-request SLO table (modeled cycles only),
4. shows doorbell-time admission control rejecting an infeasible
   request loudly instead of livelocking the queue,
5. reruns the same seed and checks the SLO digest is bit-identical.

Every number below is a modeled cycle count (no wall time), so the
transcript is deterministic; docs/serving.md reproduces it verbatim,
pinned by tests/test_docs.py::test_serving_docs_transcript.

    PYTHONPATH=src python examples/open_loop_serving.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.models import init_params
from repro.models.transformer import RunFlags
from repro.serving import (ServingEngine, SLOReport, bursty_trace,
                           replayed_trace, run_open_loop)


def _engine():
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return ServingEngine(cfg, params, max_slots=2, max_len=32,
                         prompt_pad=4, kv_pages=3, kv_page_size=8,
                         batching="continuous",
                         flags=RunFlags(attn_impl="chunked", q_chunk=16,
                                        kv_chunk=16))


def _run(eng, trace):
    eng.reset(batching="continuous", kv_pages=3, kv_page_size=8)
    ticks = run_open_loop(eng, trace)
    return ticks, SLOReport.from_run(trace, eng, label="open-loop")


def main(argv=None):
    trace = bursty_trace(23, n_requests=6, burst_size=6, gap_in_burst=10.0,
                         gap_between=500.0, prompt_lens=(3, 10),
                         max_new=(2, 4))
    print(f"arrival trace {trace.label} (digest {trace.digest()[:16]}):")
    for a in trace.arrivals:
        print(f"  rid {a.rid}: t={a.time:8.1f}  prompt[{len(a.prompt)}]"
              f"  max_new={a.max_new_tokens}")

    eng = _engine()
    ticks, slo = _run(eng, trace)
    pool = eng.kv_pool
    print(f"\nopen-loop run drained in {ticks} scheduler ticks "
          f"(2 slots, {pool.n_pages} KV pages x {pool.page_size} tokens):")
    for row in slo.to_rows():
        print(f"  {row}")
    print(f"  pool: peak {pool.peak_in_use}/{pool.n_pages} pages, "
          f"{pool.deferrals} deferred admissions, "
          f"{pool.n_free}/{pool.n_pages} free after drain")

    # a request whose padded footprint can NEVER fit the whole pool is
    # rejected at the doorbell with a logged violation — admission
    # control fails loudly up front instead of starving the queue
    eng.reset(batching="continuous", kv_pages=2, kv_page_size=4)
    hostile = replayed_trace([
        (0, 0.0, (5, 6, 7), 2),              # 2 pages: fits exactly
        (1, 10.0, tuple(range(1, 13)), 4),   # 4 pages: can never fit
        (2, 20.0, (8, 9), 2),                # fits behind the reject
    ])
    run_open_loop(eng, hostile)
    print("\ninfeasible-request demo (2 pages x 4 tokens):")
    for v in eng.csr.log.violations:
        print(f"  violation: {v}")
    done = sorted(r for r, q in eng.requests.items() if q.done)
    print(f"  completed: rids {done}; rid 1 rejected at the doorbell")

    _, again = _run(eng, trace)
    print(f"\nrerun of seed 23 -> SLO digest identical: "
          f"{again.digest() == slo.digest()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
