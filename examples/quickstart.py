"""End-to-end driver: co-verification preflight + train a ~100M-parameter
llama-family model on the synthetic induction-LM dataset with the full
production stack — jitted fwd+bwd+AdamW step, background data pipeline,
async sharded checkpoints, fault-tolerant restart, straggler monitoring,
register-file run control.

Before training, a CoVerifySession sweep (paper Fig. 5 batched lane)
co-verifies the systolic-matmul accelerator across oracle/interpret/
compiled backends under online congestion — the paper's "verify before
deploy" flow.  Skip it with --skip-preflight.

    PYTHONPATH=src python examples/quickstart.py [--steps 300] [--resume]
    PYTHONPATH=src python examples/quickstart.py --arch llama3.2-1b --smoke

A few hundred steps on the default config drives loss well below the
unigram entropy (the dataset plants copy/induction structure).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config, smoke
from repro.configs.base import ModelConfig
from repro.models.transformer import RunFlags
from repro.optim.adamw import AdamWConfig
from repro.runtime import FailureInjector, Trainer, TrainerConfig


def coverify_preflight() -> bool:
    """Batched co-verification sweep of the matmul accelerator (6 cells:
    2 sizes x {oracle, interpret, compiled}) under online congestion,
    through core/scheduler.CoVerifySession.  Returns True on pass."""
    from repro.core import CongestionConfig, CoVerifySession
    from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                     matmul_firmware)

    sess = CoVerifySession(matmul_firmware,
                           congestion=CongestionConfig(dos_prob=0.02,
                                                       seed=5))
    sess.register_op("mm", **matmul_backends())
    sess.add_sweep("mm", ("oracle", "interpret", "compiled"),
                   [{"size": 64}, {"size": 96}])
    report = sess.run(max_workers=4)
    s = report.summary()
    stalls = sum(sum(r.congestion.per_engine_stall.values())
                 for r in report.cells if r.congestion)
    print(f"preflight co-verification: {s['cells']} cells, "
          f"{s['groups']} equivalence groups, "
          f"{s['wall_seconds']:.2f}s wall, "
          f"{stalls:.0f} congestion stall cycles -> "
          f"{'PASS' if report.passed else 'FAIL: ' + str(s['failures'])}")
    return report.passed

# ~102M parameters
CONFIG_100M = ModelConfig(
    arch="quickstart-100m", family="dense", n_layers=10, d_model=640,
    n_heads=8, n_kv_heads=4, head_dim=80, d_ff=2560, vocab_size=32000,
    mlp_type="swiglu", rope="full", causal=True, tie_embeddings=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--arch", default=None,
                    help="train a smoke-reduced assigned arch instead")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="inject a transient fault at this step "
                         "(demonstrates checkpoint/restart)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    ap.add_argument("--skip-preflight", action="store_true",
                    help="skip the co-verification sweep before training")
    args = ap.parse_args()

    if not args.skip_preflight and not coverify_preflight():
        sys.exit("preflight co-verification FAILED; not training on a "
                 "divergent accelerator (use --skip-preflight to override)")

    if args.arch:
        cfg = get_config(args.arch)
        if args.smoke:
            cfg = smoke(cfg)
    else:
        cfg = CONFIG_100M

    from repro.configs import count_params
    print(f"model: {cfg.arch}  params={count_params(cfg)/1e6:.1f}M")

    tcfg = TrainerConfig(seq_len=args.seq_len, global_batch=args.batch,
                         steps=args.steps, ckpt_every=50,
                         ckpt_dir=args.ckpt_dir,
                         log_path=str(Path(args.ckpt_dir) / "metrics.jsonl"))
    inj = FailureInjector(fail_steps=[args.inject_failure]) \
        if args.inject_failure else None
    trainer = Trainer(
        cfg, tcfg,
        flags=RunFlags(attn_impl="chunked", q_chunk=128, kv_chunk=128,
                       microbatches=1),
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=20,
                            total_steps=args.steps),
        failure_injector=inj)

    state, step = trainer.train(resume=args.resume)
    log = trainer.metrics_log
    print(f"\ntrained to step {step}; restarts={trainer.restarts}; "
          f"stragglers={len(trainer.straggler.events)}")
    if log:
        for r in log[:: max(1, len(log) // 12)]:
            print(f"  step {r['step']:4d}  loss {r['loss']:.4f}  "
                  f"lr {r['lr']:.2e}  {r['step_time']*1e3:.0f} ms")
        print(f"  final loss: {log[-1]['loss']:.4f} "
              f"(first: {log[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
