"""Routed-interconnect tour (core/topology.py + core/switch.py).

Walks the three topology builders' static routing tables, then runs the
same sharded workload on a 1-device crossbar and an 8-device 2D-torus:
scatter, hierarchical all_reduce, gather — every transfer a multi-hop
journey of flit-framed, credit-flow-controlled switch hops — and reads
back the per-hop stall columns from the switch ports.

Every number below is a modeled cycle count (no wall time), so the
transcript is deterministic; docs/topology.md reproduces it verbatim,
pinned by tests/test_docs.py::test_topology_docs_transcript.

    PYTHONPATH=src python examples/topology_tour.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import FabricCluster, fat_tree, ring, torus2d
from repro.core.congestion import CongestionConfig

LINK = CongestionConfig(link_bytes_per_cycle=64.0, base_latency=100.0,
                        max_burst_bytes=4096, dos_prob=0.05, seed=11)


def _show_route(name, topo, src, dst):
    sws = [f"sw{topo.attach[src]}"]
    sws += [f"sw{topo.edges[k][1]}" for k in topo.route(src, dst)]
    print(f"  {name:12s} {src} -> {dst} : {' -> '.join(sws)}"
          f"  ({topo.n_hops(src, dst)} switch hops)")


def _run(n, topology):
    fab = FabricCluster(n, topology=topology, link_config=LINK)
    x = np.arange(64 * 16, dtype=np.float32).reshape(64, 16)
    fab.host.alloc("x", x.shape, np.float32)
    fab.host.host_write("x", x)
    fab.scatter("x", axis=0)
    for i in range(n):
        fab._dev_alloc(i, "grad", (16, 16), np.float32)
        fab.devices[i].mem.host_write(
            "grad", np.full((16, 16), float(i + 1), np.float32))
    fab.all_reduce("grad", "sum")
    fab.host.buffers["x"].array[:] = 0
    fab.gather("x", axis=0)
    return fab


def main(argv=None):
    print("routed interconnect tour: ring / 2D-torus / fat-tree")
    print("\nstatic routes (deterministic BFS, declaration-order "
          "tie-breaks):")
    _show_route("ring(8)", ring(8), 0, 4)        # clockwise on the tie
    _show_route("torus2d(8)", torus2d(8), 0, 5)  # x before y
    _show_route("fat_tree(8)", fat_tree(8), 0, 7)  # leaf -> spine -> leaf

    print("\nsame workload, crossbar oracle vs routed 2D-torus "
          "(DoS on every link,")
    print("credits=1 so the flit trains exercise credit flow control):")
    oracle = _run(1, None)
    fab = _run(8, torus2d(8, credits=1))
    same = np.array_equal(oracle.host.host_read("x"),
                          fab.host.host_read("x"))
    print(f"  gathered result bit-identical to 1-device oracle: {same}")
    print(f"  modeled fabric cycles: crossbar {oracle.time:.0f}, "
          f"torus {fab.time:.0f}")
    print(f"  grad after hierarchical all_reduce (want {sum(range(1, 9))}"
          f".0): {fab.devices[3].mem.buffers['grad'].array[0, 0]}")

    stats = fab.switch.port_stats()
    hot = sorted(stats.items(), key=lambda kv: (-kv[1]["stall"],
                                                -kv[1]["flits"], kv[0]))
    print(f"\n  per-hop stall columns ({len(stats)} switch ports, "
          f"6 hottest):")
    print("    port        flits   busy  stall  credit_stall")
    for label, s in hot[:6]:
        print(f"    {label:10s} {s['flits']:6.0f} {s['busy']:6.0f} "
              f"{s['stall']:6.0f} {s['credit_stall']:13.0f}")
    total = sum(s["stall"] for s in stats.values())
    credit = fab.switch.total_credit_stall()
    print(f"    total arbitration stall {total:.0f}, "
          f"credit stall {credit:.0f}")

    fab2 = _run(8, torus2d(8, credits=1))
    print(f"\n  run-to-run digest identical: "
          f"{fab2.digest() == fab.digest()}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
