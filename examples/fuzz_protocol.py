"""Randomized fault-injection co-verification — the paper's randomized
memory bridge + register-level protocol testing (§IV) as a CLI.

Runs N seeded fault scenarios round-robin across the enabled layers
(bridge DMA faults with three-backend differential checking, register
protocol storms against a golden shadow model, randomized serving submit
streams), audits every injected fault, then re-runs the same seed and
checks the transaction-log digest reproduces bit-for-bit.

    PYTHONPATH=src python examples/fuzz_protocol.py --seed 0 --faults 200
    PYTHONPATH=src python examples/fuzz_protocol.py --layers bridge,registers,serving
    PYTHONPATH=src python examples/fuzz_protocol.py --inject-bug --shrink

``--shrink`` minimizes the first failing scenario to its shortest failing
op prefix; ``--inject-bug`` plants a known divergence in the interpret
backend so the shrink flow can be demonstrated on a healthy tree.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import ProtocolFuzzer
from repro.core.fuzz import planted_bug_table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", type=int, default=200,
                    help="number of randomized fault scenarios")
    ap.add_argument("--layers", default="bridge,registers,serving",
                    help="comma-separated subset of bridge,registers,serving")
    ap.add_argument("--shrink", action="store_true",
                    help="minimize the first failing scenario to its "
                         "shortest failing op prefix")
    ap.add_argument("--inject-bug", action="store_true",
                    help="plant a known interpret-backend bug (demo)")
    ap.add_argument("--skip-repro-check", action="store_true",
                    help="skip the same-seed second pass")
    ap.add_argument("--coverage-report", default=None, metavar="PATH",
                    help="write the functional-coverage bin report "
                         "(core/coverage.py) to this file")
    args = ap.parse_args()

    layers = tuple(s for s in args.layers.split(",") if s)
    fz = ProtocolFuzzer(
        seed=args.seed, layers=layers,
        mm_table=planted_bug_table() if args.inject_bug else None)

    t0 = time.perf_counter()
    report = fz.run(args.faults)
    dt = time.perf_counter() - t0
    s = report.summary()
    print(f"fuzz: {s['scenarios']} scenarios in {dt:.1f}s "
          f"({s['scenarios'] / dt:.1f}/s) across {s['by_layer']}")
    print(f"  faults injected ({sum(s['faults'].values())} total):")
    for k, v in sorted(s["faults"].items()):
        print(f"    {k:20s} {v}")
    print(f"  violations audited: {s['violations_audited']}   "
          f"transactions logged: {s['transactions']}")
    print(f"  transaction-log digest: {report.digest[:16]}")
    # functional coverage: the acceptance gate is 100% of the protocol
    # bins; the report names every hole it finds
    groups = ["protocol", "burst_size", "congestion", "fault_kind"]
    if "serving" in layers:
        groups.append("serving")
    cov_text = report.coverage.report(groups=groups)
    print("  " + cov_text.replace("\n", "\n  "))
    if args.coverage_report:
        Path(args.coverage_report).write_text(
            report.coverage.report() + "\n")
        print(f"  coverage report written to {args.coverage_report}")
    print(f"  result: {'PASS' if report.passed else 'FAIL'}")
    if not report.coverage.covered("protocol"):
        print(f"  WARNING: uncovered protocol bins: "
              f"{report.coverage.holes('protocol')}")

    if not report.passed:
        for r in report.failures()[:4]:
            print(f"    scn{r.index}[{r.layer}]: {r.failures[0][:160]}")
        if args.shrink:
            fail = report.failures()[0]
            scn = fz.scenario(fail.index)
            print(f"\nshrinking scn{scn.index} "
                  f"({len(scn.ops)} ops) to shortest failing prefix...")
            sub, res = fz.shrink(scn)
            print(f"  minimal repro: {len(sub.ops)} op(s)")
            for op in sub.ops:
                print(f"    {op}")
            print(f"  failure: {res.failures[0][:200]}")
            print(f"  re-run: PYTHONPATH=src python examples/"
                  f"fuzz_protocol.py --seed {args.seed} "
                  f"--faults {fail.index + 1} --layers {fail.layer}")

    if not args.skip_repro_check:
        report2 = fz.run(args.faults)
        ok = report2.digest == report.digest
        print(f"\nseeded reproducibility (seed {args.seed}, second pass): "
              f"{'IDENTICAL transaction log' if ok else 'MISMATCH'}")
        if not ok:
            sys.exit("seed reproducibility broken")

    if not report.passed and not args.inject_bug:
        sys.exit(1)


if __name__ == "__main__":
    main()
