"""Time-travel debugging walkthrough (core/replay.py): record a
fault-injected co-verification run, replay an arbitrary window
bit-identically, then let a failing sweep localize its own divergence by
checkpoint bisection.

Every line printed is deterministic (modeled clocks, seeded faults,
content digests — no wall time), so the transcript in
docs/replay.md is verified verbatim against this output by
tests/test_replay.py::test_docs_transcript_matches_example.

    PYTHONPATH=src python examples/time_travel_debug.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import CongestionConfig, CoVerifySession, FireBridge
from repro.core import replay as rp
from repro.core.fuzz import FaultPlan, planted_bug_table
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_firmware)

CONG = CongestionConfig(dos_prob=0.05, seed=7)


def main() -> None:
    # ---- 1. record a fault-injected run as a deterministic timeline
    table = matmul_backends(tile=16, jit=False)

    def factory():
        fb = FireBridge(congestion=CONG, fault_plan=FaultPlan(seed=3))
        fb.register_op("mm", **table)
        return fb

    sess = rp.DebugSession(factory, checkpoint_interval=3, label="run")

    def program(rec):
        for j, size in enumerate((32, 48, 32)):
            rng = np.random.default_rng(size)
            a = rng.normal(size=(size, size)).astype(np.float32)
            b = rng.normal(size=(size, size)).astype(np.float32)
            rec.do("alloc", f"a{j}", a.shape, np.float32)
            rec.do("alloc", f"b{j}", b.shape, np.float32)
            rec.do("alloc", f"c{j}", (size, size), np.float32)
            rec.do("host_write", f"a{j}", a)
            rec.do("host_write", f"b{j}", b)
            rec.do("launch", "mm", "oracle", (f"a{j}", f"b{j}"),
                   (f"c{j}",), "mm", None, {})

    rec = sess.record(program)
    print(f"recorded: {rec.n_ops} ops, "
          f"checkpoints at {[c.op_index for c in rec.checkpoints]}, "
          f"{len(rec.lines)} trace lines, "
          f"{len(rec.preamble)} construction line(s)")
    print(f"log digest: {rec.log_digest[:16]}")

    # ---- 2. bit-identical window replay from the nearest checkpoint
    lo, hi = 10, rec.n_ops
    w = sess.replay(rec, lo, hi)
    print(f"replayed window [{lo}, {hi}) from checkpoint "
          f"@op {w.from_checkpoint}: "
          f"{'IDENTICAL' if w.lines == rec.window_lines(lo, hi) else 'DIVERGED'}"
          f" ({len(w.lines)} lines, digest "
          f"{'match' if w.digest() == rec.window_digest(lo, hi) else 'MISMATCH'})")

    # ---- 3. a failing sweep bisects its own divergence
    sweep = CoVerifySession(matmul_firmware, congestion=CONG)
    sweep.register_op("mm", **planted_bug_table(tile=16))
    sweep.add_sweep("mm", ("oracle", "interpret"),
                    [{"size": 32, "tile": 16}])
    report = sweep.run(max_workers=1)
    print(f"sweep passed: {report.passed}")
    (d,) = report.divergences.values()
    print(d.render())


if __name__ == "__main__":
    main()
