"""Paper §V-D end-to-end: co-verify a firmware-heavy CNN accelerator.

The firmware does the paper's firmware jobs — im2col tiling/retiling,
ping-pong buffering, weight prefetch — and launches the systolic-array
matmul kernel through the memory bridge.  The SAME firmware runs against
the jnp oracle ("early model") and the Pallas interpret kernel ("RTL sim");
final DDR state is diffed and the transaction stream is profiled (Fig. 8/9).

Congestion is emulated *online* (§IV-C): the interpret-mode bridge carries
a CongestionConfig with input-DMA priority, so the three DMA engines
contend on the shared link while the layers execute and the stall
statistics below come straight from the run — no post-hoc replay step.
This reproduces the paper's weights-DMA-stall observation (Fig. 8).

    PYTHONPATH=src python examples/coverify_cnn.py [--model resnet18]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from benchmarks.cnn_driver import (gops, resnet18_specs, run_cnn,
                                   small_cnn_specs)
from repro.core.congestion import CongestionConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=["small", "resnet18"],
                    default="small")
    args = ap.parse_args()
    specs = small_cnn_specs(16) if args.model == "small" \
        else resnet18_specs(36)
    print(f"co-verifying {args.model} ({gops(specs):.3f} GOP) "
          f"oracle vs interpret...")

    cong = CongestionConfig(
        link_bytes_per_cycle=64.0, dos_prob=0.02, seed=7,
        priorities=(("dma_input", 2), ("dma_output", 1),
                    ("dma_weights", 0)))
    fb_o = run_cnn(specs, backend="oracle")
    fb_i = run_cnn(specs, backend="interpret", congestion=cong)
    ok = True
    for name in ("act_0", "act_1"):
        a = fb_o.mem.buffers[name].array
        b = fb_i.mem.buffers[name].array
        err = float(np.max(np.abs(a - b)))
        ok &= err < 1e-3
        print(f"  DDR {name}: max |oracle - interpret| = {err:.2e}")
    print(f"  functional equivalence: {'PASS' if ok else 'FAIL'}")

    res = fb_i.congestion_stats()
    print("\nonline congestion (input DMA prioritized, paper Fig. 8):")
    for e in ("dma_weights", "dma_input", "dma_output"):
        print(f"  {e:12s} stalls={res.per_engine_stall.get(e, 0):10.0f} "
              f"busy={res.per_engine_busy.get(e, 0):10.0f} cycles")
    print(f"  link utilization: {res.link_utilization:.2%}")
    print(f"  makespan: {res.makespan:.0f} cycles "
          f"(= bridge time {fb_i.mem.time:.0f})")

    print("\ninput-read access heatmap (address x time, Fig. 9):")
    print(fb_i.log.render_heatmap(12, 64, kind="read"))


if __name__ == "__main__":
    main()
