"""Always-on counter instrumentation walkthrough (AutoCounter/TracerV
analog, paper §IV).

Runs the same fixed-seed matmul firmware through all three backends and
reads back the always-on performance-counter layer (`core/counters.py`):
the sampled counter stream of the DDR bank, the bit-exact closure of the
stall counters against the data-movement profiler's attribution, the
backend-invariant stream digest the counter-diff oracle compares — and
then plants a timing-only bug (one rogue DMA read that changes no
output) to show the oracle flagging and localizing it in far fewer
comparisons than a full trace diff.

Every number below is a modeled cycle count or a digest of modeled
state (no wall time), so the transcript is deterministic;
docs/instrumentation.md reproduces it verbatim, pinned by
tests/test_docs.py::test_instrumentation_docs_transcript.

    PYTHONPATH=src python examples/counter_dashboard.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import CongestionConfig, FireBridge
from repro.core.counters import counter_banks, diff_streams, merged_digest
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_firmware)

CONG = CongestionConfig(dos_prob=0.05, seed=7)
BACKENDS = ("oracle", "interpret", "compiled")


def _mm_run(backend):
    fb = FireBridge(congestion=CONG)
    fb.register_op("mm", **matmul_backends(tile=16, jit=False))
    matmul_firmware(fb, "mm", backend, size=32, tile=16)
    return fb


def _dma_run(rogue):
    """Fixed DMA workload; ``rogue`` plants one extra early read — a
    timing-only perturbation that changes no functional state."""
    fb = FireBridge(congestion=CONG)
    a = np.random.default_rng(7).normal(size=(32, 32)).astype(np.float32)
    fb.mem.alloc("a", a.shape, np.float32)
    fb.mem.host_write("a", a)
    if rogue:
        fb.mem.dev_read("a", engine="dma_rogue")
    for _ in range(12):
        fb.mem.dev_read("a", engine="dma")
        fb.mem.dev_write("a", a, engine="dma")
    return fb


def main(argv=None):
    print("always-on counters: fixed-seed DMA + matmul firmware, online "
          "congestion")

    good = _dma_run(rogue=False)
    bank = good.mem.counters

    print(f"\nsampled counter stream: bank {bank.name} "
          f"(interval={bank.interval:.0f} modeled cycles, sample-and-hold)")
    names = [s.name for s in bank.specs]
    cols = ("transactions", "bytes_moved", "busy_cycles", "stall_cycles",
            "cycles")
    idx = [names.index(c) for c in cols]
    print("  t        " + "".join(f"{c:>13s}" for c in cols))
    for t, row in zip(bank.stream.times, bank.stream.rows):
        print(f"  {t:7.0f}  " + "".join(f"{row[j]:13.0f}" for j in idx))

    prof = good.profiler("dashboard")
    ddr = prof.channel("ddr")
    stall = 0.0
    for name in sorted(ddr.engines):
        stall += ddr.engines[name].grant_stall
    print("\nclosure against the profiler (bit-exact, no tolerance):")
    print(f"  bank stall_cycles == profiler grant-stall fold: "
          f"{bank.value('stall_cycles') == stall}")
    total = 0.0
    for c in ("transfer", "contention", "serialization", "dos",
              "fault_delay", "compute"):
        total += ddr.breakdown.cycles[c]
    print(f"  6 stall categories sum to bank cycles "
          f"({bank.value('cycles'):.0f}): {total == bank.value('cycles')}")

    print("\ncounter-stream digests across backends (the oracle's cheap "
          "witness, same-seed matmul):")
    digests = {be: merged_digest(counter_banks(_mm_run(be)))
               for be in BACKENDS}
    for be in BACKENDS:
        print(f"  {be:10s} {digests[be][:16]}")
    print(f"  backend-invariant: {len(set(digests.values())) == 1}")

    print("\nplanted timing-only bug (one rogue DMA read, outputs "
          "unchanged):")
    bad = _dma_run(rogue=True)
    diff, comparisons = diff_streams(counter_banks(good),
                                     counter_banks(bad))
    for line in diff.render().splitlines():
        print(f"  {line}")
    trace_lines = len(good.log.canonical()) + len(bad.log.canonical())
    print(f"  localized in {comparisons} scalar comparisons vs "
          f"{trace_lines} trace lines to diff")


if __name__ == "__main__":
    main()
