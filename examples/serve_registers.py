"""Serve a small model with batched requests through the FireBridge
register-file protocol — the firmware's view of the inference accelerator.

Requests are submitted exactly like the paper's firmware drives hardware:
write the prompt to a DDR bridge buffer, program SUBMIT_* CSRs with
fb_write_32, ring the DOORBELL, poll COMPLETED.  Continuous batching with
slot reuse happens behind the CSR boundary.

    PYTHONPATH=src python examples/serve_registers.py [--requests 8]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke
from repro.models import init_params
from repro.models.transformer import RunFlags
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    eng = ServingEngine(cfg, params, max_slots=args.slots, max_len=64,
                        flags=RunFlags(attn_impl="chunked", q_chunk=16,
                                       kv_chunk=16))

    rng = np.random.default_rng(0)
    print(f"submitting {args.requests} requests over the CSR protocol "
          f"({args.slots} cache slots)...")
    for rid in range(args.requests):
        ln = int(rng.integers(4, 24))
        eng.mem.buffers["prompt_in"].array[:ln] = \
            rng.integers(0, cfg.vocab_size, ln)
        eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_ID"), rid)
        eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_LEN"), ln)
        eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_MAXNEW"),
                            int(rng.integers(4, 12)))
        eng.csr.fb_write_32(eng.csr.addr_of("DOORBELL"), 1)

    eng.run_until_done()
    # firmware-style completion wait: poll STATUS for the done value (2).
    # poll() returns -1 on timeout (distinguishable from success), so a
    # hung engine is detected instead of read as "finished on last poll".
    polls = eng.csr.poll("STATUS", 0xFFFFFFFF, 2, max_reads=8)
    if polls < 0:
        sys.exit("engine never reached STATUS=done (poll timeout)")
    done = eng.csr.fb_read_32(eng.csr.addr_of("COMPLETED"))
    print(f"COMPLETED register: {done} (STATUS done after {polls} poll(s))")
    for rid, r in sorted(eng.requests.items()):
        print(f"  req {rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
    print("\nregister/DMA transaction summary:")
    for eng_name, s in eng.mem.log.summary().items():
        print(f"  {eng_name:12s} {s['transactions']:4d} txs "
              f"{s['bytes']:9d} B  ({s['reads']}r/{s['writes']}w)")
    print(f"protocol violations: {eng.csr.log.violations or 'none'}")


if __name__ == "__main__":
    main()
