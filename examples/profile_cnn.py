"""Off-chip data-movement profiling walkthrough (paper Fig. 8, §IV).

Runs the firmware-heavy CNN through the bridge with online congestion
(input-DMA priority — the paper's design choice), then reads everything
back through the ``DataMovementProfiler``: the exhaustive stall
attribution (every modeled cycle classified, closing exactly to
``bridge.time``), the per-engine Fig. 8 series reproducing the paper's
weights-vs-input DMA stall observation, the per-layer op attribution,
and a Perfetto-loadable Chrome-trace export.

Every number below is a modeled cycle count (no wall time), so the
transcript is deterministic; docs/profiling.md reproduces it verbatim,
pinned by tests/test_docs.py::test_profiling_docs_transcript.

    PYTHONPATH=src python examples/profile_cnn.py [--trace-out PATH]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.cnn_driver import gops, small_cnn_specs, run_cnn
from repro.core import CATEGORIES, validate_trace
from repro.core.congestion import CongestionConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out",
                    default="artifacts/profile_cnn.trace.json",
                    help="where to write the Perfetto/Chrome-trace JSON "
                         "(artifacts/ is gitignored)")
    args = ap.parse_args(argv)

    specs = small_cnn_specs(16)
    cong = CongestionConfig(
        link_bytes_per_cycle=64.0, dos_prob=0.02, seed=7,
        priorities=(("dma_input", 2), ("dma_output", 1),
                    ("dma_weights", 0)))
    print(f"profiling small CNN ({gops(specs):.3f} GOP) through the "
          f"bridge: oracle backend,")
    print("online congestion, input DMA prioritized (paper Fig. 8)")

    fb = run_cnn(specs, backend="oracle", congestion=cong, profile=True)
    prof = fb.profiler("profile_cnn")
    ddr = prof.channel("ddr")

    print("\nstall attribution (ddr channel, every modeled cycle "
          "classified):")
    print("  category       cycles   share")
    for cat in CATEGORIES:
        v = ddr.breakdown.cycles[cat]
        print(f"  {cat:13s} {v:8.0f}   {100 * v / ddr.horizon:5.1f}%")
    closed = sum(ddr.breakdown.cycles.values()) == ddr.horizon == fb.mem.time
    print(f"  closure: 6 categories sum to {ddr.horizon:.0f} cycles "
          f"== bridge.time: {closed}")
    print(f"  link utilization: {ddr.utilization:.2%}")

    print("\nper-engine Fig. 8 series (weights vs input vs output DMA):")
    print("  engine          bytes   txs      busy  contention_stalls")
    eng = ddr.engines
    for e in ("dma_weights", "dma_input", "dma_output"):
        s = eng[e]
        print(f"  {e:12s} {s.bytes:8d}  {s.transactions:4d}  {s.busy:8.0f}"
              f"  {s.contention:17.0f}")
    dominate = (eng["dma_weights"].contention
                > eng["dma_input"].contention)
    print(f"  weights-DMA stalls dominate under input priority: "
          f"{dominate}")

    print("\nper-layer attribution (op marks):")
    print("  layer    bytes  stall_cycles  span_cycles")
    for _, m in prof.marks:
        txs = fb.log.txs[m.tx_lo:m.tx_hi]
        print(f"  {m.op:6s} {sum(t.nbytes for t in txs):7d}  "
              f"{sum(t.stall for t in txs):12.0f}  {m.t1 - m.t0:11.0f}")

    trace = prof.to_perfetto()
    errs = validate_trace(trace)
    path = prof.save_perfetto(args.trace_out)
    print(f"\ntrace schema valid: {not errs}")
    print(f"wrote Perfetto trace: {path.name} "
          f"({len(trace['traceEvents'])} events)")
    print("load it at https://ui.perfetto.dev (one track per DMA engine,"
          " stall + transfer slices, bandwidth counters)")


if __name__ == "__main__":
    main()
