"""Sort-based MoE dispatch vs dense-einsum reference; capacity semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke
from repro.models import moe as moe_lib


def _setup(capacity_factor):
    cfg = smoke(get_config("phi3.5-moe-42b-a6.6b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=capacity_factor))
    key = jax.random.PRNGKey(0)
    w = moe_lib.moe_init(key, cfg, 1, jnp.float32)
    w = jax.tree.map(lambda a: a[0], w)           # single layer
    x = jax.random.normal(jax.random.fold_in(key, 1), (32, cfg.d_model))
    return cfg, w, x


def _dense_reference(cfg, w, x):
    """Route every token through its top-k experts via dense one-hot math."""
    idx, cw, _ = moe_lib.route(w["router"], x, cfg.moe.top_k)
    out = jnp.zeros_like(x)
    for e in range(cfg.moe.n_experts):
        g = jax.nn.silu(x @ w["w_gate"][e]) * (x @ w["w_up"][e])
        ye = g @ w["w_down"][e]
        weight = jnp.sum(jnp.where(idx == e, cw, 0.0), axis=1)
        out = out + ye * weight[:, None]
    return out


def test_dispatch_matches_dense_reference_no_drops():
    cfg, w, x = _setup(capacity_factor=float(16))    # no drops possible
    got, aux = moe_lib.moe_apply(w, x, cfg)
    ref = _dense_reference(cfg, w, x)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4
    assert float(aux) > 0


def test_capacity_drops_are_bounded():
    cfg, w, x = _setup(capacity_factor=1.0)
    got, _ = moe_lib.moe_apply(w, x, cfg)
    ref = _dense_reference(cfg, w, x)
    # dropped tokens produce zero MoE output -> differences only shrink norms
    diff_rows = jnp.any(jnp.abs(got - ref) > 1e-4, axis=1)
    C = moe_lib.capacity(cfg, x.shape[0])
    assert int(jnp.sum(diff_rows)) <= x.shape[0]     # sanity
    # every undropped row matches
    from repro.models.moe import route
    assert float(jnp.max(jnp.abs(jnp.where(diff_rows[:, None], 0.0,
                                           got - ref)))) < 1e-4


def test_combine_weights_normalized():
    cfg, w, x = _setup(capacity_factor=4.0)
    _, cw, _ = moe_lib.route(w["router"], x, cfg.moe.top_k)
    assert np.allclose(np.asarray(jnp.sum(cw, axis=1)), 1.0, atol=1e-5)
