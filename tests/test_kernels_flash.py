"""Pallas flash-attention kernel vs ref.py oracle — shape/dtype sweeps in
interpret mode (deliverable c: per-kernel allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref as R
from repro.kernels.flash_attention.ops import flash_attention

KEY = jax.random.PRNGKey(3)

SWEEP = [
    # B, H, KH, S, D, causal, window, dtype
    (2, 4, 2, 128, 16, True, 0, jnp.float32),
    (1, 4, 4, 64, 32, False, 0, jnp.float32),
    (2, 8, 2, 128, 16, True, 48, jnp.float32),
    (2, 4, 1, 256, 64, True, 0, jnp.bfloat16),
    (1, 2, 2, 64, 128, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,KH,S,D,causal,window,dt", SWEEP)
def test_fwd_matches_ref(B, H, KH, S, D, causal, window, dt):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, S, D), dt)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, KH, S, D), dt)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, KH, S, D), dt)
    ref = R.attention_ref(q, k, v, causal=causal, window=window)
    out, lse = K.flash_fwd(q, k, v, causal=causal, window=window,
                           bq=32, bk=32)
    err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) -
                                out.astype(jnp.float32))))
    tol = 2e-5 if dt == jnp.float32 else 3e-2
    assert err < tol, err
    assert np.isfinite(np.asarray(lse)).all()


@pytest.mark.parametrize("B,H,KH,S,D,causal,window,dt", SWEEP[:3])
def test_bwd_matches_ref(B, H, KH, S, D, causal, window, dt):
    qm = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D), dt)
    km = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KH, D), dt)
    vm = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, KH, D), dt)

    def f_kernel(q, k, v):
        return (flash_attention(q, k, v, causal=causal, window=window,
                                bq=32, bk=32).astype(jnp.float32) ** 2).sum()

    def f_ref(q, k, v):
        return (R.attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal,
            window=window).astype(jnp.float32) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(qm, km, vm)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(qm, km, vm)
    for a, b in zip(gk, gr):
        err = float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                    b.astype(jnp.float32))))
        assert err < 5e-4, err
