"""Prefill+decode must reproduce full-prefill logits (KV/state-cache
bookkeeping correctness) across families — in f32 with no-drop MoE capacity
so the check is tight."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import (RunFlags, init_params, make_decode_fn,
                          make_prefill_fn)
from repro.models.inputs import make_prefill_batch

pytestmark = pytest.mark.slow      # decode sweep: ~40s across families

FLAGS = RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16,
                 compute_dtype="float32")
B, S, S0 = 2, 64, 48

ARCHS = ["mistral-nemo-12b", "granite-20b", "zamba2-2.7b", "rwkv6-7b",
         "llama-3.2-vision-11b", "moonshot-v1-16b-a3b",
         "phi3.5-moe-42b-a6.6b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = smoke(get_config(arch))
    if cfg.moe is not None:   # lift capacity so no tokens drop (determinism)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    prefill = jax.jit(make_prefill_fn(cfg, FLAGS, None, max_len=S))
    decode = jax.jit(make_decode_fn(cfg, FLAGS, None))

    batch = make_prefill_batch(cfg, B, S, key)
    logits_full, _ = prefill(params, batch)

    b0 = dict(batch)
    b0["tokens"] = batch["tokens"][:, :S0]
    lg, cache = prefill(params, b0)
    for t in range(S0, S):
        lg, cache = decode(params, cache, batch["tokens"][:, t])
    a = np.asarray(logits_full, np.float32)
    b = np.asarray(lg, np.float32)
    err = np.max(np.abs(a - b)) / max(1e-6, np.max(np.abs(a)))
    assert err < 1e-4, f"{arch}: rel_err={err:.3e}"
