import pytest

from repro.configs import (ARCHS, SHAPES, applicable_shapes, count_params,
                           get_config, non_embedding_params, smoke)

EXPECTED_PARAM_RANGE = {
    "mistral-nemo-12b": (11e9, 13.5e9),
    "granite-20b": (19e9, 22e9),
    "chatglm3-6b": (5.5e9, 7e9),
    "llama3.2-1b": (1.0e9, 1.5e9),
    "hubert-xlarge": (0.8e9, 1.1e9),
    "zamba2-2.7b": (2.2e9, 3.0e9),
    "rwkv6-7b": (6.0e9, 8.0e9),
    "llama-3.2-vision-11b": (9.5e9, 11.5e9),
    "moonshot-v1-16b-a3b": (25e9, 30e9),   # assignment config: 48L 64e
    "phi3.5-moe-42b-a6.6b": (40e9, 44e9),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_registry_and_counts(arch):
    cfg = get_config(arch)
    assert cfg.arch == arch
    n = count_params(cfg)
    lo, hi = EXPECTED_PARAM_RANGE[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
    assert non_embedding_params(cfg) < n


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduction_preserves_structure(arch):
    cfg = get_config(arch)
    s = smoke(cfg)
    assert s.family == cfg.family
    assert (s.moe is None) == (cfg.moe is None)
    assert (s.ssm is None) == (cfg.ssm is None)
    assert (s.rwkv is None) == (cfg.rwkv is None)
    assert bool(s.attn_period) == bool(cfg.attn_period)
    assert bool(s.cross_attn_period) == bool(cfg.cross_attn_period)
    assert s.d_model <= 128 and s.vocab_size <= 1024


def test_applicable_shapes_rules():
    assert applicable_shapes(get_config("hubert-xlarge"))["decode_32k"].startswith("SKIP")
    assert applicable_shapes(get_config("hubert-xlarge"))["long_500k"].startswith("SKIP")
    assert applicable_shapes(get_config("mistral-nemo-12b"))["long_500k"].startswith("SKIP")
    assert applicable_shapes(get_config("zamba2-2.7b"))["long_500k"] == "OK"
    assert applicable_shapes(get_config("rwkv6-7b"))["long_500k"] == "OK"
    total_ok = sum(1 for a in ARCHS for v in applicable_shapes(get_config(a)).values()
                   if v == "OK")
    assert total_ok == 31   # the dry-run matrix size (x2 meshes = 62)


def test_moe_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    full = count_params(cfg)
    active = count_params(cfg, active_only=True)
    assert active < full / 4    # 16 experts top-2
