"""Trainer fault tolerance, checkpoint/restart, straggler detection,
elastic resharding restore."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke
from repro.models.transformer import RunFlags
from repro.runtime import FailureInjector, StragglerMonitor, Trainer, \
    TrainerConfig

FLAGS = RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16)


def _trainer(tmp, steps=10, injector=None, ckpt_every=4):
    cfg = smoke(get_config("llama3.2-1b"))
    tcfg = TrainerConfig(seq_len=64, global_batch=4, steps=steps,
                         ckpt_every=ckpt_every, ckpt_dir=str(tmp))
    return Trainer(cfg, tcfg, FLAGS, failure_injector=injector)


@pytest.mark.slow
def test_failure_recovery_and_completion(tmp_path):
    inj = FailureInjector(fail_steps=[6])
    tr = _trainer(tmp_path / "c1", steps=10, injector=inj)
    state, step = tr.train()
    assert step == 10
    assert tr.restarts == 1
    assert inj.injected == [6]
    assert tr.csr.hw_get("STATUS") == 2


@pytest.mark.slow
def test_resume_from_checkpoint(tmp_path):
    tr = _trainer(tmp_path / "c2", steps=8)
    tr.train()
    tr2 = _trainer(tmp_path / "c2", steps=12)
    state, step = tr2.train(resume=True)
    assert step == 12
    assert tr2.metrics_log[0]["step"] == 8     # continued, not restarted


def test_too_many_failures_raises(tmp_path):
    inj = FailureInjector(fail_steps=[1, 2, 3, 4, 5])
    tr = _trainer(tmp_path / "c3", steps=8, injector=inj, ckpt_every=100)
    tr.tcfg = TrainerConfig(seq_len=64, global_batch=4, steps=8,
                            ckpt_every=100, ckpt_dir=str(tmp_path / "c3"),
                            max_restarts=2)
    with pytest.raises(Exception):
        tr.train()


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, warmup=2)
    for i in range(6):
        assert mon.observe(i, 0.1) is None
    ev = mon.observe(6, 0.5)
    assert ev is not None and ev.ratio > 2.0
    # outlier not folded into ewma
    assert abs(mon.ewma - 0.1) < 1e-6


def test_checkpoint_roundtrip_and_reshard(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", keep=2, async_save=False)
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4),
             "step": jnp.asarray(7)}
    mgr.save(3, state)
    mgr.save(5, state)
    mgr.save(9, state)
    assert mgr.list_steps() == [5, 9]          # keep=2 gc
    like = jax.eval_shape(lambda: state)
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None)),
          "step": NamedSharding(mesh, P())}
    restored = mgr.restore(9, like, shardings=sh)   # reshard on restore
    assert np.array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding == sh["w"]


def test_atomicity_no_tmp_dirs(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck2", async_save=False)
    mgr.save(1, {"x": jnp.ones((2,))})
    assert not list((tmp_path / "ck2").glob("*.tmp"))
