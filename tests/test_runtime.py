"""Trainer fault tolerance, checkpoint/restart, straggler detection,
elastic resharding restore."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke
from repro.models.transformer import RunFlags
from repro.runtime import FailureInjector, StragglerMonitor, Trainer, \
    TrainerConfig

FLAGS = RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16)


def _trainer(tmp, steps=10, injector=None, ckpt_every=4):
    cfg = smoke(get_config("llama3.2-1b"))
    tcfg = TrainerConfig(seq_len=64, global_batch=4, steps=steps,
                         ckpt_every=ckpt_every, ckpt_dir=str(tmp))
    return Trainer(cfg, tcfg, FLAGS, failure_injector=injector)


@pytest.mark.slow
def test_failure_recovery_and_completion(tmp_path):
    inj = FailureInjector(fail_steps=[6])
    tr = _trainer(tmp_path / "c1", steps=10, injector=inj)
    state, step = tr.train()
    assert step == 10
    assert tr.restarts == 1
    assert inj.injected == [6]
    assert tr.csr.hw_get("STATUS") == 2


@pytest.mark.slow
def test_resume_from_checkpoint(tmp_path):
    tr = _trainer(tmp_path / "c2", steps=8)
    tr.train()
    tr2 = _trainer(tmp_path / "c2", steps=12)
    state, step = tr2.train(resume=True)
    assert step == 12
    assert tr2.metrics_log[0]["step"] == 8     # continued, not restarted


def test_too_many_failures_raises(tmp_path):
    inj = FailureInjector(fail_steps=[1, 2, 3, 4, 5])
    tr = _trainer(tmp_path / "c3", steps=8, injector=inj, ckpt_every=100)
    tr.tcfg = TrainerConfig(seq_len=64, global_batch=4, steps=8,
                            ckpt_every=100, ckpt_dir=str(tmp_path / "c3"),
                            max_restarts=2)
    with pytest.raises(Exception):
        tr.train()


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, warmup=2)
    for i in range(6):
        assert mon.observe(i, 0.1) is None
    ev = mon.observe(6, 0.5)
    assert ev is not None and ev.ratio > 2.0
    # outlier not folded into ewma
    assert abs(mon.ewma - 0.1) < 1e-6


def test_checkpoint_roundtrip_and_reshard(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", keep=2, async_save=False)
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4),
             "step": jnp.asarray(7)}
    mgr.save(3, state)
    mgr.save(5, state)
    mgr.save(9, state)
    assert mgr.list_steps() == [5, 9]          # keep=2 gc
    like = jax.eval_shape(lambda: state)
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None)),
          "step": NamedSharding(mesh, P())}
    restored = mgr.restore(9, like, shardings=sh)   # reshard on restore
    assert np.array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding == sh["w"]


def test_atomicity_no_tmp_dirs(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck2", async_save=False)
    mgr.save(1, {"x": jnp.ones((2,))})
    assert not list((tmp_path / "ck2").glob("*.tmp"))


def test_pipeline_worker_exception_propagates_to_consumer():
    """Regression: an exception in the prefetch worker (dataset.batch or
    device_put) silently ended prefetching and the consumer hung on an
    empty queue forever.  The error must surface on the consumer's next
    ``next()`` — and keep surfacing, never hang — while the batches
    produced before the failure still arrive in order."""
    from repro.data.pipeline import DataPipeline

    class Dying:
        def batch(self, step):
            if step >= 3:
                raise ValueError(f"corrupt shard at step {step}")
            return {"x": np.full((4,), step, np.float32)}

    pipe = DataPipeline(Dying(), prefetch=2)
    try:
        for want in range(3):
            step, batch = pipe.next()
            assert step == want
            assert batch["x"][0] == want
        with pytest.raises(RuntimeError, match="worker failed") as ei:
            pipe.next()
        assert isinstance(ei.value.__cause__, ValueError)
        # subsequent calls re-raise instead of blocking on the dead worker
        with pytest.raises(RuntimeError, match="worker failed"):
            pipe.next()
    finally:
        pipe.stop()


def test_async_write_failure_leaves_no_partial_checkpoint(tmp_path,
                                                          monkeypatch):
    """Regression: the async checkpoint thread used to die silently —
    a failure mid-write left a stale ``.tmp`` on disk and the caller
    never heard about it.  A simulated mid-``npz`` crash must (a) leave
    NO partial step visible (neither committed nor staged) and (b)
    re-raise on the next ``wait()``; the manager must then keep working."""
    import repro.checkpoint.manager as mg

    mgr = CheckpointManager(tmp_path / "ck3", async_save=True)
    state = {"x": jnp.ones((4,))}
    mgr.save(1, state)
    mgr.wait()
    assert mgr.list_steps() == [1]

    real_savez = mg.np.savez

    def boom(*a, **kw):
        raise OSError("disk died mid-write")
    monkeypatch.setattr(mg.np, "savez", boom)
    mgr.save(2, state)
    with pytest.raises(OSError, match="disk died"):
        mgr.wait()
    # nothing partial is visible: no step_2, no staging dir
    assert mgr.list_steps() == [1]
    assert not list((tmp_path / "ck3").glob("*.tmp"))
    # the error does not wedge the manager: the next save commits
    monkeypatch.setattr(mg.np, "savez", real_savez)
    mgr.save(3, state)
    mgr.wait()
    assert mgr.list_steps() == [1, 3]


def test_checkpoint_context_manager_flushes_and_raises(tmp_path,
                                                       monkeypatch):
    """``with CheckpointManager(...)`` joins the in-flight write on exit
    and surfaces its error — an interpreter heading for exit can no
    longer truncate a checkpoint silently."""
    import repro.checkpoint.manager as mg

    with CheckpointManager(tmp_path / "ck4", async_save=True) as mgr:
        mgr.save(1, {"x": jnp.ones((2,))})
    assert mgr.list_steps() == [1]              # flushed on clean exit

    monkeypatch.setattr(mg.np, "savez",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            OSError("late failure")))
    with pytest.raises(OSError, match="late failure"):
        with CheckpointManager(tmp_path / "ck5", async_save=True) as mgr2:
            mgr2.save(1, {"x": jnp.ones((2,))})
    assert mgr2.list_steps() == []
    # an exception already unwinding is NOT masked by a write error
    with pytest.raises(RuntimeError, match="caller error"):
        with CheckpointManager(tmp_path / "ck6", async_save=True) as mgr3:
            mgr3.save(1, {"x": jnp.ones((2,))})
            raise RuntimeError("caller error")
