"""Time-travel replay & divergence bisection (core/replay.py): window
bit-identity witnessed by TransactionLog.digest(), checkpoint/restore
fidelity across every target type, the instrumented O(log N)+2 replay
budget for bisection, parity with a full-trace diff on the golden-trace
programs, scheduler auto-attachment, and replay-backed shrink parity."""
import math

import numpy as np
import pytest

from repro.core import (CongestionConfig, CoVerifySession, FireBridge,
                        ProtocolFuzzer)
from repro.core import replay as rp
from repro.core.fuzz import FaultPlan, planted_bug_table
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_firmware)

CONG = CongestionConfig(dos_prob=0.05, seed=7)


def _bridge_session(table=None, fault_seed=None, label="run", interval=3):
    table = table if table is not None else matmul_backends(tile=16,
                                                            jit=False)

    def factory():
        plan = FaultPlan(seed=fault_seed) if fault_seed is not None else None
        fb = FireBridge(congestion=CONG, fault_plan=plan)
        fb.register_op("mm", **table)
        return fb

    return rp.DebugSession(factory, checkpoint_interval=interval,
                           label=label)


def _launch_program(sizes, backend="oracle", engine="mm"):
    """A multi-launch bridge program driven through rec.do (distinct
    buffer names per launch, deterministic data per size+index)."""
    def program(rec):
        for j, size in enumerate(sizes):
            rng = np.random.default_rng(size * 1009 + j)
            a = rng.normal(size=(size, size)).astype(np.float32)
            b = rng.normal(size=(size, size)).astype(np.float32)
            rec.do("alloc", f"a{j}", a.shape, np.float32)
            rec.do("alloc", f"b{j}", b.shape, np.float32)
            rec.do("alloc", f"c{j}", (size, size), np.float32)
            rec.do("host_write", f"a{j}", a)
            rec.do("host_write", f"b{j}", b)
            rec.do("launch", "mm", backend, (f"a{j}", f"b{j}"),
                   (f"c{j}",), engine, None, {})
    return program


# ------------------------------------------------------------ bit identity
def test_full_range_replay_matches_transaction_log_digest():
    """Replaying [0, n) from checkpoint 0 regenerates the ENTIRE log —
    the TransactionLog.digest() witness, fault plan and congestion
    included (construction-time perturbation lines and all)."""
    sess = _bridge_session(fault_seed=3)
    rec = sess.record(_launch_program([32, 48, 32]))
    w = sess.replay(rec, 0, rec.n_ops)
    import hashlib
    h = hashlib.sha256()
    for log in rp.target_logs(w.target):
        h.update(log.digest().encode())
    assert h.hexdigest() == rec.log_digest
    assert w.lines == rec.window_lines(0, rec.n_ops)
    assert w.digest() == rec.window_digest(0, rec.n_ops)


def test_arbitrary_windows_replay_bit_identically():
    sess = _bridge_session(fault_seed=11, interval=4)
    rec = sess.record(_launch_program([32, 48, 64, 32, 48]))
    n = rec.n_ops
    for lo, hi in [(0, n), (1, n), (5, 17), (n - 1, n), (7, 7), (0, 1)]:
        w = sess.replay(rec, lo, hi)
        assert w.lines == rec.window_lines(lo, hi), (lo, hi)
        assert w.digest() == rec.window_digest(lo, hi)


def test_checkpoint_restore_roundtrip_matches_uninterrupted_run():
    """Restoring any checkpoint and replaying to the end reproduces the
    uninterrupted run's final state fingerprint exactly."""
    sess = _bridge_session(fault_seed=5)
    rec = sess.record(_launch_program([48, 32, 64, 48]))
    for ck in rec.checkpoints:
        w = sess.replay(rec, ck.op_index, rec.n_ops)
        state = w.target.get_state()
        assert rp.state_fingerprint(state) == rec.final_fingerprint, \
            f"checkpoint @{ck.op_index} diverged on restore"


def test_checkpoint_restore_keeps_lazy_digest_identity():
    """set_state rebuilds the lazy digest caches: a restored checkpoint's
    log digests equal a replayed prefix's (the incremental hash and memo
    are reset, not stale), and resuming from any checkpoint reaches the
    recorded final digest."""
    import hashlib

    def combined(target):
        h = hashlib.sha256()
        for log in rp.target_logs(target):
            h.update(log.digest().encode())
        return h.hexdigest()

    sess = _bridge_session(fault_seed=7, interval=2)
    rec = sess.record(_launch_program([32, 48, 64, 32]))
    for ck in rec.checkpoints[1:]:
        prefix = sess.replay(rec, 0, ck.op_index)
        restored = sess.replay(rec, ck.op_index, ck.op_index)
        assert combined(prefix.target) == combined(restored.target), \
            f"digest diverged after restore @{ck.op_index}"
        resumed = sess.replay(rec, ck.op_index, rec.n_ops)
        assert combined(resumed.target) == rec.log_digest


def test_recording_bridge_proxy_records_opaque_firmware():
    """An unmodified firmware callable run behind RecordingBridge yields
    the same trace as running it on the raw bridge."""
    fb = FireBridge(congestion=CONG)
    fb.register_op("mm", **matmul_backends(tile=16, jit=False))
    matmul_firmware(fb, "mm", "oracle", size=32, tile=16)

    sess = _bridge_session()
    rec = sess.record(lambda r: matmul_firmware(
        rp.RecordingBridge(r), "mm", "oracle", size=32, tile=16))
    assert rec.preamble + rec.lines == fb.log.canonical()[:len(
        rec.preamble) + len(rec.lines)]
    assert rec.target.log.canonical() == fb.log.canonical()


def test_replay_counter_instrumentation():
    sess = _bridge_session()
    rec = sess.record(_launch_program([32, 32]))
    assert sess.replays == 0 and rec.replays == 0
    sess.replay(rec, 0, rec.n_ops)
    sess.replay(rec, 3, 6)
    assert sess.replays == 2 and rec.replays == 2


# --------------------------------------------------------------- bisection
def _lockstep_first_divergence(sa, ra, sb, rb):
    """Brute-force baseline: full-range replay of BOTH runs, lockstep
    compare of every op's lines and functional state — what a full-trace
    diff (plus full state diff) would name."""
    wa = sa.replay(ra, 0, ra.n_ops)
    wb = sb.replay(rb, 0, rb.n_ops)
    for ta, tb in zip(wa.ops, wb.ops):
        if ta.lines != tb.lines or ta.func_fingerprint != tb.func_fingerprint:
            return ta.op_index
    return None


def test_bisect_planted_data_divergence_within_replay_budget():
    """A planted backend bug (wrong value, identical transaction stream)
    is localized to its exact launch op within ceil(log2(N)) + 2 window
    replays, counted via instrumentation — and agrees with the
    brute-force full-trace+state diff."""
    sizes = [32, 48, 32, 64, 48, 32, 48, 64]      # bug fires on EVERY launch
    sa = _bridge_session(label="good")
    ra = sa.record(_launch_program(sizes, backend="oracle"))
    sb = _bridge_session(table=planted_bug_table(tile=16), label="bad")
    rb = sb.record(_launch_program(sizes, backend="interpret"))

    expected = _lockstep_first_divergence(
        _bridge_session(label="good"), ra,
        _bridge_session(table=planted_bug_table(tile=16), label="bad"), rb)
    assert expected == 5          # the first launch event

    before = ra.replays + rb.replays
    rep = rp.bisect_divergence(sa, ra, sb, rb)
    used = (ra.replays + rb.replays) - before
    assert rep is not None and rep.kind == "state"
    assert rep.op_index == expected
    budget = math.ceil(math.log2(ra.n_ops)) + 2
    assert rep.n_replays == used <= budget, (used, budget)
    assert "c0" in rep.detail                 # names the divergent buffer
    assert rep.state_a["buffers"]["c0"] != rep.state_b["buffers"]["c0"]


def test_bisect_trace_divergence_names_first_divergent_line():
    """A timing/stream divergence (different DMA engine name mid-run) is
    named at the first divergent canonical line, same as a full diff."""
    sizes = [32, 48, 32, 64]
    sa = _bridge_session(label="a")
    ra = sa.record(_launch_program(sizes))

    def perturbed(rec):                 # identical until launch #2's engine
        _launch_program(sizes[:2])(rec)
        for j, size in enumerate(sizes[2:], start=2):
            rng = np.random.default_rng(size * 1009 + j)
            a = rng.normal(size=(size, size)).astype(np.float32)
            b = rng.normal(size=(size, size)).astype(np.float32)
            rec.do("alloc", f"a{j}", a.shape, np.float32)
            rec.do("alloc", f"b{j}", b.shape, np.float32)
            rec.do("alloc", f"c{j}", (size, size), np.float32)
            rec.do("host_write", f"a{j}", a)
            rec.do("host_write", f"b{j}", b)
            rec.do("launch", "mm", "oracle", (f"a{j}", f"b{j}"),
                   (f"c{j}",), "other_dma", None, {})
    sb = _bridge_session(label="b")
    rb = sb.record(perturbed)
    assert ra.n_ops == rb.n_ops

    # full-trace diff baseline over the recorded canonical streams
    la, lb = ra.preamble + ra.lines, rb.preamble + rb.lines
    full_diff_line = next(i for i, (x, y) in enumerate(zip(la, lb))
                          if x != y)

    rep = rp.bisect_divergence(sa, ra, sb, rb)
    assert rep is not None and rep.kind == "trace"
    assert rep.line_index == full_diff_line
    assert rep.line_a == la[full_diff_line]
    assert rep.line_b == lb[full_diff_line]
    assert rep.event.startswith("launch")
    assert rep.n_replays <= math.ceil(math.log2(ra.n_ops)) + 2


def test_fingerprint_covers_buffers_with_structural_names():
    """Key exclusion stops at data boundaries: a buffer that happens to
    be named like a structural state key ('time') still enters the
    functional fingerprint, so a silent data divergence there is found."""
    def prog(tail):
        def program(rec):
            rec.do("alloc", "time", (4,), np.float32)
            rec.do("host_write", "time",
                   np.asarray([1, 2, 3, tail], np.float32))
        return program

    sa = _bridge_session(label="a")
    ra = sa.record(prog(4.0))
    sb = _bridge_session(label="b")
    rb = sb.record(prog(5.0))
    assert ra.final_func_fingerprint != rb.final_func_fingerprint
    rep = rp.bisect_divergence(sa, ra, sb, rb)
    assert rep is not None and rep.kind == "state" and rep.op_index == 1


def test_bisect_identical_runs_returns_none():
    sa = _bridge_session(fault_seed=9, label="x")
    ra = sa.record(_launch_program([32, 48]))
    sb = _bridge_session(fault_seed=9, label="y")
    rb = sb.record(_launch_program([32, 48]))
    assert rp.bisect_divergence(sa, ra, sb, rb) is None


def test_bisect_timing_perturbed_runs_diverge_on_trace_not_state():
    """Two runs with different fault seeds diverge in TIMING only:
    bisection reports a trace/preamble divergence (a differing fault-plan
    injection), never a state one — the functional probe ignores timing,
    and the final DDR contents really are equal."""
    sa = _bridge_session(fault_seed=1, label="seed1")
    ra = sa.record(_launch_program([32, 48, 32]))
    sb = _bridge_session(fault_seed=2, label="seed2")
    rb = sb.record(_launch_program([32, 48, 32]))
    rep = rp.bisect_divergence(sa, ra, sb, rb)
    assert rep is not None and rep.kind in ("trace", "preamble")
    # functional state never diverged: final DDR contents equal
    assert ra.final_func_fingerprint == rb.final_func_fingerprint


def test_bisect_all_golden_trace_programs_matches_full_diff():
    """Acceptance: on every (fast) golden-trace program, a single-event
    perturbation is localized to the same first divergent op a full-trace
    (+state) diff names, within the replay budget."""
    import test_golden_traces as tgt

    cases = {
        "single_device_launch": (tgt.single_device_run, "host_write"),
        "fabric_all_reduce": (tgt.fabric_all_reduce_run, "dev_host_write"),
        "faulty_fuzz": (tgt.faulty_fuzz_run, "host_write"),
    }
    for name, (build, kind) in cases.items():
        run_a = build()
        sa, ra = run_a.session, run_a.recording
        # perturb the LAST event of the chosen kind (late divergence, so
        # the checkpoint binary search has something to narrow)
        k = max(i for i, ev in enumerate(ra.events) if ev.kind == kind)
        events = list(ra.events)
        args = list(events[k].args)
        data_i = next(i for i, a in enumerate(args)
                      if isinstance(a, np.ndarray))
        args[data_i] = args[data_i] + np.float32(1.0)
        events[k] = rp.TimelineEvent(events[k].kind, tuple(args))

        run_b = build()                  # fresh identical session
        sb = run_b.session
        rb = sb.record(events)
        expected = _lockstep_first_divergence(build().session, ra,
                                              build().session, rb)
        assert expected == k, (name, expected, k)

        before = ra.replays + rb.replays
        rep = rp.bisect_divergence(sa, ra, sb, rb)
        used = ra.replays + rb.replays - before
        budget = math.ceil(math.log2(max(2, ra.n_ops))) + 2
        assert rep is not None and rep.op_index == k, (name, rep)
        assert rep.n_replays == used <= budget, (name, used, budget)


def test_bisect_length_divergence():
    sa = _bridge_session(label="short")
    ra = sa.record(_launch_program([32, 48]))
    sb = _bridge_session(label="long")
    rb = sb.record(_launch_program([32, 48, 32]))
    rep = rp.bisect_divergence(sa, ra, sb, rb)
    assert rep is not None and rep.kind == "length"
    assert rep.op_index == ra.n_ops


# ----------------------------------------------------- scheduler attachment
def test_failing_sweep_cell_auto_attaches_divergence_report():
    """A failing equivalence group hands back a minimal divergence report
    naming the first divergent op — without re-running the whole sweep."""
    sess = CoVerifySession(matmul_firmware, congestion=CONG)
    sess.register_op("mm", **planted_bug_table(tile=16))
    sess.add_sweep("mm", ("oracle", "interpret"),
                   [{"size": 32, "tile": 16}])
    report = sess.run(max_workers=2)
    assert not report.passed
    (label,) = report.divergences
    d = report.divergences[label]
    assert isinstance(d, rp.DivergenceReport)
    assert d.kind == "state" and d.event.startswith("launch")
    assert d.n_replays <= 4       # << ceil(log2(6)) + 2 for the 6-op cell
    text = d.render()
    assert "first divergent op" in text and "device state" in text
    assert report.summary()["divergences"][label].startswith("op #")


def test_passing_sweep_attaches_nothing():
    sess = CoVerifySession(matmul_firmware, congestion=CONG)
    sess.register_op("mm", **matmul_backends(tile=16, jit=False))
    sess.add_sweep("mm", ("oracle", "interpret"),
                   [{"size": 32, "tile": 16}])
    report = sess.run(max_workers=2)
    assert report.passed and report.divergences == {}


def test_fault_plan_sweep_bisect_survives_timing_divergence():
    """Per-backend fault forks make timing differ legitimately; with a
    planted DATA bug on top, bisection must still localize the data
    divergence (functional probe ignores timing)."""
    sess = CoVerifySession(
        matmul_firmware, congestion=CONG,
        fault_plan=FaultPlan(seed=5))
    sess.register_op("mm", **planted_bug_table(tile=16))
    sess.add_sweep("mm", ("oracle", "interpret"),
                   [{"size": 32, "tile": 16}])
    report = sess.run(max_workers=2)
    assert not report.passed
    (d,) = report.divergences.values()
    assert isinstance(d, rp.DivergenceReport)
    # timing noise may surface as trace divergence first; the data bug
    # must be visible in the attached state summaries either way
    assert d.op_index >= 0


# ----------------------------------------------------- replay-backed shrink
def test_shrink_with_replay_matches_legacy_and_is_cheaper():
    fz = ProtocolFuzzer(seed=1, layers=("bridge",),
                        mm_table=planted_bug_table(), bridge_ops=(10, 11))
    scn = fz.scenario(0)
    assert len(scn.ops) == 10
    sub_new, res_new = fz.shrink(scn)
    fz2 = ProtocolFuzzer(seed=1, layers=("bridge",),
                         mm_table=planted_bug_table(), bridge_ops=(10, 11))
    sub_old, res_old = fz2.shrink(fz2.scenario(0), use_replay=False)
    assert sub_new.ops == sub_old.ops
    assert (not res_new.ok) and (not res_old.ok)
    assert res_new.failures[0].split(":")[0] == \
        res_old.failures[0].split(":")[0]


def test_shrink_replay_defers_on_non_bridge_layers():
    """Register-layer scenarios keep the legacy linear lane (trivial op
    cost) — shrink still returns a failing prefix when one exists."""
    fz = ProtocolFuzzer(seed=11, layers=("registers",))
    report = fz.run(5)
    assert report.passed                  # healthy: shrink returns full scn
    scn = fz.scenario(0)
    sub, res = fz.shrink(scn)
    assert res.ok and sub.ops == scn.ops


# ------------------------------------------------------------ storm replay
@pytest.mark.slow
def test_cluster_storm_record_replay_digest_identity():
    """Cluster-serving storm: record once, replay any window bit-
    identically (token parity + trace digest), via the golden-run
    builder's cached engine."""
    import test_golden_traces as tgt
    run = tgt.cluster_serving_storm_run()
    sess, rec = run.session, run.recording
    tokens = {rid: list(r.out_tokens)
              for rid, r in rec.target.requests.items()}
    lo = rec.n_ops - 4
    w = sess.replay(rec, lo, rec.n_ops)
    assert w.lines == rec.window_lines(lo, rec.n_ops)
    assert w.digest() == rec.window_digest(lo, rec.n_ops)
    got = {rid: list(r.out_tokens) for rid, r in w.target.requests.items()}
    assert got == tokens

    # bisection parity on the cluster golden program: perturb one
    # submission's token budget and localize it to that exact CSR write
    k = next(i for i, ev in enumerate(rec.events)
             if ev.kind == "csr_write" and ev.args[0] == "SUBMIT_MAXNEW")
    events = list(rec.events)
    events[k] = rp.TimelineEvent("csr_write",
                                 ("SUBMIT_MAXNEW", events[k].args[1] + 1))
    rb = sess.record(events)
    before = rec.replays + rb.replays
    rep = rp.bisect_divergence(sess, rec, sess, rb)
    used = rec.replays + rb.replays - before
    assert rep is not None and rep.op_index == k
    assert used == rep.n_replays <= math.ceil(
        math.log2(max(2, rec.n_ops))) + 2


@pytest.mark.slow
def test_open_loop_serving_checkpoint_restore_mid_decode():
    """Open-loop serving: restoring a MID-DECODE checkpoint (requests in
    flight, KV pages held, partial token streams) and replaying the
    remaining window regenerates the uninterrupted run bit-identically —
    tokens, transaction lines, and the final state fingerprint.  The
    engine's get_state/set_state must therefore round-trip the modeled
    clock, the KV page pool, and every in-flight request."""
    import test_serving_slo as slo
    trace = slo._trace(seed=9, n=6)
    eng = slo._engine()

    def factory():
        eng.reset(batching="continuous", kv_pages=4, kv_page_size=8,
                  kv_leak_every=0)
        return eng

    sess = rp.DebugSession(factory, checkpoint_interval=6, label="openloop")
    rec = rp.record_open_loop(sess, trace)
    tokens = {rid: list(r.out_tokens)
              for rid, r in rec.target.requests.items()}
    assert len(tokens) == len(trace.arrivals)

    # find a checkpoint that lands mid-decode: restored state has active
    # requests and at least one partially generated stream
    mid = None
    for ck in rec.checkpoints:
        if not 0 < ck.op_index < rec.n_ops:
            continue
        w = sess.replay(rec, ck.op_index, ck.op_index)
        reqs = w.target.requests
        partial = [r for r in reqs.values()
                   if 0 < len(r.out_tokens) < r.max_new_tokens
                   and not r.done]
        if w.target._n_active() and partial:
            mid = ck
            assert w.target.kv_pool.in_use > 0   # pages held mid-flight
            break
    assert mid is not None, "no checkpoint landed mid-decode"

    w = sess.replay(rec, mid.op_index, rec.n_ops)
    assert w.lines == rec.window_lines(mid.op_index, rec.n_ops)
    assert w.digest() == rec.window_digest(mid.op_index, rec.n_ops)
    assert rp.state_fingerprint(w.target.get_state()) == \
        rec.final_fingerprint
    got = {rid: list(r.out_tokens) for rid, r in w.target.requests.items()}
    assert got == tokens
    for r in w.target.requests.values():
        assert r.done and len(r.out_tokens) == r.max_new_tokens
    assert w.target.kv_pool.n_free == w.target.kv_pool.n_pages


# -------------------------------------------------------------- benchmark
@pytest.mark.slow
def test_bench_replay_quick_mode():
    """The debug-iteration benchmark's quick mode: window replay must
    re-execute a small fraction of the events a full re-run pays
    (deterministic count) and deliver the >=5x wall speedup the paper's
    debug-iteration claim rests on."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.bench_replay import run
    rows = run(quick=True)
    assert rows[0].startswith("case,")
    by = {r.split(",")[0]: r.split(",") for r in rows[1:]}
    full_events = int(by["full_rerun"][2])
    win_events = int(by["window_replay"][2])
    assert full_events >= 5 * win_events        # deterministic economics
    assert float(by["window_replay"][4]) >= 5.0     # measured wall speedup
    assert float(by["shrink_prefix_replay"][4]) > 1.0


# ----------------------------------------------------------------- docs
def test_docs_transcript_matches_example():
    """The worked bisection transcript in docs/replay.md is the
    VERBATIM output of examples/time_travel_debug.py — docs cannot drift
    from the tool."""
    import contextlib
    import importlib.util
    import io
    from pathlib import Path

    root = Path(__file__).resolve().parents[1]
    doc = (root / "docs" / "replay.md").read_text().splitlines()
    sentinel = ("prints (deterministic — modeled clocks and seeded "
                "faults, no wall time):")
    i = doc.index(sentinel)
    start = doc.index("```", i) + 1
    end = doc.index("```", start)
    expected = doc[start:end]

    spec = importlib.util.spec_from_file_location(
        "time_travel_debug", root / "examples" / "time_travel_debug.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        mod.main()
    assert buf.getvalue().splitlines() == expected


# ----------------------------------------------------------- debug bundles
def test_divergence_report_save_writes_bundle(tmp_path):
    sa = _bridge_session(label="a")
    ra = sa.record(_launch_program([32, 48]))
    sb = _bridge_session(table=planted_bug_table(tile=16), label="b")
    rb = sb.record(_launch_program([32, 48], backend="interpret"))
    rep = rp.bisect_divergence(sa, ra, sb, rb)
    path = tmp_path / "bundles" / "div.txt"
    rep.save(path)
    body = path.read_text()
    assert "first divergent op" in body and "window lines (a):" in body
