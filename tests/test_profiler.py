"""Off-chip data-movement profiler regression tests (core/profiler.py).

The load-bearing properties, asserted rather than eyeballed:

* **Closure** — on every committed golden-trace run and every profiled
  `CoVerifySession` sweep cell, the six-category stall attribution sums
  EXACTLY (bit-exact float equality) to the channel's modeled completion
  time, and the single-device DDR channel's horizon IS `bridge.time`.
* **Determinism** — same seed ⇒ byte-identical exported Perfetto JSON.
* **Replay identity** — profiling a replayed `Recording` window equals
  profiling the original run over that window; a full-range replay
  exports an identical trace.
* **Schema** — every exported trace validates against the documented
  Chrome-trace event schema (`validate_trace`), including the in-file
  closure check.
"""
import json
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from repro.core import (CATEGORIES, CongestionConfig, CoVerifySession,
                        DataMovementProfiler, FabricCluster, FaultPlan,
                        FireBridge, RooflinePlacement, profile_recording,
                        profile_window, validate_trace)
from repro.core import replay as rp
from repro.kernels.systolic_matmul import ops as mm_ops
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_fabric_firmware,
                                                 matmul_firmware)

import test_golden_traces as tg


def _assert_closed(prof: DataMovementProfiler) -> None:
    """The closure property: every channel's six categories sum
    bit-exactly to its horizon, with non-negative cycles and a vanishing
    internal transfer residual."""
    assert prof.channels, "profiler resolved no channels"
    for ch in prof.channels:
        bd = ch.breakdown
        assert set(bd.cycles) == set(CATEGORIES)
        assert sum(bd.cycles.values()) == ch.horizon == bd.total, ch.name
        assert all(v >= -1e-6 for v in bd.cycles.values()), (ch.name,
                                                            bd.cycles)
        assert ch.residual < 1e-3, (ch.name, ch.residual)


# ------------------------------------------------------- golden-run closure
@pytest.mark.parametrize("name", [tg._mark(n) for n in sorted(tg.TRACES)])
def test_stall_attribution_closes_on_golden_runs(name):
    """Acceptance gate: attribution closes on all four committed golden
    traces' runs (single-device, fabric all_reduce, fault-active fuzz,
    cluster-serving storm)."""
    run = tg.TRACES[name]()
    prof = DataMovementProfiler(run.recording.target, label=name)
    _assert_closed(prof)
    target = run.recording.target
    if isinstance(target, FireBridge):
        assert prof.channel("ddr").horizon == target.mem.time
    trace = prof.to_perfetto()
    errs = validate_trace(trace)
    assert errs == [], errs
    if name == "cluster_open_loop_serving":
        # the continuous-batching golden run must surface per-request
        # lifecycle tracks (queue/prefill/decode) in the export
        assert len(prof.requests) == 10
        assert len(prof.request_rows()) == 11
        cats = {e.get("cat") for e in trace["traceEvents"]}
        assert {"queue", "prefill", "decode"} <= cats


# ------------------------------------------------------------- determinism
def _profiled_run(profile: bool = True) -> FireBridge:
    fb = FireBridge(congestion=CongestionConfig(dos_prob=0.05, seed=7),
                    fault_plan=FaultPlan(3), profile=profile)
    fb.register_op("mm", **matmul_backends(tile=16, jit=False))
    matmul_firmware(fb, "mm", "oracle", size=32, tile=16)
    matmul_firmware_second(fb)
    return fb


def matmul_firmware_second(fb) -> None:
    """A second launch on the same bridge (distinct buffer names) so the
    profiled stream covers multiple op marks."""
    rng = np.random.default_rng(48)
    a = rng.normal(size=(48, 48)).astype(np.float32)
    fb.mem.alloc("a2", a.shape, np.float32)
    fb.mem.alloc("c2", (48, 48), np.float32)
    fb.mem.host_write("a2", a)
    fb.launch("mm", "oracle", ["a2", "a2"], ["c2"],
              burst_list=lambda: mm_ops.transactions(
                  48, 48, 48, bm=16, bn=16, bk=16, dtype_bytes=4))


def test_export_deterministic(tmp_path):
    """Same seed ⇒ byte-identical exported trace JSON."""
    p1 = _profiled_run().profiler().save_perfetto(tmp_path / "a.json")
    p2 = _profiled_run().profiler().save_perfetto(tmp_path / "b.json")
    assert p1.read_bytes() == p2.read_bytes()
    assert p1.read_bytes().endswith(b"\n")


def test_op_marks_and_engine_rows():
    fb = _profiled_run()
    prof = fb.profiler()
    _assert_closed(prof)
    ops = [m.op for _, m in prof.marks]
    assert ops == ["mm@oracle", "mm@oracle"]
    # every marked range owns at least the launch's read+write bursts
    assert all(m.tx_hi > m.tx_lo for _, m in prof.marks)
    rows = prof.op_rows()
    assert rows[0].startswith("op,meta,transactions,bytes")
    assert len(rows) == 3
    # per-engine stall matches the legacy Fig. 8 readout exactly
    res = fb.congestion_stats()
    ddr = prof.channel("ddr")
    for e, s in ddr.engines.items():
        assert s.stall == res.per_engine_stall[e]
        assert s.busy == res.per_engine_busy[e]
    assert ddr.utilization == res.link_utilization
    assert ddr.horizon == res.makespan == fb.mem.time


def test_fault_delay_attributed():
    """Injected dma_delay faults surface in the fault_delay category (and
    nowhere else classifies them)."""
    fb = _profiled_run()
    ddr = fb.profiler().channel("ddr")
    injected = [e for e in fb.mem.fault_plan.events if e.kind == "dma_delay"]
    if injected:                 # seed-dependent but stable: seed 3 injects
        assert ddr.breakdown.cycles["fault_delay"] > 0
    assert sum(s.fault_delay for s in ddr.engines.values()) > 0


# ------------------------------------------------------ fast path + schema
def test_fast_path_closure_and_schema():
    fb = FireBridge(profile=True)
    fb.register_op("mm", **matmul_backends(tile=16, jit=False))
    matmul_firmware(fb, "mm", "oracle", size=32, tile=16)
    prof = fb.profiler()
    _assert_closed(prof)
    ddr = prof.channel("ddr")
    assert ddr.kind == "clock"
    assert ddr.horizon == fb.mem.time
    assert validate_trace(prof.to_perfetto()) == []


def test_validate_trace_rejects_bad_traces():
    good = _profiled_run().profiler().to_perfetto()
    assert validate_trace(good) == []
    broken = json.loads(json.dumps(good))
    del broken["traceEvents"][0]["name"]
    assert any("missing" in e for e in validate_trace(broken))
    skewed = json.loads(json.dumps(good))
    skewed["otherData"]["attribution"]["ddr"]["transfer"] += 1.0
    assert any("sums to" in e for e in validate_trace(skewed))
    assert any("top-level" in e for e in validate_trace({"traceEvents": []}))


# --------------------------------------------------------- fabric profiling
def test_fabric_profile_ports_and_leg_attribution():
    fab = FabricCluster(4, profile=True,
                        link_config=CongestionConfig(
                            link_bytes_per_cycle=64.0, base_latency=100.0,
                            dos_prob=0.05, seed=11))
    for i in range(4):
        fab.devices[i].mem.alloc("g", (16, 16), np.float32)
        fab.devices[i].mem.host_write(
            "g", np.full((16, 16), float(i + 1), np.float32))
    fab.all_reduce("g")
    prof = fab.profiler()
    _assert_closed(prof)
    names = [c.name for c in prof.channels]
    assert "fabric/host" in names
    assert all(f"fabric/port{i}" in names for i in range(4))
    legs = [(m.op, m.meta) for _, m in prof.marks]
    assert legs == [("all_reduce", f"{phase}[{s}]")
                    for phase in ("reduce_scatter", "all_gather")
                    for s in range(3)]
    # ring legs carry nonzero traffic and port contention shows up
    rows = prof.op_rows()
    assert len(rows) == 7
    assert all(int(r.split(",")[3]) > 0 for r in rows[1:])
    assert validate_trace(prof.to_perfetto()) == []


# ------------------------------------------------------- recording profiling
def _recorded_bridge():
    table = matmul_backends(tile=16, jit=False)

    def factory():
        fb = FireBridge(congestion=CongestionConfig(dos_prob=0.05, seed=7),
                        fault_plan=FaultPlan(3))
        fb.register_op("mm", **table)
        return fb

    def program(rec):
        for j, size in enumerate([32, 48, 32, 64]):
            rng = np.random.default_rng(size * 7 + j)
            a = rng.normal(size=(size, size)).astype(np.float32)
            rec.do("alloc", f"a{j}", a.shape, np.float32)
            rec.do("alloc", f"c{j}", (size, size), np.float32)
            rec.do("host_write", f"a{j}", a)
            rec.do("launch", "mm", "oracle", (f"a{j}", f"a{j}"),
                   (f"c{j}",), "mm",
                   (lambda s=size: mm_ops.transactions(
                       s, s, s, bm=16, bn=16, bk=16, dtype_bytes=4)), {})

    sess = rp.DebugSession(factory, checkpoint_interval=4, label="prof")
    return sess, sess.record(program)


def test_profile_recording_matches_original():
    """Full-range replay profiles byte-identically to the original run."""
    sess, rec = _recorded_bridge()
    orig = DataMovementProfiler(rec.target, label="prof")
    replayed = profile_recording(sess, rec)
    _assert_closed(replayed)
    a = json.dumps(orig.to_perfetto(), sort_keys=True)
    b = json.dumps(replayed.to_perfetto(), sort_keys=True)
    assert a == b


def test_profile_window_replay_identity():
    """Profiling a replayed window equals profiling the original run over
    that window — for every checkpoint-aligned and unaligned window."""
    sess, rec = _recorded_bridge()
    for lo, hi in [(0, rec.n_ops), (5, 12), (3, 9), (10, rec.n_ops)]:
        w = sess.replay(rec, lo, hi)
        want = profile_window(rec.target, rec, lo, hi)
        got = profile_window(w.target, rec, lo, hi)
        assert got == want, (lo, hi)
    assert profile_window(rec.target, rec, 0, rec.n_ops)


# ------------------------------------------------------------ sweep wiring
def test_sweep_cells_close_and_report_columns(tmp_path):
    sess = CoVerifySession(matmul_firmware,
                           congestion=CongestionConfig(dos_prob=0.02,
                                                       seed=5),
                           fault_plan=FaultPlan(9), profile=True)
    sess.register_op("mm", **matmul_backends(tile=32))
    sess.add_sweep("mm", ("oracle", "interpret"), [{"size": 64}])
    rep = sess.run(max_workers=2)
    assert rep.passed, rep.summary()
    for r in rep.cells:
        assert r.profile is not None
        _assert_closed(r.profile)
        assert r.profile.channel("ddr").horizon == r.bridge_time
        assert 0.0 < r.utilization <= 1.0
        assert sum(r.attribution.values()) > 0
    rows = rep.to_rows()
    assert "utilization" in rows[0]
    for c in CATEGORIES:
        assert f"{c}_cycles" in rows[0]
    assert "-" not in rows[1].split(",")        # profiled: columns filled
    paths = rep.save_traces(tmp_path)
    assert len(paths) == 2
    for p in paths:
        assert validate_trace(json.loads(p.read_text())) == []


def test_unprofiled_sweep_keeps_dash_columns():
    sess = CoVerifySession(matmul_firmware)
    sess.register_op("mm", **matmul_backends(tile=32))
    sess.add_cell("mm", "oracle", {"size": 64})
    rep = sess.run(max_workers=1)
    assert rep.passed
    (r,) = rep.cells
    assert r.profile is None and r.utilization is None
    assert ",-," in rep.to_rows()[1]
    assert rep.save_traces("unused") == []


@pytest.mark.slow
def test_fabric_sweep_cells_close():
    link = CongestionConfig(link_bytes_per_cycle=64.0, base_latency=100.0)
    sess = CoVerifySession(matmul_firmware,
                           fabric_firmware=matmul_fabric_firmware,
                           link_config=link, profile=True)
    sess.register_op("mm", **matmul_backends(tile=32))
    sess.add_sweep("mm", ("oracle",), [{"size": 64}], devices=(1, 2, 4))
    rep = sess.run(max_workers=2)
    assert rep.passed, rep.summary()
    for r in rep.cells:
        _assert_closed(r.profile)
        # the cell's modeled completion time is the slowest channel
        assert max(c.horizon for c in r.profile.channels) == r.bridge_time


# ---------------------------------------------------------- serving profile
@pytest.mark.slow
def test_serving_profiler_splits_upload_vs_writeback():
    from repro.core.fuzz import _default_engine
    eng = _default_engine()
    try:
        for rid, n in ((0, 6), (1, 9)):
            prompt = np.arange(n, dtype=np.int32) + 1
            eng.mem.buffers["prompt_in"].array[:n] = prompt
            eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_ID"), rid)
            eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_LEN"), n)
            eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_MAXNEW"), 3)
            eng.csr.fb_write_32(eng.csr.addr_of("DOORBELL"), 1)
        eng.run_until_done()
        prof = eng.profiler()
        _assert_closed(prof)
        rows = prof.serving_rows()
        by = {r.split(",")[0]: r.split(",") for r in rows[1:]}
        assert int(by["prompt_upload"][2]) > 0
        assert int(by["token_writeback"][2]) > 0
        assert int(by["prompt_upload"][1]) == 2      # one read per submit
        assert int(by["token_writeback"][1]) == 2    # one row per retire
        assert validate_trace(prof.to_perfetto()) == []
    finally:
        eng.reset()


# ---------------------------------------------------------------- roofline
def test_roofline_placement_terms():
    pl = RooflinePlacement("k", {"compute": 2.0, "memory": 4.0}, ideal_s=1.0)
    assert pl.dominant == "memory"
    assert pl.limit_s == 4.0
    assert pl.roofline_frac == 0.25
    assert RooflinePlacement("z", {"compute": 0.0}).roofline_frac == 0.0


def test_profiler_roofline_uses_marked_bytes():
    fb = _profiled_run()
    prof = fb.profiler()
    pts = prof.roofline({"mm@oracle": 1e6}, peak_flops=1e9, mem_bw=1e8)
    assert len(pts) == 2
    for pt in pts:
        assert pt.terms["memory"] > 0
        assert pt.dominant in ("compute", "memory")


# ---------------------------------------------------------------- benchmark
@pytest.mark.slow
def test_bench_profiler_quick_mode():
    """The overhead gate: < 10% wall-clock with profiling enabled on the
    200-launch fuzz workload (asserted inside run()), plus a valid
    exported artifact."""
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.bench_profiler import ART, run
    rows = run(quick=True)
    assert rows[0].startswith("case,")
    by = {r.split(",")[0]: r.split(",") for r in rows[1:]}
    assert float(by["profile_on"][4]) < 10.0
    trace = json.loads((ART / "profiler_trace.json").read_text())
    assert validate_trace(trace) == []
