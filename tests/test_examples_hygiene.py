"""Example/benchmark artifact hygiene: nothing lands at the repo root.

PR history: examples/profile_cnn.py used to default its Perfetto export
to ``profile_cnn.trace.json`` in the current directory, which left an
untracked artifact at the repo root after every docs run.  Default
output paths must land under a gitignored ``artifacts/`` directory
(``artifacts/``, ``benchmarks/artifacts/``, ``tests/artifacts/``) or an
explicit tempdir; this suite enforces that statically (argparse
defaults) and dynamically (running the one exporting example).
"""
import ast
import contextlib
import importlib.util
import io
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

sys.path.insert(0, str(ROOT / "src"))

# suffixes that mark an argparse default as a file/dir OUTPUT path
_ARTIFACT_SUFFIXES = (".json", ".jsonl", ".csv", ".txt", ".trace")
# a default path is fine if it is absolute-temp or under a gitignored
# artifacts dir
_ALLOWED_PREFIXES = ("artifacts/", "benchmarks/artifacts/",
                     "tests/artifacts/", "/tmp/")


def _argparse_string_defaults(path: Path):
    """Yield (lineno, default) for every ``add_argument(..., default=<str>)``
    in the file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            continue
        for kw in node.keywords:
            if (kw.arg == "default" and isinstance(kw.value, ast.Constant)
                    and isinstance(kw.value.value, str)):
                yield node.lineno, kw.value.value


def test_default_output_paths_are_gitignored():
    """Static scan: every examples/ and benchmarks/ argparse default that
    names an output file must land under a gitignored artifacts dir."""
    offenders = []
    for d in ("examples", "benchmarks"):
        for py in sorted((ROOT / d).glob("*.py")):
            for lineno, default in _argparse_string_defaults(py):
                if not default.endswith(_ARTIFACT_SUFFIXES):
                    continue
                if not default.startswith(_ALLOWED_PREFIXES):
                    offenders.append(
                        f"{py.relative_to(ROOT)}:{lineno}: "
                        f"default={default!r} writes outside artifacts/")
    assert not offenders, "\n".join(offenders)


def test_repo_root_has_no_stray_artifacts():
    """Only the committed benchmark baselines may sit as .json at the
    repo root (the historical offender was profile_cnn.trace.json)."""
    committed = {"BENCH_runfarm.json", "BENCH_serving.json",
                 "BENCH_simspeed.json", "BENCH_counters.json"}
    stray = sorted(p.name for p in ROOT.glob("*.json")
                   if p.name not in committed)
    assert not stray, f"untracked artifacts at repo root: {stray}"


def test_profile_cnn_defaults_write_under_artifacts(tmp_path, monkeypatch):
    """Dynamic check: running the exporting example with DEFAULT args
    from a scratch cwd creates artifacts/ there and touches nothing at
    the repo root."""
    before = {p.name for p in ROOT.iterdir()}
    spec = importlib.util.spec_from_file_location(
        "profile_cnn_hygiene", ROOT / "examples" / "profile_cnn.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    monkeypatch.chdir(tmp_path)
    with contextlib.redirect_stdout(io.StringIO()):
        mod.main([])
    assert (tmp_path / "artifacts" / "profile_cnn.trace.json").exists()
    after = {p.name for p in ROOT.iterdir()}
    assert after == before, f"repo root changed: {sorted(after - before)}"
