"""Latency-SLO regression tier for open-loop serving (docs/serving.md).

The determinism bar mirrors the golden traces, one level up the stack:
one seed ⇒ one answer.  A seeded arrival trace driven through
continuous batching must produce identical token streams, identical SLO
rows, and identical transaction-log digests across the three backend
tiers (oracle = jit-disabled eager, interpret = un-jitted traced,
compiled = ``jax.jit``), and identical token streams across 1/2/4-device
scale — modeled latency may shift with scale, generated tokens may not.

The admission-control invariants ride the same runs: a 2x-oversubscribed
KV page pool degrades into deferred admission (never drops), every
admitted request retires with its exact token budget, and the pool
drains back to fully free.  The planted late-firing paging bug
(``kv_leak_every``) is localized by checkpointed replay bisection
(core/replay.py) — the leak shows up as a KV-pool STATE divergence ops
before any behavioral symptom.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.core import replay as rp
from repro.models import init_params
from repro.models.transformer import (RunFlags, make_decode_fn,
                                      make_prefill_fn)
from repro.serving import (ClusterServingEngine, ServingEngine, SLOReport,
                           bursty_trace, poisson_trace, run_open_loop)

FLAGS = RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16)
MAX_LEN = 32
BACKENDS = ("oracle", "interpret", "compiled")


@functools.lru_cache(maxsize=1)
def _model():
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return cfg, params


@functools.lru_cache(maxsize=4)
def _backend_fns(backend):
    """The three serving backend tiers as (prefill, decode) pairs —
    ``jit_fns`` injection, so every tier runs the SAME engine code and
    only the executable substrate changes (the co-verification axis):

    * oracle    — layer loop UNROLLED (``scan_layers=False``) and jitted:
                  a structurally different program for the same math
    * interpret — eager per-op dispatch, no whole-program compilation
    * compiled  — the production executable, ``lax.scan`` over layers
                  under ``jax.jit``
    """
    import dataclasses
    cfg, _ = _model()
    flags = (dataclasses.replace(FLAGS, scan_layers=False)
             if backend == "oracle" else FLAGS)
    pf = make_prefill_fn(cfg, flags, None, MAX_LEN)
    df = make_decode_fn(cfg, flags, None)
    if backend == "interpret":
        return pf, df
    return jax.jit(pf), jax.jit(df)


def _engine(backend="compiled", **kw):
    cfg, params = _model()
    kw.setdefault("max_slots", 2)
    kw.setdefault("prompt_pad", 8)
    kw.setdefault("kv_pages", 4)
    kw.setdefault("kv_page_size", 8)
    return ServingEngine(cfg, params, max_len=MAX_LEN, flags=FLAGS,
                         jit_fns=_backend_fns(backend),
                         batching="continuous", **kw)


@functools.lru_cache(maxsize=4)
def _cluster(n):
    cfg, params = _model()
    return ClusterServingEngine(cfg, params, n_devices=n, max_slots=2,
                                max_len=MAX_LEN, prompt_pad=8, flags=FLAGS,
                                batching="continuous", kv_pages=4,
                                kv_page_size=8)


def _trace(seed=3, n=8):
    return poisson_trace(seed, n_requests=n, mean_gap=150.0,
                         prompt_lens=(3, 10), max_new=(1, 4))


def _run(target, trace):
    run_open_loop(target, trace)
    slo = SLOReport.from_run(trace, target, label="slo")
    logs = "|".join(log.digest() for log in rp.target_logs(target))
    return slo, logs


# ----------------------------------------------------- determinism tier
@pytest.mark.slow
def test_same_seed_identical_across_backends():
    """oracle / interpret / compiled: identical SLO rows + token streams
    (``SLOReport.digest`` covers both) AND identical transaction-log
    digests — the serving engine's behavior is a pure function of the
    seed, not of the executable substrate."""
    trace = _trace()
    got = {}
    for be in BACKENDS:
        slo, logs = _run(_engine(be), trace)
        got[be] = (slo.digest(), logs)
    assert got["oracle"] == got["interpret"] == got["compiled"], got


@pytest.mark.slow
def test_same_seed_identical_token_streams_across_scale():
    """1 vs 2 vs 4 devices: modeled latency shifts (shared host channel,
    per-device pools) but every request's generated token stream is
    bit-identical — scheduling scale must not leak into content."""
    trace = _trace(seed=5, n=8)
    digests = {}
    rows = {}
    for n in (1, 2, 4):
        target = _engine() if n == 1 else _cluster(n)
        if n > 1:
            target.reset(None)
        slo, _ = _run(target, trace)
        digests[n] = slo.tokens_digest()
        rows[n] = slo.to_rows()
    assert digests[1] == digests[2] == digests[4], digests
    # and per-scale SLO rows are themselves rerun-stable
    target = _cluster(2)
    target.reset(None)
    slo2, _ = _run(target, trace)
    assert slo2.to_rows() == rows[2]


# ------------------------------------------------- admission invariants
def test_oversubscribed_pool_defers_but_drops_nothing():
    """2x KV oversubscription: a burst whose aggregate page demand is
    about twice the pool degrades into deferred admission — every
    admitted request still retires with its exact token budget, and the
    pool drains back to fully free (no leak, no stranded request)."""
    # 8 requests x >=2 pages each against a 4-page pool, arriving in
    # bursts, on one 4-slot engine: slots outnumber pages, so admission
    # control (not slot count) is the binding constraint
    trace = bursty_trace(11, n_requests=8, burst_size=8, gap_in_burst=5.0,
                         gap_between=400.0, prompt_lens=(3, 10),
                         max_new=(2, 4))
    eng = _engine(max_slots=4)
    run_open_loop(eng, trace)
    pool = eng.kv_pool
    assert pool.deferrals > 0, "stimulus never oversubscribed the pool"
    assert not eng.csr.log.violations
    assert len(eng.requests) == len(trace.arrivals)
    for a in trace.arrivals:
        req = eng.requests[a.rid]
        assert req.done, f"rid {a.rid} dropped"
        assert len(req.out_tokens) == a.max_new_tokens
        assert 0 <= req.t_submit <= req.t_admit <= req.t_first <= req.t_done
    assert pool.n_free == pool.n_pages and not pool.pages
    assert eng.kv_pool.peak_in_use == pool.n_pages    # it DID saturate


def test_infeasible_request_rejected_at_doorbell_not_starved():
    """A request whose whole-pool page demand can never be met is
    rejected with a logged violation at the doorbell — admission control
    must fail loudly up front, not livelock the queue."""
    # 2 pages x 4 entries; prompt_pad=4 so a short prompt pads to one
    # page's worth (page demand counts the PADDED prefill footprint)
    eng = _engine(kv_pages=2, kv_page_size=4, prompt_pad=4)
    from repro.serving import replayed_trace
    trace = replayed_trace([
        (0, 0.0, (5, 6, 7), 2),                   # fits: 2 pages exactly
        (1, 10.0, tuple(range(1, 13)), 4),        # 4 pages: never fits
        (2, 20.0, (8, 9), 2),                     # fits behind the reject
    ])
    run_open_loop(eng, trace)
    assert any("exceeds KV page pool" in v and "request 1" in v
               for v in eng.csr.log.violations)
    assert 1 not in eng.requests
    for rid in (0, 2):
        assert eng.requests[rid].done
    assert eng.kv_pool.n_free == eng.kv_pool.n_pages


@functools.lru_cache(maxsize=1)
def _checker_engine():
    """One warm-jit engine (prompt_pad=4) shared by every invariant
    check — reset() reconfigures the pool geometry per plan."""
    return _engine(max_slots=3, prompt_pad=4, kv_pages=2)


def check_admission_invariants(entries, n_pages, page_size):
    """THE admission-invariant oracle, shared by the hypothesis property
    test (tests/test_property.py) and the seeded fallback below: drive
    ``entries`` as a replayed open-loop trace against an ``n_pages`` x
    ``page_size`` pool and assert that feasible requests retire exactly,
    infeasible ones reject loudly, and the pool drains fully."""
    from repro.serving import replayed_trace
    eng = _checker_engine()
    eng.reset(batching="continuous", kv_pages=int(n_pages),
              kv_page_size=int(page_size), kv_leak_every=0)
    run_open_loop(eng, replayed_trace(entries), max_ticks=20_000)
    pool = eng.kv_pool
    for rid, _, prompt, mx in entries:
        need = pool.pages_for(eng._pad_len(len(prompt)) + mx - 1)
        if need > pool.n_pages:
            assert rid not in eng.requests, f"infeasible rid {rid} admitted"
            assert any(f"request {rid} exceeds KV page pool" in v
                       for v in eng.csr.log.violations)
        else:
            req = eng.requests[rid]
            assert req.done, f"feasible rid {rid} never retired"
            assert len(req.out_tokens) == mx
            assert (0 <= req.t_submit <= req.t_admit
                    <= req.t_first <= req.t_done)
    assert pool.n_free == pool.n_pages and not pool.pages, "page leak"


def test_admission_invariants_randomized():
    """Deterministic (seeded numpy) stand-in for the hypothesis property
    test — same oracle, 12 random plans, runs in every environment."""
    rng = np.random.default_rng(42)
    for _ in range(12):
        page_size = int(rng.choice((4, 8)))
        n_pages = int(rng.integers(2, 6))
        entries, t = [], 0.0
        for rid in range(int(rng.integers(1, 6))):
            t += float(rng.integers(0, 400))
            pl = int(rng.integers(1, 11))
            mx = int(rng.integers(1, 6))
            entries.append((rid, t, tuple(range(1, pl + 1)), mx))
        check_admission_invariants(entries, n_pages, page_size)


# ------------------------------------------------- replay-bisect tier
@pytest.mark.slow
def test_replay_bisect_localizes_planted_paging_leak():
    """The planted late-firing paging bug: ``kv_leak_every=3`` drops one
    page on every 3rd release — long before the engine visibly stalls.
    Recording the same arrival trace against the healthy and leaky
    configurations and bisecting the recordings localizes the divergence
    as a KV-pool STATE mismatch at a specific timeline op, in O(log N)
    checkpoint probes + 2 window replays."""
    trace = _trace(seed=7, n=8)
    eng = _engine()

    def mk(leak):
        def factory():
            eng.reset(batching="continuous", kv_pages=4, kv_page_size=8,
                      kv_leak_every=leak)
            return eng
        return factory

    sa = rp.DebugSession(mk(0), checkpoint_interval=8, label="healthy")
    ra = rp.record_open_loop(sa, trace)
    sb = rp.DebugSession(mk(3), checkpoint_interval=8, label="leaky")
    rb = rp.record_open_loop(sb, trace)
    d = rp.bisect_divergence(sa, ra, sb, rb)
    assert d is not None, "leak went undetected"
    assert d.kind == "state"
    # the state fingerprint names the pool's free-page count as the
    # first divergent leaf (replay.state_summary's kv_free_pages)
    assert "kv_free_pages" in d.detail, d.detail
    assert d.n_replays <= 2
    # the named op is a mid-run scheduler step, not the tail: the leak is
    # caught when it HAPPENS (a release), not when the engine starves
    assert 0 < d.op_index < ra.n_ops - 1
    # leave the shared cached engine healthy for other tests
    eng.reset(batching="continuous", kv_pages=4, kv_page_size=8,
              kv_leak_every=0)
