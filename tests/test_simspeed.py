"""Differential tier gating the vectorized modeled-time hot path.

The vectorization (core/congestion.py ``submit``/``submit_batch``, the
``BurstBatch`` columns, the lazy ``TransactionLog`` digests) is only
admissible because it is *bit-exact* against the retained scalar
reference ``LinkModel._submit_scalar``.  This module is the gate:

* three-way differential — scalar loop vs vectorized object path vs
  column-batch path over randomized burst batches × engine priorities ×
  DoS injection, asserting identical per-transaction timing, canonical
  trace bytes, link statistics, and post-run arbiter state (including
  the RNG stream position, so the paths stay interchangeable mid-run);
* the same differential through same-seeded fault perturbation
  (``perturb_bursts`` vs ``perturb_batch``);
* lazy-digest semantics — invalidation on every mutation channel,
  equality with an eager sha256 recompute, memoization, checkpoint/
  restore identity;
* a slow-marked floor check on the committed simspeed benchmark.

When hypothesis is available (CI property lane) the differential also
runs property-based; locally the 200 seeded random cases below cover
the same space deterministically.
"""
import copy
import hashlib

import numpy as np
import pytest

from repro.core.congestion import CongestionConfig, LinkModel
from repro.core.fuzz import FaultPlan
from repro.core.transactions import (BURST_DTYPE, BurstBatch, Transaction,
                                     TransactionLog)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # container image ships without hypothesis
    HAVE_HYPOTHESIS = False

ENGINES = ("dma_a", "dma_b", "host", "csr")


# ------------------------------------------------------------ case factory

def _random_case(rng):
    """(cfg, batches): each batch is (times, engines, kinds, addrs,
    nbytes, tags) column lists — contention-heavy on purpose."""
    n_eng = int(rng.integers(1, len(ENGINES) + 1))
    cfg = CongestionConfig(
        link_bytes_per_cycle=float(rng.choice([8.0, 64.0, 128.0])),
        base_latency=float(rng.choice([0.0, 40.0, 100.0])),
        dos_prob=float(rng.choice([0.0, 0.2, 0.5])),
        dos_stall=float(rng.choice([50.0, 200.0])),
        per_engine_issue_gap=float(rng.choice([0.0, 1.0, 3.0])),
        seed=int(rng.integers(1 << 31)),
        priorities=tuple((e, int(p)) for e, p in
                         zip(ENGINES, rng.integers(0, 3, len(ENGINES))))
        if rng.random() < 0.5 else (),
    )
    batches = []
    t = 0.0
    for _ in range(int(rng.integers(1, 5))):
        n = int(rng.integers(1, 33))
        t += float(rng.integers(0, 200))
        batches.append((
            (t + rng.integers(0, 50, n).astype(np.float64)).tolist(),
            [ENGINES[int(i)] for i in rng.integers(0, n_eng, n)],
            ["read" if b else "write" for b in rng.integers(0, 2, n)],
            [int(a) for a in rng.integers(0, 1 << 24, n)],
            [int(b) for b in rng.integers(1, 1 << 16, n)],
            ["" if b else "tile" for b in rng.integers(0, 2, n)],
        ))
    return cfg, batches


def _txs(spec):
    times, engines, kinds, addrs, nbs, tags = spec
    return [Transaction(t, e, k, a, nb, tg) for t, e, k, a, nb, tg in
            zip(times, engines, kinds, addrs, nbs, tags)]


def _batch(spec):
    times, engines, kinds, addrs, nbs, tags = spec
    rec = np.zeros(len(times), dtype=BURST_DTYPE)
    rec["time"] = times
    rec["addr"] = addrs
    rec["nbytes"] = nbs
    return BurstBatch(rec, list(engines), list(kinds), list(tags))


def _assert_identical(pair_a, pair_b):
    """Full observable equality of two (LinkModel, TransactionLog) runs:
    trace bytes, profiling-only columns, link statistics, arbiter state
    (rr pointer, horizons, RNG stream position)."""
    (lm_a, log_a), (lm_b, log_b) = pair_a, pair_b
    assert log_a.canonical() == log_b.canonical()
    assert log_a.digest() == log_b.digest()
    # dos/fault_delay are profiling attribution — never rendered, so
    # canonical equality alone would not catch a divergence here
    assert ([(t.dos, t.fault_delay) for t in log_a.txs]
            == [(t.dos, t.fault_delay) for t in log_b.txs])
    ra, rb = lm_a.result(), lm_b.result()
    assert ra.makespan == rb.makespan
    assert ra.per_engine_stall == rb.per_engine_stall
    assert ra.per_engine_busy == rb.per_engine_busy
    assert ra.link_utilization == rb.link_utilization
    assert ra.summary() == rb.summary()
    sa, sb = lm_a.get_state(), lm_b.get_state()
    assert sa["rng"] == sb["rng"], "RNG stream positions diverged"
    assert {k: v for k, v in sa.items() if k != "rng"} \
        == {k: v for k, v in sb.items() if k != "rng"}


def _run_three_ways(cfg, batches):
    runs = []
    for submit in ("scalar", "object", "batch"):
        lm, log = LinkModel(cfg), TransactionLog()
        for spec in batches:
            if submit == "scalar":
                lm._submit_scalar(_txs(spec), log)
            elif submit == "object":
                lm.submit(_txs(spec), log)
            else:
                lm.submit_batch(_batch(spec), log)
        runs.append((lm, log))
    return runs


# ------------------------------------------------------------ differential

def test_differential_random_cases():
    """200 seeded random cases: the two vectorized paths are bit-exact
    against the scalar reference in every observable."""
    for seed in range(200):
        cfg, batches = _random_case(np.random.default_rng(seed))
        scalar, objs, batch = _run_three_ways(cfg, batches)
        _assert_identical(scalar, objs)
        _assert_identical(scalar, batch)


def test_differential_single_engine_rr_pointer():
    """A single-engine batch still advances the round-robin pointer once
    per grant (the scalar loop's bookkeeping), so a later contended batch
    arbitrates identically no matter which path ran first."""
    cfg = CongestionConfig(dos_prob=0.0, seed=1)
    solo = ([0.0] * 7, ["dma_a"] * 7, ["read"] * 7, list(range(7)),
            [64] * 7, [""] * 7)
    contended = ([0.0] * 6, ["dma_a", "dma_b", "host"] * 2, ["read"] * 6,
                 list(range(6)), [64] * 6, [""] * 6)
    scalar, objs, batch = _run_three_ways(cfg, [solo, contended])
    assert scalar[0]._rr == objs[0]._rr == batch[0]._rr
    _assert_identical(scalar, objs)
    _assert_identical(scalar, batch)


def test_differential_priority_contention():
    """Priorities + heavy multi-engine contention exercise the closed-form
    phase computation of the grant order."""
    cfg = CongestionConfig(dos_prob=0.3, seed=9,
                           priorities=(("dma_a", 2), ("host", 1)))
    rng = np.random.default_rng(123)
    batches = []
    for _ in range(6):
        n = 24
        batches.append((
            [0.0] * n,
            [ENGINES[int(i)] for i in rng.integers(0, 4, n)],
            ["read"] * n,
            [int(a) for a in rng.integers(0, 1 << 20, n)],
            [int(b) for b in rng.integers(1, 8192, n)],
            [""] * n,
        ))
    scalar, objs, batch = _run_three_ways(cfg, batches)
    _assert_identical(scalar, objs)
    _assert_identical(scalar, batch)


def test_differential_fault_perturbation():
    """Same-seeded fault plans perturb the object list and the column
    batch draw-for-draw identically: same audit lines, same injected
    events, same post-arbitration trace, same plan RNG position."""
    rates = {"dma_reorder": 0.6, "dma_split": 0.6, "dma_delay": 0.6}
    for seed in range(60):
        cfg, batches = _random_case(np.random.default_rng(1000 + seed))
        plan_o = FaultPlan(seed=seed, rates=rates)
        plan_b = FaultPlan(seed=seed, rates=rates)
        lm_o, log_o = LinkModel(cfg), TransactionLog()
        lm_b, log_b = LinkModel(cfg), TransactionLog()
        for spec in batches:
            txs = plan_o.perturb_bursts(_txs(spec), log_o)
            lm_o._submit_scalar(txs, log_o)
            batch = _batch(spec)
            plan_b.perturb_batch(batch, log_b)
            lm_b.submit_batch(batch, log_b)
        assert log_o.faults == log_b.faults
        assert plan_o.events == plan_b.events
        assert (plan_o.rng.bit_generator.state
                == plan_b.rng.bit_generator.state)
        _assert_identical((lm_o, log_o), (lm_b, log_b))


if HAVE_HYPOTHESIS:
    @st.composite
    def _cases(draw):
        return _random_case(
            np.random.default_rng(draw(st.integers(0, 2 ** 31 - 1))))

    @given(_cases())
    @settings(max_examples=60, deadline=None)
    def test_differential_property(case):
        cfg, batches = case
        scalar, objs, batch = _run_three_ways(cfg, batches)
        _assert_identical(scalar, objs)
        _assert_identical(scalar, batch)
else:
    @pytest.mark.skip(reason="hypothesis not installed; space covered by "
                             "the 200 seeded random cases")
    def test_differential_property():
        pass


# ------------------------------------------------------------- lazy digest

def _eager_digest(log):
    h = hashlib.sha256()
    for line in log.canonical():
        h.update(line.encode())
        h.update(b"\n")
    return h.hexdigest()


def _seeded_log():
    log = TransactionLog()
    log.extend(_txs(_random_case(np.random.default_rng(7))[1][0]))
    return log


def test_digest_invalidates_on_every_mutation_channel():
    log = _seeded_log()
    seen = {log.digest()}
    log.log(Transaction(1.0, "dma_a", "read", 0x10, 64, stall=1.0,
                        complete=2.0))
    seen.add(log.digest())
    log.extend([Transaction(2.0, "host", "write", 0x20, 32, complete=3.0)])
    seen.add(log.digest())
    log.log_batch(_batch(_random_case(np.random.default_rng(8))[1][0]))
    seen.add(log.digest())
    log.violation("late completion")
    seen.add(log.digest())
    log.fault("dma_delay injected")
    seen.add(log.digest())
    assert len(seen) == 6, "every mutation channel must change the digest"
    for d in seen:
        assert len(d) == 64


def test_digest_matches_eager_recompute():
    """The incremental hash is byte-for-byte the pre-vectorization eager
    digest, through any interleaving of object and batch logging."""
    log = _seeded_log()
    assert log.digest() == _eager_digest(log)
    log.log_batch(_batch(_random_case(np.random.default_rng(9))[1][0]))
    log.violation("v1")
    assert log.digest() == _eager_digest(log)
    log.log(Transaction(5.0, "csr", "read", 0x0, 4, complete=6.0))
    log.fault("f1")
    log.log_batch(_batch(_random_case(np.random.default_rng(10))[1][0]))
    assert log.digest() == _eager_digest(log)


def test_digest_memoized_between_mutations():
    log = _seeded_log()
    d1 = log.digest()
    assert log.digest() is d1, "unchanged log must return the memo"
    log.fault("poke")
    assert log.digest() is not d1


def test_digest_lazy_batches_do_not_materialize():
    """digest()/canonical() render straight from the columns — the cheap
    path must not build Transaction objects as a side effect."""
    log = TransactionLog()
    batch = _batch(_random_case(np.random.default_rng(11))[1][0])
    batch.rec["complete"] = batch.rec["time"] + 1.0
    log.log_batch(batch)
    assert log.digest() == _eager_digest(log) != hashlib.sha256().hexdigest()
    assert batch._txs is None, "digest must not materialize lazy segments"
    assert log.n_txs == len(batch)


def test_set_state_restores_digest_identity():
    """Checkpoint/restore round-trips the digest — including restoring
    into a log whose later history diverged, and into a fresh log."""
    log = _seeded_log()
    log.violation("v")
    snap_digest = log.digest()
    state = log.get_state()
    log.log_batch(_batch(_random_case(np.random.default_rng(12))[1][0]))
    log.fault("later fault")
    assert log.digest() != snap_digest
    log.set_state(state)
    assert log.digest() == snap_digest
    fresh = TransactionLog()
    fresh.set_state(state)
    assert fresh.digest() == snap_digest
    assert fresh.canonical() == log.canonical()


def test_batch_timeline_log_aliasing():
    """A batch submitted through the link materializes once: the link
    timeline and the log share the same Transaction objects, exactly as
    object-path submission does."""
    cfg, batches = _random_case(np.random.default_rng(13))
    lm, log = LinkModel(cfg), TransactionLog()
    for spec in batches:
        lm.submit_batch(_batch(spec), log)
    assert len(lm.timeline) == len(log.txs)
    assert all(a is b for a, b in zip(lm.timeline, log.txs))


# ---------------------------------------------------------------- simspeed

@pytest.mark.slow
def test_simspeed_floor():
    """The committed acceptance floor: the vectorized pipeline clears
    >= 5x scenarios/sec on the 200-launch fuzz workload (arbitration +
    per-launch digest checkpoints) vs the scalar reference, and the two
    pipelines' checkpoint digests are identical (asserted inside
    measure())."""
    from benchmarks.bench_simspeed import (SPEEDUP_FLOOR, capture_workload,
                                           measure)
    specs = capture_workload()
    m = measure(specs, reps=2)
    assert m["txs"] > 10_000, "workload capture lost the fuzz stream"
    assert m["speedup"] >= SPEEDUP_FLOOR, m
    assert m["arb_speedup"] > 1.0, m
