"""CoVerifySession: batched sweep execution, cross-backend grouping,
divergence localization, congestion-aware cells, per-tile kernel burst
lists (core/scheduler.py; paper Fig. 5 batched lane)."""
import numpy as np
import pytest

from repro.core import CongestionConfig, CoVerifySession
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.mamba2_scan import ops as ssd_ops
from repro.kernels.rwkv6_wkv import ops as wkv_ops
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_firmware)

_firmware = matmul_firmware


def _session(bug: bool = False, congestion=None) -> CoVerifySession:
    table = matmul_backends(jit=False)

    def interp(a, b):
        out = np.array(table["interpret"](a, b))
        if bug:
            out[1, 2] += 1.0                  # injected hardware bug
        return out

    sess = CoVerifySession(_firmware, congestion=congestion)
    sess.register_op("mm", oracle=table["oracle"], interpret=interp)
    return sess


def test_sweep_runs_all_cells_and_groups():
    sess = _session()
    cells = sess.add_sweep("mm", ("oracle", "interpret"),
                           [{"size": 32}, {"size": 64}])
    assert len(cells) == 4
    report = sess.run(max_workers=2)
    assert report.passed
    assert len(report.cells) == 4
    assert len(report.equivalence) == 2       # one group per config
    assert all(r.seconds > 0 for r in report.cells)
    assert report.summary()["cells"] == 4
    assert len(report.to_rows()) == 5         # header + 4 cells


def test_sweep_localizes_divergence_per_group():
    sess = _session(bug=True)
    sess.add_sweep("mm", ("oracle", "interpret"), [{"size": 32}])
    report = sess.run()
    assert not report.passed
    (eq,) = report.equivalence.values()
    d = eq.divergences[0]
    assert d.leaf_path == "c" and d.index == (1, 2)
    assert abs(d.max_abs_err - 1.0) < 1e-3


def test_sweep_cells_carry_online_congestion():
    cong = CongestionConfig(seed=3, priorities=(("dma_a", 1),))
    sess = _session(congestion=cong)
    sess.add_sweep("mm", ("oracle",), [{"size": 64}])
    report = sess.run()
    (r,) = report.cells
    assert r.congestion is not None and r.congestion.makespan > 0
    assert sum(r.congestion.per_engine_stall.values()) > 0
    assert r.bridge_time >= r.congestion.makespan


def test_config_key_groups_equal_ndarray_configs():
    """Regression: _config_key used repr(v), so equal-valued numpy-array
    configs landed in different equivalence groups and the cross-backend
    diff was silently skipped.  Structural hashing must group them."""
    from repro.core.scheduler import _config_key

    def firmware(fb, op, backend, *, scale):
        fb.mem.alloc("c", scale.shape, np.float32)
        fb.launch(op, backend, [], ["c"], scale=scale)

    table = matmul_backends(jit=False)

    def interp(scale):
        return np.asarray(table["oracle"](scale, np.eye(2,
                                                        dtype=np.float32)))
    sess = CoVerifySession(firmware)
    sess.register_op("sc", oracle=lambda scale: scale @ np.eye(
        2, dtype=np.float32), interpret=interp)
    # two *distinct but equal* ndarray objects, one per backend
    sess.add_cell("sc", "oracle",
                  {"scale": np.ones((2, 2), np.float32)})
    sess.add_cell("sc", "interpret",
                  {"scale": np.ones((2, 2), np.float32)})
    report = sess.run(max_workers=1)
    # one group containing BOTH backends => the diff actually ran
    assert len(report.equivalence) == 1
    (eq,) = report.equivalence.values()
    assert set(eq.backends) == {"oracle", "interpret"}
    # and unequal arrays must NOT collide (repr truncation used to)
    big_a = {"scale": np.arange(4000, dtype=np.float32)}
    big_b = {"scale": np.arange(4000, dtype=np.float32)}
    big_b["scale"][2000] += 1.0          # differs deep inside the "..."
    assert _config_key(big_a) != _config_key(big_b)
    assert _config_key(big_a) == _config_key(
        {"scale": np.arange(4000, dtype=np.float32)})


def test_config_key_groups_equal_dataclass_configs():
    import dataclasses

    from repro.core.scheduler import _config_key

    @dataclasses.dataclass
    class Tile:
        bm: int
        weights: np.ndarray

    a = {"tile": Tile(32, np.ones(3, np.float32))}
    b = {"tile": Tile(32, np.ones(3, np.float32))}
    c = {"tile": Tile(32, np.zeros(3, np.float32))}
    assert _config_key(a) == _config_key(b)
    assert _config_key(a) != _config_key(c)
    # containers recurse
    assert _config_key({"x": [np.ones(2), 3]}) == \
        _config_key({"x": [np.ones(2), 3]})
    # numpy scalars hash by bit pattern: NaN configs must still group
    assert _config_key({"x": np.float32("nan")}) == \
        _config_key({"x": np.float32("nan")})
    assert _config_key({"x": np.float32(1)}) != \
        _config_key({"x": np.float64(1)})


def test_cell_error_is_contained():
    sess = _session()
    sess.register_op("boom", oracle=lambda *a: (_ for _ in ()).throw(
        RuntimeError("dead op")))
    sess.add_cell("mm", "oracle", {"size": 32})
    sess.add_cell("boom", "oracle", {"size": 32})
    report = sess.run(max_workers=2)
    assert not report.passed
    errs = [r for r in report.cells if r.error]
    assert len(errs) == 1 and "dead op" in errs[0].error


def test_add_cell_rejects_unknown_op():
    sess = _session()
    with pytest.raises(KeyError):
        sess.add_cell("nope", "oracle")


def test_sequential_and_batched_agree():
    sess = _session()
    sess.add_sweep("mm", ("oracle", "interpret"),
                   [{"size": 32}, {"size": 64}])
    seq = sess.run(max_workers=1)
    bat = sess.run(max_workers=4)
    assert seq.passed and bat.passed
    for a, b in zip(seq.cells, bat.cells):
        assert a.cell.label == b.cell.label
        for name in a.outputs:
            np.testing.assert_array_equal(a.outputs[name], b.outputs[name])


def test_report_is_independent_of_thread_completion_order():
    """Satellite regression: on a seeded 20-cell sweep (faults + online
    congestion + a coverage sink + one planted divergence), report rows,
    equivalence verdicts, divergence attachments, and the merged coverage
    model must be byte-identical between ``max_workers=1`` and
    ``max_workers=8`` — thread completion order may change wall-clock
    only, never any reported artifact (the run-farm digests depend on
    this)."""
    from repro.core import CoverageModel
    from repro.core.fuzz import FaultPlan

    configs = ([{"size": 32, "tile": t} for t in (4, 8, 16, 32)]
               + [{"size": 64, "tile": t} for t in (8, 16, 32, 64)]
               + [{"size": 96, "tile": 32}, {"size": 96, "tile": 48}])

    def run(max_workers):
        table = matmul_backends(jit=False)

        def interp(a, b):
            out = np.array(table["interpret"](a, b))
            if out.shape[0] == 96:
                out[1, 2] += 1.0          # planted divergence, size-96 only
            return out

        cov = CoverageModel()
        sess = CoVerifySession(_firmware,
                               congestion=CongestionConfig(seed=7),
                               fault_plan=FaultPlan(seed=11),
                               coverage=cov)
        sess.register_op("mm", oracle=table["oracle"], interpret=interp)
        cells = sess.add_sweep("mm", ("oracle", "interpret"), configs)
        assert len(cells) == 20
        return sess.run(max_workers=max_workers), cov

    seq, cov_seq = run(1)
    par, cov_par = run(8)
    # modeled rows: byte-identical once the wall-clock column is masked
    assert seq.to_rows(wall=False) == par.to_rows(wall=False)
    # equivalence verdicts + localized divergence attachments
    s, p = seq.summary(), par.summary()
    for k in ("cells", "groups", "passed", "failures", "divergences"):
        assert s[k] == p[k], k
    assert not seq.passed and len(s["divergences"]) == 2
    # per-cell fault traces fork from the cell label, not pool order
    assert [[e.key() for e in r.faults] for r in seq.cells] == \
        [[e.key() for e in r.faults] for r in par.cells]
    # merged functional coverage: exact counts, not just covered-bins
    assert cov_seq.counts == cov_par.counts
    assert cov_seq.covered("burst_size"), cov_seq.holes("burst_size")
    assert sum(cov_seq.counts["congestion"].values()) > 0
    assert sum(cov_seq.counts["fault_kind"].values()) > 0
    assert seq.coverage is cov_seq and par.coverage is cov_par


# ------------------------------------------------- per-tile burst lists
def _check_bursts(txs, n_engines_min=2):
    assert txs, "burst list is empty"
    assert all(nb > 0 and addr >= 0 for _, _, addr, nb in txs)
    assert len({e for e, _, _, _ in txs}) >= n_engines_min
    kinds = {k for _, k, _, _ in txs}
    assert kinds <= {"read", "write"} and "read" in kinds


def test_flash_burst_list_per_tile():
    txs = fa_ops.transactions(2, 4, 256, 256, 64, bq=128, bk=128,
                              causal=True, dtype_bytes=2)
    _check_bursts(txs, 4)
    # causal skips the upper-triangular KV tiles: fewer k reads than full
    full = fa_ops.transactions(2, 4, 256, 256, 64, bq=128, bk=128,
                               causal=False, dtype_bytes=2)
    n_k = sum(1 for e, _, _, _ in txs if e == "dma_k")
    n_k_full = sum(1 for e, _, _, _ in full if e == "dma_k")
    assert n_k < n_k_full
    # per-tile: every burst is one tile, not a whole buffer
    assert max(nb for _, _, _, nb in txs) == 128 * 64 * 2


def test_ssd_burst_list_per_tile():
    txs = ssd_ops.transactions(2, 256, 16, 32, 64, chunk=128, hb=8)
    _check_bursts(txs, 4)
    # state writes once per (batch, head-group), not per chunk
    n_state = sum(1 for e, _, _, _ in txs if e == "dma_state")
    assert n_state == 2 * (16 // 8)


def test_wkv_burst_list_per_tile():
    txs = wkv_ops.transactions(2, 64, 16, 32, chunk=16, hb=8)
    _check_bursts(txs, 4)
    n_state = sum(1 for e, _, _, _ in txs if e == "dma_state")
    assert n_state == 2 * (16 // 8)
