"""lax work-list flash attention vs naive oracle: fwd + grad sweeps."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (AttnSpec, decode_attention,
                                    flash_attention, naive_attention)

KEY = jax.random.PRNGKey(0)


def _qkv(B, S, H, KH, D, dtype=jnp.float32):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KH, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, KH, D), dtype)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    return q, k, v, pos


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("skip", [False, True])
def test_flash_matches_naive(causal, window, skip):
    B, S, H, KH, D = 2, 64, 4, 2, 16
    q, k, v, pos = _qkv(B, S, H, KH, D)
    spec = AttnSpec(causal=causal, window=window, q_chunk=16, kv_chunk=16,
                    skip_masked_tiles=skip, positions_are_arange=True)
    ref = naive_attention(q, k, v, spec=spec, q_pos=pos, kv_pos=pos)
    got = flash_attention(spec, q, k, v, pos, pos)
    assert float(jnp.max(jnp.abs(ref - got))) < 2e-5

    g_ref = jax.grad(lambda a, b, c: (naive_attention(
        a, b, c, spec=spec, q_pos=pos, kv_pos=pos) ** 2).sum(),
        argnums=(0, 1, 2))(q, k, v)
    g_got = jax.grad(lambda a, b, c: (flash_attention(
        spec, a, b, c, pos, pos) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_got):
        assert float(jnp.max(jnp.abs(a - b))) < 2e-4


@pytest.mark.parametrize("gqa", [(4, 4), (8, 2), (4, 1)])
def test_flash_gqa_variants(gqa):
    H, KH = gqa
    B, S, D = 1, 32, 8
    q, k, v, pos = _qkv(B, S, H, KH, D)
    spec = AttnSpec(causal=True, q_chunk=8, kv_chunk=8,
                    positions_are_arange=True)
    ref = naive_attention(q, k, v, spec=spec, q_pos=pos, kv_pos=pos)
    got = flash_attention(spec, q, k, v, pos, pos)
    assert float(jnp.max(jnp.abs(ref - got))) < 2e-5


def test_decode_matches_naive_with_invalid_slots():
    B, S, H, KH, D = 2, 64, 4, 2, 16
    q, k, v, _ = _qkv(B, S, H, KH, D)
    kv_pos = jnp.where(jnp.arange(S)[None, :] < 40,
                       jnp.arange(S)[None, :], -1)
    kv_pos = jnp.broadcast_to(kv_pos, (B, S))
    qp = jnp.full((B, 1), 39)
    spec = AttnSpec(causal=True)
    ref = naive_attention(q[:, :1], k, v, spec=spec, q_pos=qp, kv_pos=kv_pos)
    got = decode_attention(q[:, :1], k, v, q_pos=qp, kv_pos=kv_pos)
    assert float(jnp.max(jnp.abs(ref - got))) < 2e-5


def test_worklist_skip_count():
    from repro.models.attention import build_worklist
    spec = AttnSpec(causal=True, q_chunk=16, kv_chunk=16,
                    skip_masked_tiles=True, positions_are_arange=True)
    wl = build_worklist(spec, 8, 8)
    assert len(wl) == 8 * 9 // 2            # triangle
    spec_full = AttnSpec(causal=True, q_chunk=16, kv_chunk=16)
    assert len(build_worklist(spec_full, 8, 8)) == 64
