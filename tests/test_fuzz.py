"""Randomized fault-injection harness (core/fuzz.py): seeded
reproducibility, per-layer audit accounting, differential checking under
faults, sweep-axis wiring, and trace shrinking."""
import numpy as np
import pytest

from repro.core import (CongestionConfig, CoVerifySession, FaultPlan,
                        FireBridge, ProtocolFuzzer)
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_firmware)


def test_same_seed_identical_fault_trace_and_log():
    """Same seed => identical fault trace, violations, and TransactionLog
    digest across independent fuzzer instances."""
    r1 = ProtocolFuzzer(seed=7, layers=("bridge", "registers")).run(12)
    r2 = ProtocolFuzzer(seed=7, layers=("bridge", "registers")).run(12)
    assert r1.passed and r2.passed
    assert r1.digest == r2.digest
    for a, b in zip(r1.results, r2.results):
        assert [e.key() for e in a.faults] == [e.key() for e in b.faults]
        assert a.violations == b.violations


def test_different_seed_different_trace():
    r1 = ProtocolFuzzer(seed=1, layers=("registers",)).run(10)
    r2 = ProtocolFuzzer(seed=2, layers=("registers",)).run(10)
    assert r1.digest != r2.digest


def test_bridge_faults_injected_audited_and_healed():
    """Bridge scenarios inject DMA/bit-flip faults, every one lands in the
    fault audit, and the three backends still agree on final DDR state."""
    r = ProtocolFuzzer(seed=0, layers=("bridge",)).run(6)
    assert r.passed
    kinds = r.fault_counts()
    assert kinds.get("bitflip_read", 0) > 0
    assert kinds.get("dma_reorder", 0) > 0
    assert kinds.get("dma_delay", 0) > 0
    assert kinds.get("dma_split", 0) > 0
    assert kinds.get("congestion_perturb", 0) > 0


def test_register_storm_matches_shadow_model():
    """Illegal-access storms, W1C edges, doorbell-while-busy races and
    poll timeouts: the device must match the golden shadow on every read
    value and every violation message."""
    r = ProtocolFuzzer(seed=11, layers=("registers",)).run(25)
    assert r.passed
    kinds = r.fault_counts()
    for k in ("illegal_read", "illegal_write", "ro_write"):
        assert kinds.get(k, 0) > 0, f"storm never exercised {k}"
    assert kinds.get("doorbell_busy", 0) > 0
    assert kinds.get("poll_timeout", 0) > 0
    # every injected violation is audited: scenario counts line up
    for res in r.results:
        predicted = [e for e in res.faults
                     if e.kind in ("illegal_read", "illegal_write",
                                   "ro_write", "doorbell_busy",
                                   "poll_timeout")]
        assert len(res.violations) == len(predicted)


def test_fuzz_detects_planted_backend_bug_and_shrinks():
    """A buggy interpret backend fails the differential check, and shrink
    reduces the scenario to its shortest failing op prefix."""
    from repro.core.fuzz import planted_bug_table
    fz = ProtocolFuzzer(seed=0, layers=("bridge",),
                        mm_table=planted_bug_table())
    report = fz.run(3)
    assert not report.passed
    fail = report.failures()[0]
    assert any("divergence" in f for f in fail.failures)
    scn = fz.scenario(fail.index)
    sub, res = fz.shrink(scn)
    assert not res.ok
    assert len(sub.ops) == 1          # one launch suffices to reproduce
    assert sub.ops == scn.ops[:len(sub.ops)]


def test_fault_plan_fork_is_stateless_and_deterministic():
    plan = FaultPlan(seed=42)
    a1 = plan.fork("cell0").rng.integers(0, 1 << 30, 8)
    # consuming parent entropy must not change what a fork derives
    plan.rng.random(100)
    a2 = plan.fork("cell0").rng.integers(0, 1 << 30, 8)
    b = plan.fork("cell1").rng.integers(0, 1 << 30, 8)
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(a1, b)


def test_bitflip_read_heals_and_audits():
    """A forced bit flip on dev_read is healed by the audited retry: the
    caller sees clean data, the log sees the fault + the retry burst."""
    plan = FaultPlan(seed=0, rates={"bitflip_read": 1.0, "dma_delay": 0.0,
                                    "dma_reorder": 0.0, "dma_split": 0.0,
                                    "congestion_perturb": 0.0})
    fb = FireBridge(fault_plan=plan)
    fb.mem.alloc("x", (16,), np.float32)
    fb.mem.host_write("x", np.arange(16, dtype=np.float32))
    data = fb.mem.dev_read("x")
    np.testing.assert_array_equal(data, np.arange(16, dtype=np.float32))
    assert len(fb.log.faults) == 1 and "bitflip" in fb.log.faults[0]
    assert len(fb.log.txs) == 2       # original burst + audited retry
    assert [e.kind for e in plan.events] == ["bitflip_read"]


def test_scheduler_fault_plan_sweep_axis():
    """CoVerifySession cells run fault-injected when the session carries a
    FaultPlan; faults are audited per cell and equivalence still holds."""
    rates = {"bitflip_read": 1.0, "dma_delay": 1.0, "dma_reorder": 1.0,
             "dma_split": 1.0, "congestion_perturb": 1.0}
    sess = CoVerifySession(matmul_firmware,
                           congestion=CongestionConfig(seed=1),
                           fault_plan=FaultPlan(seed=5, rates=rates))
    sess.register_op("mm", **matmul_backends(jit=False))
    sess.add_sweep("mm", ("oracle", "interpret"), [{"size": 32}])
    report = sess.run(max_workers=2)
    assert report.passed               # faults perturb timing, not function
    assert all(r.faults for r in report.cells)
    rerun = sess.run(max_workers=2)
    for a, b in zip(report.cells, rerun.cells):
        assert [e.key() for e in a.faults] == [e.key() for e in b.faults]


def test_bench_fuzz_quick_mode():
    """The throughput benchmark's quick mode stays smoke-lane fast and
    reports passing scenario rows."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.bench_fuzz import run
    rows = run(quick=True)
    assert rows[0].startswith("case,layer")
    assert len(rows) >= 3
    assert all(r.endswith("True") for r in rows[1:])


@pytest.fixture(scope="module")
def serving_fuzzer():
    return ProtocolFuzzer(seed=9, layers=("serving",))


def test_serving_fuzz_randomized_submit_streams(serving_fuzzer):
    """Randomized submit order, duplicate ids, zero/max max_new_tokens and
    pad-straddling prompts: every accepted request emits exactly its token
    budget, every rejection is a predicted violation, same seed => same
    transaction log."""
    r1 = serving_fuzzer.run(8)
    assert r1.passed, r1.summary()["failures"]
    kinds = r1.fault_counts()
    assert kinds.get("zero_maxnew", 0) > 0
    assert kinds.get("dup_rid", 0) > 0
    assert kinds.get("bad_len", 0) > 0
    assert kinds.get("over_budget", 0) > 0
    assert kinds.get("max_maxnew", 0) > 0
    r2 = serving_fuzzer.run(8)
    assert r1.digest == r2.digest
