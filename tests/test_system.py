"""End-to-end behaviour tests: train-loss-decreases, full co-verification
flow on the CNN driver, dry-run cell artifacts sanity."""
import json
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models.transformer import RunFlags
from repro.runtime import Trainer, TrainerConfig

FLAGS = RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16)


@pytest.mark.slow
def test_training_loss_decreases(tmp_path):
    cfg = smoke(get_config("llama3.2-1b"))
    tcfg = TrainerConfig(seq_len=128, global_batch=8, steps=30,
                         ckpt_every=50, ckpt_dir=str(tmp_path / "ck"))
    from repro.optim.adamw import AdamWConfig
    opt = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=200)
    tr = Trainer(cfg, tcfg, FLAGS, opt_cfg=opt)
    tr.train()
    losses = [r["loss"] for r in tr.metrics_log]
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, f"loss did not decrease: {first} -> {last}"
    assert all(np.isfinite(l) for l in losses)


def test_cnn_coverification_small():
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.cnn_driver import run_cnn, small_cnn_specs
    fb_o = run_cnn(small_cnn_specs(8), backend="oracle")
    fb_i = run_cnn(small_cnn_specs(8), backend="interpret")
    # equivalence of final ping-pong buffers between backends
    for name in ("act_0", "act_1"):
        a = fb_o.mem.buffers[name].array
        b = fb_i.mem.buffers[name].array
        assert np.allclose(a, b, atol=1e-3)
    # identical transaction streams regardless of backend (by construction)
    assert len(fb_o.log.txs) == len(fb_i.log.txs)


def test_dryrun_artifacts_complete():
    """The committed dry-run matrix covers all 31 cells x 2 meshes and every
    cell reports fitting memory + nonzero flops."""
    art = Path(__file__).resolve().parents[1] / "benchmarks" / "artifacts" \
        / "dryrun"
    recs = [json.loads(f.read_text())
            for f in art.glob("*__baseline.json")]
    if not recs:   # artifacts not generated in this checkout
        import pytest
        pytest.skip("dry-run artifacts not present; run launch/dryrun")
    assert len(recs) == 62
    hbm = 16e9
    for r in recs:
        ma = r["memory_analysis"]
        used = ma.get("argument_size_in_bytes", 0) + \
            ma.get("temp_size_in_bytes", 0)
        # subtract XLA-CPU bf16->f32 operand-conversion buffers (absent on
        # the TPU target; see EXPERIMENTS.md SS-Dry-run caveat)
        used -= ma.get("cpu_f32_convert_artifact_bytes", 0)
        assert used < hbm, f"{r['arch']}/{r['shape']}/{r['mesh']}: " \
            f"{used/1e9:.1f}GB exceeds HBM (TPU-corrected)"
        assert r["profile"]["hlo_flops_per_dev"] > 0
        if r["kind"] == "train":
            assert r["profile"]["collective_bytes_per_dev"] > 0
