"""Golden-trace regression tests: canonical TransactionLog renderings for
seven fixed-seed runs — a single-device launch, a 4-device fabric
all_reduce, a 3-device batched-leg fabric launch, an 8-device 2D-torus
ROUTED run (multi-hop journeys + hierarchical all_reduce), a
fault-plan-active fuzz scenario, a cluster-serving storm, and an
open-loop continuous-batching serving run on a 4-device ring-routed
cluster under KV-pool admission control — diffed line-by-line against
committed traces (tests/golden/).

Every golden run is built through a ``DebugSession`` recording
(core/replay.py), so a mismatch is explained with TIME TRAVEL instead of
a bare line diff: the test maps the first divergent transaction to its
owning timeline op, replays only the surrounding window from the nearest
checkpoint, and prints the replayed transactions plus the device state
right after the divergent op — the co-verification analogue of dropping
a waveform cursor on the first diverging signal with the testbench
paused there.  The same report is saved as a debug bundle under
``$REPLAY_ARTIFACT_DIR`` (default tests/artifacts/) for CI to upload.

Regenerate after an *intentional* timing-model change with:

    PYTHONPATH=src python tests/test_golden_traces.py --regen
"""
import dataclasses
import functools
import os
import sys
from pathlib import Path
from typing import List, Optional

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (CongestionConfig, FabricCluster, FireBridge,
                        ProtocolFuzzer)
from repro.core import replay as rp
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_firmware)

GOLDEN = Path(__file__).resolve().parent / "golden"
ARTIFACTS = Path(os.environ.get("REPLAY_ARTIFACT_DIR",
                                Path(__file__).resolve().parent /
                                "artifacts"))

# Frozen stimulus parameters: changing ANY of these invalidates the traces.
SINGLE_CONG = CongestionConfig(dos_prob=0.05, seed=7)
FABRIC_LINK = CongestionConfig(link_bytes_per_cycle=64.0, base_latency=100.0,
                               max_burst_bytes=4096, dos_prob=0.05, seed=11)
FUZZ_SEED = 5                   # faulty-fuzz trace: ProtocolFuzzer seed
STORM_SEED = 0                  # cluster storm prompt seed
OPEN_LOOP_SEED = 23             # open-loop serving arrival + fault seed


@dataclasses.dataclass
class GoldenRun:
    """One recorded golden run: the rendered trace plus everything needed
    to time-travel around a divergence."""
    session: rp.DebugSession
    recording: rp.Recording
    lines: List[str]            # the trace-file rendering (materialized)
    section_lens: List[int]     # canonical line count per log section
    tx_lens: List[int]          # transaction count per log section
    headers: List[Optional[str]]

    @classmethod
    def render(cls, session: rp.DebugSession, recording: rp.Recording,
               headers: List[Optional[str]]) -> "GoldenRun":
        logs = rp.target_logs(recording.target)
        lines: List[str] = []
        section_lens, tx_lens = [], []
        for h, log in zip(headers, logs):
            sec = log.canonical()
            if h:
                lines.append(h)
            lines += sec
            section_lens.append(len(sec))
            tx_lens.append(len(log.txs))
        return cls(session, recording, lines, section_lens, tx_lens,
                   headers)

    def locate(self, line_index: int):
        """Map a global trace-line index to (log_index, tx_index) — or
        (log_index, None) for a header / violation / fault line."""
        pos = 0
        for li, (h, n, ntx) in enumerate(zip(self.headers,
                                             self.section_lens,
                                             self.tx_lens)):
            if h:
                if line_index == pos:
                    return li, None
                pos += 1
            if line_index < pos + n:
                local = line_index - pos
                return li, (local if local < ntx else None)
            pos += n
        return len(self.headers) - 1, None


def single_device_run() -> GoldenRun:
    """Fixed-seed single-device matmul launch under online congestion."""
    def factory():
        fb = FireBridge(congestion=SINGLE_CONG)
        fb.register_op("mm", **matmul_backends(tile=16, jit=False))
        return fb

    sess = rp.DebugSession(factory, checkpoint_interval=3,
                           label="single_device_launch")
    rec = sess.record(lambda r: matmul_firmware(
        rp.RecordingBridge(r), "mm", "oracle", size=32, tile=16))
    return GoldenRun.render(sess, rec, [None])


def fabric_all_reduce_run() -> GoldenRun:
    """Fixed-seed 4-device ring all_reduce over the modeled fabric."""
    def factory():
        return FabricCluster(4, link_config=FABRIC_LINK)

    sess = rp.DebugSession(factory, checkpoint_interval=4,
                           label="fabric_all_reduce")

    def program(rec):
        for i in range(4):
            rec.do("dev_alloc", i, "grad", (16, 16), np.float32)
            rec.do("dev_host_write", i, "grad",
                   np.full((16, 16), float(i + 1), np.float32))
        rec.do("all_reduce", "grad", "sum")

    rec = sess.record(program)
    return GoldenRun.render(
        sess, rec, ["# fabric interconnect log"] +
        [f"# device {i} log" for i in range(4)])


def faulty_fuzz_run() -> GoldenRun:
    """Fixed-seed fault-plan-active bridge fuzz scenario (oracle backend):
    DMA delays/reorders/splits, healed bit flips, and a perturbed
    congestion link, all audited in the trace's fault channel."""
    fz = ProtocolFuzzer(seed=FUZZ_SEED, layers=("bridge",),
                        bridge_ops=(3, 4))
    scn = fz.scenario(0)
    sess, rec = fz._record_bridge_scenario(scn, "oracle",
                                           checkpoint_every=1)
    return GoldenRun.render(sess, rec, [None])


def fabric_batched_launch_run() -> GoldenRun:
    """Fixed-seed 3-device program pinning the batched same-launch
    fabric-leg path: every transfer's legs are built as per-link burst
    batches and issued per launch (core/fabric.py ``_issue_legs``), with
    DoS on the links and an active fault plan perturbing the batches.
    Covers contiguous (axis-0) and strided-run (axis-1) scatters, a
    broadcast, per-device launches under device-local congestion, a
    gather, a cross-device copy, and a replicated collect."""
    from repro.core.fuzz import FaultPlan

    def factory():
        fab = FabricCluster(3, congestion=SINGLE_CONG,
                            link_config=FABRIC_LINK,
                            fault_plan=FaultPlan(seed=13))
        fab.register_op("mm", **matmul_backends(tile=16, jit=False))
        return fab

    sess = rp.DebugSession(factory, checkpoint_interval=3,
                           label="fabric_batched_launch")

    def program(rec):
        rng = np.random.default_rng(21)
        act = rng.normal(size=(48, 48)).astype(np.float32)
        wts = rng.normal(size=(48, 48)).astype(np.float32)
        for name, arr in (("act", act), ("act2", act), ("wts", wts)):
            rec.do("host_alloc", name, arr.shape, np.float32)
            rec.do("host_write", name, arr)
        rec.do("scatter", "act", 0)       # contiguous per-shard runs
        rec.do("scatter", "act2", 1)      # strided inner-axis runs
        rec.do("broadcast", "wts")
        for i in range(3):
            rec.do("dev_alloc", i, "out", (16, 48), np.float32)
            rec.do("launch", i, "mm", "oracle", ("act", "wts"), ("out",),
                   {})
        rec.do("gather", "out", 0)
        rec.do("dev_copy", 0, 2, "act", "act_copy")
        rec.do("collect_replicated", "wts")

    rec = sess.record(program)
    return GoldenRun.render(
        sess, rec, ["# fabric interconnect log"] +
        [f"# device {i} log" for i in range(3)])


def fabric_torus_all_reduce_run() -> GoldenRun:
    """Fixed-seed 8-device 2D-torus run pinning the ROUTED fabric path:
    every transfer is a multi-hop journey (source leg, flit-framed
    credit-flow-controlled switch hops, destination leg) and all_reduce
    runs the hierarchical local/tree schedule, with DoS on every link
    (switch ports included, decorrelated seeds) and an active fault plan
    perturbing the hop batches.  Covers scatter/broadcast journeys from
    the host attachment, a multi-hop dev_copy, the hierarchical
    all_reduce, a gather, and a replicated collect."""
    from repro.core.fuzz import FaultPlan

    def factory():
        return FabricCluster(8, link_config=FABRIC_LINK,
                             fault_plan=FaultPlan(seed=13),
                             topology="torus2d")

    sess = rp.DebugSession(factory, checkpoint_interval=3,
                           label="fabric_torus_all_reduce")

    def program(rec):
        rng = np.random.default_rng(29)
        act = rng.normal(size=(32, 32)).astype(np.float32)
        rec.do("host_alloc", "act", act.shape, np.float32)
        rec.do("host_write", "act", act)
        rec.do("scatter", "act", 0)
        rec.do("host_alloc", "wts", (16, 16), np.float32)
        rec.do("host_write", "wts",
               rng.normal(size=(16, 16)).astype(np.float32))
        rec.do("broadcast", "wts")
        for i in range(8):
            rec.do("dev_alloc", i, "grad", (16, 16), np.float32)
            rec.do("dev_host_write", i, "grad",
                   np.full((16, 16), float(i + 1), np.float32))
        rec.do("all_reduce", "grad", "sum")
        rec.do("dev_copy", 0, 5, "grad", "grad_copy")  # x + y hops
        rec.do("gather", "act", 0)
        rec.do("collect_replicated", "wts")

    rec = sess.record(program)
    return GoldenRun.render(
        sess, rec, ["# fabric interconnect log"] +
        [f"# device {i} log" for i in range(8)])


def _storm_requests():
    rng = np.random.default_rng(STORM_SEED)
    return [(rid, [int(t) for t in rng.integers(0, 100, 6 + rid % 5)],
             2 + rid % 3) for rid in range(6)]


@functools.lru_cache(maxsize=1)
def _cluster_engine():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke
    from repro.models import init_params
    from repro.models.transformer import RunFlags
    from repro.serving.cluster import ClusterServingEngine
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return ClusterServingEngine(
        cfg, params, n_devices=2, max_slots=2, max_len=32, prompt_pad=8,
        flags=RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16))


def cluster_serving_storm_run() -> GoldenRun:
    """Fixed cluster-serving storm: 6 requests round-robined across 2
    device-local engines behind one CSR front-end, prompt/token DMA
    contending on the shared host channel.  Token VALUES never enter the
    trace (only burst metadata), so the trace is platform-independent."""
    clu = _cluster_engine()

    def factory():
        clu.reset(None)
        return clu

    sess = rp.DebugSession(factory, checkpoint_interval=0,
                           label="cluster_serving_storm")
    rec = rp.record_serving_storm(sess, _storm_requests())
    return GoldenRun.render(
        sess, rec, ["# cluster front log"] +
        [f"# engine {i} log" for i in range(clu.n)])


@functools.lru_cache(maxsize=1)
def _open_loop_cluster():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke
    from repro.models import init_params
    from repro.models.transformer import RunFlags
    from repro.serving.cluster import ClusterServingEngine
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return ClusterServingEngine(
        cfg, params, n_devices=4, max_slots=2, max_len=32, prompt_pad=8,
        flags=RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16),
        topology="ring", batching="continuous",
        kv_pages=3, kv_page_size=8)


def _open_loop_trace():
    # a burst of up to 8 lands ~2 requests per device, and every request
    # reserves >= 2 of its engine's 3 pages — the second concurrent
    # request per engine must defer, so queueing delay enters the trace
    from repro.serving.arrivals import bursty_trace
    return bursty_trace(OPEN_LOOP_SEED, n_requests=10, burst_size=8,
                        gap_in_burst=10.0, gap_between=900.0,
                        prompt_lens=(3, 10), max_new=(1, 4))


def cluster_open_loop_serving_run() -> GoldenRun:
    """Fixed open-loop serving run: a seeded bursty arrival trace driven
    through continuous batching on a 4-device ring-ROUTED cluster with
    per-device KV page pools (4 pages x 8 entries — a burst oversubscribes
    a pool, so deferred admission shapes the trace) and an active fault
    plan perturbing the host-channel DMA.  Pins the whole tentpole path:
    arrival-driven CSR submissions, admission control, modeled-clock
    prefill/decode cadence, and routed prompt/token DMA."""
    from repro.core.fuzz import FaultPlan
    clu = _open_loop_cluster()

    def factory():
        clu.reset(FaultPlan(seed=OPEN_LOOP_SEED))
        return clu

    sess = rp.DebugSession(factory, checkpoint_interval=0,
                           label="cluster_open_loop_serving")
    rec = rp.record_open_loop(sess, _open_loop_trace())
    return GoldenRun.render(
        sess, rec, ["# cluster front log"] +
        [f"# engine {i} log" for i in range(clu.n)])


TRACES = {
    "single_device_launch": single_device_run,
    "fabric_all_reduce": fabric_all_reduce_run,
    "fabric_batched_launch": fabric_batched_launch_run,
    "fabric_torus_all_reduce": fabric_torus_all_reduce_run,
    "faulty_fuzz": faulty_fuzz_run,
    "cluster_serving_storm": cluster_serving_storm_run,
    "cluster_open_loop_serving": cluster_open_loop_serving_run,
}
# jit the smoke model
SLOW = {"cluster_serving_storm", "cluster_open_loop_serving"}

# Golden COUNTER corpus (core/counters.py): the always-on sampled
# counter streams of two structurally different runs — the single-device
# bridge and the 8-device routed torus — committed alongside the traces.
# Byte-identity here pins the whole instrumentation layer: bank order,
# column declarations, boundary times and every sampled value.
COUNTER_TRACES = ("single_device_launch", "fabric_torus_all_reduce")


def _counter_lines(run: GoldenRun) -> List[str]:
    from repro.core.counters import counter_banks
    lines: List[str] = []
    for bank in counter_banks(run.recording.target):
        lines += bank.canonical()
    return lines


def _mark(name):
    return pytest.param(name, marks=pytest.mark.slow) if name in SLOW \
        else name


def _explain(name: str, run: GoldenRun, i: int, golden: list,
             live: list) -> str:
    """Time-travel explanation of a trace divergence at line ``i``:
    replay the window around the owning op and render device state."""
    li, tx = run.locate(i)
    if tx is None:
        return "(divergent line is a header/audit line — no replay window)"
    op = run.recording.op_of_tx(li, tx)
    if op < 0:
        return "(divergent transaction predates the first timeline op)"
    text = rp.window_report(run.session, run.recording, op)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    bundle = ARTIFACTS / f"golden_{name}_divergence.txt"
    bundle.write_text(
        f"golden-trace divergence: {name} at line {i + 1}\n"
        f"  golden: {golden[i] if i < len(golden) else '<missing>'}\n"
        f"  live:   {live[i] if i < len(live) else '<missing>'}\n\n"
        + text + "\n")
    return text + f"\n(debug bundle: {bundle})"


def _diff(name: str, run: GoldenRun, golden: list) -> None:
    live = run.lines
    if live == golden:
        return
    n = min(len(live), len(golden))
    for i in range(n):
        if live[i] != golden[i]:
            pytest.fail(
                f"{name}: first divergent transaction at line {i + 1}:\n"
                f"  golden: {golden[i]}\n"
                f"  live:   {live[i]}\n"
                f"{_explain(name, run, i, golden, live)}\n"
                f"(lengths: golden {len(golden)}, live {len(live)}; "
                f"regenerate with `python tests/test_golden_traces.py "
                f"--regen` ONLY for intentional timing-model changes)")
    pytest.fail(
        f"{name}: trace lengths diverge after a common prefix of {n} "
        f"lines (golden {len(golden)}, live {len(live)}); first extra "
        f"line: {(live + golden)[n]!r}\n"
        f"{_explain(name, run, n, golden, live)}")


@pytest.mark.parametrize("name", [_mark(n) for n in sorted(TRACES)])
def test_trace_matches_golden(name):
    golden = (GOLDEN / f"{name}.trace").read_text().splitlines()
    _diff(name, TRACES[name](), golden)


@pytest.mark.parametrize("name", [_mark(n) for n in sorted(TRACES)])
def test_trace_is_run_to_run_deterministic(name):
    assert TRACES[name]().lines == TRACES[name]().lines


@pytest.mark.parametrize("name", [_mark(n) for n in sorted(TRACES)])
def test_full_range_replay_reproduces_trace(name):
    """The time-travel witness on every golden run: replaying the entire
    timeline from checkpoint 0 regenerates logs whose canonical rendering
    (and therefore TransactionLog.digest()) equals the recorded trace
    bit-for-bit."""
    run = TRACES[name]()
    w = run.session.replay(run.recording, 0, run.recording.n_ops)
    logs = rp.target_logs(w.target)
    lines = []
    for h, log in zip(run.headers, logs):
        if h:
            lines.append(h)
        lines += log.canonical()
    assert lines == run.lines


@pytest.mark.parametrize("name", COUNTER_TRACES)
def test_counter_stream_matches_golden(name):
    """The sampled counter streams of the committed counter corpus are
    byte-identical to tests/golden/<name>.counters."""
    golden = (GOLDEN / f"{name}.counters").read_text().splitlines()
    live = _counter_lines(TRACES[name]())
    if live == golden:
        return
    n = min(len(live), len(golden))
    for i in range(n):
        if live[i] != golden[i]:
            pytest.fail(
                f"{name}: first divergent counter line at {i + 1}:\n"
                f"  golden: {golden[i]}\n"
                f"  live:   {live[i]}\n"
                f"(regenerate with `python tests/test_golden_traces.py "
                f"--regen` ONLY for intentional timing-model or "
                f"instrumentation changes)")
    pytest.fail(f"{name}: counter stream lengths diverge "
                f"(golden {len(golden)}, live {len(live)})")


def test_single_device_digest_matches_canonical():
    run = single_device_run()
    fb = run.recording.target
    import hashlib
    h = hashlib.sha256()
    for line in fb.log.canonical():
        h.update(line.encode())
        h.update(b"\n")
    assert fb.log.digest() == h.hexdigest()


def test_explain_names_owning_op_and_replays_window():
    """The mismatch explainer maps a transaction line to its timeline op
    and produces a replayed window containing that op's state."""
    run = single_device_run()
    # pick the last transaction line of the trace
    i = len(run.lines) - 1
    li, tx = run.locate(i)
    assert li == 0 and tx is not None
    op = run.recording.op_of_tx(li, tx)
    assert 0 <= op < run.recording.n_ops
    text = _explain("selftest", run, i, run.lines, run.lines)
    assert f">> op #{op}" in text
    assert "device state after op" in text
    assert (ARTIFACTS / "golden_selftest_divergence.txt").exists()


if __name__ == "__main__":
    if "--regen" not in sys.argv[1:]:
        sys.exit("usage: python tests/test_golden_traces.py --regen")
    GOLDEN.mkdir(exist_ok=True)
    for name, fn in TRACES.items():
        path = GOLDEN / f"{name}.trace"
        run = fn()
        path.write_text("\n".join(run.lines) + "\n")
        print(f"wrote {path} ({len(run.lines)} lines)")
        if name in COUNTER_TRACES:
            cpath = GOLDEN / f"{name}.counters"
            clines = _counter_lines(run)
            cpath.write_text("\n".join(clines) + "\n")
            print(f"wrote {cpath} ({len(clines)} lines)")
