"""Golden-trace regression tests: canonical TransactionLog digests for a
fixed-seed single-device launch and a fixed-seed fabric all_reduce,
diffed line-by-line against committed traces (tests/golden/*.trace).

A trace file holds the canonical rendering (transactions.canonical());
its sha256 is the digest.  On mismatch the test prints the FIRST
divergent transaction — the co-verification analogue of dropping a
waveform cursor on the first diverging signal.

Regenerate after an *intentional* timing-model change with:

    PYTHONPATH=src python tests/test_golden_traces.py --regen
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import CongestionConfig, FabricCluster, FireBridge
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_firmware)

GOLDEN = Path(__file__).resolve().parent / "golden"

# Frozen stimulus parameters: changing ANY of these invalidates the traces.
SINGLE_CONG = CongestionConfig(dos_prob=0.05, seed=7)
FABRIC_LINK = CongestionConfig(link_bytes_per_cycle=64.0, base_latency=100.0,
                               max_burst_bytes=4096, dos_prob=0.05, seed=11)


def single_device_trace() -> list:
    """Fixed-seed single-device matmul launch under online congestion."""
    fb = FireBridge(congestion=SINGLE_CONG)
    fb.register_op("mm", **matmul_backends(tile=16, jit=False))
    matmul_firmware(fb, "mm", "oracle", size=32, tile=16)
    return fb.log.canonical()


def fabric_all_reduce_trace() -> list:
    """Fixed-seed 4-device ring all_reduce over the modeled fabric."""
    fab = FabricCluster(4, link_config=FABRIC_LINK)
    for i in range(4):
        fab.devices[i].mem.alloc("grad", (16, 16), np.float32)
        fab.devices[i].mem.host_write(
            "grad", np.full((16, 16), float(i + 1), np.float32))
    fab.all_reduce("grad")
    lines = ["# fabric interconnect log"] + fab.log.canonical()
    for i, d in enumerate(fab.devices):
        lines += [f"# device {i} log"] + d.log.canonical()
    return lines


TRACES = {
    "single_device_launch": single_device_trace,
    "fabric_all_reduce": fabric_all_reduce_trace,
}


def _diff(name: str, live: list, golden: list) -> None:
    if live == golden:
        return
    n = min(len(live), len(golden))
    for i in range(n):
        if live[i] != golden[i]:
            pytest.fail(
                f"{name}: first divergent transaction at line {i + 1}:\n"
                f"  golden: {golden[i]}\n"
                f"  live:   {live[i]}\n"
                f"(lengths: golden {len(golden)}, live {len(live)}; "
                f"regenerate with `python tests/test_golden_traces.py "
                f"--regen` ONLY for intentional timing-model changes)")
    pytest.fail(
        f"{name}: trace lengths diverge after a common prefix of {n} "
        f"lines (golden {len(golden)}, live {len(live)}); first extra "
        f"line: "
        f"{(live + golden)[n]!r}")


@pytest.mark.parametrize("name", sorted(TRACES))
def test_trace_matches_golden(name):
    golden = (GOLDEN / f"{name}.trace").read_text().splitlines()
    _diff(name, TRACES[name](), golden)


@pytest.mark.parametrize("name", sorted(TRACES))
def test_trace_is_run_to_run_deterministic(name):
    assert TRACES[name]() == TRACES[name]()


def test_single_device_digest_matches_canonical():
    fb = FireBridge(congestion=SINGLE_CONG)
    fb.register_op("mm", **matmul_backends(tile=16, jit=False))
    matmul_firmware(fb, "mm", "oracle", size=32, tile=16)
    import hashlib
    h = hashlib.sha256()
    for line in fb.log.canonical():
        h.update(line.encode())
        h.update(b"\n")
    assert fb.log.digest() == h.hexdigest()


if __name__ == "__main__":
    if "--regen" not in sys.argv[1:]:
        sys.exit("usage: python tests/test_golden_traces.py --regen")
    GOLDEN.mkdir(exist_ok=True)
    for name, fn in TRACES.items():
        path = GOLDEN / f"{name}.trace"
        lines = fn()
        path.write_text("\n".join(lines) + "\n")
        print(f"wrote {path} ({len(lines)} lines)")
