"""Docs-integrity suite: the `docs/` pages cannot drift from the tools.

Two classes of checks, both run by the CI docs-integrity step:

* **Transcript pinning** — fenced blocks introduced by a "prints
  (deterministic ...)" sentinel are the VERBATIM output of a committed
  example; this file pins the profiling walkthrough
  (`examples/profile_cnn.py` ↔ docs/profiling.md) the same way
  `tests/test_replay.py::test_docs_transcript_matches_example` pins the
  time-travel walkthrough in docs/replay.md.
* **Structure** — docs/index.md links every page of the suite, and every
  relative markdown link in README.md and docs/*.md resolves to a real
  file.
"""
import contextlib
import importlib.util
import io
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = ROOT / "docs"

sys.path.insert(0, str(ROOT / "src"))


def _fenced_transcript(doc_path: Path, sentinel: str) -> list:
    doc = doc_path.read_text().splitlines()
    i = doc.index(sentinel)
    start = doc.index("```", i) + 1
    end = doc.index("```", start)
    return doc[start:end]


def test_profiling_docs_transcript(tmp_path):
    """The worked profiling transcript in docs/profiling.md is the
    verbatim output of examples/profile_cnn.py."""
    expected = _fenced_transcript(
        DOCS / "profiling.md",
        "prints (deterministic — modeled cycles only, no wall time):")
    spec = importlib.util.spec_from_file_location(
        "profile_cnn", ROOT / "examples" / "profile_cnn.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        mod.main(["--trace-out", str(tmp_path / "profile_cnn.trace.json")])
    assert buf.getvalue().splitlines() == expected
    assert (tmp_path / "profile_cnn.trace.json").exists()


def test_instrumentation_docs_transcript():
    """The always-on counter walkthrough transcript in
    docs/instrumentation.md is the verbatim output of
    examples/counter_dashboard.py."""
    expected = _fenced_transcript(
        DOCS / "instrumentation.md",
        "prints (deterministic — modeled cycles only, no wall time):")
    spec = importlib.util.spec_from_file_location(
        "counter_dashboard", ROOT / "examples" / "counter_dashboard.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        mod.main([])
    assert buf.getvalue().splitlines() == expected


def test_topology_docs_transcript():
    """The routed-interconnect tour transcript in docs/topology.md is the
    verbatim output of examples/topology_tour.py."""
    expected = _fenced_transcript(
        DOCS / "topology.md",
        "prints (deterministic — modeled cycles only, no wall time):")
    spec = importlib.util.spec_from_file_location(
        "topology_tour", ROOT / "examples" / "topology_tour.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert mod.main([]) == 0
    assert buf.getvalue().splitlines() == expected


def test_runfarm_docs_transcript():
    """The run-farm campaign transcript in docs/runfarm.md is the
    verbatim output of examples/campaign.py (which itself asserts the
    cross-process determinism bar before returning 0)."""
    expected = _fenced_transcript(
        DOCS / "runfarm.md",
        "prints (deterministic — digests, unit counts, and coverage "
        "only, no wall time):")
    spec = importlib.util.spec_from_file_location(
        "campaign", ROOT / "examples" / "campaign.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert mod.main([]) == 0
    assert buf.getvalue().splitlines() == expected


def test_performance_docs_transcript():
    """The simspeed selftest transcript in docs/performance.md is the
    verbatim output of benchmarks/bench_simspeed.py --selftest."""
    expected = _fenced_transcript(
        DOCS / "performance.md",
        "prints (deterministic — modeled cycles only, no wall time):")
    spec = importlib.util.spec_from_file_location(
        "bench_simspeed", ROOT / "benchmarks" / "bench_simspeed.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        mod.selftest()
    assert buf.getvalue().splitlines() == expected


def test_serving_docs_transcript():
    """The open-loop serving walkthrough transcript in docs/serving.md
    is the verbatim output of examples/open_loop_serving.py (which
    itself asserts rerun digest identity before returning 0)."""
    expected = _fenced_transcript(
        DOCS / "serving.md",
        "prints (deterministic — modeled cycles only, no wall time):")
    spec = importlib.util.spec_from_file_location(
        "open_loop_serving", ROOT / "examples" / "open_loop_serving.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert mod.main([]) == 0
    assert buf.getvalue().splitlines() == expected


def test_index_links_every_page():
    index = (DOCS / "index.md").read_text()
    pages = sorted(p.name for p in DOCS.glob("*.md") if p.name != "index.md")
    assert pages, "docs suite is empty"
    for page in pages:
        assert f"({page})" in index, f"docs/index.md does not link {page}"


_LINK = re.compile(r"\]\(([^)#]+?)(?:#[^)]*)?\)")


def _relative_links(md: Path):
    for target in _LINK.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        yield target


def test_markdown_links_resolve():
    missing = []
    for md in [ROOT / "README.md"] + sorted(DOCS.glob("*.md")):
        for target in _relative_links(md):
            if not (md.parent / target).exists():
                missing.append(f"{md.relative_to(ROOT)} -> {target}")
    assert not missing, f"dangling markdown links: {missing}"


def test_readme_maps_profiler():
    readme = (ROOT / "README.md").read_text()
    assert "core/profiler.py" in readme
    assert "docs/profiling.md" in readme
    # the old monolith links must have been rewired to the suite
    assert "docs/index.md" in readme
