"""FireBridge core: three-way equivalence, divergence localization,
transaction profiling, congestion priorities, online link timing."""
import copy

import jax.numpy as jnp
import numpy as np

from repro.core import (CongestionConfig, FireBridge, check_equivalence,
                        coverify, simulate)
from repro.core.transactions import Transaction, TransactionLog
from repro.kernels.systolic_matmul import kernel as MM, ops as MMops, \
    ref as MMref


def _ops(bug: bool = False):
    def interp(a, b):
        out = np.array(MM.matmul(jnp.asarray(a), jnp.asarray(b),
                                 bm=32, bn=32, bk=32, interpret=True))
        if bug:
            out[3, 7] += 0.5          # injected hardware bug
        return out

    return {"mm": dict(
        oracle=lambda a, b: np.asarray(MMref.matmul_ref(jnp.asarray(a),
                                                        jnp.asarray(b))),
        interpret=interp,
    )}


def _firmware(fb, backend):
    rng = np.random.default_rng(0)
    a = rng.normal(size=(64, 64)).astype(np.float32)
    b = rng.normal(size=(64, 64)).astype(np.float32)
    fb.mem.alloc("a", a.shape, np.float32)
    fb.mem.alloc("b", b.shape, np.float32)
    fb.mem.alloc("c", (64, 64), np.float32)
    fb.mem.host_write("a", a)
    fb.mem.host_write("b", b)
    fb.launch("mm", backend, ["a", "b"], ["c"],
              burst_list=lambda: MMops.transactions(64, 64, 64, bm=32,
                                                    bn=32, bk=32,
                                                    dtype_bytes=4))


def test_coverify_pass_and_profiling():
    res = coverify(_firmware, _ops(), backends=("oracle", "interpret"),
                   tol=1e-4, congestion=CongestionConfig(dos_prob=0.1,
                                                         seed=3))
    assert res.passed
    assert res.tx_summary["dma_a"]["transactions"] == 2 * 2 * 2
    assert res.congestion.makespan > 0
    assert res.equivalence.passed


def test_coverify_localizes_injected_bug():
    res = coverify(_firmware, _ops(bug=True),
                   backends=("oracle", "interpret"), tol=1e-4)
    assert not res.passed
    d = res.equivalence.divergences[0]
    assert d.leaf_path == "c"               # the output buffer
    assert d.index == (3, 7)                # exact coordinates of the bug
    assert abs(d.max_abs_err - 0.5) < 1e-3


def test_equivalence_reports_shapes():
    rep = check_equivalence(
        {"a": lambda: {"x": np.zeros((2, 2))},
         "b": lambda: {"x": np.zeros((2, 2))}}, (), tol=1e-6)
    assert rep.passed and "EQUIVALENT" in str(rep)


def test_congestion_priorities():
    txs = []
    for i in range(50):
        txs.append(Transaction(0.0, "hi", "read", 0, 4096))
        txs.append(Transaction(0.0, "lo", "read", 0, 4096))
    res = simulate(txs, CongestionConfig(
        priorities=(("hi", 1), ("lo", 0)), seed=0))
    assert res.per_engine_stall["lo"] > res.per_engine_stall["hi"]


def _mixed_stream(n=60, nbytes=4096):
    txs = []
    for i in range(n):
        txs.append(Transaction(0.0, "dma_a", "read", i * nbytes, nbytes))
        txs.append(Transaction(0.0, "dma_b", "read", i * nbytes, nbytes))
    return txs


def test_online_congestion_during_launch():
    """A FireBridge constructed with a CongestionConfig produces nonzero
    per-engine stalls during launch() — no offline replay step."""
    cfg = CongestionConfig(dos_prob=0.0, seed=1,
                           priorities=(("dma_a", 1), ("dma_b", 0)))
    fb = FireBridge(congestion=cfg)
    fb.register_op("mm", oracle=lambda a, b: np.asarray(
        MMref.matmul_ref(jnp.asarray(a), jnp.asarray(b))))
    _firmware_on(fb, "oracle")
    res = fb.congestion_stats()
    assert res is not None and res.makespan > 0
    # contention on the shared link stalls the lower-priority engine
    assert res.per_engine_stall["dma_b"] > 0
    assert res.per_engine_stall["dma_b"] > res.per_engine_stall["dma_a"]
    # bridge time advanced to the modeled makespan, not a logical counter
    assert fb.mem.time >= res.makespan
    # transactions carry completion times filled in online
    assert all(t.complete > 0 for t in fb.log.txs
               if t.engine.startswith("dma_"))


def _firmware_on(fb, backend):
    rng = np.random.default_rng(0)
    a = rng.normal(size=(64, 64)).astype(np.float32)
    b = rng.normal(size=(64, 64)).astype(np.float32)
    fb.mem.alloc("a", a.shape, np.float32)
    fb.mem.alloc("b", b.shape, np.float32)
    fb.mem.alloc("c", (64, 64), np.float32)
    fb.mem.host_write("a", a)
    fb.mem.host_write("b", b)
    fb.launch("mm", backend, ["a", "b"], ["c"],
              burst_list=lambda: MMops.transactions(64, 64, 64, bm=32,
                                                    bn=32, bk=32,
                                                    dtype_bytes=4))


def test_congestion_determinism_same_seed():
    """Same CongestionConfig.seed => identical per_engine_stall/makespan,
    both offline and through the online bridge."""
    cfg = CongestionConfig(dos_prob=0.3, seed=42)
    r1 = simulate(_mixed_stream(), cfg)
    r2 = simulate(_mixed_stream(), cfg)
    assert r1.makespan == r2.makespan
    assert r1.per_engine_stall == r2.per_engine_stall
    assert r1.per_engine_busy == r2.per_engine_busy

    def run_bridge():
        fb = FireBridge(congestion=cfg)
        fb.register_op("mm", oracle=lambda a, b: np.asarray(
            MMref.matmul_ref(jnp.asarray(a), jnp.asarray(b))))
        _firmware_on(fb, "oracle")
        return fb.congestion_stats()
    b1, b2 = run_bridge(), run_bridge()
    assert b1.makespan == b2.makespan
    assert b1.per_engine_stall == b2.per_engine_stall


def test_congestion_priority_overrides_round_robin():
    """The Fig. 8 input-DMA-priority experiment: prioritizing an engine
    shifts stalls onto the other engines vs. plain round-robin."""
    cfg_rr = CongestionConfig(seed=0)
    cfg_pr = CongestionConfig(seed=0, priorities=(("dma_a", 2),))
    rr = simulate(_mixed_stream(), cfg_rr)
    pr = simulate(_mixed_stream(), cfg_pr)
    # under round-robin the two engines stall about equally; with dma_a
    # prioritized its stalls drop and dma_b absorbs the contention
    assert pr.per_engine_stall["dma_a"] < rr.per_engine_stall["dma_a"]
    assert pr.per_engine_stall["dma_b"] > pr.per_engine_stall["dma_a"]


def test_online_matches_offline_replay():
    """One burst list submitted through the online bridge link times out
    identically to an offline simulate() replay of the same stream — they
    share the arbitration core."""
    cfg = CongestionConfig(dos_prob=0.2, seed=9,
                           priorities=(("dma_a", 1),))
    stream = _mixed_stream(40)
    offline = simulate(copy.deepcopy(stream), cfg)

    fb = FireBridge(congestion=cfg)
    fb.mem.log_burst_list([(t.engine, t.kind, t.addr, t.nbytes)
                           for t in stream])
    online = fb.congestion_stats()
    assert online.makespan == offline.makespan
    assert online.per_engine_stall == offline.per_engine_stall
    assert online.per_engine_busy == offline.per_engine_busy
    assert fb.mem.time == offline.makespan


def test_congestion_disabled_fast_path():
    """Without a CongestionConfig the bridge keeps the logical-time fast
    path: one tick per access, no stall fields, no link."""
    fb = FireBridge()
    fb.mem.alloc("x", (8, 8), np.float32)
    t0 = fb.mem.time
    fb.mem.dev_read("x")
    assert fb.mem.time == t0 + 1
    assert fb.congestion_stats() is None
    assert all(t.stall == 0.0 for t in fb.log.txs)


def test_launch_rejects_output_count_mismatch():
    """An op returning fewer/more outputs than out_bufs raises instead of
    silently truncating the writeback."""
    fb = FireBridge()
    fb.register_op("two", oracle=lambda a: (a, a))
    fb.mem.alloc("x", (4,), np.float32)
    fb.mem.alloc("y", (4,), np.float32)
    fb.mem.alloc("z", (4,), np.float32)
    import pytest
    with pytest.raises(ValueError, match="two.*2 output"):
        fb.launch("two", "oracle", ["x"], ["y"])          # too many
    with pytest.raises(ValueError, match="2 output"):
        fb.launch("two", "oracle", ["x"], ["y", "z", "x"])  # too few


def test_alloc_rejects_silent_shadowing():
    import pytest
    fb = FireBridge()
    fb.mem.alloc("x", (4,), np.float32)
    with pytest.raises(ValueError, match="already allocated"):
        fb.mem.alloc("x", (8,), np.float32)


def test_host_and_dev_write_reject_shape_broadcast():
    import pytest
    fb = FireBridge()
    fb.mem.alloc("x", (4, 4), np.float32)
    with pytest.raises(ValueError, match="refusing silent broadcast"):
        fb.mem.host_write("x", np.zeros((4,), np.float32))
    with pytest.raises(ValueError, match="refusing silent broadcast"):
        fb.mem.dev_write("x", np.zeros((2, 4), np.float32))
    fb.mem.host_write("x", np.ones((4, 4), np.int32))     # cast still fine
    assert fb.mem.host_read("x").sum() == 16


def test_poll_timeout_distinguishable_from_success():
    import pytest
    from repro.core.registers import RegisterFile
    rf = RegisterFile()
    rf.define("STATUS", 0x0, access="ro")
    rf.hw_set("STATUS", 1)
    n = rf.poll("STATUS", 1, 1, max_reads=3)
    assert n == 1                       # success on first read
    assert rf.poll("STATUS", 1, 0, max_reads=3) == -1   # timeout
    assert any("poll timeout" in v for v in rf.log.violations)
    with pytest.raises(TimeoutError):
        rf.poll("STATUS", 1, 0, max_reads=3, strict=True)


def test_register_on_read_refreshes_status():
    from repro.core.registers import RegisterFile
    rf = RegisterFile()
    state = {"n": 0}

    def refresh():
        state["n"] += 1
        rf.hw_set("STATUS", 1 if state["n"] >= 3 else 0)
    rf.define("STATUS", 0x0, access="ro", on_read=refresh)
    assert rf.poll("STATUS", 1, 1, max_reads=10) == 3


def test_heatmap_and_timeline_shapes():
    log = TransactionLog()
    for i in range(100):
        log.log(Transaction(float(i), "e", "read", i * 64, 64))
    hm = log.heatmap(8, 16)
    assert hm.shape == (8, 16) and hm.sum() > 0
    edges, tl = log.bandwidth_timeline(10)
    assert tl["e"].shape == (10,)
    assert log.render_heatmap(4, 8).count("\n") == 3
