"""FireBridge core: three-way equivalence, divergence localization,
transaction profiling, congestion priorities."""
import jax.numpy as jnp
import numpy as np

from repro.core import (CongestionConfig, check_equivalence, coverify,
                        simulate)
from repro.core.transactions import Transaction, TransactionLog
from repro.kernels.systolic_matmul import kernel as MM, ops as MMops, \
    ref as MMref


def _ops(bug: bool = False):
    def interp(a, b):
        out = np.array(MM.matmul(jnp.asarray(a), jnp.asarray(b),
                                 bm=32, bn=32, bk=32, interpret=True))
        if bug:
            out[3, 7] += 0.5          # injected hardware bug
        return out

    return {"mm": dict(
        oracle=lambda a, b: np.asarray(MMref.matmul_ref(jnp.asarray(a),
                                                        jnp.asarray(b))),
        interpret=interp,
    )}


def _firmware(fb, backend):
    rng = np.random.default_rng(0)
    a = rng.normal(size=(64, 64)).astype(np.float32)
    b = rng.normal(size=(64, 64)).astype(np.float32)
    fb.mem.alloc("a", a.shape, np.float32)
    fb.mem.alloc("b", b.shape, np.float32)
    fb.mem.alloc("c", (64, 64), np.float32)
    fb.mem.host_write("a", a)
    fb.mem.host_write("b", b)
    fb.launch("mm", backend, ["a", "b"], ["c"],
              burst_list=lambda: MMops.transactions(64, 64, 64, bm=32,
                                                    bn=32, bk=32,
                                                    dtype_bytes=4))


def test_coverify_pass_and_profiling():
    res = coverify(_firmware, _ops(), backends=("oracle", "interpret"),
                   tol=1e-4, congestion=CongestionConfig(dos_prob=0.1,
                                                         seed=3))
    assert res.passed
    assert res.tx_summary["dma_a"]["transactions"] == 2 * 2 * 2
    assert res.congestion.makespan > 0
    assert res.equivalence.passed


def test_coverify_localizes_injected_bug():
    res = coverify(_firmware, _ops(bug=True),
                   backends=("oracle", "interpret"), tol=1e-4)
    assert not res.passed
    d = res.equivalence.divergences[0]
    assert d.leaf_path == "c"               # the output buffer
    assert d.index == (3, 7)                # exact coordinates of the bug
    assert abs(d.max_abs_err - 0.5) < 1e-3


def test_equivalence_reports_shapes():
    rep = check_equivalence(
        {"a": lambda: {"x": np.zeros((2, 2))},
         "b": lambda: {"x": np.zeros((2, 2))}}, (), tol=1e-6)
    assert rep.passed and "EQUIVALENT" in str(rep)


def test_congestion_priorities():
    txs = []
    for i in range(50):
        txs.append(Transaction(0.0, "hi", "read", 0, 4096))
        txs.append(Transaction(0.0, "lo", "read", 0, 4096))
    res = simulate(txs, CongestionConfig(
        priorities=(("hi", 1), ("lo", 0)), seed=0))
    assert res.per_engine_stall["lo"] > res.per_engine_stall["hi"]


def test_heatmap_and_timeline_shapes():
    log = TransactionLog()
    for i in range(100):
        log.log(Transaction(float(i), "e", "read", i * 64, 64))
    hm = log.heatmap(8, 16)
    assert hm.shape == (8, 16) and hm.sum() > 0
    edges, tl = log.bandwidth_timeline(10)
    assert tl["e"].shape == (10,)
    assert log.render_heatmap(4, 8).count("\n") == 3
