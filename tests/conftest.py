import sys
from pathlib import Path

# src-layout import without installation; tests must see exactly the real
# device count (dryrun.py alone forces 512 host devices).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
