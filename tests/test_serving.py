"""Register-driven continuous-batching serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import init_params
from repro.models.transformer import RunFlags
from repro.serving import Request, ServingEngine

FLAGS = RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16)


def _engine(max_slots=3):
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return cfg, ServingEngine(cfg, params, max_slots=max_slots, max_len=64,
                              flags=FLAGS)


def test_register_protocol_submission_and_completion():
    cfg, eng = _engine()
    rng = np.random.default_rng(0)
    for rid in range(5):
        ln = int(rng.integers(5, 30))
        eng.mem.buffers["prompt_in"].array[:ln] = \
            rng.integers(0, cfg.vocab_size, ln)
        eng.csr.fb_write_32(0x0C, rid)
        eng.csr.fb_write_32(0x10, ln)
        eng.csr.fb_write_32(0x14, 6 + rid)
        eng.csr.fb_write_32(0x08, 1)            # doorbell
    eng.run_until_done()
    assert eng.completed == 5
    assert not eng.csr.log.violations
    assert eng.csr.hw_get("COMPLETED") == 5
    for rid, r in eng.requests.items():
        assert r.done and len(r.out_tokens) == 6 + rid
        out = eng.mem.buffers["tokens_out"].array
        assert (out >= 0).all()


def test_protocol_violation_detection():
    cfg, eng = _engine()
    eng.csr.fb_write_32(0x10, 10_000)          # absurd SUBMIT_LEN
    eng.csr.fb_write_32(0x08, 1)
    assert any("SUBMIT_LEN" in v for v in eng.csr.log.violations)
    eng.csr.fb_write_32(0x04, 1)               # write to RO STATUS
    assert any("read-only" in v for v in eng.csr.log.violations)


def test_continuous_batching_oversubscription():
    cfg, eng = _engine(max_slots=2)
    rng = np.random.default_rng(1)
    for rid in range(4):                        # 4 requests, 2 slots
        eng.submit(Request(rid, rng.integers(0, cfg.vocab_size, 8)
                           .astype(np.int32), 5))
    eng.run_until_done()
    assert eng.completed == 4


def test_max_new_tokens_respected_on_prefill_path():
    """A max_new_tokens=1 request completes at prefill with exactly one
    token (the old path emitted two), frees its slot immediately, and
    writes tokens_out."""
    cfg, eng = _engine(max_slots=2)
    rng = np.random.default_rng(3)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 8)
                       .astype(np.int32), 1))
    eng.step()
    r = eng.requests[0]
    assert r.done and len(r.out_tokens) == 1
    assert eng.completed == 1 and eng._n_active() == 0
    assert eng.csr.hw_get("COMPLETED") == 1
    assert eng.mem.buffers["tokens_out"].array[0, 0] == r.out_tokens[0]


def test_zero_max_new_tokens_rejected_with_violation():
    cfg, eng = _engine()
    rng = np.random.default_rng(4)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 8)
                       .astype(np.int32), 0))
    assert 0 not in eng.requests and not eng.pending
    assert any("SUBMIT_MAXNEW" in v for v in eng.csr.log.violations)
    # same rejection over the CSR doorbell path
    eng.mem.buffers["prompt_in"].array[:4] = \
        rng.integers(0, cfg.vocab_size, 4)
    eng.csr.fb_write_32(0x0C, 1)
    eng.csr.fb_write_32(0x10, 4)
    eng.csr.fb_write_32(0x14, 0)
    eng.csr.fb_write_32(0x08, 1)
    assert 1 not in eng.requests
    eng.run_until_done()
    assert eng.completed == 0


def test_duplicate_submit_id_is_violation_not_overwrite():
    cfg, eng = _engine()
    rng = np.random.default_rng(5)
    first = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng.submit(Request(7, first, 3))
    eng.submit(Request(7, rng.integers(0, cfg.vocab_size, 8)
                       .astype(np.int32), 9))
    assert any("duplicate SUBMIT_ID 7" in v for v in eng.csr.log.violations)
    assert np.array_equal(eng.requests[7].prompt, first)
    assert eng.requests[7].max_new_tokens == 3    # first submission wins
    eng.run_until_done()
    assert eng.completed == 1 and len(eng.requests[7].out_tokens) == 3
    # a retired id may be recycled (bounded-width SUBMIT_ID CSR)
    n_viol = len(eng.csr.log.violations)
    eng.submit(Request(7, first, 2))
    assert len(eng.csr.log.violations) == n_viol
    eng.run_until_done()
    assert eng.completed == 2 and len(eng.requests[7].out_tokens) == 2


def test_requests_exceeding_kv_capacity_rejected():
    """prompt-bucket + max_new_tokens past max_len would silently drop KV
    writes; the doorbell rejects it with a violation instead."""
    cfg, eng = _engine()            # max_len=64, prompt_pad=16
    rng = np.random.default_rng(6)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 8)
                       .astype(np.int32), 64))   # 16 + 63 > 64
    assert 0 not in eng.requests
    assert any("exceeds KV capacity" in v for v in eng.csr.log.violations)
    # the largest budget that fits is accepted
    eng.submit(Request(1, rng.integers(0, cfg.vocab_size, 8)
                       .astype(np.int32), 64 - 16 + 1))
    assert 1 in eng.requests


@pytest.mark.slow
def test_decode_matches_unbatched_prefill():
    """A slot's generation is independent of other slots (cache isolation)."""
    cfg, eng1 = _engine(max_slots=1)
    cfg, eng3 = _engine(max_slots=3)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    eng1.submit(Request(0, prompt, 6))
    eng1.run_until_done()
    eng3.submit(Request(0, prompt, 6))
    eng3.submit(Request(1, rng.integers(0, cfg.vocab_size, 16)
                        .astype(np.int32), 6))
    eng3.run_until_done()
    assert eng1.requests[0].out_tokens == eng3.requests[0].out_tokens
