"""Systolic matmul / Mamba2 SSD / RWKV6 WKV kernels vs oracles (interpret
mode), plus the static BlockSpec transaction stream."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.mamba2_scan import kernel as SSD, ref as SSDref
from repro.kernels.rwkv6_wkv import kernel as WKV, ref as WKVref
from repro.kernels.systolic_matmul import kernel as MM, ops as MMops, \
    ref as MMref

KEY = jax.random.PRNGKey(5)


@pytest.mark.parametrize("M,N,K,bm,dt", [
    (256, 128, 128, 64, jnp.float32),
    (128, 256, 512, 64, jnp.bfloat16),
    (128, 128, 128, 128, jnp.float32),
])
def test_matmul_kernel(M, N, K, bm, dt):
    a = jax.random.normal(jax.random.fold_in(KEY, 1), (M, K), dt)
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (K, N), dt)
    got = MM.matmul(a, b, bm=bm, bn=bm, bk=bm)
    ref = MMref.matmul_ref(a, b)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) -
                                ref.astype(jnp.float32))))
    assert err < (1e-4 if dt == jnp.float32 else 1.0) * max(1.0, float(
        jnp.max(jnp.abs(ref.astype(jnp.float32)))))


def test_matmul_transaction_stream():
    txs = MMops.transactions(256, 128, 128, bm=64, bn=64, bk=64,
                             dtype_bytes=2)
    reads = [t for t in txs if t[1] == "read"]
    writes = [t for t in txs if t[1] == "write"]
    # grid 4x2x2: 2 reads per k step, 1 write per (m,n)
    assert len(reads) == 4 * 2 * 2 * 2 and len(writes) == 4 * 2
    assert sum(t[3] for t in writes) == 256 * 128 * 2


@pytest.mark.parametrize("B,L,H,P,N,chunk", [
    (2, 64, 8, 16, 8, 16),
    (1, 128, 4, 8, 16, 32),
])
def test_ssd_kernel(B, L, H, P, N, chunk):
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 4),
                                           (B, L, H)))
    B_ = jax.random.normal(jax.random.fold_in(KEY, 5), (B, L, N))
    C_ = jax.random.normal(jax.random.fold_in(KEY, 6), (B, L, N))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 7), (H,)) * 0.5)
    D = jnp.ones((H,))
    y_k, st_k = SSD.ssd_scan(x, dt, B_, C_, A, D, chunk=chunk, hb=4)
    y_r, st_r = SSDref.ssd_scan_ref(x, dt, B_, C_, A, D)
    assert float(jnp.max(jnp.abs(y_k - y_r))) < 1e-3
    assert float(jnp.max(jnp.abs(st_k - st_r))) < 1e-3


@pytest.mark.parametrize("B,L,H,K", [(2, 64, 4, 16), (1, 32, 8, 32)])
def test_wkv_kernel(B, L, H, K):
    r = jax.random.normal(jax.random.fold_in(KEY, 8), (B, L, H, K))
    k = jax.random.normal(jax.random.fold_in(KEY, 9), (B, L, H, K))
    v = jax.random.normal(jax.random.fold_in(KEY, 10), (B, L, H, K))
    w = jnp.exp(-jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 11),
                                           (B, L, H, K))))
    u = jax.random.normal(jax.random.fold_in(KEY, 12), (H, K)) * 0.5
    y_k, st_k = WKV.wkv_scan(r, k, v, w, u, chunk=16, hb=4)
    y_r, st_r = WKVref.wkv_scan_ref(r, k, v, w, u)
    assert float(jnp.max(jnp.abs(y_k - y_r))) < 1e-3
    assert float(jnp.max(jnp.abs(st_k - st_r))) < 1e-3


def test_model_wkv_matches_kernel_path():
    """The model's lax time-mix chunk and the Pallas kernel agree."""
    from repro.models.rwkv6 import _wkv_chunk
    B, c, H, K = 2, 16, 4, 16
    r = jax.random.normal(jax.random.fold_in(KEY, 13), (B, c, H, K))
    k = jax.random.normal(jax.random.fold_in(KEY, 14), (B, c, H, K))
    v = jax.random.normal(jax.random.fold_in(KEY, 15), (B, c, H, K))
    w = jnp.exp(-jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 16),
                                           (B, c, H, K))))
    u = jax.random.normal(jax.random.fold_in(KEY, 17), (H, K)) * 0.5
    st0 = jnp.zeros((B, H, K, K))
    st_m, y_m = _wkv_chunk(st0, r, k, v, w, u)
    y_kk, st_kk = WKV.wkv_scan(r, k, v, w, u, chunk=16, hb=4)
    assert float(jnp.max(jnp.abs(y_m - y_kk))) < 1e-4
    assert float(jnp.max(jnp.abs(st_m - st_kk))) < 1e-4
