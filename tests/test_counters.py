"""Always-on counter instrumentation + counter-diff oracle tests
(core/counters.py).

Four pillars, mirroring the module's design rules:

* **Closure** — the link-probed counters close BIT-EXACTLY against the
  profiler's stall attribution (same float folds in the same order), on
  the golden runs and on a profiled CNN workload.
* **Digest identity** — same seed, same counter-stream digest across
  oracle/interpret/compiled backends; same functional digest across
  1/2/4 devices (the counter-diff oracle's two scopes).
* **Sampling invariance** — a stream sampled at 2I is exactly the
  even-boundary subsequence of the stream sampled at I.
* **Oracle economics** — a planted timing-only bug (invisible to the
  output diff) is flagged by the oracle and localized with fewer scalar
  comparisons than a full trace diff, and the CoVerifySession pre-check
  escalates it into the replay-bisection lane.

``check_counter_replay_invariants`` is shared with the hypothesis tier
(tests/test_property.py) — the seeded run here is its pre-validated
numpy fallback for environments without hypothesis.
"""
import functools
import sys
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))
sys.path.insert(0, str(ROOT / "src"))

from repro.core import CongestionConfig, FireBridge
from repro.core.counters import (counter_banks, diff_streams,
                                 functional_digest, functional_totals,
                                 merged_digest, merged_totals,
                                 sampling_disabled)
from repro.core.profiler import CATEGORIES
from repro.core.scheduler import CoVerifySession
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_firmware)

BACKENDS = ("oracle", "interpret", "compiled")
CONG = CongestionConfig(dos_prob=0.05, seed=7)


def _mm_run(backend: str, interval=None) -> FireBridge:
    """One fixed-seed matmul launch under online congestion."""
    fb = FireBridge(congestion=CONG)
    fb.register_op("mm", **matmul_backends(tile=16, jit=False))
    if interval is not None:
        fb.mem.counters.set_interval(interval)
    matmul_firmware(fb, "mm", backend, size=32, tile=16)
    return fb


# ------------------------------------------------------------------ closure
def _assert_link_closure(bank, ch) -> None:
    """One link-backed bank against its profiler channel: every shared
    quantity must agree BIT-exactly — the probes and the profiler fold
    the same float sequences in the same order."""
    eng = ch.engines
    assert bank.value("bytes_moved") == sum(e.bytes for e in eng.values())
    # grant_stall / busy are the arbiter's per-engine accumulators folded
    # again by the profiler in timeline (= grant) order; the bank's probe
    # sums them in sorted-engine order — replicate that exact fold
    stall = 0.0
    for name in sorted(eng):
        stall += eng[name].grant_stall
    assert bank.value("stall_cycles") == stall
    busy = 0.0
    for name in sorted(eng):
        busy += eng[name].busy
    assert bank.value("busy_cycles") == busy
    assert bank.value("dos_cycles") == ch.breakdown.cycles["dos"]
    # stall-category closure: the six categories sum (left fold in
    # CATEGORIES order) exactly to the channel horizon == the bank's
    # sampled clock
    total = 0.0
    for c in CATEGORIES:
        total += ch.breakdown.cycles[c]
    assert total == ch.horizon == bank.value("cycles")


def test_counter_closure_single_device_golden():
    import test_golden_traces as gt
    run = gt.single_device_run()
    fb = run.recording.target
    prof = fb.profiler("closure")
    _assert_link_closure(fb.mem.counters, prof.channel("ddr"))


def test_counter_closure_routed_torus_golden():
    """Every fabric bank of the 8-device routed torus golden run — host
    attachment, device ports, and all credit-flow-controlled switch
    ports — closes against its profiler channel."""
    import test_golden_traces as gt
    run = gt.fabric_torus_all_reduce_run()
    fab = run.recording.target
    prof = fab.profiler("closure")
    checked = 0
    for bank in fab._counter_banks:
        if bank.name.startswith("fabric/sw:"):
            ch = prof.channel("fabric/" + bank.name[len("fabric/sw:"):])
        else:
            ch = prof.channel(bank.name)
        _assert_link_closure(bank, ch)
        checked += 1
    assert checked >= 1 + 8 + 8          # host + ports + >=8 switch ports


def test_counter_closure_profiled_cnn():
    """The profiled Fig. 8 CNN workload (op marks active): attribution
    still closes bit-exactly against the always-on counters."""
    from benchmarks.cnn_driver import run_cnn, small_cnn_specs
    cong = CongestionConfig(
        link_bytes_per_cycle=64.0, dos_prob=0.02, seed=7,
        priorities=(("dma_input", 2), ("dma_output", 1),
                    ("dma_weights", 0)))
    fb = run_cnn(small_cnn_specs(16), backend="oracle", congestion=cong,
                 profile=True)
    prof = fb.profiler("closure")
    _assert_link_closure(fb.mem.counters, prof.channel("ddr"))


# ----------------------------------------------------------- digest identity
def test_backend_digest_identity():
    """Same seed ⇒ byte-identical counter streams across all three
    backends: modeled timing is backend-invariant, and the digest is the
    cheap witness the oracle compares."""
    runs = {be: _mm_run(be) for be in BACKENDS}
    digests = {be: merged_digest(counter_banks(fb))
               for be, fb in runs.items()}
    assert len(set(digests.values())) == 1, digests
    # the canonical streams themselves are line-identical, not just
    # hash-identical
    ref = runs["oracle"].mem.counters.canonical()
    for be in BACKENDS[1:]:
        assert runs[be].mem.counters.canonical() == ref
    assert runs["oracle"].mem.counters.stream.n_samples > 0


@functools.lru_cache(maxsize=None)
def _cluster(n: int):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke
    from repro.models import init_params
    from repro.models.transformer import RunFlags
    from repro.serving.cluster import ClusterServingEngine
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return ClusterServingEngine(
        cfg, params, n_devices=n, max_slots=2, max_len=32, prompt_pad=8,
        flags=RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16))


@pytest.mark.slow
def test_scale_functional_digest_identity():
    """The cross-scale side of the oracle: the same (unique-rid) request
    storm on 1/2/4-device clusters retires identical functional totals —
    doorbells, requests, tokens — while the full per-bank streams differ
    per scale (more engines, different timing)."""
    import test_golden_traces as gt
    from repro.core import replay as rp
    reqs = gt._storm_requests()          # rids 0..5, all unique
    functional, full = {}, {}
    for n in (1, 2, 4):
        clu = _cluster(n)

        def factory(clu=clu):
            clu.reset(None)
            return clu

        sess = rp.DebugSession(factory, checkpoint_interval=0,
                               label=f"counters_scale_x{n}")
        rp.record_serving_storm(sess, reqs)
        banks = counter_banks(clu)
        functional[n] = functional_digest(banks)
        full[n] = merged_digest(banks)
        totals = functional_totals(banks)
        assert totals["doorbells"] == len(reqs)
        assert totals["requests_retired"] == len(reqs)
        assert totals["tokens_retired"] > 0
    assert len(set(functional.values())) == 1, functional
    assert len(set(full.values())) == 3, full


# ------------------------------------------------------- sampling invariance
def test_sampling_interval_invariance():
    """A stream sampled at 2I is exactly the even-boundary subsequence of
    the stream sampled at I — boundary times come from multiplication and
    rows are sample-and-hold, so coarser sampling loses rows, never
    changes them."""
    fine = _mm_run("oracle", interval=128.0).mem.counters.stream
    coarse = _mm_run("oracle", interval=256.0).mem.counters.stream
    assert fine.n_samples > coarse.n_samples > 0
    sub = [(t, r) for t, r in zip(fine.times, fine.rows) if t % 256.0 == 0.0]
    assert sub == list(zip(coarse.times, coarse.rows))


def test_sampling_disabled_is_scoped():
    with sampling_disabled():
        fb = _mm_run("oracle")
        assert fb.mem.counters.stream.n_samples == 0
    assert _mm_run("oracle").mem.counters.stream.n_samples > 0


# --------------------------------------------------------- state round-trip
def test_counter_state_roundtrip():
    """get_state/set_state moves a bank between structurally identical
    owners bit-exactly, and the epoch bump keeps digests honest after a
    restore (no stale memo)."""
    bank = _mm_run("oracle").mem.counters
    d0 = bank.digest()
    fresh = FireBridge(congestion=CONG).mem.counters
    assert fresh.stream.n_samples == 0
    fresh.set_state(bank.get_state())
    assert fresh.canonical() == bank.canonical()
    assert fresh.digest() == bank.digest() == d0
    # restoring over an already-digested bank must recompute, not serve
    # the memo for the old epoch
    bank.set_state(bank.get_state())
    assert bank.digest() == d0


# ------------------------------------------------ replay/monotone invariants
def _bridge_session(case, interval):
    """Recorded bridge session for the replay invariants — the same op
    vocabulary as the hypothesis tier's ``replay_programs`` strategy."""
    from repro.core import replay as rp
    from repro.core.fuzz import FaultPlan
    shapes, ops, cong_seed, fault_seed = case

    def factory():
        return FireBridge(
            congestion=CongestionConfig(dos_prob=0.2, seed=cong_seed,
                                        max_burst_bytes=64),
            fault_plan=FaultPlan(seed=fault_seed))

    def program(rec):
        for i, (m, n) in enumerate(shapes):
            rec.do("alloc", f"b{i}", (m, n), np.float32)
        for kind, b, v in ops:
            name = f"b{b}"
            m, n = shapes[b]
            if kind == "dev_read":
                rec.do("dev_read", name, "dma")
            elif kind == "dev_write":
                rec.do("dev_write", name,
                       np.full((m, n), float(v % 97), np.float32), "dma")
            elif kind == "host_write":
                rec.do("host_write", name,
                       np.full((m, n), float(v % 89), np.float32))
            else:
                rec.do("log_burst_list",
                       [("eng_a", "read", 0x1000, 1 + v % 512),
                        ("eng_b", "write", 0x2000, 1 + v % 256)], None)

    return rp.DebugSession(factory, checkpoint_interval=interval), program


def check_counter_replay_invariants(case, interval, lo, hi) -> None:
    """Shared property checker (hypothesis tier + seeded fallback):

    * every ``monotone`` counter is non-decreasing across samples;
    * replaying any ``[lo, hi)`` window regenerates a counter stream that
      is an exact prefix of the recorded one (the restored checkpoint
      carries the stream prefix; re-run ops regenerate the suffix
      bit-identically);
    * full-range replay regenerates the entire stream.
    """
    sess, program = _bridge_session(case, interval)
    rec = sess.record(program)
    banks = counter_banks(rec.target)
    for b in banks:
        for j, s in enumerate(b.specs):
            if not s.monotone:
                continue
            col = [row[j] for row in b.stream.rows]
            assert all(x <= y for x, y in zip(col, col[1:])), \
                f"{b.name}/{s.name} decreased across samples"
    orig = [b.canonical() for b in banks]
    lo, hi = min(lo, rec.n_ops), min(hi, rec.n_ops)
    w = sess.replay(rec, lo, hi)
    for b, ref in zip(counter_banks(w.target), orig):
        live = b.canonical()
        assert live == ref[:len(live)], f"{b.name}: replay diverged"
    w = sess.replay(rec, 0, rec.n_ops)
    assert [b.canonical() for b in counter_banks(w.target)] == orig


def test_counter_replay_invariants_randomized():
    """Seeded numpy fallback of the hypothesis property
    (tests/test_property.py::test_counter_stream_replay_and_monotonicity)
    — pre-validated here so the property tier never guards an unexercised
    checker."""
    for seed in range(4):
        rng = np.random.default_rng(seed)
        shapes = [(int(rng.integers(1, 24)), 4)
                  for _ in range(rng.integers(1, 4))]
        kinds = ("dev_read", "dev_write", "host_write", "burst")
        ops = [(kinds[rng.integers(0, 4)],
                int(rng.integers(0, len(shapes))),
                int(rng.integers(0, 2 ** 16)))
               for _ in range(rng.integers(4, 18))]
        case = (shapes, ops, int(rng.integers(0, 2 ** 20)),
                int(rng.integers(0, 2 ** 20)))
        n = len(shapes) + len(ops)
        lo = int(rng.integers(0, n + 1))
        hi = int(rng.integers(lo, n + 1))
        check_counter_replay_invariants(case, 1 + seed % 4, lo, hi)


# -------------------------------------------------- the counter-diff oracle
def _stream_workload(fb: FireBridge, rogue: bool) -> None:
    """Fixed DMA workload; ``rogue`` plants one extra early read — a
    timing-only perturbation that never changes functional state."""
    rng = np.random.default_rng(7)
    a = rng.normal(size=(32, 32)).astype(np.float32)
    fb.mem.alloc("a", a.shape, np.float32)
    fb.mem.host_write("a", a)
    if rogue:
        fb.mem.dev_read("a", engine="dma_rogue")
    for _ in range(12):
        fb.mem.dev_read("a", engine="dma")
        fb.mem.dev_write("a", a, engine="dma")


def test_counter_diff_localizes_cheaper_than_trace_diff():
    """The oracle's economics: the planted timing bug is found in fewer
    scalar comparisons than a full trace-line diff would spend — the
    ~10x-cheaper pre-check the sweep runs before output comparison."""
    good, bad = FireBridge(congestion=CONG), FireBridge(congestion=CONG)
    _stream_workload(good, rogue=False)
    _stream_workload(bad, rogue=True)
    diff, comparisons = diff_streams(counter_banks(good),
                                     counter_banks(bad))
    assert diff is not None
    assert diff.bank == "ddr"
    assert "counter divergence" in diff.render()
    trace_lines = (len(good.log.canonical()) + len(bad.log.canonical()))
    assert comparisons < trace_lines, \
        f"oracle spent {comparisons} vs {trace_lines} trace lines"
    # identical runs: no diff, and confirming equality is still cheap
    twin = FireBridge(congestion=CONG)
    _stream_workload(twin, rogue=False)
    none_diff, _ = diff_streams(counter_banks(good), counter_banks(twin))
    assert none_diff is None


def _buggy_firmware(fb, op, backend, *, size, tile=16):
    """matmul firmware with a planted backend-conditional timing bug:
    one backend issues an extra DMA read.  Outputs are unchanged, so the
    output diff alone passes — only the counter oracle sees it."""
    matmul_firmware(fb, op, backend, size=size, tile=tile)
    if backend == "interpret":
        fb.mem.dev_read("a", engine="dma_rogue")


def test_sweep_counter_oracle_clean_pass():
    """Clean sweep: every cell carries the oracle payload, same-timing-key
    digests agree, and no mismatch is recorded."""
    sess = CoVerifySession(matmul_firmware, congestion=CONG)
    sess.register_op("mm", **matmul_backends(tile=16, jit=False))
    sess.add_sweep("mm", ("oracle", "interpret"),
                   [{"size": 32, "tile": 16}])
    rep = sess.run(max_workers=1, bisect_failures=False)
    assert rep.passed and rep.counter_mismatches == {}
    cs = [r.counters for r in rep.cells]
    assert all(c is not None for c in cs)
    assert cs[0]["timing_key"] == cs[1]["timing_key"]
    assert cs[0]["digest"] == cs[1]["digest"]
    assert cs[0]["functional"] == cs[1]["functional"]
    assert cs[0]["totals"]["transactions"] > 0


def test_sweep_counter_oracle_flags_planted_timing_bug():
    """The planted bug fails the sweep via counter_mismatches (kind
    ``stream``) even though the output diff PASSES, and the mismatch is
    escalated into the replay-bisection lane."""
    sess = CoVerifySession(_buggy_firmware, congestion=CONG)
    sess.register_op("mm", **matmul_backends(tile=16, jit=False))
    sess.add_sweep("mm", ("oracle", "interpret"), [{"size": 32}])
    rep = sess.run(max_workers=1)
    assert not rep.passed
    (lab, m), = rep.counter_mismatches.items()
    assert m["kind"] == "stream"
    assert set(m["pair"]) == {"oracle", "interpret"}
    assert set(m["totals"]) == {"oracle", "interpret"}
    # the timing-only bug is INVISIBLE to the output diff — this is
    # exactly the class of divergence the oracle exists to catch
    assert all(e.passed for e in rep.equivalence.values())
    assert lab in rep.divergences
    assert "stream mismatch" in \
        str(rep.summary()["counter_mismatches"].values())


def test_sweep_digest_identity_across_backends_and_scales():
    """The acceptance bar, end to end through the sweep: one seed, two
    backends, devices 1/2/4 — within every device count the full counter
    stream digests are identical across backends (no fault plan, so all
    cells of a scale share a timing key), and no oracle mismatch fires."""
    from repro.kernels.systolic_matmul.sweep import matmul_fabric_firmware
    sess = CoVerifySession(matmul_firmware, congestion=CONG,
                           fabric_firmware=matmul_fabric_firmware)
    sess.register_op("mm", **matmul_backends(tile=16, jit=False))
    sess.add_sweep("mm", ("oracle", "interpret"),
                   [{"size": 32, "tile": 16}], devices=(1, 2, 4))
    rep = sess.run(max_workers=1, bisect_failures=False)
    assert rep.passed and rep.counter_mismatches == {}
    by_key = {}
    for r in rep.cells:
        assert r.counters is not None
        by_key.setdefault(r.counters["timing_key"],
                          set()).add(r.counters["digest"])
    assert sorted(k[0] for k in by_key) == [1, 2, 4]
    for key, digests in by_key.items():
        assert len(digests) == 1, f"stream digests diverge at {key}"
    assert len({r.counters["functional"] for r in rep.cells}) == 1
