"""FabricCluster: modeled interconnect, sharded launches, collectives,
devices= sweep axis, cluster serving (core/fabric.py, serving/cluster.py).

The acceptance surface for the multi-device fabric: 4-device sharded
sweep cells bit-identical to the single-device oracle with non-zero
modeled inter-device link stalls, and same-seed transaction-log digest
reproducibility.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FABRIC_LINK, CongestionConfig, CoVerifySession,
                        CoverageModel, FabricCluster, FaultPlan)
from repro.kernels.flash_attention.sweep import (flash_backends,
                                                 flash_fabric_firmware,
                                                 flash_firmware)
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_fabric_firmware,
                                                 matmul_firmware)

LINK = FABRIC_LINK


# ------------------------------------------------------------- primitives
def test_scatter_gather_roundtrip_bit_identical():
    fab = FabricCluster(4, link_config=LINK)
    data = np.arange(7 * 6, dtype=np.float32).reshape(7, 6)   # uneven split
    fab.host.alloc("x", data.shape, np.float32)
    fab.host.host_write("x", data)
    fab.scatter("x")
    for i, sh in enumerate(np.array_split(data, 4)):
        assert np.array_equal(fab.devices[i].mem.buffers["x"].array, sh)
    fab.host.buffers["x"].array[:] = 0          # prove gather repopulates
    fab.gather("x")
    assert np.array_equal(fab.host.host_read("x"), data)
    assert fab.time > 0 and len(fab.log.txs) > 0


def test_dev_copy_moves_data_and_advances_clock():
    fab = FabricCluster(3, link_config=LINK)
    fab.devices[0].mem.alloc("w", (16, 16), np.float32)
    w = np.random.default_rng(0).normal(size=(16, 16)).astype(np.float32)
    fab.devices[0].mem.host_write("w", w)
    t0 = fab.time
    fab.dev_copy(0, 2, "w")
    assert np.array_equal(fab.devices[2].mem.buffers["w"].array, w)
    assert fab.time > t0
    engines = {t.engine for t in fab.log.txs}
    assert "d0->d2" in engines


def test_broadcast_contends_on_host_channel():
    fab = FabricCluster(4, link_config=LINK)
    fab.host.alloc("b", (64, 64), np.float32)
    fab.host.host_write("b", np.ones((64, 64), np.float32))
    fab.broadcast("b")
    for d in fab.devices:
        assert np.array_equal(d.mem.buffers["b"].array,
                              np.ones((64, 64), np.float32))
    # four replicas crossing one channel: somebody waited
    host = fab.link_stats()["host"]
    assert sum(host.per_engine_stall.values()) > 0


def test_all_reduce_sum_and_determinism():
    arrs = [np.random.default_rng(i).normal(size=(8, 8)).astype(np.float32)
            for i in range(4)]

    def build():
        fab = FabricCluster(4, link_config=LINK)
        for i, a in enumerate(arrs):
            fab.devices[i].mem.alloc("g", a.shape, np.float32)
            fab.devices[i].mem.host_write("g", a)
        fab.all_reduce("g")
        return fab

    fab = build()
    ref = arrs[0] + arrs[1] + arrs[2] + arrs[3]
    for d in fab.devices:
        got = d.mem.buffers["g"].array
        assert np.allclose(got, ref, atol=1e-5)
        # every device converged to the same bits
        assert np.array_equal(got, fab.devices[0].mem.buffers["g"].array)
    # ring steps put a tx and an rx leg on every port: stalls are modeled
    assert fab.total_link_stall() > 0
    # same data, fresh cluster => identical transaction-log digest
    assert build().digest() == fab.digest()


def test_scatter_gather_empty_shards_move_nothing():
    """More devices than rows: empty shards must not emit zero-byte
    bursts (which would pay full base_latency) on either leg."""
    fab = FabricCluster(6, link_config=LINK)
    data = np.arange(4 * 2, dtype=np.float32).reshape(4, 2)
    fab.host.alloc("x", data.shape, np.float32)
    fab.host.host_write("x", data)
    fab.scatter("x")
    fab.gather("x")
    assert np.array_equal(fab.host.host_read("x"), data)
    assert all(t.nbytes > 0 for t in fab.log.txs)
    # exactly the 4 non-empty shards crossed, each with 2 legs each way
    assert len(fab.log.txs) == 4 * 2 * 2


def test_device_congestion_seeds_are_decorrelated():
    """Per-device DDR links must not share one DoS stream; device 0 keeps
    the caller's seed so it times like a standalone bridge."""
    cong = CongestionConfig(dos_prob=0.5, seed=9)
    fab = FabricCluster(3, congestion=cong, link_config=LINK)
    assert fab.devices[0].mem.congestion.seed == 9
    assert len({d.mem.congestion.seed for d in fab.devices}) == 3

    def stalls(dev):
        dev.mem.alloc("x", (64, 64), np.float32)
        dev.mem.dev_read("x")
        return [t.stall for t in dev.log.txs]

    streams = [stalls(d) for d in fab.devices]
    assert streams[0] != streams[1] or streams[0] != streams[2]


def test_all_reduce_degenerate_chunks_move_nothing():
    """More devices than elements: empty ring chunks must not emit
    zero-byte bursts or advance the fabric clock for moving no data."""
    fab = FabricCluster(4, link_config=LINK)
    for i in range(4):
        fab.devices[i].mem.alloc("g", (2,), np.float32)
        fab.devices[i].mem.host_write("g", np.float32([i, i]))
    fab.all_reduce("g")
    assert np.array_equal(fab.devices[0].mem.buffers["g"].array,
                          np.float32([6, 6]))
    assert all(t.nbytes > 0 for t in fab.log.txs)


def test_all_reduce_single_device_is_noop():
    fab = FabricCluster(1, link_config=LINK)
    fab.devices[0].mem.alloc("g", (4,), np.float32)
    fab.devices[0].mem.host_write("g", np.ones(4, np.float32))
    fab.all_reduce("g")
    assert np.array_equal(fab.devices[0].mem.buffers["g"].array,
                          np.ones(4, np.float32))
    assert len(fab.log.txs) == 0


def test_fault_plan_forks_are_deterministic_and_audited():
    def run():
        fab = FabricCluster(2, link_config=LINK, fault_plan=FaultPlan(7))
        fab.host.alloc("x", (32, 32), np.float32)
        fab.host.host_write("x", np.ones((32, 32), np.float32))
        fab.scatter("x")
        fab.gather("x")
        return fab

    a, b = run(), run()
    assert a.digest() == b.digest()
    # fabric-link faults are audited in the fabric log, and the data still
    # arrives intact (faults perturb timing, never function)
    assert len(a.log.faults) == len(a.fault_plan.events)
    assert np.array_equal(a.host.host_read("x"), np.ones((32, 32),
                                                         np.float32))


def test_timing_monotonicity_extra_traffic_never_helps():
    def total_time(extra: bool) -> float:
        fab = FabricCluster(2, link_config=CongestionConfig(
            dos_prob=0.0, max_burst_bytes=4096))
        fab.host.alloc("x", (64, 64), np.float32)
        fab.host.host_write("x", np.zeros((64, 64), np.float32))
        if extra:
            fab.host.alloc("y", (64, 64), np.float32)
            fab.host.host_write("y", np.zeros((64, 64), np.float32))
            fab.broadcast("y")                  # contending traffic
        fab.scatter("x")
        fab.gather("x")
        return fab.time

    assert total_time(extra=True) >= total_time(extra=False)


def test_inner_axis_shard_addresses_are_strided():
    """Host-side DMA legs of an inner-axis scatter/gather must be logged
    at the shard's true strided byte runs, not one contiguous block —
    regression for the Fig. 9 address-attribution bug."""
    from repro.core.fabric import shard_runs
    # (2, 4, 3) f32, shard axis 1 into [0,2) and [2,4)
    assert shard_runs((2, 4, 3), 4, 1, 0, 2) == [(0, 24), (48, 24)]
    assert shard_runs((2, 4, 3), 4, 1, 2, 4) == [(24, 24), (72, 24)]
    # axis 0 stays one contiguous run (golden-trace compatible)
    assert shard_runs((8, 6), 4, 0, 2, 4) == [(2 * 24, 2 * 24)]
    assert shard_runs((4,), 4, 0, 2, 2) == []          # empty shard

    fab = FabricCluster(2, link_config=LINK)
    fab.host.alloc("q", (2, 4, 3), np.float32)
    data = np.arange(24, dtype=np.float32).reshape(2, 4, 3)
    fab.host.host_write("q", data)
    fab.scatter("q", axis=1)
    hbuf = fab.host.buffers["q"]
    reads = sorted((t.addr - hbuf.addr, t.nbytes) for t in fab.log.txs
                   if t.kind == "read" and t.engine.startswith("h->"))
    assert reads == [(0, 24), (24, 24), (48, 24), (72, 24)]
    fab.gather("q", axis=1)
    assert np.array_equal(fab.host.host_read("q"), data)


def test_fabric_feeds_coverage():
    cov = CoverageModel()
    fab = FabricCluster(2, link_config=LINK, coverage=cov)
    fab.host.alloc("x", (16, 16), np.float32)
    fab.host.host_write("x", np.zeros((16, 16), np.float32))
    fab.host.alloc("w", (8, 8), np.float32)
    fab.host.host_write("w", np.zeros((8, 8), np.float32))
    fab.scatter("x")
    fab.broadcast("w")
    fab.gather("x")
    fab.devices[0].mem.alloc("g", (4,), np.float32)
    fab.devices[1].mem.alloc("g", (4,), np.float32)
    fab.all_reduce("g")
    fab.dev_copy(0, 1, "x", dst_name="x2")
    assert cov.covered("fabric"), cov.holes("fabric")
    assert sum(cov.counts["burst_size"].values()) > 0


# ------------------------------------------------- sharded sweeps (tentpole)
@pytest.mark.slow
def test_matmul_sweep_4dev_bit_identical_with_link_stalls():
    """Acceptance: 4-device systolic_matmul cells bit-identical to the
    single-device oracle in the SweepReport, with non-zero modeled
    inter-device link stalls."""
    sess = CoVerifySession(matmul_firmware,
                           fabric_firmware=matmul_fabric_firmware,
                           link_config=LINK)
    sess.register_op("mm", **matmul_backends(tile=32))
    sess.add_sweep("mm", ("oracle", "interpret", "compiled"),
                   [{"size": 128}], devices=(1, 4))
    report = sess.run(max_workers=4)
    assert report.passed, report.summary()
    (eq,) = report.equivalence.values()
    assert set(eq.backends) == {"oracle", "interpret", "compiled",
                                "oracle@4dev", "interpret@4dev",
                                "compiled@4dev"}
    by = {r.cell.group_member: r for r in report.cells}
    for be in ("oracle", "interpret", "compiled"):
        assert np.array_equal(by[be].outputs["c"],
                              by[f"{be}@4dev"].outputs["c"])
    for r in report.cells:
        if r.cell.devices > 1:
            assert r.link_stall > 0, r.cell.label
            # inter-device ports specifically, not just the host channel
            port_stall = sum(sum(c.per_engine_stall.values())
                             for n, c in r.links.items() if n != "host")
            assert port_stall >= 0 and r.links["host"] is not None


@pytest.mark.slow
def test_flash_sweep_4dev_bit_identical_with_link_stalls():
    """Acceptance: 4-device flash_attention cells bit-identical to the
    single-device oracle."""
    sess = CoVerifySession(flash_firmware,
                           fabric_firmware=flash_fabric_firmware,
                           link_config=LINK)
    sess.register_op("fa", **flash_backends())
    cfg = {"batch": 1, "heads": 8, "seq": 64, "dim": 16}
    sess.add_sweep("fa", ("oracle", "interpret"), [cfg], devices=(1, 4))
    report = sess.run(max_workers=4)
    assert report.passed, report.summary()
    by = {r.cell.group_member: r for r in report.cells}
    for be in ("oracle", "interpret"):
        assert np.array_equal(by[be].outputs["o"],
                              by[f"{be}@4dev"].outputs["o"])
    assert by["oracle@4dev"].link_stall > 0


def test_devices_sweep_seed_reproducibility():
    """Acceptance: same seed => identical fabric transaction-log digests
    across two runs of a sharded launch."""
    def digest():
        fab = FabricCluster(4, link_config=LINK, fault_plan=FaultPlan(3))
        fab.register_op("mm", **matmul_backends(tile=32, jit=False))
        matmul_fabric_firmware(fab, "mm", "oracle", size=64, tile=32)
        return fab.digest()

    assert digest() == digest()


def test_sweep_report_scaling_rows():
    sess = CoVerifySession(matmul_firmware,
                           fabric_firmware=matmul_fabric_firmware,
                           link_config=LINK)
    sess.register_op("mm", **matmul_backends(tile=32, jit=False))
    sess.add_sweep("mm", ("oracle",), [{"size": 64}], devices=(1, 2))
    report = sess.run(max_workers=2)
    assert report.passed
    rows = report.scaling()
    assert rows[0].startswith("op,backend,devices")
    assert len(rows) == 3
    assert ",1," in rows[1] and ",2," in rows[2]
    # to_rows carries the devices + link-stall columns too
    assert "link_stall_cycles" in report.to_rows()[0]


def test_fabric_cell_error_does_not_kill_sweep():
    def bad_firmware(fab, op, backend, **cfg):
        raise RuntimeError("boom")

    sess = CoVerifySession(matmul_firmware, fabric_firmware=bad_firmware,
                           link_config=LINK)
    sess.register_op("mm", **matmul_backends(tile=32, jit=False))
    sess.add_cell("mm", "oracle", {"size": 32}, devices=2)
    report = sess.run()
    assert not report.passed
    assert "RuntimeError" in report.cells[0].error


@pytest.mark.slow
def test_bench_fabric_scaling_quick_mode():
    """The scaling benchmark's quick mode reports 1/2/4-device crossbar
    rows plus a routed 4-device torus with per-hop stall columns, modeled
    cycles, and non-zero link stalls at every multi-device scale."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.bench_fabric_scaling import run
    rows = run(quick=True)
    assert rows[0].startswith("case,op,backend,devices,topology")
    body = [r.split(",") for r in rows[1:] if r.startswith("fabric,")]
    hops = [r.split(",") for r in rows[1:]
            if r.startswith("hop,") and not r.startswith("hop,op,")]
    assert {int(r[3]) for r in body} == {1, 2, 4}
    assert {r[4] for r in body} == {"crossbar", "torus2d"}
    for r in body:
        assert r[-1] == "True"
        if int(r[3]) > 1:
            assert float(r[6]) > 0          # link stalls modeled
        if r[4] != "crossbar":
            assert float(r[7]) >= float(r[8]) >= 0   # hop columns close
    # routed cells break down per switch port
    assert hops and all(h[4] == "torus2d" for h in hops)
    assert any(float(h[6]) > 0 for h in hops)


# ------------------------------------------------------- cluster serving
FLAGS = None


def _smoke_model():
    from repro.configs import get_config, smoke
    from repro.models import init_params
    from repro.models.transformer import RunFlags
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return cfg, params, RunFlags(attn_impl="chunked", q_chunk=16,
                                 kv_chunk=16)


def _submit(e, cfg, prompts, mx=5):
    for rid, p in prompts.items():
        e.mem.buffers["prompt_in"].array[:len(p)] = p
        e.csr.fb_write_32(e.csr.addr_of("SUBMIT_ID"), rid)
        e.csr.fb_write_32(e.csr.addr_of("SUBMIT_LEN"), len(p))
        e.csr.fb_write_32(e.csr.addr_of("SUBMIT_MAXNEW"), mx)
        e.csr.fb_write_32(e.csr.addr_of("DOORBELL"), 1)
    e.run_until_done()


@pytest.mark.slow
def test_cluster_serving_matches_single_engine():
    from repro.serving import ClusterServingEngine, ServingEngine
    cfg, params, flags = _smoke_model()
    single = ServingEngine(cfg, params, max_slots=3, max_len=64,
                           flags=flags)
    clu = ClusterServingEngine(cfg, params, n_devices=2, max_slots=2,
                               max_len=64, flags=flags)
    rng = np.random.default_rng(0)
    prompts = {rid: rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(5, 30)))
               for rid in range(6)}
    _submit(single, cfg, prompts)
    _submit(clu, cfg, prompts)
    assert single.completed == clu.completed == 6
    assert clu.csr.hw_get("COMPLETED") == 6
    assert clu.csr.hw_get("NDEV") == 2
    # round-robin placement across both device-local engines
    assert set(clu.placement.values()) == {0, 1}
    # identical generations regardless of placement
    for rid in prompts:
        assert single.requests[rid].out_tokens == \
            clu.requests[rid].out_tokens
    # prompt upload + token writeback both crossed the shared channel
    st = clu.fabric_stats()
    assert any(e.startswith("h->e") for e in st.per_engine_stall)
    assert any(e.startswith("e") and "->h" in e
               for e in st.per_engine_stall)
    # concurrent retirements contend on the channel
    assert sum(st.per_engine_stall.values()) > 0
    assert not clu.violations
    # reset + identical storm reproduces the transaction digest
    clu.reset()
    _submit(clu, cfg, prompts)
    d1 = clu.digest()
    clu.reset()
    _submit(clu, cfg, prompts)
    assert clu.digest() == d1


@pytest.mark.slow
def test_cluster_serving_rejects_propagate():
    from repro.serving import ClusterServingEngine
    cfg, params, flags = _smoke_model()
    clu = ClusterServingEngine(cfg, params, n_devices=2, max_slots=2,
                               max_len=64, flags=flags)
    clu.csr.fb_write_32(clu.csr.addr_of("SUBMIT_ID"), 0)
    clu.csr.fb_write_32(clu.csr.addr_of("SUBMIT_LEN"), 10_000)
    clu.csr.fb_write_32(clu.csr.addr_of("SUBMIT_MAXNEW"), 4)
    clu.csr.fb_write_32(clu.csr.addr_of("DOORBELL"), 1)
    assert any("SUBMIT_LEN" in v for v in clu.violations)
    assert 0 not in clu.placement
    clu.run_until_done()
    assert clu.completed == 0
    # the rejected submission must not burn engine 0's round-robin turn
    p = np.random.default_rng(3).integers(0, cfg.vocab_size, 8)
    clu.mem.buffers["prompt_in"].array[:8] = p
    clu.csr.fb_write_32(clu.csr.addr_of("SUBMIT_ID"), 1)
    clu.csr.fb_write_32(clu.csr.addr_of("SUBMIT_LEN"), 8)
    clu.csr.fb_write_32(clu.csr.addr_of("SUBMIT_MAXNEW"), 2)
    clu.csr.fb_write_32(clu.csr.addr_of("DOORBELL"), 1)
    assert clu.placement[1] == 0


@pytest.mark.slow
def test_cluster_rejects_cross_engine_duplicate_rid():
    """Regression: a duplicate in-flight SUBMIT_ID used to slip past the
    per-engine check when round-robin routed it to a different engine.
    The front-end must reject it cluster-wide; retired ids may recycle."""
    from repro.serving import ClusterServingEngine
    cfg, params, flags = _smoke_model()
    clu = ClusterServingEngine(cfg, params, n_devices=2, max_slots=2,
                               max_len=64, flags=flags)
    rng = np.random.default_rng(2)

    def ring(rid, mx=4):
        p = rng.integers(0, cfg.vocab_size, 10)
        clu.mem.buffers["prompt_in"].array[:10] = p
        clu.csr.fb_write_32(clu.csr.addr_of("SUBMIT_ID"), rid)
        clu.csr.fb_write_32(clu.csr.addr_of("SUBMIT_LEN"), 10)
        clu.csr.fb_write_32(clu.csr.addr_of("SUBMIT_MAXNEW"), mx)
        clu.csr.fb_write_32(clu.csr.addr_of("DOORBELL"), 1)

    ring(7)
    ring(7)                   # would land on the OTHER engine
    assert clu.violations == [
        "duplicate SUBMIT_ID 7: request still in flight"]
    clu.run_until_done()
    assert clu.completed == 1
    assert len(clu.requests[7].out_tokens) == 4
    # retired id recycles cleanly — and the merged view stays unambiguous
    ring(7, mx=2)
    assert len(clu.violations) == 1         # no new violation
    clu.run_until_done()
    assert clu.completed == 2
    assert len(clu.requests[7].out_tokens) == 2
    assert sum(7 in e.requests for e in clu.engines) == 1
    # recycle landing back on the SAME engine must re-arm the writeback
    # (a stale _written marker used to freeze COMPLETED forever)
    ring(8)                   # advance round-robin so 7 -> its old engine
    ring(7, mx=3)
    clu.run_until_done()
    assert clu.completed == 4
    assert clu.csr.hw_get("COMPLETED") == 4
    assert len(clu.requests[7].out_tokens) == 3
