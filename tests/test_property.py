"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.congestion import CongestionConfig, simulate
from repro.core.registers import RO, W1C, RegisterFile
from repro.core.transactions import Transaction
from repro.models.layers import apply_rope, softmax_cross_entropy
from repro.optim.compress import BLOCK, compress_decompress, ef_compress

# ---------------------------------------------------------------- congestion


@st.composite
def tx_streams(draw):
    n = draw(st.integers(1, 40))
    engines = draw(st.lists(st.sampled_from(["a", "b", "c"]), min_size=1,
                            max_size=3, unique=True))
    return [Transaction(0.0, draw(st.sampled_from(engines)), "read", 0,
                        draw(st.integers(1, 1 << 16))) for _ in range(n)]


@given(tx_streams(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_congestion_conservation_and_determinism(txs, seed):
    cfg = CongestionConfig(dos_prob=0.3, seed=seed)
    import copy
    r1 = simulate(copy.deepcopy(txs), cfg)
    r2 = simulate(copy.deepcopy(txs), cfg)
    # determinism under the seed
    assert r1.makespan == r2.makespan
    assert r1.per_engine_stall == r2.per_engine_stall
    # every transaction completes, after its issue time
    assert len(r1.timeline) == len(txs)
    assert all(t.complete > t.time for t in r1.timeline)
    # makespan is at least serial transfer time of all bytes
    serial = sum(t.nbytes for t in txs) / cfg.link_bytes_per_cycle
    assert r1.makespan >= serial
    # stalls are non-negative
    assert all(s >= 0 for s in r1.per_engine_stall.values())


# ------------------------------------------------------------------- fabric


@st.composite
def fabric_cases(draw):
    """Arbitrary (shape, device count, shard axis) scatter/gather cases —
    including uneven splits and more devices than rows."""
    nd = draw(st.integers(1, 3))
    shape = tuple(draw(st.integers(1, 12)) for _ in range(nd))
    n_dev = draw(st.integers(1, 6))
    axis = draw(st.integers(0, nd - 1))
    return shape, n_dev, axis


@given(fabric_cases(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_fabric_scatter_gather_roundtrip_bit_identical(case, seed):
    """Shard/gather round-trips leave buffers bit-identical for arbitrary
    shapes x device counts x axes (core/fabric.py)."""
    from repro.core.fabric import FabricCluster
    shape, n_dev, axis = case
    fab = FabricCluster(n_dev, link_config=CongestionConfig(
        dos_prob=0.1, seed=seed, max_burst_bytes=64))
    data = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    fab.host.alloc("x", shape, np.float32)
    fab.host.host_write("x", data)
    fab.scatter("x", axis=axis)
    # device shards are exactly the np.array_split slices
    for dev, sh in zip(fab.devices, np.array_split(data, n_dev, axis=axis)):
        assert np.array_equal(dev.mem.buffers["x"].array, sh)
    fab.host.buffers["x"].array[:] = 0
    fab.gather("x", axis=axis)
    assert np.array_equal(fab.host.host_read("x"), data)


@st.composite
def fabric_traffic(draw):
    n_bufs = draw(st.integers(1, 4))
    sizes = [draw(st.integers(1, 64)) for _ in range(n_bufs)]
    return sizes


@given(fabric_traffic(), fabric_traffic(), st.integers(2, 4))
@settings(max_examples=30, deadline=None)
def test_fabric_timing_monotonicity(base, extra, n_dev):
    """Adding contending traffic never decreases modeled completion time
    (DoS off: arbitration is work-conserving, so more traffic can only
    push the link-free horizon out)."""
    from repro.core.fabric import FabricCluster

    def run(extra_first):
        fab = FabricCluster(n_dev, link_config=CongestionConfig(
            dos_prob=0.0, max_burst_bytes=128))
        if extra_first:
            for j, rows in enumerate(extra):
                name = f"y{j}"
                fab.host.alloc(name, (rows, 4), np.float32)
                fab.host.host_write(name, np.zeros((rows, 4), np.float32))
                fab.broadcast(name)
        for j, rows in enumerate(base):
            name = f"x{j}"
            fab.host.alloc(name, (rows, 4), np.float32)
            fab.host.host_write(name, np.zeros((rows, 4), np.float32))
            fab.scatter(name)
            fab.gather(name)
        return fab.time

    assert run(extra_first=True) >= run(extra_first=False)


@st.composite
def routed_cases(draw):
    """Arbitrary (topology, shape, shard axis) routed-fabric cases over
    every core/topology.py builder at assorted device counts."""
    kind = draw(st.sampled_from(("ring", "torus2d", "fat_tree")))
    n_dev = draw(st.integers(2, 9))
    nd = draw(st.integers(1, 3))
    shape = tuple(draw(st.integers(1, 10)) for _ in range(nd))
    axis = draw(st.integers(0, nd - 1))
    return kind, n_dev, shape, axis


@given(routed_cases(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_routed_scatter_gather_bit_identical_to_single_device(case, seed):
    """Routing reshapes TIMING, never data: for any topology / device
    count / shape / axis, scatter->gather through the switched fabric
    (DoS on every link, switch ports included) round-trips the host
    buffer bit-identically to the 1-device crossbar oracle."""
    from repro.core.fabric import FabricCluster
    kind, n_dev, shape, axis = case
    data = np.random.default_rng(seed).normal(size=shape).astype(np.float32)

    def run(n, topology):
        fab = FabricCluster(n, topology=topology,
                            link_config=CongestionConfig(
                                dos_prob=0.1, seed=seed,
                                max_burst_bytes=64))
        fab.host.alloc("x", shape, np.float32)
        fab.host.host_write("x", data)
        fab.scatter("x", axis=axis)
        fab.host.buffers["x"].array[:] = 0
        fab.gather("x", axis=axis)
        return fab.host.host_read("x")

    oracle = run(1, None)
    routed = run(n_dev, kind)
    assert np.array_equal(oracle, data)
    assert np.array_equal(routed, oracle)


@given(st.integers(4, 12), st.integers(1, 32),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_routed_time_monotone_in_hop_count(n_dev, rows, seed):
    """At dos=0, a lone transfer's modeled completion is monotone in its
    switch-hop count: store-and-forward means every extra hop adds at
    least one flit's base latency, so a farther destination on the same
    ring can never complete earlier than a nearer one."""
    from repro.core.fabric import FabricCluster
    from repro.core.topology import ring

    topo = ring(n_dev)
    cfg = CongestionConfig(dos_prob=0.0, max_burst_bytes=128)
    prev = None
    # ring hop count from device 0 grows with min(d, n-d); walk dst along
    # increasing distance and require completion times to be sorted
    dsts = sorted(range(1, n_dev), key=lambda d: min(d, n_dev - d))
    for dst in dsts:
        fab = FabricCluster(n_dev, topology=topo, link_config=cfg)
        fab.alloc_sharded("x", (rows, 4), np.float32, axis=None)
        done = fab.dev_copy(0, dst, "x")
        hops = topo.n_hops(0, dst)
        if prev is not None:
            assert (hops, done) >= prev, \
                f"dst {dst}: {hops} hops done at {done}, after {prev}"
        prev = (hops, done)


# -------------------------------------------------------------------- replay


@st.composite
def replay_programs(draw):
    """Arbitrary bridge op sequences: a few buffers, then a random mix of
    device reads/writes, host writes, and kernel burst lists — under
    drawn congestion + fault-plan seeds (the hostile case for replay)."""
    n_bufs = draw(st.integers(1, 3))
    shapes = [(draw(st.integers(1, 24)), 4) for _ in range(n_bufs)]
    ops = []
    for _ in range(draw(st.integers(1, 18))):
        b = draw(st.integers(0, n_bufs - 1))
        kind = draw(st.sampled_from(["dev_read", "dev_write", "host_write",
                                     "burst"]))
        ops.append((kind, b, draw(st.integers(0, 2 ** 16))))
    return shapes, ops, draw(st.integers(0, 2 ** 20)), \
        draw(st.integers(0, 2 ** 20))


def _replay_session_and_program(case, interval):
    from repro.core import replay as rp
    from repro.core.bridge import FireBridge
    from repro.core.fuzz import FaultPlan
    shapes, ops, cong_seed, fault_seed = case

    def factory():
        return FireBridge(
            congestion=CongestionConfig(dos_prob=0.2, seed=cong_seed,
                                        max_burst_bytes=64),
            fault_plan=FaultPlan(seed=fault_seed))

    def program(rec):
        for i, (m, n) in enumerate(shapes):
            rec.do("alloc", f"b{i}", (m, n), np.float32)
        for kind, b, v in ops:
            name = f"b{b}"
            m, n = shapes[b]
            if kind == "dev_read":
                rec.do("dev_read", name, "dma")
            elif kind == "dev_write":
                rec.do("dev_write", name,
                       np.full((m, n), float(v % 97), np.float32), "dma")
            elif kind == "host_write":
                rec.do("host_write", name,
                       np.full((m, n), float(v % 89), np.float32))
            else:
                rec.do("log_burst_list",
                       [("eng_a", "read", 0x1000, 1 + v % 512),
                        ("eng_b", "write", 0x2000, 1 + v % 256)], None)

    return rp.DebugSession(factory, checkpoint_interval=interval), program


@given(replay_programs(), st.integers(1, 7), st.data())
@settings(max_examples=25, deadline=None)
def test_record_replay_digest_identity(case, interval, data):
    """Replay of ANY window of ANY recorded op sequence, at ANY checkpoint
    interval, reproduces the recorded canonical lines (and digest)
    bit-for-bit — fault injections and DoS stalls included
    (core/replay.py's central contract)."""
    sess, program = _replay_session_and_program(case, interval)
    rec = sess.record(program)
    n = rec.n_ops
    lo = data.draw(st.integers(0, n), label="lo")
    hi = data.draw(st.integers(lo, n), label="hi")
    w = sess.replay(rec, lo, hi)
    assert w.lines == rec.window_lines(lo, hi)
    assert w.digest() == rec.window_digest(lo, hi)


@given(replay_programs(), st.integers(1, 7), st.data())
@settings(max_examples=25, deadline=None)
def test_checkpoint_restore_roundtrip_replays_identically(case, interval,
                                                          data):
    """Restoring ANY transaction-boundary checkpoint and replaying to the
    end reproduces the uninterrupted run: identical final state
    fingerprint AND identical remaining transaction stream."""
    from repro.core import replay as rp
    sess, program = _replay_session_and_program(case, interval)
    rec = sess.record(program)
    ck = data.draw(st.sampled_from(rec.checkpoints), label="checkpoint")
    w = sess.replay(rec, ck.op_index, rec.n_ops)
    assert w.lines == rec.window_lines(ck.op_index, rec.n_ops)
    assert rp.state_fingerprint(w.target.get_state()) == \
        rec.final_fingerprint


# ----------------------------------------------------------------- registers


@given(st.lists(st.tuples(st.booleans(), st.integers(0, 5),
                          st.integers(0, 2 ** 32 - 1)), max_size=40))
@settings(max_examples=30, deadline=None)
def test_register_protocol_invariants(ops):
    rf = RegisterFile()
    rf.define("rw0", 0x0)
    rf.define("ro0", 0x4, access=RO, reset=0x1234)
    rf.define("w1c", 0x8, access=W1C, reset=0xFF)
    addrs = [0x0, 0x4, 0x8, 0xC, 0x10, 0x14]      # last three unmapped
    for is_write, ai, val in ops:
        if is_write:
            rf.fb_write_32(addrs[ai], val)
        else:
            rf.fb_read_32(addrs[ai])
    # RO register never changes
    assert rf.hw_get("ro0") == 0x1234
    # W1C only ever clears bits of its reset value
    assert rf.hw_get("w1c") & ~0xFF == 0
    # every unmapped access was flagged
    unmapped = sum(1 for w, ai, _ in ops if ai >= 3)
    assert len(rf.log.violations) >= unmapped and (
        unmapped == 0 or rf.log.violations)
    # transaction log is complete
    assert len(rf.log.txs) == len(ops)


# --------------------------------------------------------------- compression


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_int8_compression_error_bound(seed, nblocks):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(nblocks * BLOCK,)) *
                    rng.uniform(1e-6, 10), jnp.float32)
    cq = compress_decompress(g)
    # blockwise error bound: |x - q(x)| <= scale/2 = max|block| / 254
    # (relative slack: half-to-even hits the bound exactly and the f32
    # dequant multiply can land an ulp above it — found by hypothesis)
    blocks = np.asarray(g).reshape(-1, BLOCK)
    bound = np.abs(blocks).max(axis=1, keepdims=True) / 127.0
    err = np.abs(np.asarray(cq).reshape(-1, BLOCK) - blocks)
    assert (err <= bound * 0.5 * (1 + 1e-5) + 1e-9).all()


def test_error_feedback_preserves_sum():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(BLOCK * 2,)), jnp.float32)}
    err = {"w": jnp.zeros((BLOCK * 2,), jnp.float32)}
    total_sent = jnp.zeros_like(g["w"])
    total_true = jnp.zeros_like(g["w"])
    for _ in range(50):
        sent, err = ef_compress(g, err)
        total_sent = total_sent + sent["w"]
        total_true = total_true + g["w"]
    # EF: cumulative compressed stream tracks the true sum within one step's
    # quantization error (residual is bounded, not accumulating)
    resid = float(jnp.max(jnp.abs(total_sent - total_true)))
    one_step_bound = float(jnp.max(jnp.abs(g["w"]))) / 127.0 * 2
    assert resid <= one_step_bound * 2


# ----------------------------------------------------------------- numerics


@given(st.integers(0, 1000), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_rope_is_relative(offset, seed):
    """q_i . k_j after RoPE depends only on i - j (position-shift invariant),
    which is what makes the serving engine's left-padding exact."""
    key = jax.random.PRNGKey(seed)
    D, S = 16, 8
    q = jax.random.normal(jax.random.fold_in(key, 0), (1, S, 1, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 1, D))
    pos = jnp.arange(S)[None, :]
    s0 = jnp.einsum("bqhd,bkhd->bqk", apply_rope(q, pos, "full"),
                    apply_rope(k, pos, "full"))
    s1 = jnp.einsum("bqhd,bkhd->bqk", apply_rope(q, pos + offset, "full"),
                    apply_rope(k, pos + offset, "full"))
    assert float(jnp.max(jnp.abs(s0 - s1))) < 1e-3


@given(st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_cross_entropy_matches_onehot(V, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(3, 5, V)) * 5, jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=(3, 5)), jnp.int32)
    loss, _ = softmax_cross_entropy(logits, labels)
    onehot = jax.nn.one_hot(labels, V)
    ref = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))
    assert abs(float(loss) - float(ref)) < 1e-4


# -------------------------------------------------------------- hlo profiler


def test_hlo_profiler_scan_trip_correction():
    """A 12-step scanned matmul must report 12x the flops of its body."""
    from repro.core.hlo_profiler import profile_hlo

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y.sum()

    def direct(x, w):
        return jnp.tanh(x @ w).sum()

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ps = profile_hlo(jax.jit(scanned).lower(x, w).compile().as_text(), 1)
    pd = profile_hlo(jax.jit(direct).lower(x, w).compile().as_text(), 1)
    assert abs(ps.flops - 12 * pd.flops) / (12 * pd.flops) < 0.05


def test_hlo_profiler_collective_bytes_fixture():
    """Ring-model byte accounting on a hand-written post-SPMD HLO module."""
    from repro.core.hlo_profiler import profile_hlo
    hlo = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[512,256]{1,0} all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={0}
  %sl = f32[128,256]{1,0} slice(%ag), slice={[0:128], [0:256]}
  ROOT %cp = f32[128,256]{1,0} collective-permute(%sl), source_target_pairs={{0,1}}
}
"""
    p = profile_hlo(hlo, 8)
    n = 128 * 256 * 4
    expect = 2 * n * 3 // 4 + (4 * n) * 3 // 4 + n
    assert abs(p.collective_bytes - expect) < 1e-6
    assert {c.kind for c in p.collectives} == {
        "all-reduce", "all-gather", "collective-permute"}


# ------------------------------------------- open-loop serving admission
@st.composite
def arrival_plans(draw):
    """Arbitrary open-loop arrival traces x KV page-pool geometries."""
    page_size = draw(st.sampled_from([4, 8]))
    n_pages = draw(st.integers(2, 5))
    entries = []
    t = 0
    for rid in range(draw(st.integers(1, 5))):
        t += draw(st.integers(0, 400))
        pl = draw(st.integers(1, 10))
        mx = draw(st.integers(1, 5))
        entries.append((rid, float(t), tuple(range(1, pl + 1)), mx))
    return entries, n_pages, page_size


@given(arrival_plans())
@settings(max_examples=15, deadline=None)
def test_open_loop_admission_invariants(plan):
    """Arbitrary arrival trace x pool geometry: every pool-feasible
    request retires with exactly its token budget and monotone lifecycle
    stamps, every infeasible request is rejected loudly at the doorbell
    (never silently starved), and the pool drains back to fully free —
    no page leaks, no stranded requests, under ANY stimulus.  The
    deterministic fallback for environments without hypothesis is
    tests/test_serving_slo.py::test_admission_invariants_randomized."""
    import test_serving_slo as slo
    entries, n_pages, page_size = plan
    slo.check_admission_invariants(entries, n_pages, page_size)


# ----------------------------------------------------- counter instrumentation


@given(replay_programs(), st.integers(1, 7), st.data())
@settings(max_examples=20, deadline=None)
def test_counter_stream_replay_and_monotonicity(case, interval, data):
    """Arbitrary recorded op sequence, at ANY checkpoint interval: every
    counter declared ``monotone`` is non-decreasing across samples, and
    replaying ANY [lo, hi) window regenerates a counter stream that is an
    exact prefix of the recorded one (full-range replay regenerates the
    whole stream) — the always-on instrumentation is as replayable as the
    transaction log it rides on.  The deterministic fallback for
    environments without hypothesis is
    tests/test_counters.py::test_counter_replay_invariants_randomized."""
    import test_counters as tc
    shapes, ops, _, _ = case
    n = len(shapes) + len(ops)
    lo = data.draw(st.integers(0, n), label="lo")
    hi = data.draw(st.integers(lo, n), label="hi")
    tc.check_counter_replay_invariants(case, interval, lo, hi)
