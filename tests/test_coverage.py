"""Functional-coverage model (core/coverage.py): bin bookkeeping, hole
naming, and the acceptance gate — the 200-scenario protocol fuzz run must
reach 100% of the register-protocol bins."""
import numpy as np
import pytest

from repro.core import CoverageModel, ProtocolFuzzer
from repro.core.coverage import (BURST_BUCKETS, FAULT_BINS, GROUPS,
                                 PROTOCOL_BINS)
from repro.core.fuzz import DEFAULT_RATES


def test_declared_bins_and_drift_guards():
    cov = CoverageModel()
    for g, bins in GROUPS.items():
        assert cov.percent(g) == 0.0 and not cov.covered(g)
        assert cov.holes(g) == [f"{g}.{b}" for b in bins]
    cov.hit("protocol", "doorbell_ok")
    assert cov.counts["protocol"]["doorbell_ok"] == 1
    with pytest.raises(KeyError):
        cov.hit("protocol", "no_such_bin")
    with pytest.raises(KeyError):
        cov.hit("no_such_group", "doorbell_ok")


def test_fault_bins_match_fuzz_taxonomy():
    # the coverage bin set is pinned to the injected-fault taxonomy; if a
    # fault kind is added to fuzz.DEFAULT_RATES this must be updated too
    assert set(FAULT_BINS) == set(DEFAULT_RATES)


def test_burst_bucketing_boundaries():
    cov = CoverageModel()
    cov.hit_burst(4)            # CSR word
    cov.hit_burst(64)
    cov.hit_burst(65)
    cov.hit_burst(1024)
    cov.hit_burst(4096)
    cov.hit_burst(4097)
    c = cov.counts["burst_size"]
    assert c == {"le_64B": 2, "le_1KB": 2, "le_4KB": 1, "gt_4KB": 1}
    assert cov.covered("burst_size")


def test_congestion_bucketing():
    cov = CoverageModel()
    cov.hit_congestion(0.0)
    cov.hit_congestion(12.5)
    assert cov.counts["congestion"] == {"free": 1, "stalled": 1}


def test_report_names_every_hole():
    cov = CoverageModel()
    for b in PROTOCOL_BINS:
        if b not in ("poll_timeout", "doorbell_busy"):
            cov.hit("protocol", b)
    rep = cov.report(groups=["protocol"])
    assert "UNCOVERED" in rep
    assert "protocol.poll_timeout" in rep
    assert "protocol.doorbell_busy" in rep
    assert "protocol.doorbell_ok" not in rep.split("UNCOVERED")[1]
    cov.hit("protocol", "poll_timeout")
    cov.hit("protocol", "doorbell_busy")
    assert "no uncovered bins" in cov.report(groups=["protocol"])
    assert cov.percent("protocol") == 100.0


def test_routed_fabric_closes_interconnect_coverage():
    """Satellite gate: the topology / hops / credit_stall groups the
    switch layer feeds all close under one short routed run per topology
    kind (plus the crossbar default), and the bin set is pinned to
    core/topology.py's builder registry."""
    from repro.core.coverage import TOPOLOGY_BINS
    from repro.core.fabric import FabricCluster
    from repro.core.topology import TOPOLOGY_KINDS, fat_tree, ring, torus2d

    assert set(TOPOLOGY_BINS) == {"crossbar"} | set(TOPOLOGY_KINDS)
    cov = CoverageModel()
    FabricCluster(1, coverage=cov)                # crossbar default

    def run(topology, src, dst):
        fab = FabricCluster(topology.n_devices, coverage=cov,
                            topology=topology)
        fab.alloc_sharded("x", (64,), np.float32, axis=None)
        fab.dev_copy(src, dst, "x")

    run(fat_tree(4, leaf_width=4), 0, 1)          # h0: same leaf switch
    run(ring(4), 0, 1)                            # h1: ring neighbours
    run(torus2d(8), 0, 5)                         # h2: one x + one y hop
    run(ring(8), 0, 4)                            # h3plus: 4 hops around
    assert cov.covered("topology"), cov.holes("topology")
    assert cov.covered("hops"), cov.holes("hops")
    # credit exhaustion: broadcasting through a credits=1 ring funnels
    # two journeys over switch 0's clockwise egress port, so the second
    # flit train must wait for the first to drain its single credit
    fab = FabricCluster(4, coverage=cov, topology=ring(4, credits=1))
    fab.host.alloc("b", (4096,), np.float32)
    fab.broadcast("b")
    assert cov.covered("credit_stall"), cov.holes("credit_stall")
    assert cov.counts["credit_stall"]["waited"] > 0


def test_hit_is_thread_safe_under_pool_hammering():
    """Regression for the lost-update race: ``counts[g][b] += n`` is a
    load/add/store read-modify-write, and CoVerifySession.run executes
    cells on a ThreadPoolExecutor that may share one coverage sink — any
    thread switch between the load and the store drops increments.  The
    bin dict is instrumented with a Python-level ``__getitem__`` that
    yields the GIL inside that window, turning the latent interleaving
    into a deterministic one: pre-fix this loses ~half the hits; with the
    per-model lock the totals are exact."""
    import time
    from concurrent.futures import ThreadPoolExecutor

    class PreemptingDict(dict):
        # a legal thread-switch point between the += load and store
        def __getitem__(self, k):
            v = dict.__getitem__(self, k)
            time.sleep(0)
            return v

    cov = CoverageModel()
    cov.counts["protocol"] = PreemptingDict(cov.counts["protocol"])
    n_threads, n_hits = 8, 2_000

    def hammer(_):
        for _ in range(n_hits):
            cov.hit("protocol", "doorbell_ok")
    with ThreadPoolExecutor(max_workers=n_threads) as ex:
        list(ex.map(hammer, range(n_threads)))
    assert cov.counts["protocol"]["doorbell_ok"] == n_threads * n_hits


def test_counts_roundtrip_and_new_bin_detection():
    """Sparse snapshot round-trip (the runfarm's per-unit record format)
    and merge_counts naming exactly the newly covered bins in
    deterministic group.bin order."""
    a = CoverageModel()
    a.hit("protocol", "w1c_clear", 3)
    a.hit("burst_size", "le_64B", 7)
    counts = a.to_counts()
    assert counts == {"protocol": {"w1c_clear": 3},
                      "burst_size": {"le_64B": 7}}
    b = CoverageModel.from_counts(counts)
    assert b.counts == a.counts
    merged = CoverageModel()
    merged.hit("protocol", "w1c_clear")           # already covered
    new = merged.merge_counts(counts)
    assert new == ["burst_size.le_64B"]           # only the fresh bin
    assert merged.counts["protocol"]["w1c_clear"] == 4
    with pytest.raises(KeyError):                 # drift guard survives
        merged.merge_counts({"protocol": {"bogus": 1}})
    # models ship across processes: pickling drops and re-grows the lock
    import pickle
    c = pickle.loads(pickle.dumps(a))
    assert c.counts == a.counts
    c.hit("protocol", "poll_ok")


def test_merge_accumulates():
    a, b = CoverageModel(), CoverageModel()
    a.hit("protocol", "w1c_clear", 2)
    b.hit("protocol", "w1c_clear", 3)
    b.hit("protocol", "poll_ok")
    a.merge(b)
    assert a.counts["protocol"]["w1c_clear"] == 5
    assert a.counts["protocol"]["poll_ok"] == 1


@pytest.mark.slow
def test_fuzz_acceptance_run_closes_protocol_coverage():
    """Acceptance: the 200-scenario fuzz run reaches 100% of the protocol
    bins (and the shared-stimulus bins it also feeds), and the report
    names any hole it finds in the not-exercised groups."""
    fz = ProtocolFuzzer(seed=0, layers=("bridge", "registers"))
    report = fz.run(200)
    assert report.passed, report.summary()
    cov = report.coverage
    assert cov is fz.coverage
    assert cov.covered("protocol"), \
        f"uncovered protocol bins: {cov.holes('protocol')}"
    assert cov.percent("protocol") == 100.0
    assert cov.covered("fault_kind"), cov.holes("fault_kind")
    assert cov.covered("burst_size"), cov.holes("burst_size")
    assert cov.covered("congestion"), cov.holes("congestion")
    # the report names exactly the holes of the layers that did not run
    rep = cov.report()
    assert "protocol     8/8 = 100.0%" in rep
    for hole in cov.holes("serving") + cov.holes("fabric"):
        assert hole in rep
    # summary plumbing for benchmarks / the CLI
    s = report.summary()
    assert s["coverage"]["protocol"]["percent"] == 100.0
    assert s["coverage"]["protocol"]["holes"] == []
