"""Run-farm campaign orchestrator (src/repro/runfarm/): cross-process
determinism tier.

The load-bearing bar: same campaign seed ⇒ byte-identical merged
coverage, per-unit digest set, and final campaign digest at ANY worker
count (0 = the sequential in-process oracle, 1/2/8 = spawned process
pools), across a SIGKILL'd worker mid-campaign, and across a clean
interrupt + resume from the JSONL store.
"""
import json

import pytest

from repro.core.fuzz import FaultPlan
from repro.runfarm import (CampaignInterrupted, CampaignManager,
                           ResultStore, execute_unit, fork_seed,
                           fuzz_units, golden_units, serving_units,
                           sweep_units, unit_uid)


def _campaign(tmp, name, workers, **kw):
    units = fuzz_units(seed=42, n_scenarios=300, batch=75,
                       layers=("registers",))
    return CampaignManager(tmp / name, units, seed=42, workers=workers,
                           generations=2, children_per_parent=2,
                           max_parents=3, **kw)


def _det(res):
    """The determinism-gated view of a campaign result."""
    return (res.digest,
            {u: res.records[u]["digest"] for u in res.uids},
            res.coverage.counts,
            res.report["deterministic"])


# ------------------------------------------------------------ unit model
def test_unit_seeds_fork_like_fault_plans():
    """Unit seeds use the FaultPlan.fork construction, so a unit's
    stimulus is a pure function of (campaign seed, uid) — never of
    scheduling."""
    assert fork_seed(42, "g00/u00003") == \
        FaultPlan(42).fork("g00/u00003").seed
    units = fuzz_units(seed=42, n_scenarios=100, batch=30)
    assert [u.uid for u in units] == [unit_uid(0, i) for i in range(4)]
    assert [u.params["count"] for u in units] == [30, 30, 30, 10]
    again = fuzz_units(seed=42, n_scenarios=100, batch=30)
    assert [(u.seed, u.payload_hash()) for u in units] == \
        [(u.seed, u.payload_hash()) for u in again]
    # payload hash is an input-identity: any param change must move it
    other = fuzz_units(seed=42, n_scenarios=100, batch=30,
                       rates={"dma_delay": 0.5})
    assert other[0].payload_hash() != units[0].payload_hash()


def test_store_tolerates_torn_tail_and_latest_wins(tmp_path):
    """A campaign killed mid-append leaves at most one torn JSONL line;
    load() must skip it (the unit just re-runs) and keep the latest
    record per uid."""
    store = ResultStore(tmp_path / "results.jsonl")
    store.append({"uid": "g00/u00000", "digest": "aaa", "ok": True})
    store.append({"uid": "g00/u00001", "digest": "bbb", "ok": True})
    store.append({"uid": "g00/u00000", "digest": "ccc", "ok": True})
    store.close()
    with open(tmp_path / "results.jsonl", "a") as fh:
        fh.write('{"uid": "g00/u00002", "digest": "tor')   # torn tail
    recs = ResultStore(tmp_path / "results.jsonl").load()
    assert set(recs) == {"g00/u00000", "g00/u00001"}
    assert recs["g00/u00000"]["digest"] == "ccc"            # latest wins
    d1 = ResultStore.final_digest(recs)
    d2 = ResultStore.final_digest(recs, uids=["g00/u00001"])
    assert d1 != d2 and len(d1) == 64


def test_sequential_campaign_reproduces_and_resumes(tmp_path):
    """workers=0 is the oracle: two fresh runs agree bit-for-bit, and a
    re-run over the same store executes nothing yet reports the same
    digest, coverage, and trajectory."""
    a = _campaign(tmp_path, "a", 0).run()
    b = _campaign(tmp_path, "b", 0).run()
    assert _det(a) == _det(b)
    assert a.passed and len(a.uids) > 4     # gen 0 + mutation children
    resumed = _campaign(tmp_path, "a", 0).run()
    assert _det(resumed) == _det(a)
    assert resumed.report["timing"]["units_resumed_from_store"] == \
        len(a.uids)


def test_spec_drift_invalidates_stored_records(tmp_path):
    """Same uid but different unit payload (spec changed between runs)
    must re-run, not silently reuse the stale record."""
    units = fuzz_units(seed=1, n_scenarios=40, batch=20)
    res = CampaignManager(tmp_path / "c", units, seed=1).run()
    drifted = fuzz_units(seed=1, n_scenarios=40, batch=10)
    assert drifted[0].uid == units[0].uid           # same uid, new payload
    assert drifted[0].payload_hash() != units[0].payload_hash()
    res2 = CampaignManager(tmp_path / "c", drifted, seed=1).run()
    assert res2.report["timing"]["units_resumed_from_store"] == 0
    assert res2.digest != res.digest


def test_interrupt_then_resume_reproduces_digest(tmp_path):
    """A campaign stopped cleanly after N units resumes from the store
    and lands on the oracle digest, skipping exactly the stored units."""
    oracle = _campaign(tmp_path, "oracle", 0).run()
    with pytest.raises(CampaignInterrupted):
        _campaign(tmp_path, "intr", 0, interrupt_after=2).run()
    resumed = _campaign(tmp_path, "intr", 0).run()
    assert _det(resumed) == _det(oracle)
    assert resumed.report["timing"]["units_resumed_from_store"] == 2


def test_coverage_guided_scheduling_is_plateau_bounded(tmp_path):
    """Generation g+1 mutates only seeds whose results newly covered
    bins; once a generation finds nothing new the campaign stops even
    with generation budget left."""
    units = fuzz_units(seed=7, n_scenarios=200, batch=50)
    res = CampaignManager(tmp_path / "c", units, seed=7, workers=0,
                          generations=10, children_per_parent=2,
                          max_parents=2).run()
    traj = res.report["deterministic"]["trajectory"]
    assert len(traj) < 10                   # plateau stop, not budget stop
    assert traj[0]["new_bins"] > 0
    assert traj[-1]["new_bins"] == 0
    # lineage is recorded: every generation>0 unit names its parent
    gen1 = [u for u in res.uids if u.startswith("g01/")]
    assert gen1
    for rec in (res.records[u] for u in gen1):
        assert rec["scenarios"] == 50       # params inherited from parent


def test_failure_harvesting_shrinks_and_bundles(tmp_path):
    """A failing unit ships a worker-side harvest (the existing
    ProtocolFuzzer.shrink replay lane) and the manager persists it as a
    self-contained bundle under <campaign>/bundles/."""
    units = fuzz_units(seed=5, n_scenarios=2, batch=2, layers=("bridge",),
                       bridge_ops=[2, 4], mm_bug=(1, 2, 1.0))
    res = CampaignManager(tmp_path / "c", units, seed=5).run()
    assert not res.passed
    assert res.bundles, "planted bug produced no bundle"
    bundle = json.loads(res.bundles[0].read_text())
    h = bundle["harvest"]
    assert h["layer"] == "bridge"
    assert 1 <= h["shrunk_ops"] <= h["full_ops"]
    assert "divergence" in h["failures"][0]
    # the bundle is seed-closed: re-executing the recorded unit
    # reproduces the same failing digest
    from repro.runfarm.units import WorkUnit
    redo = execute_unit(WorkUnit.from_json(bundle["unit"]))
    assert not redo.ok
    assert redo.digest == res.records[res.uids[0]]["digest"]


def test_sweep_and_golden_units_run_in_farm(tmp_path):
    """The farm shards CoVerifySession sweep slices and golden-trace
    regeneration alongside fuzz batches; sweep digests are stable and
    golden units diff against the committed traces."""
    su = sweep_units(seed=3, configs=[{"size": 32}, {"size": 64}],
                     configs_per_unit=1)
    ra = CampaignManager(tmp_path / "s1", su, seed=3).run()
    rb = CampaignManager(tmp_path / "s2", su, seed=3).run()
    assert ra.passed and ra.digest == rb.digest
    assert ra.coverage.counts == rb.coverage.counts
    gu = golden_units(["single_device_launch", "faulty_fuzz"])
    rg = CampaignManager(tmp_path / "g", gu).run()
    assert rg.passed, [rg.records[u]["failures"] for u in rg.uids]


def test_serving_units_run_in_farm(tmp_path):
    """Open-loop serving units (tentpole lane): the farm shards (trace x
    pool x devices) cells, each unit's SLO digest is a pure function of
    its uid, admission invariants hold worker-side, and a tight pool
    surfaces deferred-admission coverage."""
    su = serving_units(
        seed=9,
        traces=[{"kind": "bursty",
                 "params": {"n_requests": 8, "burst_size": 4,
                            "gap_between": 400.0}}],
        pools=[{"kv_pages": 3, "kv_page_size": 8}],
        devices=(1, 2))
    assert [u.kind for u in su] == ["serving", "serving"]
    assert su[0].payload_hash() != su[1].payload_hash()
    ra = CampaignManager(tmp_path / "v1", su, seed=9).run()
    rb = CampaignManager(tmp_path / "v2", su, seed=9).run()
    assert ra.passed, [ra.records[u]["failures"] for u in ra.uids]
    assert ra.digest == rb.digest
    assert ra.coverage.counts == rb.coverage.counts
    # the 3-page pool oversubscribes a 4-burst: admission control must
    # have deferred at least once, and the arrivals group saw the shape
    assert ra.coverage.counts["arrivals"]["bursty"] >= 2
    assert ra.coverage.counts["arrivals"]["deferred"] >= 1


# -------------------------------------------- cross-process determinism
def test_two_worker_pool_matches_sequential_oracle(tmp_path):
    """Smoke-lane cross-process gate: a 2-worker spawned pool reproduces
    the sequential oracle's digest, per-unit digests, merged coverage,
    and deterministic report slice."""
    oracle = _campaign(tmp_path, "w0", 0).run()
    pool = _campaign(tmp_path, "w2", 2).run()
    assert _det(pool) == _det(oracle)
    # utilization accounting saw both workers
    assert len(pool.report["timing"]["per_worker"]) == 2


@pytest.mark.slow
def test_worker_counts_1_2_8_and_sigkill_resume_match_oracle(tmp_path):
    """The ISSUE's determinism tier: same campaign seed at 1/2/8 workers
    ⇒ identical merged coverage summary and per-unit digests; SIGKILL a
    worker mid-campaign and the respawned pool still lands on the oracle
    digest; a killed-then-resumed campaign reports identically."""
    oracle = _campaign(tmp_path, "w0", 0).run()
    for n in (1, 2, 8):
        res = _campaign(tmp_path, f"w{n}", n).run()
        assert _det(res) == _det(oracle), f"workers={n} diverged"
    # SIGKILL worker 0 before its 2nd unit: unit re-enqueued, worker
    # respawned, digest unchanged
    killed = _campaign(tmp_path, "kill", 2,
                       kill_worker_after={0: 1}).run()
    assert _det(killed) == _det(oracle)
    assert killed.report["timing"]["workers_respawned"] >= 1
    # clean interrupt of a POOL campaign, then resume on fresh workers
    with pytest.raises(CampaignInterrupted):
        _campaign(tmp_path, "intr", 2, interrupt_after=2).run()
    resumed = _campaign(tmp_path, "intr", 2).run()
    assert _det(resumed) == _det(oracle)
    assert resumed.report["timing"]["units_resumed_from_store"] >= 2
