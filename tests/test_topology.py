"""Switched interconnect (core/topology.py + core/switch.py): static
routing tables, credit-based flow control, and the routed-fabric
acceptance surface — 16-device ring and 2D-torus sharded launches
bit-identical to the 1-device crossbar oracle with nonzero per-hop
switch-port stalls, profiler closure bit-exact on every switch-port
channel, and time-travel replay / divergence bisection holding through
routed runs (switch queue/credit state in checkpoints)."""
import numpy as np
import pytest

from repro.core import (FABRIC_LINK, CongestionConfig, CoVerifySession,
                        FabricCluster, FaultPlan, SwitchFabric, SwitchPort,
                        Topology, build_topology, fat_tree, ring, torus2d)
from repro.core import replay as rp
from repro.core.topology import TOPOLOGY_KINDS
from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                 matmul_fabric_firmware,
                                                 matmul_firmware)

LINK = FABRIC_LINK


# ------------------------------------------------------------- topologies
def test_ring_routes_shortest_way_clockwise_ties():
    t = ring(6)
    assert t.n_switches == 6 and t.attach == tuple(range(6))
    assert t.n_hops(0, 1) == 1 and t.n_hops(0, 5) == 1
    assert t.n_hops(0, 2) == 2 and t.n_hops(0, 4) == 2
    # even-ring antipode: both ways are 3 hops; clockwise declared first
    hops = [t.edges[k] for k in t.route(0, 3)]
    assert hops == [(0, 1), (1, 2), (2, 3)]
    assert t.route(2, 2) == ()


def test_torus_routes_x_before_y():
    t = torus2d(16)                     # 4x4 grid
    # 0 -> 5 is one +x then one +y; x-first declaration order means the
    # BFS table takes the x hop first
    hops = [t.edges[k] for k in t.route(0, 5)]
    assert hops == [(0, 1), (1, 5)]
    assert t.n_hops(0, 15) == 2         # wraparound both dims
    with pytest.raises(ValueError):
        torus2d(10, rows=4)             # 10 does not tile into 4 rows


def test_fat_tree_groups_and_spine_spread():
    t = fat_tree(8, leaf_width=2, spines=2)
    assert t.groups() == [[0, 1], [2, 3], [4, 5], [6, 7]]
    assert t.n_hops(0, 1) == 0          # same leaf: zero switch hops
    assert t.n_hops(0, 7) == 2          # leaf -> spine -> leaf
    # static spine rotation: different source leaves prefer different
    # spines, so uplink load spreads without adaptive routing
    up = {t.edges[t.route(2 * leaf, (2 * leaf + 2) % 8)[0]][1]
          for leaf in range(4)}
    assert len(up) == 2
    # single-leaf degenerate tree has no switches to cross
    assert fat_tree(3, leaf_width=4).n_hops(0, 2) == 0


def test_topology_validation_and_registry():
    assert set(TOPOLOGY_KINDS) == {"ring", "torus2d", "fat_tree"}
    assert build_topology("ring", 4).kind == "ring"
    with pytest.raises(ValueError):
        build_topology("mesh3d", 4)
    with pytest.raises(ValueError):
        Topology("bad", 2, 1, (0,), ())          # attach len mismatch
    with pytest.raises(ValueError):
        Topology("bad", 1, 1, (0,), ((0, 1),))   # switch id out of range
    with pytest.raises(ValueError):              # disconnected graph
        Topology("bad", 2, 2, (0, 1), ()).route(0, 1)
    with pytest.raises(ValueError):              # device-count mismatch
        FabricCluster(4, topology=ring(8))


# ----------------------------------------------------------- credit model
def test_credit_window_gates_and_accounts():
    p = SwitchPort("sw0->sw1", CongestionConfig(), credits=2)
    assert p.acquire(10.0) == 10.0               # window empty
    p.release([50.0, 80.0])                      # two flits in flight
    assert p.acquire(20.0) == 50.0               # full window: wait oldest
    assert p.credit_stall == 30.0 and p.credit_waits == 1
    p.release([120.0])                           # keeps the 2 largest
    assert p._inflight == [80.0, 120.0]
    assert p.acquire(90.0) == 90.0               # one credit freed by 90
    assert p.credit_grants == 2
    # checkpoint/restore round-trips the window and counters
    st = p.get_state()
    q = SwitchPort("sw0->sw1", CongestionConfig(), credits=2)
    q.set_state(st)
    assert q._inflight == p._inflight
    assert q.credit_stall == p.credit_stall
    assert q.acquire(0.0) == p.acquire(0.0)


def test_switch_port_seeds_decorrelated():
    sw = SwitchFabric(ring(4), CongestionConfig(dos_prob=0.2, seed=3))
    seeds = {p.link.cfg.seed for p in sw.ports}
    assert len(seeds) == len(sw.ports)           # one DoS stream per port
    # and none collide with the device-port seeds (seed+1..seed+n)
    assert seeds.isdisjoint({3 + i for i in range(5)})


# ------------------------------------------ acceptance: 16-device routing
@pytest.mark.parametrize("kind", ["ring", "torus2d"])
def test_16dev_sharded_launch_bit_identical_with_hop_stalls(kind):
    """The tentpole acceptance: a 16-device routed sharded_launch gathers
    results bit-identical to the 1-device oracle, with nonzero per-hop
    switch-port stalls and bit-exact profiler closure on every
    switch-port channel."""
    def run(n, topology):
        fab = FabricCluster(n, topology=topology, link_config=LINK,
                            profile=True)
        fab.register_op("mm", **matmul_backends(tile=32, jit=False))
        matmul_fabric_firmware(fab, "mm", "oracle", size=64)
        return fab

    oracle = run(1, None)
    fab = run(16, kind)
    for name, arr in oracle.outputs().items():
        assert np.array_equal(fab.outputs()[name], arr), name
    # per-hop stall readout: the switch ports really arbitrated flits,
    # and at least one hop congested
    stats = fab.switch.port_stats()
    assert sum(s["flits"] for s in stats.values()) > 0
    assert sum(s["stall"] for s in stats.values()) > 0
    # profiler closure stays bit-exact on every channel, switch ports
    # included (one channel per port)
    prof = fab.profiler()
    sw_chans = [c for c in prof.channels if c.name.startswith("fabric/sw")]
    assert len(sw_chans) == len(fab.switch.ports)
    for ch in prof.channels:
        bd = ch.breakdown
        assert sum(bd.cycles.values()) == ch.horizon == bd.total, ch.name
        assert ch.residual < 1e-3, (ch.name, ch.residual)


def test_topology_sweep_axis_diffs_against_single_device_oracle():
    """CoVerifySession's topology= axis: routed multi-device cells join
    the same (op, config) equivalence group as the 1-device oracle, and
    the report distinguishes members by topology."""
    sess = CoVerifySession(matmul_firmware,
                           fabric_firmware=matmul_fabric_firmware,
                           link_config=LINK)
    sess.register_op("mm", **matmul_backends(tile=32, jit=False))
    cells = sess.add_sweep("mm", ("oracle",), [{"size": 64}],
                           devices=(1, 8), topologies=(None, "torus2d"))
    # topologies only fan out the multi-device counts
    assert [(c.devices, c._topo_kind) for c in cells] == \
        [(1, None), (8, None), (8, "torus2d")]
    report = sess.run(max_workers=1)
    assert report.passed, report.summary()
    members = {r.cell.group_member for r in report.cells}
    assert members == {"oracle", "oracle@8dev", "oracle@8dev@torus2d"}
    routed = next(r for r in report.cells if r.cell.topology is not None)
    assert any(k.startswith("sw:") for k in routed.links)


# --------------------------------------------- replay through routed runs
def _torus_session(label):
    def factory():
        fab = FabricCluster(8, topology="torus2d",
                            link_config=CongestionConfig(
                                link_bytes_per_cycle=64.0,
                                base_latency=100.0, max_burst_bytes=4096,
                                dos_prob=0.05, seed=11),
                            fault_plan=FaultPlan(seed=13))
        return fab

    return rp.DebugSession(factory, checkpoint_interval=3, label=label)


def _torus_program(grad_scale=1.0):
    def program(rec):
        rng = np.random.default_rng(17)
        act = rng.normal(size=(32, 8)).astype(np.float32)
        rec.do("host_alloc", "act", act.shape, np.float32)
        rec.do("host_write", "act", act)
        rec.do("scatter", "act", 0)
        for i in range(8):
            rec.do("dev_alloc", i, "grad", (8, 8), np.float32)
            rec.do("dev_host_write", i, "grad",
                   np.full((8, 8), grad_scale * (i + 1), np.float32))
        rec.do("all_reduce", "grad", "sum")
        rec.do("dev_copy", 0, 5, "grad", "grad2")
        rec.do("gather", "act", 0)
    return program


def test_routed_run_checkpoints_carry_switch_state():
    sess = _torus_session("torus_ckpt")
    rec = sess.record(_torus_program())
    state = rec.target.get_state()
    assert state["switch"] is not None
    ports = state["switch"]["ports"]
    assert len(ports) == len(rec.target.switch.ports)
    # the run really exercised flow control, and the window survives a
    # state round-trip
    assert any(p["inflight"] for p in ports)
    rec.target.set_state(state)
    assert rec.target.get_state()["switch"] == state["switch"]


def test_routed_window_replay_digest_identity():
    """Record -> window-replay digest identity on a routed torus run:
    every window (checkpoint-aligned or not) replays bit-identically,
    which requires checkpoints to restore switch queue/credit state."""
    sess = _torus_session("torus_replay")
    rec = sess.record(_torus_program())
    n = rec.n_ops
    for lo, hi in [(0, n), (1, n), (2, n - 1), (n - 1, n), (0, 1)]:
        w = sess.replay(rec, lo, hi)
        assert w.lines == rec.window_lines(lo, hi), (lo, hi)
        assert w.digest() == rec.window_digest(lo, hi)


def test_bisect_parity_on_routed_runs():
    """bisect_divergence through routed runs: identical torus runs report
    no divergence; a data-divergent run is localized to the op that
    wrote the differing gradient."""
    sa = _torus_session("torus_a")
    ra = sa.record(_torus_program())
    sb = _torus_session("torus_b")
    rb = sb.record(_torus_program())
    assert rp.bisect_divergence(sa, ra, sb, rb) is None
    sc = _torus_session("torus_c")
    rc = sc.record(_torus_program(grad_scale=2.0))
    rep = rp.bisect_divergence(sa, ra, sc, rc)
    assert rep is not None and rep.kind == "state"
    # first divergent op is the first dev_host_write of the scaled grad
    assert rep.op_index == 4
