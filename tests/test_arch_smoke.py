"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke
from repro.models import RunFlags, init_params, make_loss_fn
from repro.models.inputs import make_train_batch
from repro.models.transformer import forward, lm_logits, padded_vocab

FLAGS = RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16)
B, S = 2, 64


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_train_batch(cfg, B, S, key)

    # forward: logits shape + finite
    from repro.models.transformer import cast_params
    x, aux, _ = forward(cfg, cast_params(params), batch, FLAGS, None)
    logits = lm_logits(cfg, cast_params(params), x, None)
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch} NaN"

    # one train step: loss + grads finite and nonzero
    loss_fn = make_loss_fn(cfg, FLAGS, None)
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss))
    gsum = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
               for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0
