"""Run-farm scaling benchmark: campaign scenarios/sec at 1 vs N workers
(ROADMAP item 2 — overnight-scale campaigns, FireSim run-farm style).

The workload is a seeded registers-layer fuzz campaign sharded into
250-scenario units by ``fuzz_units`` and driven end-to-end through
``CampaignManager`` — shard, spawn, execute, merge coverage, persist to
the JSONL store — so the measurement includes every orchestration cost a
real campaign pays, not just raw fuzzer throughput.  Determinism is
asserted OUTSIDE the timed region: every worker count must land on the
byte-identical final campaign digest, so the scaling is free.

The ≥4x scenarios/sec floor at 8 workers is **core-gated**: a pool
cannot beat physics, so the floor is enforced only when the host exposes
at least ``MIN_CORES_FOR_FLOOR`` usable cores; either way the committed
``BENCH_runfarm.json`` records the core count and whether the floor was
enforced, so a 1-core CI runner measures honestly instead of asserting
an impossibility.

    PYTHONPATH=src python benchmarks/bench_runfarm.py            # quick
    PYTHONPATH=src python benchmarks/bench_runfarm.py --full --json BENCH_runfarm.json
    PYTHONPATH=src python benchmarks/bench_runfarm.py --ci       # CI lane
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Sequence

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.runfarm import CampaignInterrupted, CampaignManager, fuzz_units

SEED = 2026
BATCH = 250
FULL_SCENARIOS = 100_000        # the committed BENCH_runfarm.json point
QUICK_SCENARIOS = 2_000         # benchmarks/run.py quick mode
CI_SCENARIOS = 10_000           # the CI mini-campaign lane
WORKER_COUNTS = (1, 8)
SPEEDUP_FLOOR = 4.0             # 8-worker vs 1-worker scenarios/sec
MIN_CORES_FOR_FLOOR = 4         # floor enforced only with real parallelism

ART = Path(__file__).resolve().parent / "artifacts"


def usable_cores() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:                      # non-Linux fallback
        return os.cpu_count() or 1


def measure(n_scenarios: int, worker_counts: Sequence[int],
            base: Path) -> Dict:
    """One campaign per worker count over identical units; digests must
    agree bit-for-bit across all of them (the determinism bar)."""
    units = fuzz_units(seed=SEED, n_scenarios=n_scenarios, batch=BATCH)
    lanes = []
    for w in worker_counts:
        res = CampaignManager(base / f"w{w}", units, seed=SEED, workers=w,
                              generations=1).run()
        t = res.report["timing"]
        lanes.append({"workers": w, "digest": res.digest,
                      "scn_per_s": round(t["scenarios_per_sec"], 1),
                      "wall_s": round(t["wall_seconds"], 2),
                      "utilization": t["pool_utilization"]})
        if not res.passed:
            raise RuntimeError(f"workers={w} campaign failed: "
                               f"{[res.records[u]['failures'] for u in res.uids if not res.records[u]['ok']][:2]}")
    digests = {l["digest"] for l in lanes}
    if len(digests) != 1:
        raise RuntimeError(f"determinism broken across worker counts: "
                           f"{[(l['workers'], l['digest'][:16]) for l in lanes]}")
    speedup = round(lanes[-1]["scn_per_s"] / lanes[0]["scn_per_s"], 2)
    return {"scenarios": n_scenarios, "units": len(units),
            "digest": lanes[0]["digest"], "lanes": lanes,
            "speedup": speedup}


def run() -> List[str]:
    """Quick mode for benchmarks/run.py: CSV rows."""
    base = Path(tempfile.mkdtemp(prefix="bench_runfarm_"))
    try:
        m = measure(QUICK_SCENARIOS, (1, 2), base)
    finally:
        shutil.rmtree(base, ignore_errors=True)
    rows = ["lane,scenarios_per_sec,detail"]
    for l in m["lanes"]:
        rows.append(f"workers{l['workers']},{l['scn_per_s']},"
                    f"util={l['utilization']}")
    rows.append(f"speedup,{m['speedup']},digest={m['digest'][:16]};"
                f"cores={usable_cores()}")
    return rows


def ci_lane() -> int:
    """The CI mini-campaign: bounded scenarios on 4 workers with a forced
    worker SIGKILL, plus an interrupt + resume — both digest-gated
    against the sequential oracle.  Campaign dirs land under
    benchmarks/artifacts/runfarm_ci/ (report + harvest bundles) so CI
    uploads them per run."""
    base = ART / "runfarm_ci"
    shutil.rmtree(base, ignore_errors=True)
    base.mkdir(parents=True)
    units = fuzz_units(seed=SEED, n_scenarios=CI_SCENARIOS, batch=BATCH)
    oracle = CampaignManager(base / "oracle", units, seed=SEED,
                             workers=0, generations=1).run()
    killed = CampaignManager(base / "killed", units, seed=SEED, workers=4,
                             generations=1,
                             kill_worker_after={0: 2}).run()
    try:
        CampaignManager(base / "resumed", units, seed=SEED, workers=4,
                        generations=1, interrupt_after=6).run()
    except CampaignInterrupted:
        pass
    resumed = CampaignManager(base / "resumed", units, seed=SEED,
                              workers=4, generations=1).run()
    checks = {
        "killed_pool_digest": killed.digest == oracle.digest,
        "killed_pool_respawned":
            killed.report["timing"]["workers_respawned"] >= 1,
        "resumed_digest": resumed.digest == oracle.digest,
        "resumed_skipped":
            resumed.report["timing"]["units_resumed_from_store"] >= 6,
        "coverage_merge":
            killed.coverage.counts == oracle.coverage.counts
            and resumed.coverage.counts == oracle.coverage.counts,
    }
    print(f"runfarm CI lane: {CI_SCENARIOS} scenarios, "
          f"{len(units)} units, 4 workers, cores={usable_cores()}")
    print(f"  oracle digest {oracle.digest[:16]}")
    for name, ok in checks.items():
        print(f"  {name}: {'OK' if ok else 'FAIL'}")
    ok = all(checks.values())
    print("runfarm check:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def main(argv: List[str]) -> int:
    if "--ci" in argv:
        return ci_lane()
    n = FULL_SCENARIOS if "--full" in argv else QUICK_SCENARIOS
    cores = usable_cores()
    base = Path(tempfile.mkdtemp(prefix="bench_runfarm_"))
    try:
        m = measure(n, WORKER_COUNTS, base)
    finally:
        shutil.rmtree(base, ignore_errors=True)
    print(f"workload: {m['scenarios']} fuzz scenarios in {m['units']} "
          f"units (seed={SEED}, batch={BATCH}), cores={cores}")
    for l in m["lanes"]:
        print(f"  workers={l['workers']}: {l['scn_per_s']:.1f} "
              f"scenarios/sec (wall {l['wall_s']:.2f}s, "
              f"utilization {l['utilization']})")
    print(f"digest identical across worker counts: {m['digest'][:16]}")
    enforce = cores >= MIN_CORES_FOR_FLOOR
    note = (f"floor enforced (cores={cores})" if enforce else
            f"floor not enforced: only {cores} usable core(s), "
            f"parallel speedup is physically unavailable")
    print(f"speedup {WORKER_COUNTS[-1]}v{WORKER_COUNTS[0]} workers: "
          f"{m['speedup']:.2f}x (floor {SPEEDUP_FLOOR}x; {note})")
    out = next((argv[i + 1] for i, a in enumerate(argv)
                if a == "--json" and i + 1 < len(argv)), None)
    if out:
        path = Path(out)
        doc = json.loads(path.read_text()) if path.exists() else {
            "bench": "runfarm",
            "unit": "scenarios/sec: end-to-end campaign throughput "
                    "(shard -> spawn -> execute -> merge coverage -> "
                    "JSONL store) over a seeded registers-layer fuzz "
                    "campaign",
            "workload": {"seed": SEED, "batch": BATCH,
                         "worker_counts": list(WORKER_COUNTS)},
            "floors": {"speedup": SPEEDUP_FLOOR,
                       "enforced_when_cores_ge": MIN_CORES_FOR_FLOOR},
            "trajectory": [],
        }
        point = {"date": time.strftime("%Y-%m-%d"), "cores": cores,
                 "scenarios": m["scenarios"],
                 "digest": m["digest"][:16],
                 "speedup": m["speedup"], "floor_enforced": enforce,
                 "note": note}
        for l in m["lanes"]:
            point[f"workers{l['workers']}_scn_per_s"] = l["scn_per_s"]
        doc["trajectory"].append(point)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {path}")
    if "--check" in argv:
        ok = (not enforce) or m["speedup"] >= SPEEDUP_FLOOR
        print("runfarm check:", "PASS" if ok else "FAIL")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
