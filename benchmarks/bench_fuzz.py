"""Fault-injection throughput: randomized co-verification scenarios/sec
per fuzz layer (core/fuzz.py).

The metric that matters for the "thousands of hostile scenarios" goal is
how many seeded fault scenarios the harness retires per second — bridge
scenarios pay for three backend runs + differential check, register
scenarios are pure protocol, serving scenarios drive the full engine.

Quick mode (the default, used by benchmarks/run.py and safe for the smoke
lane) sizes the scenario counts to finish in seconds and skips the
model-building serving layer; ``--full`` measures all three layers at
10x the scenario count.

    PYTHONPATH=src:. python benchmarks/bench_fuzz.py [--full]
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import ProtocolFuzzer

QUICK_N = {"bridge": 8, "registers": 60}
FULL_N = {"bridge": 80, "registers": 600, "serving": 40}


def run(quick: bool = True) -> list[str]:
    counts = QUICK_N if quick else FULL_N
    rows = ["case,layer,scenarios,seconds,scenarios_per_s,faults,passed"]
    for layer, n in counts.items():
        fz = ProtocolFuzzer(seed=0, layers=(layer,))
        if layer == "serving":          # build + jit outside the timing
            fz.run(1)
        t0 = time.perf_counter()
        report = fz.run(n)
        dt = time.perf_counter() - t0
        nfaults = sum(report.fault_counts().values())
        rows.append(f"fuzz,{layer},{n},{dt:.2f},{n / dt:.1f},"
                    f"{nfaults},{report.passed}")
    return rows


def run_full() -> list[str]:
    return run(quick=False)


if __name__ == "__main__":
    print("\n".join(run(quick="--full" not in sys.argv[1:])))
