"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines (plus each figure's
detailed CSV) and writes artifacts under benchmarks/artifacts/.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ART = Path(__file__).resolve().parent / "artifacts"


def _run(name: str, fn) -> list[str]:
    t0 = time.perf_counter()
    rows = fn()
    us = (time.perf_counter() - t0) * 1e6
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.csv").write_text("\n".join(rows))
    derived = rows[-1].replace(",", ";") if rows else ""
    print(f"{name},{us:.0f},{derived}")
    for r in rows:
        print(f"  {r}")
    return rows


def main() -> None:
    from benchmarks import (bench_access_patterns, bench_bandwidth_profile,
                            bench_counters, bench_debug_iteration,
                            bench_fabric_scaling, bench_fuzz,
                            bench_hls4ml_scaling, bench_profiler,
                            bench_replay, bench_runfarm, bench_serving,
                            bench_simspeed)
    from benchmarks import roofline as roofline_mod

    print("name,us_per_call,derived")
    _run("fig5_debug_iteration", bench_debug_iteration.run)
    _run("fig5_batched_sweep", bench_debug_iteration.run_sweep)
    _run("fig7_hls4ml_scaling", bench_hls4ml_scaling.run)
    _run("fig8_bandwidth_profile", bench_bandwidth_profile.run)
    _run("fig9_access_patterns", bench_access_patterns.run)
    _run("fuzz_throughput", bench_fuzz.run)         # quick mode
    _run("fabric_scaling", bench_fabric_scaling.run)  # quick mode
    _run("replay_debug_iteration", bench_replay.run)  # quick mode
    _run("profiler_overhead", bench_profiler.run)   # quick mode
    _run("counters_overhead", bench_counters.run)   # quick mode
    _run("simspeed", bench_simspeed.run)            # quick mode
    _run("runfarm_scaling", bench_runfarm.run)      # quick mode
    _run("serving_slo", bench_serving.run)          # quick mode

    def _roofline():
        recs = roofline_mod.load("baseline")
        (ART / "dryrun_table.md").write_text(
            roofline_mod.render_dryrun_table(recs))
        (ART / "roofline_table.md").write_text(
            roofline_mod.render_roofline_table(recs))
        return [f"roofline,baseline_cells,{len(recs)}",
                "roofline,tables,dryrun_table.md;roofline_table.md"]

    _run("roofline_tables", _roofline)


if __name__ == "__main__":
    main()
