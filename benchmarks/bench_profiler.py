"""Profiling overhead + Perfetto export economics on the 200-launch
fault-injected fuzz workload (the same long bridge scenario the replay
benchmark debugs).

The paper positions off-chip data-movement profiling as something the
verification loop produces as a side effect, not a separate slow pass —
so the check here is that running the workload with ``profile=True``
(op marks + per-burst attribution fields recorded online) costs < 10%
wall-clock over the unprofiled run.  Post-hoc analysis (building the
``DataMovementProfiler``, exporting the Chrome-trace JSON) is reported
separately: it happens after the firmware returns, off the modeled path.

Rows:

  profile_off    best-of-reps wall ms of the raw 200-launch run
  profile_on     same run with profile=True + overhead % (asserted < 10)
  profiler_build ms to compute the full stall attribution post-hoc
  perfetto_export events + ms to serialize the trace (artifact written to
                 benchmarks/artifacts/profiler_trace.json — CI uploads it)

    PYTHONPATH=src:. python benchmarks/bench_profiler.py [--full]
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import DataMovementProfiler, FireBridge, ProtocolFuzzer
from repro.kernels.systolic_matmul import ops as mm_ops

OPS = 200                       # launches in the long fuzz scenario
MAX_OVERHEAD = 0.10             # the acceptance ceiling
ART = Path(__file__).resolve().parent / "artifacts"


def _fuzzer() -> ProtocolFuzzer:
    return ProtocolFuzzer(seed=0, layers=("bridge",), backends=("oracle",),
                          bridge_ops=(OPS, OPS + 1))


def _run_workload(fz: ProtocolFuzzer, scn, profile: bool) -> FireBridge:
    """One oracle-backend pass over the scenario — the exact op stream
    ``ProtocolFuzzer._run_bridge`` executes, with the bridge optionally
    profiled."""
    plan = fz.plan.fork(f"{scn.label}/oracle", scenario=scn.index)
    fb = FireBridge(congestion=fz.congestion, fault_plan=plan,
                    profile=profile)
    fb.register_op("mm", **fz._matmul_table())
    for j, (_, size) in enumerate(scn.ops):
        rng = np.random.default_rng(size * 1009 + j)
        a = rng.normal(size=(size, size)).astype(np.float32)
        b = rng.normal(size=(size, size)).astype(np.float32)
        fb.mem.alloc(f"a{j}", a.shape, np.float32)
        fb.mem.alloc(f"b{j}", b.shape, np.float32)
        fb.mem.alloc(f"c{j}", (size, size), np.float32)
        fb.mem.host_write(f"a{j}", a)
        fb.mem.host_write(f"b{j}", b)
        fb.launch("mm", "oracle", [f"a{j}", f"b{j}"], [f"c{j}"],
                  engine="mm",
                  burst_list=lambda s=size: mm_ops.transactions(
                      s, s, s, bm=fz.TILE, bn=fz.TILE, bk=fz.TILE,
                      dtype_bytes=4))
    return fb


def _median_ms(fn, repeats: int) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e3)
    ts.sort()
    return ts[len(ts) // 2]


def run(quick: bool = True) -> list[str]:
    repeats = 5 if quick else 9
    fz = _fuzzer()
    scn = fz.scenario(0)
    _run_workload(fz, scn, profile=False)       # warm the jitted backends

    # interleave the lanes (A B A B ...) so slow-box noise hits both, and
    # take best-of-reps per lane: scheduler noise is strictly additive,
    # and with the vectorized hot path the unprofiled run is short enough
    # (~230 ms) that a single preempted rep would swamp the ~10 ms true
    # overhead under a median
    off_ts, on_ts = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _run_workload(fz, scn, profile=False)
        off_ts.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        fb = _run_workload(fz, scn, profile=True)
        on_ts.append((time.perf_counter() - t0) * 1e3)
    off_ms = min(off_ts)
    on_ms = min(on_ts)
    overhead = (on_ms - off_ms) / off_ms

    build_ms = _median_ms(lambda: fb.profiler("bench"), repeats)
    prof = fb.profiler("bench")
    trace = prof.to_perfetto()
    ART.mkdir(parents=True, exist_ok=True)
    t0 = time.perf_counter()
    path = prof.save_perfetto(ART / "profiler_trace.json")
    export_ms = (time.perf_counter() - t0) * 1e3

    rows = ["case,ops,events,ms,overhead_pct"]
    rows.append(f"profile_off,{OPS},-,{off_ms:.1f},-")
    rows.append(f"profile_on,{OPS},-,{on_ms:.1f},"
                f"{100.0 * overhead:.1f}")
    rows.append(f"profiler_build,{OPS},{sum(len(c.txs) for c in prof.channels)},"
                f"{build_ms:.1f},-")
    rows.append(f"perfetto_export,{OPS},{len(trace['traceEvents'])},"
                f"{export_ms:.1f},-")
    rows.append(f"artifact,{OPS},-,-,{path.name}")
    assert overhead < MAX_OVERHEAD, (
        f"profiling overhead {100 * overhead:.1f}% exceeds the "
        f"{100 * MAX_OVERHEAD:.0f}% ceiling on the {OPS}-launch workload "
        f"(off {off_ms:.1f} ms, on {on_ms:.1f} ms)")
    return rows


def run_full() -> list[str]:
    return run(quick=False)


if __name__ == "__main__":
    print("\n".join(run(quick="--full" not in sys.argv[1:])))
