"""Fig. 8 reproduction: memory-bandwidth utilization + stalls of the three
DMA engines of a CGRA-style accelerator over a ResNet-18 inference
(~0.7 GOP), with input-DMA priority (the paper's design choice) — the
weights DMA should therefore accumulate the most interconnect stalls,
validating the early-modeling tradeoff exactly as the paper observes.

The congestion link runs *online* (§IV-C) and the numbers are read back
through the off-chip data-movement profiler (core/profiler.py): the
bridge runs with ``profile=True`` and every row below — per-engine bytes,
transactions, stalls, busy cycles, link utilization, makespan, and the
bandwidth-timeline sparklines — comes from one ``DataMovementProfiler``
over the finished run (byte-identical to the pre-profiler readout, which
mixed ``log.summary()`` and ``congestion_stats()``).
"""
from __future__ import annotations

from benchmarks.cnn_driver import gops, resnet18_specs, run_cnn
from repro.core.congestion import CongestionConfig


def run() -> list[str]:
    specs = resnet18_specs(hw=36)            # ~0.7 GOP like the paper
    cfg = CongestionConfig(
        link_bytes_per_cycle=64.0, base_latency=40.0, dos_prob=0.02,
        seed=7, priorities=(("dma_input", 2), ("dma_output", 1),
                            ("dma_weights", 0)))
    fb = run_cnn(specs, backend="oracle", congestion=cfg, profile=True)
    prof = fb.profiler()
    ddr = prof.channel("ddr")

    rows = [f"# ResNet-18 {gops(specs):.2f} GOP through the bridge; "
            f"input DMA prioritized (paper's design choice); online link",
            "case,engine,bytes,transactions,stall_cycles,busy_cycles"]
    for e in ("dma_weights", "dma_input", "dma_output"):
        s = ddr.engines[e]
        rows.append(
            f"fig8,{e},{s.bytes},{s.transactions},"
            f"{s.stall:.0f},{s.busy:.0f}")
    rows.append(f"fig8,link_utilization,,,{ddr.utilization:.3f},")
    rows.append(f"fig8,makespan_cycles,,,{ddr.horizon:.0f},")

    # bandwidth-utilization timeline (bucketed), per engine
    edges, tl = prof.bandwidth_timeline(n_buckets=24)
    for e, series in sorted(tl.items()):
        if not e.startswith("dma_"):
            continue
        spark = "".join(" .:-=+*#%@"[min(int(v / (series.max() or 1) * 9), 9)]
                        for v in series)
        rows.append(f"fig8_timeline,{e},[{spark}]")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
