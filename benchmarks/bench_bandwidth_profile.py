"""Fig. 8 reproduction: memory-bandwidth utilization + stalls of the three
DMA engines of a CGRA-style accelerator over a ResNet-18 inference
(~0.7 GOP), with input-DMA priority (the paper's design choice) — the
weights DMA should therefore accumulate the most interconnect stalls,
validating the early-modeling tradeoff exactly as the paper observes.

The congestion link runs *online* (§IV-C): the bridge is constructed with
the CongestionConfig and stalls accumulate while the layers execute — the
stats below come straight from fb.congestion_stats(), no replay step.
"""
from __future__ import annotations

from benchmarks.cnn_driver import gops, resnet18_specs, run_cnn
from repro.core.congestion import CongestionConfig


def run() -> list[str]:
    specs = resnet18_specs(hw=36)            # ~0.7 GOP like the paper
    cfg = CongestionConfig(
        link_bytes_per_cycle=64.0, base_latency=40.0, dos_prob=0.02,
        seed=7, priorities=(("dma_input", 2), ("dma_output", 1),
                            ("dma_weights", 0)))
    fb = run_cnn(specs, backend="oracle", congestion=cfg)
    res = fb.congestion_stats()

    rows = [f"# ResNet-18 {gops(specs):.2f} GOP through the bridge; "
            f"input DMA prioritized (paper's design choice); online link",
            "case,engine,bytes,transactions,stall_cycles,busy_cycles"]
    summ = fb.log.summary()
    for e in ("dma_weights", "dma_input", "dma_output"):
        rows.append(
            f"fig8,{e},{summ[e]['bytes']},{summ[e]['transactions']},"
            f"{res.per_engine_stall.get(e, 0):.0f},"
            f"{res.per_engine_busy.get(e, 0):.0f}")
    rows.append(f"fig8,link_utilization,,,{res.link_utilization:.3f},")
    rows.append(f"fig8,makespan_cycles,,,{res.makespan:.0f},")

    # bandwidth-utilization timeline (bucketed), per engine
    edges, tl = fb.log.bandwidth_timeline(n_buckets=24)
    for e, series in sorted(tl.items()):
        if not e.startswith("dma_"):
            continue
        spark = "".join(" .:-=+*#%@"[min(int(v / (series.max() or 1) * 9), 9)]
                        for v in series)
        rows.append(f"fig8_timeline,{e},[{spark}]")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
