"""Debug-iteration wall time: checkpointed window replay vs full re-run,
and replay-backed shrink vs re-run-per-prefix shrink (core/replay.py —
the paper's 50x debug-iteration claim, measured on this stack).

Two lanes:

* **debug iteration** — one long fixed-seed fault-injected fuzz scenario
  (200 launches; the 200-scenario debug workload).  The iteration under
  test is "show me the device state at launch k": the baseline
  re-executes ops 1..k from time zero, the time-travel lane restores the
  nearest transaction-boundary checkpoint and replays only the window.
  Both materialize bit-identical state (core/replay.py contract), so the
  comparison is pure economics; ``events`` counts actually-executed
  timeline ops per iteration (deterministic), ``ms`` is wall time.
* **shrink** — ``ProtocolFuzzer.shrink`` on a scenario whose planted bug
  fires only on a LATE launch, with and without prefix replay: the
  legacy loop re-runs the whole prefix per candidate (quadratic in ops),
  the replay loop records once and restores checkpoints (linear).

    PYTHONPATH=src:. python benchmarks/bench_replay.py [--full]
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import ProtocolFuzzer

OPS = 200                       # launches in the long fuzz scenario
INSPECT_AT = 150                # the debug iteration targets launch #150
CHECKPOINT_EVERY = 8            # scenario ops between checkpoints
SHRINK_OPS_QUICK, SHRINK_OPS_FULL = 24, 48
EVENTS_PER_OP = ProtocolFuzzer._BRIDGE_EVENTS_PER_OP


def _median_ms(fn, repeats: int) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e3)
    ts.sort()
    return ts[len(ts) // 2]


_TABLE_CACHE: dict = {}


def _late_bug_table(tile: int = ProtocolFuzzer.TILE) -> dict:
    """Backend table whose interpret lane diverges ONLY for size-64
    launches — so the failing prefix sits wherever the scenario first
    draws a 64 and shrink must walk there.  Built once: both shrink lanes
    share the jitted executables (only the walk economics differ)."""
    if "t" in _TABLE_CACHE:
        return _TABLE_CACHE["t"]
    from repro.kernels.systolic_matmul.sweep import matmul_backends
    table = matmul_backends(tile=tile)
    good = table["interpret"]

    def buggy(a, b):
        out = np.array(good(a, b))
        if a.shape[0] == 64:
            out[1, 2] += 1.0
        return out
    _TABLE_CACHE["t"] = dict(table, interpret=buggy)
    return _TABLE_CACHE["t"]


def _late_bug_fuzzer(n_ops: int):
    """Fuzzer + a constructed scenario whose ONLY size-64 launch (where
    the planted bug fires) sits at 3/4 of the op list — the position
    shrink must walk to."""
    from repro.core.fuzz import Scenario
    fz = ProtocolFuzzer(seed=0, layers=("bridge",),
                        backends=("oracle", "interpret"),
                        mm_table=_late_bug_table(),
                        bridge_ops=(n_ops, n_ops + 1))
    bug_at = (3 * n_ops) // 4
    sizes = [(32, 48)[j % 2] for j in range(n_ops)]
    sizes[bug_at - 1] = 64
    scn = Scenario(0, "bridge", [("launch", s) for s in sizes])
    return fz, scn, bug_at


def run(quick: bool = True) -> list[str]:
    repeats = 3 if quick else 7
    rows = ["case,ops,events,ms,speedup"]

    # ---- debug iteration: state at launch INSPECT_AT of a 200-op
    # fault-injected scenario (single backend: the run under debug)
    fz = ProtocolFuzzer(seed=0, layers=("bridge",), backends=("oracle",),
                        bridge_ops=(OPS, OPS + 1))
    scn = fz.scenario(0)
    # time-travel lane: record ONCE with checkpoints, then window-replay
    sess, rec = fz._record_bridge_scenario(scn, "oracle", CHECKPOINT_EVERY)
    # baseline lane: same recording with NO interior checkpoints — a
    # prefix probe must re-execute everything from time zero
    sess0, rec0 = fz._record_bridge_scenario(scn, "oracle", OPS + 1)
    k = INSPECT_AT * EVENTS_PER_OP

    sess0.ops_applied = 0
    full_ms = _median_ms(lambda: sess0.replay(rec0, k, k), repeats)
    full_events = sess0.ops_applied // repeats

    sess.ops_applied = 0
    win_ms = _median_ms(lambda: sess.replay(rec, k, k), repeats)
    win_events = sess.ops_applied // repeats

    speedup = full_ms / max(win_ms, 1e-9)
    rows.append(f"full_rerun,{INSPECT_AT},{full_events},{full_ms:.1f},1.0")
    rows.append(f"window_replay,{INSPECT_AT},{win_events},{win_ms:.1f},"
                f"{speedup:.1f}")

    # ---- shrink with a late-firing planted bug
    n_shrink = SHRINK_OPS_QUICK if quick else SHRINK_OPS_FULL
    _, _, bug_at = _late_bug_fuzzer(n_shrink)
    table = _late_bug_table()
    for size in ProtocolFuzzer.SIZES:   # compile outside the timed lanes
        x = np.zeros((size, size), np.float32)
        table["interpret"](x, x), table["compiled"](x, x)

    def shrink_once(use_replay: bool) -> None:
        f, s, _ = _late_bug_fuzzer(n_shrink)
        sub, res = f.shrink(s, use_replay=use_replay)
        assert not res.ok and len(sub.ops) == bug_at

    reps = 1 if quick else 3
    slow_ms = _median_ms(lambda: shrink_once(False), reps)
    fast_ms = _median_ms(lambda: shrink_once(True), reps)
    # events: the rerun lane re-executes every prefix 1..bug_at on every
    # backend (exact); the replay lane's count is record + O(log n)
    # checkpoint-window probes + one authoritative prefix — report "-"
    # rather than an estimate
    rows.append(f"shrink_rerun_per_prefix,{n_shrink},"
                f"{bug_at * (bug_at + 1) // 2 * EVENTS_PER_OP * 2},"
                f"{slow_ms:.1f},1.0")
    rows.append(f"shrink_prefix_replay,{n_shrink},-,"
                f"{fast_ms:.1f},{slow_ms / max(fast_ms, 1e-9):.1f}")
    return rows


def run_full() -> list[str]:
    return run(quick=False)


if __name__ == "__main__":
    print("\n".join(run(quick="--full" not in sys.argv[1:])))
