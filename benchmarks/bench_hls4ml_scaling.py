"""Fig. 7 reproduction: runtime and peak memory of FireBridge verification
vs FPGA prototyping for HLS4ML-style cascaded dense networks of growing
width, until the design no longer fits the ZCU102.

Measured side: wall time + tracemalloc peak of a full bridge verification
(oracle vs interpret backends) of an N-wide 4-layer 16-bit-quantized dense
cascade.  FPGA side modeled from the paper (Vivado HLS+synth minutes and
EDA peak memory), labeled accordingly.
"""
from __future__ import annotations

import time
import tracemalloc

import jax.numpy as jnp
import numpy as np

from repro.core import coverify
from repro.kernels.systolic_matmul import ref as mm_ref
from repro.kernels.systolic_matmul.kernel import matmul as mm_kernel

WIDTHS = [32, 64, 128, 256, 512]
ZCU102_DSP = 2520
# paper-modeled Vivado flow: minutes and GB vs width (fails past the DSPs)
FPGA_MIN = {32: 22, 64: 31, 128: 55, 256: 96, 512: None}
FPGA_GB = {32: 6.5, 64: 8.0, 128: 11.0, 256: 18.0, 512: None}


def verify_cascade(width: int) -> tuple[float, float]:
    rng = np.random.default_rng(width)
    layers = 4
    x = rng.normal(size=(8, width)).astype(np.float32)
    ws = [rng.normal(size=(width, width)).astype(np.float32) / np.sqrt(width)
          for _ in range(layers)]

    def quant16(v):     # hls4ml ap_fixed<16,6>-style quantization
        return np.round(v * 1024) / 1024

    def firmware(fb, backend):
        fb.mem.alloc("x", x.shape, np.float32)
        fb.mem.host_write("x", x)
        cur = "x"
        for i, w in enumerate(ws):
            fb.mem.alloc(f"w{i}", w.shape, np.float32)
            fb.mem.host_write(f"w{i}", quant16(w))
            fb.mem.alloc(f"y{i}", x.shape, np.float32)
            fb.launch("dense", backend, [cur, f"w{i}"], [f"y{i}"])
            cur = f"y{i}"

    tile = min(32, width)
    ops = {"dense": dict(
        oracle=lambda a, w: np.maximum(np.asarray(
            mm_ref.matmul_ref(jnp.asarray(a), jnp.asarray(w))), 0.0),
        interpret=lambda a, w: np.maximum(np.asarray(mm_kernel(
            jnp.asarray(np.pad(a, ((0, (-a.shape[0]) % tile), (0, 0)))),
            jnp.asarray(w), bm=tile, bn=tile, bk=tile,
            interpret=True))[:a.shape[0]], 0.0),
    )}
    tracemalloc.start()
    t0 = time.perf_counter()
    res = coverify(firmware, ops, backends=("oracle", "interpret"), tol=1e-3)
    dt = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert res.passed
    return dt, peak / 1e9


def run() -> list[str]:
    rows = ["case,width,dsp_estimate,fits_zcu102,firebridge_s,"
            "firebridge_peak_gb,fpga_s(modeled),fpga_peak_gb(modeled)"]
    for w in WIDTHS:
        dsp = w * 4          # ~1 DSP per MAC column per layer (16-bit)
        fits = dsp <= ZCU102_DSP
        dt, peak = verify_cascade(w)
        fpga_s = FPGA_MIN[w] * 60 if FPGA_MIN[w] else "DNF"
        fpga_g = FPGA_GB[w] if FPGA_GB[w] else "DNF"
        rows.append(f"fig7,{w},{dsp},{fits},{dt:.2f},{peak:.3f},"
                    f"{fpga_s},{fpga_g}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
