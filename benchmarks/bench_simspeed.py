"""Simulator-speed benchmark: the vectorized modeled-time hot path vs the
retained scalar reference (ROADMAP item 4 — iteration speed must keep
pace with design size, the FERIVer/ZynqParrot argument).

The workload is the 200-launch bridge fuzz scenario's recorded
arbitration stream: every burst batch that crossed ``LinkModel`` during
one fixed-seed run (fuzz perturbations already applied, so the stream is
deterministic).  One *scenario* replays that stream through a fresh
shared link the way the replay-backed regression tier consumes it —
build each batch, arbitrate it, log it, and take a trace-digest
checkpoint at launch granularity (every ``CHECKPOINT_EVERY`` batches,
the cadence the time-travel recorder and divergence bisection digest
at).  Two lanes:

* **scalar** — per-burst ``Transaction`` objects through
  ``LinkModel._submit_scalar`` plus the pre-vectorization digest, which
  re-rendered every canonical line and re-hashed the whole stream on
  each call (O(total) per checkpoint),
* **vector** — ``BurstBatch`` columns through ``LinkModel.submit_batch``
  (grant order, DoS draws and transfer latencies batched; lazy log
  segments) plus the lazy incremental digest (renders each line once,
  O(delta) per checkpoint).

An ``arb`` lane pair times arbitration alone (no checkpoints) so the
two contributions stay separable.  Both pipelines must produce
byte-identical digests at every checkpoint — asserted outside the timed
region — so the speedup is free: the ≥5x acceptance floor on the full
scenario is enforced here (``--check``, the CI simspeed lane) and by
the slow-marked smoke test (tests/test_simspeed.py).  Results append to
the committed ``BENCH_simspeed.json`` trajectory.

    PYTHONPATH=src python benchmarks/bench_simspeed.py [--full]
    PYTHONPATH=src python benchmarks/bench_simspeed.py --check
    PYTHONPATH=src python benchmarks/bench_simspeed.py --selftest
"""
from __future__ import annotations

import hashlib
import json
import sys
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.congestion import CongestionConfig, LinkModel
from repro.core.transactions import (BURST_DTYPE, BurstBatch, Transaction,
                                     TransactionLog)

# One link config for the replayed stream: DoS active so the seeded
# draw-stream equivalence is exercised, not just the arithmetic.
CFG = CongestionConfig(dos_prob=0.05, seed=7)
FUZZ_SEED = 0
LAUNCHES = 200                  # ops in the captured fuzz scenario
CHECKPOINT_EVERY = 4            # ~1 digest per launch (859 batches/200)
SPEEDUP_FLOOR = 5.0             # acceptance: vector >= 5x scalar
SCN_PER_S_FLOOR = 2.0           # absolute floor for the CI lane (slow
                                # shared runners; local is far higher)

# A batch spec: parallel columns (times, engines, kinds, addrs, nbytes,
# tags) — neutral ground both pipelines build their native form from.
Spec = Tuple[List[float], List[str], List[str], List[int], List[int],
             List[str]]


def capture_workload() -> List[Spec]:
    """Record every arbitration batch of the 200-launch fuzz scenario by
    spying on both LinkModel entry points (the live path is batched; the
    spy keeps working if a caller still submits objects)."""
    from repro.core.fuzz import ProtocolFuzzer
    specs: List[Spec] = []
    orig_s, orig_b = LinkModel.submit, LinkModel.submit_batch

    def spy_s(self, txs, log=None):
        specs.append(([t.time for t in txs], [t.engine for t in txs],
                      [t.kind for t in txs], [t.addr for t in txs],
                      [t.nbytes for t in txs], [t.tag for t in txs]))
        return orig_s(self, txs, log)

    def spy_b(self, batch, log=None):
        specs.append((batch.rec["time"].tolist(), list(batch.engine),
                      list(batch.kind), batch.rec["addr"].tolist(),
                      batch.rec["nbytes"].tolist(), list(batch.tag)))
        return orig_b(self, batch, log)

    LinkModel.submit, LinkModel.submit_batch = spy_s, spy_b
    try:
        fz = ProtocolFuzzer(seed=FUZZ_SEED, layers=("bridge",),
                            backends=("oracle",),
                            bridge_ops=(LAUNCHES, LAUNCHES + 1))
        fz.run(1)
    finally:
        LinkModel.submit, LinkModel.submit_batch = orig_s, orig_b
    return specs


def eager_digest(log: TransactionLog) -> str:
    """The pre-vectorization ``TransactionLog.digest``, replicated: build
    every canonical line from scratch and hash the full stream — what
    each replay checkpoint paid before digests went lazy."""
    lines = [TransactionLog.canonical_line(t) for t in log.txs]
    lines += [f"violation: {v}" for v in log.violations]
    lines += [f"fault: {f}" for f in log.faults]
    h = hashlib.sha256()
    for line in lines:
        h.update(line.encode())
        h.update(b"\n")
    return h.hexdigest()


def scenario_scalar(specs: List[Spec],
                    checkpoints: bool = True) -> List[str]:
    """The pre-vectorization pipeline: Transaction objects per burst
    through the scalar arbitration loop, eager digest per checkpoint."""
    lm = LinkModel(CFG)
    log = TransactionLog()
    sigs: List[str] = []
    for i, (times, engines, kinds, addrs, nbs, tags) in enumerate(specs):
        txs = [Transaction(t, e, k, a, nb, tg)
               for t, e, k, a, nb, tg in zip(times, engines, kinds, addrs,
                                             nbs, tags)]
        lm._submit_scalar(txs, log)
        if checkpoints and (i + 1) % CHECKPOINT_EVERY == 0:
            sigs.append(eager_digest(log))
    if checkpoints:
        sigs.append(eager_digest(log))
    return sigs


def scenario_vector(specs: List[Spec],
                    checkpoints: bool = True) -> List[str]:
    """The batched pipeline: column batches through submit_batch, lazy
    incremental digest per checkpoint."""
    lm = LinkModel(CFG)
    log = TransactionLog()
    sigs: List[str] = []
    for i, (times, engines, kinds, addrs, nbs, tags) in enumerate(specs):
        rec = np.zeros(len(times), dtype=BURST_DTYPE)
        rec["time"] = times
        rec["addr"] = addrs
        rec["nbytes"] = nbs
        lm.submit_batch(BurstBatch(rec, engines, kinds, tags), log)
        if checkpoints and (i + 1) % CHECKPOINT_EVERY == 0:
            sigs.append(log.digest())
    if checkpoints:
        sigs.append(log.digest())
    return sigs


def _best_s(fn, specs, checkpoints: bool, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(specs, checkpoints)
        best = min(best, time.perf_counter() - t0)
    return best


def measure(specs: List[Spec], reps: int) -> dict:
    """Scenarios/sec for both pipelines + the bit-exactness check."""
    sa = scenario_scalar(specs)                 # warmup + witness
    sb = scenario_vector(specs)
    assert sa == sb, "vectorized pipeline diverged from scalar reference"
    scalar_s = _best_s(scenario_scalar, specs, True, reps)
    vector_s = _best_s(scenario_vector, specs, True, reps)
    scalar_arb_s = _best_s(scenario_scalar, specs, False, reps)
    vector_arb_s = _best_s(scenario_vector, specs, False, reps)
    return {
        "batches": len(specs),
        "txs": int(sum(len(s[0]) for s in specs)),
        "checkpoints": len(sa),
        "scalar_scn_per_s": round(1.0 / scalar_s, 2),
        "vector_scn_per_s": round(1.0 / vector_s, 2),
        "speedup": round(scalar_s / vector_s, 2),
        "arb_speedup": round(scalar_arb_s / vector_arb_s, 2),
        "digest": sa[-1],
    }


def run(reps: int = 2) -> List[str]:
    """Quick mode for benchmarks/run.py: CSV rows."""
    specs = capture_workload()
    m = measure(specs, reps)
    return [
        "lane,scenarios_per_sec,detail",
        f"scalar,{m['scalar_scn_per_s']},txs={m['txs']}",
        f"vector,{m['vector_scn_per_s']},txs={m['txs']}",
        f"speedup,{m['speedup']},floor={SPEEDUP_FLOOR}",
        f"arb_speedup,{m['arb_speedup']},no-checkpoint lane",
    ]


def selftest() -> None:
    """Deterministic output (no wall times) — pinned by docs/performance.md
    via tests/test_docs.py.  A tiny synthetic workload through both
    pipelines; everything printed derives from modeled cycles only."""
    rng = np.random.default_rng(42)
    specs: List[Spec] = []
    t = 0.0
    for _ in range(8):
        n = int(rng.integers(4, 17))
        engs = [f"e{int(rng.integers(3))}" for _ in range(n)]
        t += float(rng.integers(0, 100))
        specs.append(([t] * n, engs, ["read"] * n,
                      [int(a) for a in rng.integers(0, 1 << 20, n)],
                      [int(b) for b in rng.integers(1, 4096, n)],
                      [""] * n))
    sa, sb = scenario_scalar(specs), scenario_vector(specs)
    print("simspeed selftest")
    print(f"workload: {len(specs)} batches, {sum(len(s[0]) for s in specs)} "
          f"bursts, {len(sa)} digest checkpoints")
    print(f"scalar final digest: {sa[-1][:16]}")
    print(f"vector final digest: {sb[-1][:16]}")
    print("checkpoint identity:", "OK" if sa == sb else "MISMATCH")
    assert sa == sb


def main(argv: List[str]) -> int:
    if "--selftest" in argv:
        selftest()
        return 0
    reps = 5 if "--full" in argv else 2
    specs = capture_workload()
    m = measure(specs, reps)
    print(f"workload: {m['batches']} batches, {m['txs']} txs, "
          f"{m['checkpoints']} digest checkpoints "
          f"({LAUNCHES}-launch fuzz scenario, seed={FUZZ_SEED})")
    print(f"scalar: {m['scalar_scn_per_s']:.2f} scenarios/sec")
    print(f"vector: {m['vector_scn_per_s']:.2f} scenarios/sec")
    print(f"speedup: {m['speedup']:.2f}x (floor {SPEEDUP_FLOOR}x); "
          f"arbitration-only lane {m['arb_speedup']:.2f}x")
    out = next((argv[i + 1] for i, a in enumerate(argv)
                if a == "--json" and i + 1 < len(argv)), None)
    if out:
        point = {"date": time.strftime("%Y-%m-%d")}
        point.update({k: m[k] for k in ("scalar_scn_per_s",
                                        "vector_scn_per_s", "speedup",
                                        "arb_speedup")})
        path = Path(out)
        doc = json.loads(path.read_text()) if path.exists() else {
            "bench": "simspeed",
            "unit": "scenarios/sec: modeled-time pipeline (batch build -> "
                    "arbitrate -> log -> per-launch digest checkpoint) "
                    "over the recorded 200-launch fuzz arbitration stream",
            "workload": {"fuzz_seed": FUZZ_SEED, "launches": LAUNCHES,
                         "batches": m["batches"], "txs": m["txs"],
                         "checkpoints": m["checkpoints"]},
            "floors": {"speedup": SPEEDUP_FLOOR,
                       "vector_scn_per_s": SCN_PER_S_FLOOR},
            "trajectory": [],
        }
        doc["trajectory"].append(point)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {path}")
    if "--check" in argv:
        ok = (m["speedup"] >= SPEEDUP_FLOOR
              and m["vector_scn_per_s"] >= SCN_PER_S_FLOOR)
        print("simspeed check:", "PASS" if ok else "FAIL")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
