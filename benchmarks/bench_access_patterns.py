"""Fig. 9 reproduction: memory-access-pattern heatmaps (address x time) for
a small CNN and ResNet-18 through the bridge.  The ping-pong activation
buffering of the firmware is visible as alternating address bands in the
input-read heatmap, and the weights stream as a monotonically advancing
band — the two signatures the paper calls out.
"""
from __future__ import annotations

from pathlib import Path

from benchmarks.cnn_driver import (gops, resnet18_specs, run_cnn,
                                   small_cnn_specs)

ART = Path(__file__).resolve().parent / "artifacts"


def run() -> list[str]:
    rows = ["case,model,gop,reads,writes,heatmap_file"]
    for name, specs in (("small_cnn", small_cnn_specs(16)),
                        ("resnet18", resnet18_specs(36))):
        fb = run_cnn(specs, backend="oracle")
        reads = sum(1 for t in fb.log.txs if t.kind == "read")
        writes = sum(1 for t in fb.log.txs if t.kind == "write")
        out = ART / f"fig9_heatmap_{name}.txt"
        out.parent.mkdir(parents=True, exist_ok=True)
        txt = ["# address (vertical, high->low) x time (horizontal)",
               "## reads", fb.log.render_heatmap(24, 72, kind="read"),
               "## writes", fb.log.render_heatmap(24, 72, kind="write")]
        out.write_text("\n".join(txt))
        rows.append(f"fig9,{name},{gops(specs):.3f},{reads},{writes},"
                    f"{out.name}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
