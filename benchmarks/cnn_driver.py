"""Shared CNN-through-the-bridge driver for the Fig. 8 / Fig. 9
reproductions (paper §V-D: CGRA accelerator + firmware-heavy ResNet-18).

The firmware does what the paper's firmware does: im2col tiling/retiling of
every conv (host NumPy = paper's C data transformations), double-buffered
("ping-pong") activation buffers, weight prefetch, and launches the matmul
on the accelerator backend through the bridge.  Three DMA engines match the
paper's CGRA: weights / input / output.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.bridge import FireBridge
from repro.core.congestion import CongestionConfig
from repro.kernels.systolic_matmul import ops as mm_ops, ref as mm_ref


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    cin: int
    cout: int
    k: int
    stride: int
    hw: int        # input spatial size (square)


def resnet18_specs(hw: int = 32) -> List[ConvSpec]:
    """ResNet-18 conv shapes at CIFAR-style resolution (~0.7 GOP at 36px)."""
    s: List[ConvSpec] = [ConvSpec("conv1", 3, 64, 3, 1, hw)]
    cfg = [(64, 64, 1), (64, 64, 1), (64, 128, 2), (128, 128, 1),
           (128, 256, 2), (256, 256, 1), (256, 512, 2), (512, 512, 1)]
    cur = hw
    for i, (cin, cout, stride) in enumerate(cfg):
        s.append(ConvSpec(f"block{i}a", cin, cout, 3, stride, cur))
        cur = cur // stride
        s.append(ConvSpec(f"block{i}b", cout, cout, 3, 1, cur))
    return s


def small_cnn_specs(hw: int = 16) -> List[ConvSpec]:
    return [ConvSpec("c0", 3, 16, 3, 1, hw),
            ConvSpec("c1", 16, 32, 3, 2, hw),
            ConvSpec("c2", 32, 32, 3, 1, hw // 2),
            ConvSpec("c3", 32, 64, 3, 2, hw // 2)]


def gops(specs: List[ConvSpec]) -> float:
    total = 0
    for c in specs:
        out_hw = c.hw // c.stride
        total += 2 * out_hw * out_hw * c.cout * c.cin * c.k * c.k
    return total / 1e9


def _im2col(x: np.ndarray, k: int, stride: int) -> np.ndarray:
    """x (H, W, C) -> (out_h*out_w, k*k*C).  Firmware-side retiling."""
    H, W, C = x.shape
    pad = k // 2
    xp = np.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    oh, ow = H // stride, W // stride
    cols = np.empty((oh * ow, k * k * C), x.dtype)
    idx = 0
    for oi in range(oh):
        for oj in range(ow):
            i, j = oi * stride, oj * stride
            cols[idx] = xp[i:i + k, j:j + k].reshape(-1)
            idx += 1
    return cols


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def run_cnn(specs: List[ConvSpec], backend: str = "oracle",
            seed: int = 0, tile: int = 64,
            congestion: Optional[CongestionConfig] = None,
            profile: bool = False) -> FireBridge:
    """Run one inference through the bridge; returns the bridge with the
    full transaction log (3 DMA engines + CSRs).

    With `congestion` set the three DMA engines contend on the online
    shared link *while the layers run* (paper §IV-C) — stall statistics
    come from fb.congestion_stats(), no post-hoc replay.  With `profile`
    each layer's DMA batch is op-marked, so `fb.profiler()` reports
    per-layer attribution (core/profiler.py; examples/profile_cnn.py)."""
    fb = FireBridge("cgra", congestion=congestion, profile=profile)
    fb.csr.define("CTRL", 0x0)
    fb.csr.define("STATUS", 0x4, access="ro")
    fb.csr.define("LAYER", 0x8)
    fb.register_op("matmul", oracle=_mm_oracle, interpret=_mm_interp)

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(specs[0].hw, specs[0].hw, specs[0].cin)) \
        .astype(np.float32) * 0.1
    # ping-pong activation buffers (paper Fig. 9 "alternating layers")
    for layer, c in enumerate(specs):
        cols = _im2col(x, c.k, c.stride)                 # firmware retiling
        M = _round_up(cols.shape[0], tile)
        K = _round_up(cols.shape[1], tile)
        N = _round_up(c.cout, tile)
        a = np.zeros((M, K), np.float32)
        a[:cols.shape[0], :cols.shape[1]] = cols
        w = (rng.normal(size=(K, N)).astype(np.float32) *
             (1.0 / np.sqrt(K)))
        ping = f"act_{layer % 2}"
        pong = f"act_{(layer + 1) % 2}"
        if ping not in fb.mem.buffers:
            fb.mem.alloc(ping, (2 ** 22,), np.float32)   # 16 MB arena
        if pong not in fb.mem.buffers:
            fb.mem.alloc(pong, (2 ** 22,), np.float32)
        wname = f"w_{layer}"
        fb.mem.alloc(wname, w.shape, np.float32)
        fb.mem.host_write(wname, w)

        fb.csr.fb_write_32(0x8, layer)
        fb.csr.fb_write_32(0x0, 1)                       # start layer
        out = fb._ops["matmul"][backend](a, w, tile)
        out = np.maximum(out, 0.0)                       # firmware ReLU
        # DMA bursts: weights prefetch, input read, output write — one
        # batch per layer, so the three engines contend on the shared link
        # (and priorities arbitrate) when congestion is enabled (§IV-C).
        with fb.mem.mark(c.name, "dma"):
            fb.mem.log_burst_list(
                [("dma_weights", "read", fb.mem.buffers[wname].addr + off,
                  tile * tile * 4)
                 for off in range(0, w.nbytes, tile * tile * 4)] +
                [("dma_input", "read", fb.mem.buffers[ping].addr + off,
                  tile * tile * 4)
                 for off in range(0, a.nbytes, tile * tile * 4)] +
                [("dma_output", "write", fb.mem.buffers[pong].addr + off,
                  tile * tile * 4)
                 for off in range(0, out[:cols.shape[0], :c.cout].nbytes,
                                  tile * tile * 4)])
        oh = c.hw // c.stride
        x = out[:oh * oh, :c.cout].reshape(oh, oh, c.cout)
        fb.csr.hw_set("STATUS", layer + 1)
    return fb


def _mm_oracle(a, w, tile):
    return np.asarray(mm_ref.matmul_ref(jnp.asarray(a), jnp.asarray(w)))


def _mm_interp(a, w, tile):
    from repro.kernels.systolic_matmul.kernel import matmul
    return np.asarray(matmul(jnp.asarray(a), jnp.asarray(w), bm=tile,
                             bn=tile, bk=tile, interpret=True))
