"""Fabric scaling: the multi-device co-verification sweep across device
counts AND interconnect topologies (core/fabric.py + core/topology.py;
the FireSim-style scale-out lane).

For each (device count, topology) point the same systolic-matmul cell
runs sharded across a FabricCluster through the CoVerifySession
``devices=``/``topologies=`` axes, reporting

* modeled fabric cycles (scatter/broadcast/launch/gather through the
  per-port links + shared host channel, congestion-arbitrated),
* modeled link stall cycles (the Fig. 8 series, now inter-device),
* routed runs' switch-hop stalls: total flit-arbitration stall summed
  over switch ports plus the single hottest port, and
* wall-clock seconds per cell,

with every gathered result equivalence-checked against the 1-device
crossbar oracle (bit-identical by construction — reduction axes are
never split, and routing reshapes timing, never data).  After the main
table a ``hop`` section breaks the routed cells down per switch port —
the per-hop stall columns that expose WHERE a topology congests.

Quick mode (benchmarks/run.py) keeps the 1/2/4-device crossbar sweep
plus one routed 4-device torus; full mode sweeps ring / 2D-torus /
fat-tree at 4/8/16 devices and adds the head-sharded flash-attention op.

    PYTHONPATH=src:. python benchmarks/bench_fabric_scaling.py [--full]
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import FABRIC_LINK, CoVerifySession

LINK = FABRIC_LINK
MM_SIZE = 128
FA_CFG = {"batch": 1, "heads": 8, "seq": 64, "dim": 16}
TOPOLOGIES = (None, "ring", "torus2d", "fat_tree")


def _sweep(op, firmware, fabric_firmware, backends, table, config,
           devices, topologies):
    sess = CoVerifySession(firmware, fabric_firmware=fabric_firmware,
                           link_config=LINK)
    sess.register_op(op, **table)
    sess.add_sweep(op, backends, [config], devices=devices,
                   topologies=topologies)
    return sess.run(max_workers=4)


def _hop_stalls(result):
    """(total, hottest) switch-port flit-arbitration stall of one routed
    cell, from the ``sw:*`` entries of its link_stats."""
    per_port = {name: sum(r.per_engine_stall.values())
                for name, r in (result.links or {}).items()
                if name.startswith("sw:")}
    return per_port, sum(per_port.values()), max(per_port.values(),
                                                 default=0.0)


def run(quick: bool = True) -> list[str]:
    from repro.kernels.flash_attention import sweep as fa_sweep
    from repro.kernels.systolic_matmul import sweep as mm_sweep

    devices = (1, 2, 4) if quick else (1, 4, 8, 16)
    topologies = (None, "torus2d") if quick else TOPOLOGIES
    rows = ["case,op,backend,devices,topology,bridge_cycles,"
            "link_stall_cycles,hop_stall_cycles,max_hop_stall,wall_s,"
            "equivalent"]
    hop_rows = ["hop,op,backend,devices,topology,port,stall_cycles,"
                "busy_cycles"]
    jobs = [("mm", mm_sweep.matmul_firmware,
             mm_sweep.matmul_fabric_firmware,
             ("oracle", "compiled") if quick else ("oracle", "interpret",
                                                   "compiled"),
             mm_sweep.matmul_backends(tile=32), {"size": MM_SIZE})]
    if not quick:
        jobs.append(("fa", fa_sweep.flash_firmware,
                     fa_sweep.flash_fabric_firmware,
                     ("oracle", "interpret"),
                     fa_sweep.flash_backends(), FA_CFG))
    for op, fw, ffw, backends, table, config in jobs:
        report = _sweep(op, fw, ffw, backends, table, config, devices,
                        topologies)
        assert report.passed, report.summary()
        for r in sorted(report.cells,
                        key=lambda r: (r.cell.backend, r.cell.devices,
                                       r.cell._topo_kind or "")):
            topo = r.cell._topo_kind or "crossbar"
            per_port, hop_total, hop_max = _hop_stalls(r)
            if r.cell.devices > 1:
                assert r.link_stall > 0, \
                    f"no modeled link stalls at {r.cell.label}"
            if r.cell.topology is not None:
                assert per_port, f"no switch ports at {r.cell.label}"
            rows.append(f"fabric,{op},{r.cell.backend},{r.cell.devices},"
                        f"{topo},{r.bridge_time:.0f},{r.link_stall:.0f},"
                        f"{hop_total:.0f},{hop_max:.0f},{r.seconds:.3f},"
                        f"{report.passed}")
            for port, stall in sorted(per_port.items()):
                busy = sum(r.links[port].per_engine_busy.values())
                hop_rows.append(
                    f"hop,{op},{r.cell.backend},{r.cell.devices},{topo},"
                    f"{port[3:]},{stall:.0f},{busy:.0f}")
    return rows + hop_rows


def run_full() -> list[str]:
    return run(quick=False)


if __name__ == "__main__":
    print("\n".join(run(quick="--full" not in sys.argv[1:])))
