"""Fabric scaling: the multi-device co-verification sweep at 1/2/4
devices (core/fabric.py; the FireSim-style scale-out lane).

For each device count the same systolic-matmul cell runs sharded across a
FabricCluster through the CoVerifySession ``devices=`` axis, reporting

* modeled fabric cycles (scatter/broadcast/launch/gather through the
  per-port links + shared host channel, congestion-arbitrated),
* modeled link stall cycles (the Fig. 8 series, now inter-device), and
* wall-clock seconds per cell,

with the gathered result equivalence-checked against the single-device
run (bit-identical by construction — reduction axes are never split).
Full mode adds the head-sharded flash-attention op.

    PYTHONPATH=src:. python benchmarks/bench_fabric_scaling.py [--full]
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import FABRIC_LINK, CoVerifySession

DEVICES = (1, 2, 4)
LINK = FABRIC_LINK
MM_SIZE = 128
FA_CFG = {"batch": 1, "heads": 8, "seq": 64, "dim": 16}


def _sweep(op, firmware, fabric_firmware, backends, table, config):
    sess = CoVerifySession(firmware, fabric_firmware=fabric_firmware,
                           link_config=LINK)
    sess.register_op(op, **table)
    sess.add_sweep(op, backends, [config], devices=DEVICES)
    return sess.run(max_workers=4)


def run(quick: bool = True) -> list[str]:
    from repro.kernels.flash_attention import sweep as fa_sweep
    from repro.kernels.systolic_matmul import sweep as mm_sweep

    rows = ["case,op,backend,devices,bridge_cycles,link_stall_cycles,"
            "wall_s,equivalent"]
    jobs = [("mm", mm_sweep.matmul_firmware,
             mm_sweep.matmul_fabric_firmware,
             ("oracle", "compiled") if quick else ("oracle", "interpret",
                                                   "compiled"),
             mm_sweep.matmul_backends(tile=32), {"size": MM_SIZE})]
    if not quick:
        jobs.append(("fa", fa_sweep.flash_firmware,
                     fa_sweep.flash_fabric_firmware,
                     ("oracle", "interpret"),
                     fa_sweep.flash_backends(), FA_CFG))
    for op, fw, ffw, backends, table, config in jobs:
        report = _sweep(op, fw, ffw, backends, table, config)
        assert report.passed, report.summary()
        for r in sorted(report.cells, key=lambda r: (r.cell.backend,
                                                     r.cell.devices)):
            if r.cell.devices > 1:
                assert r.link_stall > 0, \
                    f"no modeled link stalls at {r.cell.label}"
            rows.append(f"fabric,{op},{r.cell.backend},{r.cell.devices},"
                        f"{r.bridge_time:.0f},{r.link_stall:.0f},"
                        f"{r.seconds:.3f},{report.passed}")
    return rows


def run_full() -> list[str]:
    return run(quick=False)


if __name__ == "__main__":
    print("\n".join(run(quick="--full" not in sys.argv[1:])))
