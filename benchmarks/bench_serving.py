"""Open-loop serving SLO benchmark: the latency trajectory under load
(ROADMAP item 3 — deployment-shaped traffic against the co-verified
serving engine).

Three smoke-scale cells share one warm-jit engine (``reset()`` swaps the
pool geometry between runs):

* **poisson_light** — Poisson arrivals against a pool with headroom:
  the no-contention baseline (queueing ~ 0).
* **bursty_2x**    — an ON-OFF burst whose aggregate page demand is
  about twice the pool: admission defers, p99 TTFT absorbs the
  queueing delay, nothing drops.
* **paged_tight**  — the same burst against a 3-page pool: the
  saturation corner the seventh golden trace pins at cluster scale.

Every per-cell number is **modeled cycles** (deterministic, platform-
independent — token *values* stay out of the witness, exactly like the
golden traces), so the committed ``BENCH_serving.json`` carries the
cells verbatim and ``--check`` (the CI serving lane) is a digest gate:
live SLO rows must hash to the committed digests, and the modeled
floors (p99 TTFT budget, throughput floor, zero drops) must hold.
Wall-clock throughput (runs/sec, warm) rides the ``--json`` trajectory
only — it never gates.

    PYTHONPATH=src python benchmarks/bench_serving.py
    PYTHONPATH=src python benchmarks/bench_serving.py --check
    PYTHONPATH=src python benchmarks/bench_serving.py --json BENCH_serving.json
"""
from __future__ import annotations

import hashlib
import json
import sys
import time
from pathlib import Path
from typing import Dict, List

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serving.json"

# Modeled floors for the CI lane (cycles / tokens-per-kcycle): a
# scheduler regression that inflates tail latency or strands requests
# fails deterministically, no wall-clock noise involved.
P99_TTFT_BUDGET = 1500.0
TOK_PER_KCYC_FLOOR = 5.0

CELLS = (
    ("poisson_light",
     {"kind": "poisson", "seed": 3,
      "params": {"n_requests": 8, "mean_gap": 150.0,
                 "prompt_lens": (3, 10), "max_new": (1, 4)}},
     {"kv_pages": 4, "kv_page_size": 8}),
    ("bursty_2x",
     {"kind": "bursty", "seed": 11,
      "params": {"n_requests": 8, "burst_size": 8, "gap_in_burst": 5.0,
                 "gap_between": 400.0, "prompt_lens": (3, 10),
                 "max_new": (2, 4)}},
     {"kv_pages": 4, "kv_page_size": 8}),
    ("paged_tight",
     {"kind": "bursty", "seed": 11,
      "params": {"n_requests": 8, "burst_size": 8, "gap_in_burst": 5.0,
                 "gap_between": 400.0, "prompt_lens": (3, 10),
                 "max_new": (2, 4)}},
     {"kv_pages": 3, "kv_page_size": 8}),
)


def _engine():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke
    from repro.models import init_params
    from repro.models.transformer import RunFlags

    from repro.serving import ServingEngine
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return ServingEngine(cfg, params, max_slots=4, max_len=32,
                         prompt_pad=8, kv_pages=4, kv_page_size=8,
                         batching="continuous",
                         flags=RunFlags(attn_impl="chunked", q_chunk=16,
                                        kv_chunk=16))


def _run_cell(eng, spec, pool):
    from repro.serving import SLOReport, build_trace, run_open_loop
    trace = build_trace(spec["kind"], spec["seed"], **spec["params"])
    eng.reset(batching="continuous", **pool)
    run_open_loop(eng, trace)
    return trace, SLOReport.from_run(trace, eng)


def _rows_digest(slo) -> str:
    """Platform-independent witness: modeled-cycle SLO rows only (token
    values never enter — the golden-trace rule)."""
    h = hashlib.sha256()
    for row in slo.to_rows():
        h.update(row.encode())
        h.update(b"\n")
    return h.hexdigest()


def measure(eng=None) -> Dict[str, dict]:
    eng = eng if eng is not None else _engine()
    cells: Dict[str, dict] = {}
    for name, spec, pool in CELLS:
        trace, slo = _run_cell(eng, spec, pool)
        assert slo.completed == len(trace.arrivals), \
            f"{name}: dropped an admitted request"
        assert eng.kv_pool.n_free == eng.kv_pool.n_pages, \
            f"{name}: KV page leak"
        cells[name] = {
            "rows_digest": _rows_digest(slo),
            "completed": slo.completed,
            "deferrals": slo.deferrals,
            "rejected": slo.rejected,
            "p50_ttft": round(slo.p50_ttft(), 1),
            "p99_ttft": round(slo.p99_ttft(), 1),
            "p50_itl": round(slo.p50_itl(), 1),
            "p99_itl": round(slo.p99_itl(), 1),
            "tok_per_kcyc": round(slo.tokens_per_kcycle(), 3),
        }
    return cells


def run() -> List[str]:
    """Quick mode for benchmarks/run.py: CSV rows (modeled cycles)."""
    cells = measure()
    rows = ["cell,completed,deferrals,p50_ttft,p99_ttft,tok_per_kcyc,"
            "rows_digest16"]
    for name, c in cells.items():
        rows.append(f"{name},{c['completed']},{c['deferrals']},"
                    f"{c['p50_ttft']},{c['p99_ttft']},"
                    f"{c['tok_per_kcyc']},{c['rows_digest'][:16]}")
    return rows


def check(cells: Dict[str, dict]) -> List[str]:
    """The CI gate: committed-cell digest identity + modeled floors."""
    problems: List[str] = []
    committed = (json.loads(BENCH_PATH.read_text())["cells"]
                 if BENCH_PATH.exists() else None)
    if committed is None:
        problems.append(f"{BENCH_PATH.name} missing")
        committed = {}
    for name, c in cells.items():
        want = committed.get(name)
        if want is None:
            problems.append(f"{name}: not in committed cells")
        elif want != c:
            diff = [k for k in c if want.get(k) != c[k]]
            problems.append(f"{name}: drifted from committed cell "
                            f"(fields: {diff})")
        if c["p99_ttft"] > P99_TTFT_BUDGET:
            problems.append(f"{name}: p99 TTFT {c['p99_ttft']} > "
                            f"budget {P99_TTFT_BUDGET}")
        if c["tok_per_kcyc"] < TOK_PER_KCYC_FLOOR:
            problems.append(f"{name}: {c['tok_per_kcyc']} tok/kcyc < "
                            f"floor {TOK_PER_KCYC_FLOOR}")
        if c["rejected"]:
            problems.append(f"{name}: {c['rejected']} doorbell "
                            f"rejections in a feasible workload")
    if "bursty_2x" in cells and not cells["bursty_2x"]["deferrals"]:
        problems.append("bursty_2x: stimulus never oversubscribed "
                        "the pool")
    return problems


def main(argv: List[str]) -> int:
    eng = _engine()
    cells = measure(eng)
    # determinism witness: a warm rerun must reproduce every cell
    assert measure(eng) == cells, "serving cells are not rerun-stable"
    print("cell,completed,deferrals,p50_ttft,p99_ttft,p99_itl,"
          "tok_per_kcyc,rows_digest16")
    for name, c in cells.items():
        print(f"{name},{c['completed']},{c['deferrals']},{c['p50_ttft']},"
              f"{c['p99_ttft']},{c['p99_itl']},{c['tok_per_kcyc']},"
              f"{c['rows_digest'][:16]}")

    out = next((argv[i + 1] for i, a in enumerate(argv)
                if a == "--json" and i + 1 < len(argv)), None)
    if out:
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            measure(eng)
        wall = (time.perf_counter() - t0) / reps
        path = Path(out)
        doc = json.loads(path.read_text()) if path.exists() else {
            "bench": "serving",
            "unit": "modeled-cycle SLO cells (deterministic, gated) + "
                    "warm wall-clock runs/sec trajectory (not gated)",
            "floors": {"p99_ttft_cycles": P99_TTFT_BUDGET,
                       "tok_per_kcyc": TOK_PER_KCYC_FLOOR},
            "cells": {},
            "trajectory": [],
        }
        doc["cells"] = cells
        doc["trajectory"].append({
            "date": time.strftime("%Y-%m-%d"),
            "runs_per_s": round(1.0 / wall, 2),
        })
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {path}")

    if "--check" in argv:
        problems = check(cells)
        for p in problems:
            print(f"  FAIL {p}")
        print("serving check:", "FAIL" if problems else "PASS")
        return 1 if problems else 0
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
