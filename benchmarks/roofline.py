"""Roofline aggregation: reads launch/dryrun artifacts and renders the
EXPERIMENTS.md §Dry-run and §Roofline tables.

Two memory columns are reported:
  * mem_lax        — parsed from the compiled HLO (the program the dry-run
                     actually lowers: lax attention/scan twins, whose tile
                     intermediates round-trip HBM at XLA fusion granularity)
  * mem_kernelized — first-principles HBM model with the Pallas kernels
                     substituted for their lax twins (tile/state traffic
                     VMEM-resident; weights + layer-boundary activations +
                     kernel operand streams only).  This is the number the
                     TPU deployment with kernels enabled would see; the
                     derivation is in kernel_traffic_model() below.

Roofline placement (dominant term, attainable fraction) is computed by
``core/profiler.RooflinePlacement`` — the same placement the
data-movement profiler produces for per-kernel points — so this table and
the profiler cannot disagree on what "memory-bound" means.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import SHAPES, get_config, non_embedding_params  # noqa: E402
from repro.core.hlo_profiler import HBM_BW, PEAK_FLOPS_BF16  # noqa: E402
from repro.core.profiler import RooflinePlacement  # noqa: E402

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# Kernelized HBM-traffic model (per device, bytes)
# ---------------------------------------------------------------------------


def kernel_traffic_model(arch: str, shape_name: str, world: int,
                         microbatches: int = 4) -> float:
    """Ideal-but-honest HBM traffic with Pallas kernels:

      weights    : read 3x per microbatch in train (fwd, remat fwd, bwd),
                   1x in serve; grads/opt state r/w once per step (f32).
      activations: ~12 (B,S,d)-equivalent bf16 tensors per layer boundary,
                   x3 passes in train (fwd, remat, bwd), x1 serve.
      kernels    : flash/SSD/WKV stream operands+outputs exactly once
                   (k/v or state resident in VMEM per block).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kind = shape.kind
    n = non_embedding_params(cfg, active_only=cfg.moe is not None)
    emb = cfg.vocab_size * cfg.d_model
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    tok_dev = tokens / world
    d = cfg.d_model

    if kind == "train":
        w = (n + emb) * 2 / 16 * 3 * microbatches      # bf16, model-sharded
        opt = (n + emb) * 4 / world * (3 * 2 + 2)      # m,v,p r/w + grads r/w
        acts = tok_dev * d * 2 * 12 * cfg.n_layers * 3
        return w + opt + acts
    if kind == "prefill":
        w = (n + emb) * 2 / 16
        acts = tok_dev * d * 2 * 12 * cfg.n_layers
        cache = tok_dev * cfg.n_layers * cfg.d_kv * 2 * 2
        return w + acts + cache
    # decode: weights + full KV/state cache read + tiny activations
    w = (n + emb) * 2 / 16
    if cfg.family == "ssm":
        st = cfg.n_layers * shape.global_batch * cfg.n_heads * 64 * 64 * 4
        cache = 2 * st / world
    elif cfg.family == "hybrid":
        d_in = cfg.ssm.expand * d
        st = cfg.n_layers * shape.global_batch * (d_in // 64) * 64 * 64 * 4
        win = 9 * shape.global_batch * min(cfg.attn_window, shape.seq_len) * \
            cfg.d_kv * 2 * 2
        cache = (2 * st + win) / world
    else:
        cache = (cfg.n_layers * shape.global_batch * shape.seq_len *
                 cfg.d_kv * 2 * 2) / world
    acts = shape.global_batch / world * d * 2 * 12 * cfg.n_layers
    return w + cache + acts


# ---------------------------------------------------------------------------
# Table rendering
# ---------------------------------------------------------------------------


def load(tag: str = "baseline") -> list[dict]:
    recs = []
    for f in sorted(ART.glob(f"*__{tag}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def render_dryrun_table(recs) -> str:
    lines = ["| arch | shape | mesh | compile_s | args GB/dev | temp GB/dev* | "
             "HLO GFLOP/dev | coll GB/dev | collective mix |",
             "|---|---|---|---|---|---|---|---|---|",
             "<!-- *temp is TPU-corrected: XLA-CPU bf16->f32 operand-"
             "conversion buffers subtracted (per-cell raw values in the "
             "JSON artifacts) -->"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        ma = r["memory_analysis"]
        args = ma.get("argument_size_in_bytes", 0) / 1e9
        temp = (ma.get("temp_size_in_bytes", 0) -
                ma.get("cpu_f32_convert_artifact_bytes", 0)) / 1e9
        p = r["profile"]
        mix = ",".join(f"{k}:{v['count']}" for k, v in
                       sorted(p["collective_summary"].items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.1f} | {args:.2f} | {temp:.2f} | "
            f"{p['hlo_flops_per_dev']/1e9:.1f} | "
            f"{p['collective_bytes_per_dev']/1e9:.3f} | {mix} |")
    return "\n".join(lines)


def render_roofline_table(recs, single_pod_only: bool = True) -> str:
    lines = ["| arch | shape | compute_s | mem_lax_s | mem_kern_s | coll_s | "
             "dominant | useful | roofline_frac(kern) | what would move the "
             "dominant term |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if single_pod_only and r["mesh"] != "16x16":
            continue
        rl = r["roofline"]
        mk = kernel_traffic_model(r["arch"], r["shape"], r["world"],
                                  r["flags"].get("microbatches", 4)) / HBM_BW
        pl = RooflinePlacement(
            f"{r['arch']}/{r['shape']}",
            {"compute": rl["compute_s"], "memory": mk,
             "collective": rl["collective_s"]},
            ideal_s=rl["model_flops_per_dev"] / PEAK_FLOPS_BF16)
        dom, frac = pl.dominant, pl.roofline_frac
        hint = _hint(r, dom)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {mk:.3e} | {rl['collective_s']:.3e} | "
            f"{dom} | {rl['useful_ratio']:.2f} | {frac:.3f} | {hint} |")
    return "\n".join(lines)


def _hint(r, dom) -> str:
    kind = r["kind"]
    fam = get_config(r["arch"]).family
    if dom == "compute":
        if kind in ("train", "prefill"):
            return "skip fully-masked causal tiles (halves attention FLOPs)"
        return "batch more decode requests per step"
    if dom == "memory":
        if kind == "decode":
            return "KV/state cache is the floor; quantize cache to int8"
        if fam == "ssm":
            return "larger WKV chunk + Pallas kernel keeps state in VMEM"
        return "Pallas kernels keep tile intermediates in VMEM"
    return "reduce-scatter instead of all-reduce; shard_map EP all-to-all (MoE)"


def main():
    recs = load("baseline")
    print(f"{len(recs)} baseline artifacts")
    out = Path(__file__).resolve().parent / "artifacts"
    (out / "dryrun_table.md").write_text(render_dryrun_table(recs))
    (out / "roofline_table.md").write_text(render_roofline_table(recs))
    print("wrote dryrun_table.md, roofline_table.md")


if __name__ == "__main__":
    main()
