"""Fig. 5 reproduction: debug-iteration time, FireBridge flow vs FPGA EDA
flow, scaling with systolic-array size (PE count).

Measured side: wall-clock of ONE full co-verification iteration — firmware
change + bridge simulation (Pallas interpret = "RTL sim") + three-way
equivalence check — on a matmul workload sized so the active tile equals
the paper's PE-array size.  FPGA side: the paper's Vivado synth+P&R times
(`modeled-from-paper`, DESIGN.md §9).  The paper's claim is up to 50x at
the largest design that fits the ZCU102 (2500 PEs).

Second measurement (the batched lane): a >=8-cell (op, backend, config)
sweep through the CoVerifySession scheduler vs. the sequential per-op
coverify loop — the scheduler shares compiled backends across cells and
overlaps independent cells on a thread pool (core/scheduler.py).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import CongestionConfig, CoVerifySession, coverify
from repro.kernels.systolic_matmul import ops as mm_ops, ref as mm_ref, \
    sweep as sweep_mod
from repro.kernels.systolic_matmul.kernel import matmul as mm_kernel

# (PE count, matrix size) — tile = sqrt(PE) x sqrt(PE); matrix 16 tiles wide
# so the interpret-mode "RTL sim" streams a non-trivial workload through the
# array.  Note: the resulting speedup exceeds the paper's 50x because our
# simulated subsystem is a single kernel, not their full SoC — the claim is
# reproduced conservatively (flow shape + >=50x at every size).
CASES = [(100, 10 * 16), (400, 20 * 16), (900, 30 * 16), (1600, 40 * 16),
         (2500, 50 * 16)]

# Vivado 2020.2 synth+place+route+ILA minutes for the paper's SoC at these
# PE counts (paper Fig. 5 flow; modeled-from-paper).
VIVADO_MIN = {100: 18.0, 400: 27.0, 900: 42.0, 1600: 68.0, 2500: 105.0}


def one_iteration(pes: int, size: int) -> float:
    tile = int(np.sqrt(pes)) * 8 // 8
    tile = max(8, int(np.sqrt(pes)))
    rng = np.random.default_rng(pes)
    a = rng.normal(size=(size, size)).astype(np.float32)
    b = rng.normal(size=(size, size)).astype(np.float32)

    def firmware(fb, backend):
        fb.mem.alloc("a", a.shape, np.float32)
        fb.mem.alloc("b", b.shape, np.float32)
        fb.mem.alloc("c", (size, size), np.float32)
        fb.mem.host_write("a", a)
        fb.mem.host_write("b", b)
        fb.launch("mm", backend, ["a", "b"], ["c"],
                  burst_list=lambda: mm_ops.transactions(
                      size, size, size, bm=tile, bn=tile, bk=tile,
                      dtype_bytes=4))

    ops = {"mm": dict(
        oracle=lambda x, y: np.asarray(mm_ref.matmul_ref(
            jnp.asarray(x), jnp.asarray(y))),
        interpret=lambda x, y: np.asarray(mm_kernel(
            jnp.asarray(x), jnp.asarray(y), bm=tile, bn=tile, bk=tile,
            interpret=True)),
    )}
    t0 = time.perf_counter()
    res = coverify(firmware, ops, backends=("oracle", "interpret"),
                   tol=1e-3, congestion=CongestionConfig(dos_prob=0.05,
                                                         seed=pes))
    dt = time.perf_counter() - t0
    assert res.passed, f"co-verification failed at {pes} PEs"
    return dt


def run() -> list[str]:
    rows = ["case,pe_count,firebridge_s,fpga_flow_s(modeled-from-paper),speedup"]
    for pes, size in CASES:
        dt = one_iteration(pes, size)
        fpga = VIVADO_MIN[pes] * 60.0
        rows.append(f"fig5,{pes},{dt:.2f},{fpga:.0f},{fpga/dt:.0f}x")
    return rows


# ------------------------------------------------- batched sweep (Fig. 5+)
SWEEP_SIZES = (64, 96, 128, 160)
SWEEP_TILE = 32

# The sequential per-op loop calls matmul_backends() fresh every iteration
# (exactly like one_iteration above), discarding the jitted trace/
# executable cache across cells; the CoVerifySession registers one table
# for the whole sweep, so each backend is traced and compiled once per
# shape for the entire session — the scheduler's compiled-backend cache.
_sweep_firmware = sweep_mod.matmul_firmware


def _make_mm_backends():
    return sweep_mod.matmul_backends(tile=SWEEP_TILE)


def sweep_comparison(sizes=SWEEP_SIZES,
                     backends=("oracle", "interpret", "compiled"),
                     max_workers: int = 4) -> tuple[float, float, bool]:
    """(sequential_s, batched_s, both_passed) on a len(sizes)*3-cell sweep.

    Sequential lane: one coverify() call per config, fresh backend lambdas
    each time — the pre-scheduler flow.  Batched lane: one CoVerifySession
    with shared backends and a thread pool.  Both lanes are measured after
    one warmup pass over every shape (steady-state debug iterations: the
    sweep is re-run after each firmware edit with XLA caches warm).
    """
    cong = CongestionConfig(dos_prob=0.02, seed=11)

    def run_sequential() -> tuple[float, bool]:
        t0 = time.perf_counter()
        ok = True
        for size in sizes:
            def fw(fb, backend, size=size):
                _sweep_firmware(fb, "mm", backend, size=size)
            res = coverify(fw, {"mm": _make_mm_backends()},
                           backends=backends, tol=1e-3, congestion=cong)
            ok &= res.passed
        return time.perf_counter() - t0, ok

    # ONE session for all batched sweep re-runs — its registered backend
    # table (jitted callables) persists, so re-sweeps after a firmware
    # edit hit the trace/executable cache instead of recompiling.
    sess = CoVerifySession(_sweep_firmware, congestion=cong)
    sess.register_op("mm", **_make_mm_backends())
    sess.add_sweep("mm", backends, [{"size": s} for s in sizes])

    def run_batched() -> tuple[float, bool]:
        t0 = time.perf_counter()
        report = sess.run(max_workers=max_workers)
        return time.perf_counter() - t0, report.passed

    run_sequential()                      # warmup: populate XLA shape caches
    seq_s, seq_ok = run_sequential()
    run_batched()                         # warmup: populate session caches
    bat_s, bat_ok = run_batched()
    return seq_s, bat_s, seq_ok and bat_ok


def run_sweep() -> list[str]:
    ncells = len(SWEEP_SIZES) * 3
    seq_s, bat_s, ok = sweep_comparison()
    return [f"case,cells,sequential_s,batched_s,speedup,passed",
            f"fig5_sweep,{ncells},{seq_s:.2f},{bat_s:.2f},"
            f"{seq_s/bat_s:.2f}x,{ok}"]


if __name__ == "__main__":
    print("\n".join(run()))
    print("\n".join(run_sweep()))
