"""Always-on counter overhead + the fleet counter report.

AutoCounter-style instrumentation is only allowed to be *always on* if
it is effectively free, so the first gate mirrors
``benchmarks/bench_profiler.py``: the 200-launch fault-injected fuzz
workload with the counter layer live vs scoped off via
``sampling_disabled()``, interleaved A/B, best-of-reps, overhead
asserted < 10%.

The second half is the fleet view: a bounded run-farm sweep campaign
with counters enabled on every unit, run sequentially (the oracle) and
on a 2-worker pool — the campaign digest AND the uid-merged fleet
counter totals must be byte-identical across worker counts, and the
fleet counter report is written to
``benchmarks/artifacts/counters_ci/fleet_counters.json`` (CI uploads it
per run).

    PYTHONPATH=src:. python benchmarks/bench_counters.py           # quick
    PYTHONPATH=src:. python benchmarks/bench_counters.py --full --json BENCH_counters.json
    PYTHONPATH=src:. python benchmarks/bench_counters.py --ci      # CI lane
"""
from __future__ import annotations

import json
import shutil
import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.counters import (counter_banks, merged_totals,
                                 sampling_disabled)
from repro.runfarm import CampaignManager, sweep_units

SEED = 2026
MAX_OVERHEAD = 0.10             # the acceptance ceiling, same as profiling
SWEEP_SIZES = (16, 32, 64)      # the CI fleet campaign's matmul configs
ART = Path(__file__).resolve().parent / "artifacts"


def measure_overhead(repeats: int) -> Dict:
    """Best-of-reps wall ms of the 200-launch fuzz workload with the
    always-on counter layer live vs scoped off — the lanes interleave so
    scheduler noise hits both equally."""
    from benchmarks.bench_profiler import _fuzzer, _run_workload
    fz = _fuzzer()
    scn = fz.scenario(0)
    _run_workload(fz, scn, profile=False)       # warm the jitted backends
    off_ts, on_ts = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        with sampling_disabled():
            _run_workload(fz, scn, profile=False)
        off_ts.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        fb = _run_workload(fz, scn, profile=False)
        on_ts.append((time.perf_counter() - t0) * 1e3)
    off_ms, on_ms = min(off_ts), min(on_ts)
    samples = fb.mem.counters.stream.n_samples
    assert samples > 0, "counter lane produced no samples"
    return {"off_ms": off_ms, "on_ms": on_ms,
            "overhead": (on_ms - off_ms) / off_ms, "samples": samples,
            "totals": merged_totals(counter_banks(fb))}


def fleet_campaign(sizes, base: Path, worker_counts=(0, 2)) -> Dict:
    """One counters-on sweep campaign per worker count over identical
    units: campaign digests AND uid-merged fleet counter totals must be
    byte-identical (worker count is an execution detail, never a
    measurement detail)."""
    units = sweep_units(seed=SEED, configs=[{"size": s} for s in sizes])
    lanes = []
    for w in worker_counts:
        res = CampaignManager(base / f"w{w}", units, seed=SEED, workers=w,
                              generations=1).run()
        if not res.passed:
            raise RuntimeError(f"workers={w} counters campaign failed")
        lanes.append({"workers": w, "digest": res.digest,
                      "counters": dict(res.counters)})
    digests = {l["digest"] for l in lanes}
    fleets = [l["counters"] for l in lanes]
    return {"units": len(units), "lanes": lanes,
            "digest_identical": len(digests) == 1,
            "fleet_identical": all(f == fleets[0] for f in fleets),
            "counters": fleets[0]}


def _write_fleet_report(m: Dict) -> Path:
    out = ART / "counters_ci"
    out.mkdir(parents=True, exist_ok=True)
    path = out / "fleet_counters.json"
    path.write_text(json.dumps(
        {"bench": "counters", "units": m["units"],
         "campaign_digest": m["lanes"][0]["digest"],
         "worker_counts": [l["workers"] for l in m["lanes"]],
         "digest_identical": m["digest_identical"],
         "fleet_identical": m["fleet_identical"],
         "counters": {n: round(float(v), 6)
                      for n, v in sorted(m["counters"].items())}},
        indent=2) + "\n")
    return path


def run(quick: bool = True) -> List[str]:
    """Quick mode for benchmarks/run.py: CSV rows."""
    ov = measure_overhead(5 if quick else 9)
    base = Path(tempfile.mkdtemp(prefix="bench_counters_"))
    try:
        m = fleet_campaign(SWEEP_SIZES[:2], base)
    finally:
        shutil.rmtree(base, ignore_errors=True)
    rows = ["case,ms,detail"]
    rows.append(f"counters_off,{ov['off_ms']:.1f},-")
    rows.append(f"counters_on,{ov['on_ms']:.1f},"
                f"overhead={100 * ov['overhead']:.1f}%;"
                f"samples={ov['samples']}")
    rows.append(f"fleet_campaign,-,units={m['units']};"
                f"digest_identical={m['digest_identical']};"
                f"fleet_identical={m['fleet_identical']}")
    assert ov["overhead"] < MAX_OVERHEAD, (
        f"always-on counter overhead {100 * ov['overhead']:.1f}% exceeds "
        f"the {100 * MAX_OVERHEAD:.0f}% ceiling "
        f"(off {ov['off_ms']:.1f} ms, on {ov['on_ms']:.1f} ms)")
    assert m["digest_identical"] and m["fleet_identical"]
    return rows


def ci_lane() -> int:
    """The CI counters lane: the overhead gate on the 200-launch
    workload plus the worker-count-invariant fleet campaign; the fleet
    counter report lands under benchmarks/artifacts/counters_ci/ so CI
    uploads it per run."""
    ov = measure_overhead(5)
    base = ART / "counters_ci"
    shutil.rmtree(base, ignore_errors=True)
    m = fleet_campaign(SWEEP_SIZES, base / "campaign")
    path = _write_fleet_report(m)
    checks = {
        "overhead_under_ceiling": ov["overhead"] < MAX_OVERHEAD,
        "stream_sampled": ov["samples"] > 0,
        "campaign_digest_identical": m["digest_identical"],
        "fleet_counters_identical": m["fleet_identical"],
        "fleet_counters_nonempty": bool(m["counters"]),
    }
    print(f"counters CI lane: 200-launch workload, "
          f"off {ov['off_ms']:.1f} ms, on {ov['on_ms']:.1f} ms, "
          f"overhead {100 * ov['overhead']:.1f}% "
          f"(ceiling {100 * MAX_OVERHEAD:.0f}%)")
    print(f"  fleet campaign: {m['units']} sweep units x workers "
          f"{[l['workers'] for l in m['lanes']]}, "
          f"{len(m['counters'])} fleet counters -> {path}")
    for name, ok in checks.items():
        print(f"  {name}: {'OK' if ok else 'FAIL'}")
    ok = all(checks.values())
    print("counters check:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def main(argv: List[str]) -> int:
    if "--ci" in argv:
        return ci_lane()
    ov = measure_overhead(9 if "--full" in argv else 5)
    base = Path(tempfile.mkdtemp(prefix="bench_counters_"))
    try:
        m = fleet_campaign(SWEEP_SIZES, base)
    finally:
        shutil.rmtree(base, ignore_errors=True)
    print(f"workload: 200-launch fault-injected fuzz scenario, "
          f"always-on counters ({ov['samples']} samples) vs "
          f"sampling_disabled()")
    print(f"  counters_off: {ov['off_ms']:.1f} ms (best of reps)")
    print(f"  counters_on:  {ov['on_ms']:.1f} ms "
          f"-> overhead {100 * ov['overhead']:.2f}% "
          f"(ceiling {100 * MAX_OVERHEAD:.0f}%)")
    print(f"fleet campaign: {m['units']} sweep units, digest identical "
          f"across workers {[l['workers'] for l in m['lanes']]}: "
          f"{m['digest_identical']}, fleet counters identical: "
          f"{m['fleet_identical']}")
    out = next((argv[i + 1] for i, a in enumerate(argv)
                if a == "--json" and i + 1 < len(argv)), None)
    if out:
        path = Path(out)
        doc = json.loads(path.read_text()) if path.exists() else {
            "bench": "counters",
            "unit": "wall-ms overhead of the always-on counter layer on "
                    "the 200-launch fuzz workload (vs "
                    "sampling_disabled()), plus the worker-count-"
                    "invariant fleet counter campaign",
            "workload": {"seed": SEED, "launches": 200,
                         "sweep_sizes": list(SWEEP_SIZES)},
            "floors": {"max_overhead": MAX_OVERHEAD},
            "trajectory": [],
        }
        doc["trajectory"].append({
            "date": time.strftime("%Y-%m-%d"),
            "off_ms": round(ov["off_ms"], 1),
            "on_ms": round(ov["on_ms"], 1),
            "overhead_pct": round(100 * ov["overhead"], 2),
            "samples": ov["samples"],
            "fleet_units": m["units"],
            "campaign_digest": m["lanes"][0]["digest"][:16],
            "fleet_identical": m["fleet_identical"],
        })
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {path}")
    if "--check" in argv:
        ok = (ov["overhead"] < MAX_OVERHEAD and m["digest_identical"]
              and m["fleet_identical"])
        print("counters check:", "PASS" if ok else "FAIL")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
