from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.failures import FailureInjector, StragglerMonitor

__all__ = ["Trainer", "TrainerConfig", "FailureInjector", "StragglerMonitor"]
