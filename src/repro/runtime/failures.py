"""Fault injection + straggler detection.

FailureInjector is the test harness for the trainer's checkpoint/restart
path (the software analogue of FireBridge's randomized denial-of-service:
deterministic, seeded, assertable).  StragglerMonitor is the per-host
step-time EWMA detector used at scale to trigger mitigation (re-balance /
hot-spare swap); here mitigation is recorded and surfaced in metrics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    """Deterministic schedule of failures/delays keyed by step."""

    def __init__(self, fail_steps=(), delay_steps: Optional[Dict[int, float]] = None,
                 seed: int = 0, fail_prob: float = 0.0):
        self.fail_steps = set(fail_steps)
        self.delay_steps = delay_steps or {}
        self.rng = np.random.default_rng(seed)
        self.fail_prob = fail_prob
        self.injected: List[int] = []

    def check(self, step: int) -> None:
        if step in self.delay_steps:
            time.sleep(self.delay_steps.pop(step))
        if step in self.fail_steps or (
                self.fail_prob and self.rng.random() < self.fail_prob):
            # transient fault: fires once, then the retried step succeeds
            self.fail_steps.discard(step)
            self.injected.append(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ewma: float
    ratio: float


class StragglerMonitor:
    """EWMA step-time monitor; flags steps slower than `threshold` x EWMA."""

    def __init__(self, alpha: float = 0.2, threshold: float = 2.0,
                 warmup: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.n = 0
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        self.n += 1
        if self.ewma is None:
            self.ewma = step_time
            return None
        ev = None
        if self.n > self.warmup and step_time > self.threshold * self.ewma:
            ev = StragglerEvent(step, step_time, self.ewma,
                                step_time / self.ewma)
            self.events.append(ev)
            # mitigation: do NOT fold the outlier into the EWMA
            return ev
        self.ewma = self.alpha * step_time + (1 - self.alpha) * self.ewma
        return ev
