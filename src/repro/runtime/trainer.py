"""Production trainer: jitted train step + data pipeline + async sharded
checkpointing + failure recovery + straggler monitoring + elastic rescale.

The control flow is deliberately firmware-shaped (FireBridge §IV-A): the
host loop reads/writes a RegisterFile for run control (RUN/STOP/STATUS/
STEP), so the register-protocol tests drive the trainer exactly like the
paper's firmware drives its accelerator.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.registers import RO, RegisterFile
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import SyntheticLMDataset
from repro.launch import steps as steps_lib
from repro.models.transformer import RunFlags, ShardCtx
from repro.optim.adamw import AdamWConfig
from repro.optim.compress import ef_compress, init_error
from repro.runtime.failures import (FailureInjector, SimulatedFailure,
                                    StragglerMonitor)


@dataclasses.dataclass
class TrainerConfig:
    seq_len: int = 256
    global_batch: int = 8
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    seed: int = 0
    log_path: Optional[str] = None
    grad_compression: str = "none"        # none | int8_ef
    max_restarts: int = 3


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 flags: RunFlags = RunFlags(microbatches=1),
                 opt_cfg: AdamWConfig = AdamWConfig(),
                 mesh=None, ctx: Optional[ShardCtx] = None,
                 failure_injector: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.flags = flags
        self.mesh = mesh
        self.ctx = ctx
        self.injector = failure_injector
        self.straggler = StragglerMonitor()
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self.metrics_log: list[dict] = []
        self.restarts = 0

        # control-plane registers (fb_read_32/fb_write_32 protocol)
        self.csr = RegisterFile("trainer.csr")
        self.csr.define("CTRL", 0x00)                  # bit0 = run
        self.csr.define("STATUS", 0x04, access=RO)     # 0 idle 1 run 2 done 3 err
        self.csr.define("STEP", 0x08, access=RO)
        self.csr.define("RESTARTS", 0x0C, access=RO)

        self._step_fn = steps_lib.make_train_step(cfg, flags, ctx, opt_cfg)
        self._jit_step = jax.jit(self._step_fn, donate_argnums=0)
        self._ef = None

        self.dataset = SyntheticLMDataset(cfg.vocab_size, tcfg.seq_len,
                                          tcfg.global_batch, seed=tcfg.seed)

    # ------------------------------------------------------------------
    def init_state(self):
        return steps_lib.make_train_state(self.cfg,
                                          jax.random.PRNGKey(self.tcfg.seed))

    def _resume_or_init(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return self.init_state(), 0
        like = jax.eval_shape(self.init_state)
        state = self.ckpt.restore(latest, like)
        return state, latest

    # ------------------------------------------------------------------
    def train(self, state=None, start_step: int = 0, resume: bool = False):
        if resume:
            state, start_step = self._resume_or_init()
        elif state is None:
            state = self.init_state()
        self.csr.hw_set("STATUS", 1)
        self.csr.fb_write_32(self.csr.addr_of("CTRL"), 1)

        pipe = DataPipeline(self.dataset, start_step=start_step)
        step = start_step
        try:
            while step < self.tcfg.steps:
                if not (self.csr.fb_read_32(self.csr.addr_of("CTRL")) & 1):
                    break                               # host requested stop
                t0 = time.perf_counter()
                try:
                    if self.injector is not None:
                        self.injector.check(step)
                    _, batch = pipe.next()
                    if self.tcfg.grad_compression == "int8_ef":
                        batch = batch                   # compression inside step below
                    state, metrics = self._jit_step(state, batch)
                    loss = float(metrics["loss"])
                except SimulatedFailure:
                    # fault tolerance: restore last checkpoint and continue
                    self.restarts += 1
                    self.csr.hw_set("RESTARTS", self.restarts)
                    if self.restarts > self.tcfg.max_restarts:
                        self.csr.hw_set("STATUS", 3)
                        raise
                    pipe.stop()
                    state, step = self._resume_or_init()
                    pipe = DataPipeline(self.dataset, start_step=step)
                    continue
                dt = time.perf_counter() - t0
                ev = self.straggler.observe(step, dt)
                rec = {"step": step, "loss": loss,
                       "lr": float(metrics["lr"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "step_time": dt,
                       "straggler": bool(ev)}
                self.metrics_log.append(rec)
                self.csr.hw_set("STEP", step)
                step += 1
                if step % self.tcfg.ckpt_every == 0 or step == self.tcfg.steps:
                    self.ckpt.save(step, state)
        finally:
            pipe.stop()
            self.ckpt.wait()
            if self.tcfg.log_path:
                Path(self.tcfg.log_path).write_text(
                    "\n".join(json.dumps(r) for r in self.metrics_log))
        self.csr.hw_set("STATUS", 2)
        return state, step

    # ------------------------------------------------------------------
    def rescale(self, state, new_mesh, new_ctx: ShardCtx):
        """Elastic rescale: checkpoint-free resharding onto a new mesh."""
        from repro.sharding.specs import param_specs, to_shardings
        st_shape = jax.eval_shape(lambda: state)
        pspec = param_specs(self.cfg, st_shape["params"], new_mesh)
        sh = to_shardings({"params": pspec, "m": pspec, "v": pspec},
                          new_mesh)
        new_state = {
            "params": jax.device_put(state["params"], sh["params"]),
            "m": jax.device_put(state["m"], sh["m"]),
            "v": jax.device_put(state["v"], sh["v"]),
            "step": state["step"],
        }
        self.mesh, self.ctx = new_mesh, new_ctx
        self._step_fn = steps_lib.make_train_step(self.cfg, self.flags,
                                                  new_ctx)
        self._jit_step = jax.jit(self._step_fn, donate_argnums=0)
        return new_state
