"""Rule-based PartitionSpec assignment.

Specs are derived from parameter *paths* + shapes with divisibility checks,
so one rule set covers all 10 architectures.  Baseline layout (Megatron
style):

  * embeddings / lm_head: vocab on "model"
  * attn: q heads on "model"; k/v heads on "model" only when KH divides it
  * mlp / experts: hidden (or expert) dim on "model"
  * batch on ("pod","data"); decode caches: batch on "data", time on "model"
    (context-parallel decode); SSM states: heads on "model", state on "data"
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return "/".join(out)


def _div(n: int, m: int) -> bool:
    return n % m == 0


def _mesh_sizes(mesh, data_axes, model_axis):
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsize = 1
    for a in data_axes:
        dsize *= ax[a]
    return dsize, ax[model_axis]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _param_rule(cfg: ModelConfig, path: str, shape: Tuple[int, ...],
                msize: int, model: str) -> P:
    nd = len(shape)
    none = (None,) * nd

    def shard(dim: int) -> P:
        dim = dim % nd
        if not _div(shape[dim], msize):
            return P(*none)
        spec = [None] * nd
        spec[dim] = model
        return P(*spec)

    leaf = path.rsplit("/", 1)[-1]
    if leaf == "embed":
        return shard(0)
    if leaf == "lm_head":
        return shard(-1)
    # attention
    if leaf == "wq":
        return shard(-1)
    if leaf in ("wk", "wv"):
        kh = cfg.n_kv_heads
        return shard(-1) if _div(kh, msize) else P(*none)
    if leaf == "wo":
        return shard(-2)
    # dense mlp / experts
    if "moe" in path and leaf in ("w_gate", "w_up", "w_down", "w_in", "w_out"):
        # experts dim is axis 1 of (L, E, ...)
        if nd >= 2 and _div(shape[1], msize):
            spec = [None] * nd
            spec[1] = model
            return P(*spec)
        return P(*none)
    if leaf in ("w_gate", "w_up", "w_in"):
        return shard(-1)
    if leaf in ("w_down",):
        return shard(-2)
    if leaf == "w_out" and "mamba" not in path and "blocks" in path:
        return shard(-2)
    # mamba2
    if "mamba" in path:
        if leaf in ("w_z", "w_x", "w_dt"):
            return shard(-1)
        if leaf == "w_out":
            return shard(-2)
        if leaf == "conv_x":
            return shard(-1)
        if leaf in ("A_log", "D", "dt_bias"):
            return shard(-1)
        if leaf == "norm":
            return shard(-1)
    # rwkv6
    if "tmix" in path:
        if leaf in ("w_r", "w_k", "w_v", "w_g", "decay_w"):
            return shard(-1)
        if leaf == "w_o":
            return shard(-2)
        if leaf in ("u", "ln"):
            return shard(-2)          # (H, K) -> heads
    if "cmix" in path:
        if leaf == "w_k":
            return shard(-1)
        if leaf == "w_v":
            return shard(-2)
    return P(*none)


def param_specs(cfg: ModelConfig, params_shape: Any, mesh,
                model_axis: str = "model") -> Any:
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = ax[model_axis]

    def rule(path, leaf):
        return _param_rule(cfg, _path_str(path), leaf.shape, msize, model_axis)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------
# Batches
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, batch_shape: Any, mesh,
                data_axes: Tuple[str, ...] = ("data",),
                model_axis: str = "model") -> Any:
    dsize, _ = _mesh_sizes(mesh, data_axes, model_axis)
    dspec = data_axes if len(data_axes) > 1 else data_axes[0]

    def rule(path, leaf):
        nd = len(leaf.shape)
        if nd >= 1 and _div(leaf.shape[0], dsize) and leaf.shape[0] > 1:
            return P(*((dspec,) + (None,) * (nd - 1)))
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, cache_shape: Any, mesh,
                data_axes: Tuple[str, ...] = ("data",),
                model_axis: str = "model") -> Any:
    dsize, msize = _mesh_sizes(mesh, data_axes, model_axis)
    dspec = data_axes if len(data_axes) > 1 else data_axes[0]

    def rule(path, leaf):
        p = _path_str(path)
        leafname = p.rsplit("/", 1)[-1]
        sh = leaf.shape
        nd = len(sh)
        spec = [None] * nd

        def put(dim, axis, size):
            if _div(sh[dim], size) and sh[dim] >= size:
                spec[dim] = axis
                return True
            return False

        if leafname in ("k", "v"):                 # (L,B,S,KH,hd)
            put(1, dspec, dsize)
            put(2, model_axis, msize)
        elif leafname in ("cross_k", "cross_v"):   # (nc,B,M,KH,hd)
            put(1, dspec, dsize)
            put(2, model_axis, msize)
        elif leafname == "kv_pos":                 # (B,S)
            put(0, dspec, dsize)
            put(1, model_axis, msize)
        elif leafname in ("win_k", "win_v"):       # (ns,B,W,KH,hd)
            put(1, dspec, dsize)
            put(2, model_axis, msize)
        elif leafname == "win_pos":                # (ns,B,W)
            put(1, dspec, dsize)
            put(2, model_axis, msize)
        elif leafname == "mamba_state":            # (ns,per,B,H,P,N)
            if not put(2, dspec, dsize):
                put(4, dspec, dsize)
            put(3, model_axis, msize)
        elif "conv_tails" in p:                    # (ns,per,B,cw-1,C)
            put(2, dspec, dsize)
            put(4, model_axis, msize)
        elif leafname == "wkv_state":              # (L,B,H,K,V)
            if not put(1, dspec, dsize):
                put(3, dspec, dsize)
            put(2, model_axis, msize)
        elif leafname in ("tmix_shift", "cmix_shift"):   # (L,B,1,d)
            put(1, dspec, dsize)
            put(3, model_axis, msize)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def to_shardings(specs: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Fabric (multi-device co-verification) layouts: which dim of each op buffer
# is split across the FabricCluster devices (core/fabric.py).  Expressed as
# PartitionSpecs over a "fabric" axis so the scale-out layouts use the same
# vocabulary as the training/serving mesh layouts above.  Reduction axes are
# never split, so sharded launches stay bit-identical to one device.
# ---------------------------------------------------------------------------

FABRIC_AXIS = "fabric"

FABRIC_OP_SPECS = {
    # C = A @ B: row-shard A and C, replicate B (K is never split)
    "systolic_matmul": {"a": P(FABRIC_AXIS, None), "b": P(None, None),
                        "c": P(FABRIC_AXIS, None)},
    # flash attention, kernel layout (B, H, S, D): heads are independent,
    # so head-sharding q/k/v/o is exact; GQA groups stay device-aligned
    # whenever n_devices divides both H and KH.
    "flash_attention": {"q": P(None, FABRIC_AXIS, None, None),
                        "k": P(None, FABRIC_AXIS, None, None),
                        "v": P(None, FABRIC_AXIS, None, None),
                        "o": P(None, FABRIC_AXIS, None, None)},
}


def fabric_shard_axis(spec: P, axis_name: str = FABRIC_AXIS) -> Optional[int]:
    """Index of the dim a PartitionSpec shards on ``axis_name`` (None when
    the buffer is replicated across the fabric)."""
    for i, s in enumerate(tuple(spec)):
        names = s if isinstance(s, tuple) else (s,)
        if axis_name in [n for n in names if n is not None]:
            return i
    return None


# ---------------------------------------------------------------------------
# ZeRO sharding: additionally shard a replicated dim over the data axes.
# Level 1: optimizer moments (+grad accumulators); level 3: master params too
# (GSPMD then inserts the FSDP all-gathers in the forward pass).
# ---------------------------------------------------------------------------


def zero_spec(spec: P, shape: Tuple[int, ...], mesh,
              data_axes: Tuple[str, ...]) -> P:
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsize = 1
    for a in data_axes:
        dsize *= ax[a]
    dspec = data_axes if len(data_axes) > 1 else data_axes[0]
    cur = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    # choose the largest unsharded dim divisible by the data-axis size
    best, best_dim = -1, None
    for i, (s, d) in enumerate(zip(shape, cur)):
        if d is None and s % dsize == 0 and s >= dsize and s > best:
            best, best_dim = s, i
    if best_dim is None:
        return spec
    out = list(cur)
    out[best_dim] = dspec
    return P(*out)


def zero_specs(spec_tree: Any, shape_tree: Any, mesh,
               data_axes: Tuple[str, ...]) -> Any:
    return jax.tree.map(
        lambda s, sh: zero_spec(s, sh.shape, mesh, data_axes),
        spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P))
