"""Explicit expert-parallel MoE via shard_map (§Perf hillclimb).

Baseline pathology (measured, EXPERIMENTS.md §Perf-2): under pjit the
sort-based dispatch makes GSPMD all-gather the full routed token tensor in
f32 (f32[T*k, d] per device, ~TB/step for phi/moonshot train).

This path instead exploits the layout that already exists in the Megatron
mesh: activations are replicated across "model", experts are sharded across
"model".  Each (data, model) device routes its local tokens, keeps only the
top-k assignments that hit ITS local experts, computes them with a local
sort-based capacity dispatch, and psums the combined output over "model".
Wire cost: ONE all-reduce of (T_loc, d) bf16 — identical shape to a TP
MLP reduction — instead of repeated full-token f32 all-gathers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib


def _local_moe(x, router_w, wg, wu, wd, *, cfg: ModelConfig, model_axis: str,
               n_local: int):
    """Body run per (data, model) shard.  x (T_loc, d) replicated across the
    model axis; wg/wu/wd hold the n_local experts owned by this shard."""
    m = cfg.moe
    T, d = x.shape
    k = m.top_k
    my = jax.lax.axis_index(model_axis)
    e_lo = my * n_local

    idx, cw, aux = moe_lib.route(router_w, x, k)             # global expert ids
    e_flat = idx.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w_flat = cw.reshape(-1)
    loc = e_flat - e_lo
    mine = (loc >= 0) & (loc < n_local)
    loc = jnp.where(mine, loc, n_local)                      # parked bucket

    # capacity sized for the local expert share (+ slack for imbalance)
    C = moe_lib.capacity(cfg, T)                             # per-expert, global T
    order = jnp.argsort(loc)                                 # parked sort last
    sl, st, sw, sm = loc[order], t_flat[order], w_flat[order], mine[order]
    counts = jnp.bincount(loc, length=n_local + 1)
    seg_start = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - seg_start[sl]
    keep = sm & (pos_in_e < C)
    dest = jnp.where(keep, sl * C + pos_in_e, n_local * C)

    xt = jnp.take(x, st, axis=0)
    buf = jnp.zeros((n_local * C, d), x.dtype).at[dest].set(
        xt * keep[:, None].astype(x.dtype), mode="drop")
    buf = buf.reshape(n_local, C, d)

    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)
    else:
        y = jnp.einsum("ecf,efd->ecd",
                       jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, wg)), wd)
    y = y.reshape(n_local * C, d)

    yt = jnp.take(y, jnp.where(keep, dest, 0), axis=0)
    yt = yt * (sw * keep).astype(y.dtype)[:, None]
    out = jnp.zeros((T, d), y.dtype).at[st].add(yt)
    # each token's k experts live on (possibly) different model shards:
    # sum the partial combines — the ONLY cross-shard traffic in this path.
    out = jax.lax.psum(out, model_axis)
    aux = jax.lax.pmean(aux, model_axis)
    return out, aux


def moe_apply_ep(w: dict, x, cfg: ModelConfig, ctx):
    """x (T, d) -> (out, aux).  Requires n_experts % model_axis_size == 0."""
    mesh = ctx.mesh
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = ax[ctx.model_axis]
    n_local = cfg.moe.n_experts // msize
    assert n_local * msize == cfg.moe.n_experts

    dspec = ctx.data_spec
    T = x.shape[0]
    dsize = 1
    for a in ctx.data_axes:
        dsize *= ax[a]
    tspec = dspec if (T % dsize == 0 and T >= dsize) else None

    def body(x_l, rw, wg, wu, wd):
        return _local_moe(x_l, rw, wg, wu, wd, cfg=cfg,
                          model_axis=ctx.model_axis, n_local=n_local)

    if cfg.mlp_type == "swiglu":
        wg, wu, wd = w["w_gate"], w["w_up"], w["w_down"]
    else:
        wg, wu, wd = w["w_in"], w["w_in"], w["w_out"]
    out, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(tspec, None), P(None, None),
                  P(ctx.model_axis, None, None),
                  P(ctx.model_axis, None, None),
                  P(ctx.model_axis, None, None)),
        out_specs=(P(tspec, None), P()),
        check_vma=False)(x, w["router"], wg, wu, wd)
    return out, aux
