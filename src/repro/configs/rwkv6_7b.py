"""rwkv6-7b [ssm] — arXiv:2404.05892 (Finch).

32L d_model=4096, attention-free (WKV6 time-mix with data-dependent decay),
channel-mix d_ff=14336, vocab=65536, head_size=64 (64 heads).
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    arch="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # d_model / head_size
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    mlp_type="gelu",     # channel-mix uses squared-relu; field unused by ssm path
    rope="none",
    causal=True,
    rwkv=RWKVConfig(head_size=64),
)
