"""phi3.5-moe-42b-a6.6b [moe] — hf:microsoft/Phi-3.5-MoE-instruct.

32L d_model=4096 32H (GQA kv=8) head_dim=128, MoE 16 experts top-2 with
per-expert d_ff=6400, vocab=32064.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    mlp_type="swiglu",
    rope="full",
    causal=True,
    moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=6400),
)
