"""Config system for FireBridge-JAX.

Every assigned architecture is a frozen ``ModelConfig``; every assigned input
shape is a ``ShapeConfig``.  The (arch x shape) product defines the dry-run /
roofline matrix.  ``smoke(cfg)`` derives the reduced config used by CPU smoke
tests; the full configs are only ever lowered via ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    # capacity_factor bounds the sort-based dispatch buffers (dropless-ish).
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""

    d_state: int = 64
    head_dim: int = 64          # SSD head dim (P)
    expand: int = 2             # d_inner = expand * d_model
    chunk: int = 128            # SSD chunk length
    conv_width: int = 4


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    # RWKV-6 channel-mix hidden = d_ff from the arch spec.


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                 # dense | audio | hybrid | ssm | vlm | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    mlp_type: str = "swiglu"    # swiglu | gelu
    rope: str = "full"          # full | half | none
    causal: bool = True
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- family extensions -------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # hybrid (zamba2): one *shared* attention block applied every
    # ``attn_period`` layers (weights shared across occurrences).
    attn_period: int = 0
    # sliding window for the hybrid shared-attention KV cache (sub-quadratic
    # long-context path); 0 = full attention.
    attn_window: int = 0
    # vlm: a cross-attention layer every ``cross_attn_period`` layers.
    cross_attn_period: int = 0
    n_media_tokens: int = 0     # patch-embedding count from the stub frontend
    # frontend stub kind: token ids ("tokens"), precomputed frame embeddings
    # ("frames"), tokens + precomputed patch embeddings ("tokens+patches").
    frontend: str = "tokens"

    # ------------------------------------------------------------------ util
    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode? (SSM / hybrid-with-window.)"""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.attn_window > 0:
            return True
        return False

    @property
    def d_q(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.head_dim


# ---------------------------------------------------------------------------
# Shape config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> dict[str, str]:
    """Map shape-name -> "OK" or "SKIP(<reason>)" for this arch."""
    out: dict[str, str] = {}
    for name, sh in SHAPES.items():
        if sh.kind == "decode" and cfg.is_encoder_only:
            out[name] = "SKIP(encoder-only: no autoregressive decode step)"
        elif name == "long_500k" and not cfg.sub_quadratic:
            out[name] = "SKIP(pure full-attention arch: no sub-quadratic path)"
        else:
            out[name] = "OK"
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCHS: Tuple[str, ...] = (
    "mistral-nemo-12b",
    "granite-20b",
    "chatglm3-6b",
    "llama3.2-1b",
    "hubert-xlarge",
    "zamba2-2.7b",
    "rwkv6-7b",
    "llama-3.2-vision-11b",
    "moonshot-v1-16b-a3b",
    "phi3.5-moe-42b-a6.6b",
)

_MODULE_FOR_ARCH = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR_ARCH:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR_ARCH[arch]}")
    return mod.CONFIG


def list_archs() -> Tuple[str, ...]:
    return ARCHS


# ---------------------------------------------------------------------------
# Smoke reduction
# ---------------------------------------------------------------------------


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (small layers/width/
    experts/tables), preserving every structural feature of the full arch."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads * 4 // cfg.n_heads)),
        head_dim=16,
        d_ff=128,
        vocab_size=512,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=4, top_k=min(2, cfg.moe.top_k),
                              expert_d_ff=32,
                              capacity_factor=cfg.moe.capacity_factor)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=8, head_dim=8, expand=2, chunk=16,
                              conv_width=cfg.ssm.conv_width)
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_size=16)
    if cfg.attn_period:
        kw["n_layers"] = 4
        kw["attn_period"] = 2
        if cfg.attn_window:
            kw["attn_window"] = 32
    if cfg.cross_attn_period:
        kw["n_layers"] = 4
        kw["cross_attn_period"] = 2
        kw["n_media_tokens"] = 16
    return replace(cfg, **kw)


# ---------------------------------------------------------------------------
# Parameter counting (for MODEL_FLOPS = 6*N*D; MoE uses N_active)
# ---------------------------------------------------------------------------


def _attn_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    return d * cfg.d_q + 2 * d * cfg.d_kv + cfg.d_q * d


def _mlp_params(d_model: int, d_ff: int, mlp_type: str) -> int:
    if mlp_type == "swiglu":
        return 3 * d_model * d_ff
    return 2 * d_model * d_ff


def _mamba2_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    in_proj = cfg.d_model * (2 * d_in + 2 * s.d_state + nh)   # z,x,B,C,dt
    conv = s.conv_width * (d_in + 2 * s.d_state)
    out_proj = d_in * cfg.d_model
    return in_proj + conv + out_proj + nh + d_in              # + A_log, D... approx

def _rwkv6_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    tm = 4 * d * d + d * cfg.rwkv.head_size  # r,k,v,o (+g via lora, counted in misc)
    tm += 2 * (d * 64 + 64 * d)              # decay/ddlerp loras (approx)
    cm = cfg.d_model * cfg.d_ff + cfg.d_ff * cfg.d_model
    return tm + cm


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic parameter count (embeddings included once; 6·N·D convention
    counts non-embedding params — we report both)."""
    d = cfg.d_model
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0
    if cfg.family == "ssm":
        per_layer = _rwkv6_params(cfg)
        layers = per_layer * cfg.n_layers
    elif cfg.family == "hybrid":
        layers = _mamba2_params(cfg) * cfg.n_layers
        # one shared attn+mlp block
        layers += _attn_params(cfg) + _mlp_params(d, cfg.d_ff, cfg.mlp_type)
    else:
        per_layer = _attn_params(cfg)
        if cfg.moe is not None:
            n_used = cfg.moe.top_k if active_only else cfg.moe.n_experts
            per_layer += n_used * _mlp_params(d, cfg.moe.expert_d_ff, cfg.mlp_type)
            per_layer += d * cfg.moe.n_experts  # router
        else:
            per_layer += _mlp_params(d, cfg.d_ff, cfg.mlp_type)
        layers = per_layer * cfg.n_layers
        if cfg.cross_attn_period:
            n_cross = cfg.n_layers // cfg.cross_attn_period
            layers += n_cross * _attn_params(cfg)
    return layers + emb


def non_embedding_params(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return count_params(cfg, active_only=active_only) - emb
