"""llama3.2-1b [dense] — hf:meta-llama/Llama-3.2-1B.

16L d_model=2048 32H (GQA kv=8) head_dim=64 d_ff=8192 vocab=128256.
Also serves as the ~1B-class end-to-end training example (tied embeddings).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    mlp_type="swiglu",
    rope="full",
    causal=True,
    tie_embeddings=True,
)
