"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B.

48L d_model=2048 16H MHA (kv=16) head_dim=128, MoE 64 experts top-6 with
per-expert d_ff=1408, vocab=163840.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    mlp_type="swiglu",
    rope="full",
    causal=True,
    moe=MoEConfig(n_experts=64, top_k=6, expert_d_ff=1408),
)
