"""zamba2-2.7b [hybrid] — arXiv:2411.15242.

54 Mamba2 layers (d_state=64) with a SHARED attention+MLP block applied every
6th layer (9 occurrences, weights shared), d_model=2560, 32H MHA (kv=32)
head_dim=80, d_ff=10240, vocab=32000.  The shared attention block uses a
4096-token sliding window so the long_500k decode path stays sub-quadratic
(design note in DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    mlp_type="gelu",
    rope="full",
    causal=True,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=128),
    attn_period=6,
    attn_window=4096,
)
