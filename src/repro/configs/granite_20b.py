"""granite-20b [dense] — arXiv:2405.04324 (Granite Code 20B).

52L d_model=6144 48H (MQA kv=1) head_dim=128 d_ff=24576 vocab=49152.
d_ff = 4*d_model => classic GELU MLP; llama-style RoPE attention per assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    rope="full",
    causal=True,
)
