"""hubert-xlarge [audio] — arXiv:2106.07447.

Encoder-only (bidirectional) transformer backbone, same arch as wav2vec2:
48L d_model=1280 16H (MHA kv=16) head_dim=80 d_ff=5120 vocab=504 (targets).
The conv feature-extractor frontend is a STUB: input_specs() provides
precomputed frame embeddings (batch, frames, d_model).  No decode shapes.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    mlp_type="gelu",
    rope="none",
    causal=False,
    frontend="frames",
)
