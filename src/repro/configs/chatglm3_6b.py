"""chatglm3-6b [dense] — arXiv:2406.12793.

28L d_model=4096 32H (GQA kv=2) head_dim=128 d_ff=13696 vocab=65024.
"RoPE 2d": rotary embedding applied to half of each head dim (rope="half").
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    mlp_type="swiglu",
    rope="half",
    causal=True,
)
