"""llama-3.2-vision-11b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision.

40L text backbone d_model=4096 32H (GQA kv=8) head_dim=128 d_ff=14336
vocab=128256, with a cross-attention image layer every 5th layer (8 of 40).
Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (batch, n_media_tokens, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    mlp_type="swiglu",
    rope="full",
    causal=True,
    cross_attn_period=5,
    n_media_tokens=1600,
    frontend="tokens+patches",
)
