from repro.configs.base import (
    ARCHS,
    SHAPES,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
    ShapeConfig,
    applicable_shapes,
    count_params,
    get_config,
    list_archs,
    non_embedding_params,
    smoke,
)

__all__ = [
    "ARCHS", "SHAPES", "ModelConfig", "MoEConfig", "RWKVConfig", "SSMConfig",
    "ShapeConfig", "applicable_shapes", "count_params", "get_config",
    "list_archs", "non_embedding_params", "smoke",
]
