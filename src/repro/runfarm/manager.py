"""CampaignManager: sharded, resumable, coverage-guided campaigns.

The run farm turns the harness's per-run determinism into fleet-scale
throughput (ROADMAP item 2; FireSim's ``run_farm.py`` /
``instance_deploy_manager.py`` idiom).  A campaign is a seed plus a list
of generation-0 ``WorkUnit``s; the manager

* executes units **sequentially in-process** (``workers=0``, the oracle
  lane) or across **spawned worker processes**, each with a private task
  queue and manager-tracked assignment — a SIGKILL'd worker is detected
  by process liveness, its in-flight unit re-enqueued to a fresh worker,
  and the campaign continues;
* **persists** every completed unit to a JSONL ``ResultStore`` (single
  writer: the manager); a restarted campaign skips stored units whose
  payload hash still matches and reproduces the identical final digest;
* merges per-unit sparse coverage into one ``CoverageModel`` **in uid
  order at the generation barrier** (never concurrently), and schedules
  the next generation **coverage-guided**: units whose results newly
  covered bins become mutation parents — seeds that find new behaviour
  get mutation priority, seeds that don't are dropped (Grimm-style
  semiformal stimulus search);
* collects worker-side **failure harvests** (shrunk fuzz repros,
  bisected sweep divergences — built on the existing ``shrink()`` /
  ``bisect_divergence`` machinery) into ``<campaign>/bundles/``.

Determinism bar: unit seeds are uid-forked, the merge is uid-ordered,
and generations are barriers — so the merged coverage, every per-unit
digest, and the final campaign digest are byte-identical at ANY worker
count, across kill+respawn, and across interrupt+resume.  Wall-clock
(``seconds``, per-worker utilization) is measured honestly and kept out
of every digest.
"""
from __future__ import annotations

import collections
import dataclasses
import multiprocessing as mp
import queue as queue_mod
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

from repro.core.coverage import CoverageModel
from repro.runfarm.report import campaign_report, write_report
from repro.runfarm.store import ResultStore
from repro.runfarm.units import WorkUnit, mutate_unit, unit_uid
from repro.runfarm.worker import worker_main


class CampaignInterrupted(RuntimeError):
    """Raised by the ``interrupt_after`` test hook: the campaign stopped
    cleanly mid-flight with its store intact — construct a new manager on
    the same directory to resume."""


@dataclasses.dataclass
class CampaignResult:
    digest: str                       # uid-ordered (uid, digest) sha256
    uids: List[str]                   # this campaign's executed unit set
    records: Dict[str, dict]          # uid -> store record
    coverage: CoverageModel           # merged across all units, uid order
    report: dict                      # campaign_report() payload
    bundles: List[Path]               # harvested failure bundles
    # fleet-wide counter totals (core/counters.py), merged by name in
    # uid order at each generation barrier — like coverage
    counters: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def passed(self) -> bool:
        return all(self.records[u].get("ok", False) for u in self.uids)


class CampaignManager:
    def __init__(self, campaign_dir, units: List[WorkUnit], *,
                 seed: int = 0, workers: int = 0, generations: int = 1,
                 children_per_parent: int = 2, max_parents: int = 4,
                 mutate: Callable[[WorkUnit, int, str], WorkUnit]
                 = mutate_unit,
                 kill_worker_after: Optional[Dict[int, int]] = None,
                 interrupt_after: Optional[int] = None,
                 extra_sys_path: Optional[List[str]] = None) -> None:
        self.dir = Path(campaign_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.units = list(units)
        self.seed = int(seed)
        self.workers = int(workers)
        self.generations = max(1, int(generations))
        self.children_per_parent = max(1, int(children_per_parent))
        self.max_parents = max(1, int(max_parents))
        self.mutate = mutate
        # test hooks: {initial worker index: SIGKILL before its (n+1)-th
        # unit} / raise CampaignInterrupted after N newly stored units
        self.kill_worker_after = dict(kill_worker_after or {})
        self.interrupt_after = interrupt_after
        self.extra_sys_path = (list(extra_sys_path)
                               if extra_sys_path is not None
                               else self._default_sys_path())
        self.store = ResultStore(self.dir / "results.jsonl")
        self.coverage = CoverageModel()
        # pool state (populated while running with workers > 0)
        self._workers: Dict[int, dict] = {}
        self._result_q = None
        self._ctx = None
        self._spawned = 0
        self._respawned = 0
        self._completed_new = 0

    @staticmethod
    def _default_sys_path() -> List[str]:
        import repro
        src = Path(next(iter(repro.__path__))).resolve().parent
        return [str(src), str(src.parent)]     # src/ + repo root (tests.*)

    # ---------------------------------------------------------------- run
    def run(self) -> CampaignResult:
        t0 = time.perf_counter()
        records = self.store.load()
        executed: List[str] = []
        trajectory: List[dict] = []
        worker_stats: Dict[int, dict] = {}
        bundles: List[Path] = []
        skipped = 0
        self.coverage = CoverageModel()
        counter_totals: Dict[str, float] = {}
        gen_units = sorted(self.units, key=lambda u: u.uid)
        gen = 0
        try:
            if self.workers > 0:
                self._pool_start()
            while gen_units:
                skipped += self._run_generation(gen_units, records,
                                                worker_stats)
                # generation barrier: merge coverage + pick parents in
                # uid order — deterministic at any worker count
                parents: List[WorkUnit] = []
                new_bins_total: List[str] = []
                for u in gen_units:
                    rec = records[u.uid]
                    new = self.coverage.merge_counts(rec.get("counts")
                                                     or {})
                    for cname, v in (rec.get("counters") or {}).items():
                        counter_totals[cname] = (counter_totals.get(cname, 0)
                                                 + v)
                    executed.append(u.uid)
                    if new:
                        parents.append(u)
                        new_bins_total.extend(new)
                    if rec.get("harvest") or not rec.get("ok", True):
                        bundles.append(self._write_bundle(u, rec))
                trajectory.append({
                    "generation": gen,
                    "units": len(gen_units),
                    "new_bins": len(new_bins_total),
                    "newly_covered": new_bins_total[:32],
                    "covered": sum(1 for g in self.coverage.counts
                                   for n in
                                   self.coverage.counts[g].values()
                                   if n > 0),
                })
                gen += 1
                if gen >= self.generations or not parents:
                    break                     # budget spent / plateau
                gen_units = [
                    self.mutate(p, j, unit_uid(gen, i * self.
                                               children_per_parent + j))
                    for i, p in enumerate(parents[:self.max_parents])
                    for j in range(self.children_per_parent)]
        finally:
            if self.workers > 0:
                self._pool_stop()
            self.store.close()
        wall = time.perf_counter() - t0
        digest = ResultStore.final_digest(records, executed)
        report = campaign_report(
            seed=self.seed, workers=self.workers, wall_seconds=wall,
            records=records, uids=executed, coverage=self.coverage,
            trajectory=trajectory, worker_stats=worker_stats,
            skipped=skipped, respawned=self._respawned,
            final_digest=digest, counter_totals=counter_totals)
        write_report(self.dir / "report.json", report)
        return CampaignResult(digest=digest, uids=sorted(executed),
                              records=records, coverage=self.coverage,
                              report=report, bundles=bundles,
                              counters=counter_totals)

    # -------------------------------------------------- generation driving
    def _run_generation(self, units: List[WorkUnit],
                        records: Dict[str, dict],
                        worker_stats: Dict[int, dict]) -> int:
        """Execute one generation's units (resume-aware); returns how many
        were skipped because the store already holds a matching record."""
        to_run: List[WorkUnit] = []
        skipped = 0
        for u in units:
            rec = records.get(u.uid)
            if rec is not None and rec.get("payload") == u.payload_hash():
                skipped += 1              # resumed: record replays merge
            else:
                to_run.append(u)
        if not to_run:
            return skipped
        if self.workers == 0:
            for u in to_run:
                from repro.runfarm.builtin import execute_unit
                res = execute_unit(u)
                res.worker = 0
                self._commit(res.record(u.payload_hash()), records,
                             worker_stats)
        else:
            self._run_pool_generation(to_run, records, worker_stats)
        return skipped

    def _commit(self, rec: dict, records: Dict[str, dict],
                worker_stats: Dict[int, dict]) -> None:
        """Single-writer store append + bookkeeping + interrupt hook."""
        self.store.append(rec)
        records[rec["uid"]] = rec
        ws = worker_stats.setdefault(int(rec.get("worker", 0)),
                                     {"units": 0, "busy_seconds": 0.0})
        ws["units"] += 1
        ws["busy_seconds"] += float(rec.get("seconds", 0.0))
        self._completed_new += 1
        if (self.interrupt_after is not None
                and self._completed_new >= self.interrupt_after):
            raise CampaignInterrupted(
                f"interrupted after {self._completed_new} new units "
                f"(store: {self.store.path})")

    # ------------------------------------------------------- process pool
    def _pool_start(self) -> None:
        self._ctx = mp.get_context("spawn")
        self._result_q = self._ctx.Queue()
        for i in range(self.workers):
            self._spawn_worker(kill_after=self.kill_worker_after.get(i))

    def _spawn_worker(self, kill_after: Optional[int] = None) -> None:
        wid = self._spawned
        self._spawned += 1
        q = self._ctx.Queue()
        proc = self._ctx.Process(
            target=worker_main,
            args=(wid, q, self._result_q, self.extra_sys_path, kill_after),
            daemon=True)
        proc.start()
        self._workers[wid] = {"proc": proc, "q": q, "unit": None}

    def _run_pool_generation(self, to_run: List[WorkUnit],
                             records: Dict[str, dict],
                             worker_stats: Dict[int, dict]) -> None:
        pending = {u.uid: u for u in to_run}
        backlog = collections.deque(to_run)
        while pending:
            # assign idle workers (manager-tracked, one unit in flight
            # per worker — the crash-recovery unit of accounting)
            for w in self._workers.values():
                if w["unit"] is None and backlog:
                    u = backlog.popleft()
                    w["unit"] = u
                    w["q"].put(u.to_json())
            try:
                kind, wid, payload = self._result_q.get(timeout=0.2)
            except queue_mod.Empty:
                self._reap_dead_workers(pending, backlog)
                continue
            if kind == "done":
                uid = payload["uid"]
                w = self._workers.get(wid)
                if w is not None and w["unit"] is not None \
                        and w["unit"].uid == uid:
                    w["unit"] = None
                if uid in pending:        # duplicate delivery: ignore
                    del pending[uid]
                    self._commit(payload, records, worker_stats)
            elif kind == "error":
                raise RuntimeError(
                    f"unit {payload['uid']} failed in worker {wid}: "
                    f"{payload['error']}")
            # "bye" only arrives during shutdown

    def _reap_dead_workers(self, pending: Dict[str, WorkUnit],
                           backlog: collections.deque) -> None:
        """Crash recovery: a dead worker's in-flight unit goes back on
        the backlog and a replacement (without any kill hook) spawns."""
        for wid in [w for w, st in self._workers.items()
                    if not st["proc"].is_alive()]:
            st = self._workers.pop(wid)
            st["q"].cancel_join_thread()
            st["q"].close()
            u = st["unit"]
            if u is not None and u.uid in pending:
                backlog.append(u)
            # cap respawns so a worker that dies at STARTUP (broken env,
            # not a mid-unit crash) fails the campaign instead of
            # spawn-storming forever
            if self._respawned >= 2 * self.workers + 4:
                raise RuntimeError(
                    f"worker {wid} died and the respawn budget is spent "
                    f"({self._respawned} respawns) — workers appear "
                    f"unable to start; see campaign dir {self.dir}")
            self._respawned += 1
            self._spawn_worker()

    def _pool_stop(self) -> None:
        for st in self._workers.values():
            try:
                st["q"].put(None)
            except (ValueError, OSError):
                pass
        deadline = time.monotonic() + 10.0
        for st in self._workers.values():
            st["proc"].join(timeout=max(0.1, deadline - time.monotonic()))
            if st["proc"].is_alive():
                st["proc"].terminate()
                st["proc"].join(timeout=2.0)
            st["q"].cancel_join_thread()
            st["q"].close()
        self._workers.clear()
        if self._result_q is not None:
            self._result_q.cancel_join_thread()
            self._result_q.close()
            self._result_q = None

    # ----------------------------------------------------------- bundles
    def _write_bundle(self, unit: WorkUnit, rec: dict) -> Path:
        """Persist one harvested failure: the seed-closed unit spec plus
        its shrunk repro / divergence localization — enough to reproduce
        without the campaign."""
        import json
        bdir = self.dir / "bundles"
        bdir.mkdir(exist_ok=True)
        path = bdir / (unit.uid.replace("/", "_") + ".json")
        path.write_text(json.dumps(
            {"unit": unit.to_json(), "failures": rec.get("failures", []),
             "harvest": rec.get("harvest")}, indent=2, sort_keys=True)
            + "\n")
        return path
