"""Worker-process entry point (spawn context).

One worker = one process with a PRIVATE task queue; the manager assigns
units one at a time and tracks the assignment, FireSim
instance-deploy-manager style.  Private queues mean a SIGKILL'd worker
can never die holding a shared queue lock and wedge its peers — the
manager just notices the dead process, re-enqueues its assigned unit to
a fresh worker, and carries on.

Spawn (not fork) keeps workers clean of the parent's jax/session state;
``extra_sys_path`` re-creates the parent's import path (sys.path does not
propagate across spawn).  ``kill_after`` is the crash-recovery test hook:
the worker SIGKILLs itself when it receives its (N+1)-th unit — after
the assignment, before any result — the worst-case death point.
"""
from __future__ import annotations

import os
import signal
import sys


def worker_main(worker_id: int, task_q, result_q, extra_sys_path,
                kill_after=None) -> None:
    for p in reversed(list(extra_sys_path or [])):
        if p not in sys.path:
            sys.path.insert(0, p)
    from repro.runfarm.builtin import execute_unit
    from repro.runfarm.units import WorkUnit

    done = 0
    while True:
        msg = task_q.get()
        if msg is None:                       # clean shutdown
            result_q.put(("bye", worker_id, None))
            return
        unit = WorkUnit.from_json(msg)
        if kill_after is not None and done >= kill_after:
            os.kill(os.getpid(), signal.SIGKILL)    # test hook: die dirty
        try:
            res = execute_unit(unit)
            res.worker = worker_id
            result_q.put(("done", worker_id,
                          res.record(unit.payload_hash())))
        except BaseException as e:            # unit execution error: the
            result_q.put(("error", worker_id,  # manager records + re-raises
                          {"uid": unit.uid,
                           "error": f"{type(e).__name__}: {e}"}))
        done += 1
