"""Run-farm campaign orchestration (docs/runfarm.md; ROADMAP item 2).

Shards fuzz batches, co-verify sweep slices, and golden-trace
regeneration across worker processes with a resumable JSONL result
store, uid-ordered coverage merging, coverage-guided generation
scheduling, and worker-side failure harvesting — same campaign seed ⇒
same merged digest at any worker count.
"""
from repro.runfarm.builtin import EXECUTORS, execute_unit
from repro.runfarm.manager import (CampaignInterrupted, CampaignManager,
                                   CampaignResult)
from repro.runfarm.report import campaign_report, deterministic_view, \
    write_report
from repro.runfarm.store import ResultStore
from repro.runfarm.units import (UnitResult, WorkUnit, fork_seed,
                                 fuzz_units, golden_units, mutate_unit,
                                 serving_units, sweep_units, unit_uid)

__all__ = [
    "CampaignInterrupted", "CampaignManager", "CampaignResult",
    "EXECUTORS", "ResultStore", "UnitResult", "WorkUnit",
    "campaign_report", "deterministic_view", "execute_unit", "fork_seed",
    "fuzz_units", "golden_units", "mutate_unit", "serving_units",
    "sweep_units", "unit_uid", "write_report",
]
