"""Persistent, resumable campaign result store — one JSON line per
completed unit.

Single-writer by construction: only the MANAGER process appends (workers
ship results over a queue), so records are never interleaved.  Each
append is flushed and fsynced before the unit counts as done; a campaign
killed mid-append leaves at most one torn final line, which ``load``
tolerates (skips) — that unit simply re-runs on resume.

The record schema is ``UnitResult.record()`` (units.py): uid, kind, ok,
digest, sparse coverage counts, scenario count, failures, the unit's
``payload`` hash (spec-drift guard), worker id, and seconds.  ``seconds``
and ``worker`` are the only non-deterministic fields and are excluded
from every digest.

``final_digest`` hashes ``(uid, digest)`` pairs in uid order — the
campaign's determinism witness: same seed ⇒ same digest at any worker
count, with or without an intervening kill/resume.
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path
from typing import Dict, Optional


class ResultStore:
    def __init__(self, path) -> None:
        self.path = Path(path)
        self._fh = None

    # ------------------------------------------------------------ reading
    def load(self) -> Dict[str, dict]:
        """All committed records, keyed by uid (latest wins).  Tolerates a
        torn final line from a killed campaign."""
        records: Dict[str, dict] = {}
        if not self.path.exists():
            return records
        for line in self.path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue                    # torn tail — unit re-runs
            if isinstance(rec, dict) and "uid" in rec and "digest" in rec:
                records[rec["uid"]] = rec
        return records

    # ------------------------------------------------------------ writing
    def append(self, rec: dict) -> None:
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")
        self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "ResultStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ digests
    @staticmethod
    def final_digest(records: Dict[str, dict],
                     uids: Optional[list] = None) -> str:
        """sha256 over uid-sorted ``(uid, digest)`` pairs.  ``uids``
        restricts to one campaign's unit set (a store may hold more, e.g.
        after a spec change)."""
        h = hashlib.sha256()
        for uid in sorted(uids if uids is not None else records):
            h.update(f"{uid}:{records[uid]['digest']}\n".encode())
        return h.hexdigest()
