"""Built-in work-unit executors: fuzz batches, co-verify sweep slices,
golden-trace regeneration.

Every executor is a pure function of its ``WorkUnit`` — fresh fuzzer /
session / coverage model per call, nothing read from ambient state — so a
unit executes bit-identically in the sequential oracle (``workers=0``)
and in any spawned worker process.  Imports are deliberately lazy: a
registers-layer fuzz worker never touches jax, which keeps spawn-context
worker start-up fast.

Failure harvesting happens HERE, worker-side, where the failing state is
live: a failing fuzz scenario is minimized with the existing
``ProtocolFuzzer.shrink`` (checkpointed replay, core/replay.py) and a
divergent sweep group is localized by the scheduler's
``bisect_divergence`` lane; the shrunk repro rides back to the manager in
``UnitResult.harvest`` and lands in the campaign's ``bundles/``.
"""
from __future__ import annotations

import hashlib
import time
from typing import Callable, Dict

from repro.runfarm.units import UnitResult, WorkUnit


def execute_unit(unit: WorkUnit) -> UnitResult:
    """Run one unit under its registered executor (timed)."""
    try:
        fn = EXECUTORS[unit.kind]
    except KeyError:
        raise KeyError(f"no executor for unit kind {unit.kind!r} "
                       f"(known: {sorted(EXECUTORS)})") from None
    t0 = time.perf_counter()
    res = fn(unit)
    res.seconds = time.perf_counter() - t0
    return res


# ---------------------------------------------------------- fuzz batches
def _planted_table(index, delta):
    """No-jit variant of core/fuzz.planted_bug_table: the same known
    interpret-backend divergence, but on the un-jitted backend table so
    fuzz workers stay trace-compilation-free."""
    import numpy as np

    from repro.core.fuzz import ProtocolFuzzer
    from repro.kernels.systolic_matmul.sweep import matmul_backends
    table = matmul_backends(tile=ProtocolFuzzer.TILE, jit=False)
    good = table["interpret"]

    def buggy(a, b):
        out = np.array(good(a, b))
        out[int(index[0]), int(index[1])] += delta
        return out
    return dict(table, interpret=buggy)


def _run_fuzz_batch(unit: WorkUnit) -> UnitResult:
    from repro.core.coverage import CoverageModel
    from repro.core.fuzz import ProtocolFuzzer
    p = unit.params
    kw = {}
    if p.get("rates"):
        kw["rates"] = dict(p["rates"])
    if p.get("bridge_ops"):
        kw["bridge_ops"] = tuple(p["bridge_ops"])
    if p.get("mm_bug"):
        i, j, delta = p["mm_bug"]
        kw["mm_table"] = _planted_table((i, j), float(delta))
    cov = CoverageModel()
    fz = ProtocolFuzzer(seed=unit.seed, layers=tuple(p["layers"]),
                        coverage=cov, **kw)
    report = fz.run(int(p["count"]))
    failing = report.failures()
    harvest = None
    if failing and p.get("shrink_failures", True):
        # minimize the FIRST failing scenario (checkpointed-replay shrink
        # for bridge scenarios, linear prefix search otherwise) — the
        # batch is seed-closed, so the bundle alone reproduces it
        r0 = failing[0]
        scn = fz.scenario(r0.index)
        sub, res = fz.shrink(scn)
        harvest = {"scenario": r0.index, "layer": r0.layer,
                   "seed": unit.seed,
                   "full_ops": len(scn.ops), "shrunk_ops": len(sub.ops),
                   "ops": [repr(op) for op in sub.ops],
                   "failures": res.failures[:4]}
    return UnitResult(
        uid=unit.uid, kind=unit.kind, ok=report.passed,
        digest=report.digest, counts=cov.to_counts(),
        scenarios=len(report.results),
        failures=[f"scn{r.index}[{r.layer}]: {r.failures[0]}"
                  for r in failing][:8],
        harvest=harvest)


# ------------------------------------------------------------ sweep cells
def _run_sweep(unit: WorkUnit) -> UnitResult:
    import numpy as np

    from repro.core import CongestionConfig, CoVerifySession
    from repro.core.coverage import CoverageModel
    from repro.core.fuzz import FaultPlan
    from repro.kernels.systolic_matmul.sweep import (matmul_backends,
                                                     matmul_firmware)
    p = unit.params
    table = matmul_backends(jit=False)
    interp = table["interpret"]
    if p.get("mm_bug"):
        bi, bj, delta = p["mm_bug"]
        good = interp

        def interp(a, b, _good=good, _i=int(bi), _j=int(bj),
                   _d=float(delta)):
            out = np.array(_good(a, b))
            out[_i, _j] += _d
            return out
    cov = CoverageModel()
    sess = CoVerifySession(
        matmul_firmware,
        congestion=CongestionConfig(seed=int(p.get("congestion_seed", 7))),
        fault_plan=FaultPlan(unit.seed), coverage=cov)
    sess.register_op("mm", oracle=table["oracle"], interpret=interp)
    for cfg in p["configs"]:
        for be in p.get("backends", ("oracle", "interpret")):
            sess.add_cell("mm", be, dict(cfg))
    # in-unit max_workers=1: parallelism is the FARM's axis; the unit
    # itself stays the sequential oracle (bisect_failures localizes any
    # divergent group via the replay machinery)
    rep = sess.run(max_workers=1, bisect_failures=True)
    h = hashlib.sha256()
    for row in rep.to_rows(wall=False):
        h.update(row.encode())
        h.update(b"\n")
    for r in rep.cells:
        for name in sorted(r.outputs):
            h.update(name.encode())
            h.update(np.ascontiguousarray(r.outputs[name]).tobytes())
    summary = rep.summary()
    harvest = None
    if summary["divergences"]:
        harvest = {"seed": unit.seed, "divergences": summary["divergences"],
                   "failures": summary["failures"]}
    # always-on counter totals summed over the unit's cells (each cell
    # already carries its oracle payload)
    counters: Dict[str, float] = {}
    for r in rep.cells:
        for name, v in (r.counters or {}).get("totals", {}).items():
            counters[name] = counters.get(name, 0) + v
    return UnitResult(
        uid=unit.uid, kind=unit.kind, ok=rep.passed, digest=h.hexdigest(),
        counts=cov.to_counts(), scenarios=len(rep.cells),
        failures=summary["failures"][:8], harvest=harvest,
        counters=counters)


# --------------------------------------------------- open-loop serving SLO
def _run_serving_campaign(unit: WorkUnit) -> UnitResult:
    """One open-loop serving unit: regenerate the arrival trace from the
    unit's forked seed (serving/arrivals.build_trace — the trace is pure
    JSON + seed), drive it against a fresh continuous-batching engine with
    a paged KV cache, and witness the run with ``SLOReport.digest()`` —
    rows AND token streams, so any latency-model or behavioral drift flips
    the campaign digest.  Admission invariants (exact token budgets, pool
    fully drained) are checked worker-side where the engine is live."""
    from repro.core.coverage import CoverageModel
    from repro.core.replay import target_logs
    from repro.serving import SLOReport, build_trace, run_open_loop

    p = unit.params
    trace = build_trace(p["kind"], unit.seed, **dict(p.get("trace") or {}))
    pool = dict(p.get("pool") or {})
    target = _serving_target(
        devices=int(p.get("devices", 1)),
        max_slots=int(pool.get("max_slots", 2)),
        max_len=int(pool.get("max_len", 32)),
        prompt_pad=int(pool.get("prompt_pad", 8)),
        kv_pages=pool.get("kv_pages"),
        kv_page_size=int(pool.get("kv_page_size", 8)))
    failures = []
    slo = None
    try:
        run_open_loop(target, trace,
                      max_ticks=int(p.get("max_ticks", 50_000)))
        slo = SLOReport.from_run(trace, target,
                                 label=f"{unit.uid}:{trace.label}")
    except Exception as e:
        failures.append(f"{type(e).__name__}: {e}")
    violations = (list(target.violations)
                  if hasattr(target, "violations")
                  else list(target.mem.log.violations))
    engines = getattr(target, "engines", None) or [target]
    # admission invariants: every admitted request retired with its exact
    # decode budget, and every reserved page came back to the pool
    rejected = {int(v.split()[1]) for v in violations
                if "exceeds KV page pool" in v}
    for a in trace.arrivals:
        req = target.requests.get(a.rid)
        if a.rid in rejected:
            if req is not None:
                failures.append(f"rejected rid {a.rid} holds a slot")
            continue
        if req is None or not req.done:
            failures.append(f"admitted rid {a.rid} never retired")
        elif len(req.out_tokens) != a.max_new_tokens:
            failures.append(
                f"rid {a.rid}: {len(req.out_tokens)} tokens != "
                f"budget {a.max_new_tokens}")
    for i, eng in enumerate(engines):
        kp = eng.kv_pool
        if kp is not None and (kp.n_free != kp.n_pages or kp.pages):
            failures.append(f"engine {i} leaked KV pages: "
                            f"{kp.n_free}/{kp.n_pages} free after drain")
    cov = CoverageModel()
    for log in target_logs(target):
        for tx in log.txs:
            cov.hit_burst(tx.nbytes)
            cov.hit_congestion(tx.stall)
    cov.hit("arrivals", trace.kind)
    pools = [e.kv_pool for e in engines if e.kv_pool is not None]
    deferrals = sum(kp.deferrals for kp in pools)
    if deferrals:
        cov.hit("arrivals", "deferred", deferrals)
    if any(kp.peak_in_use == kp.n_pages for kp in pools):
        cov.hit("arrivals", "pool_full")
    if rejected:
        cov.hit("arrivals", "infeasible_reject", len(rejected))
    if slo is not None:
        digest = slo.digest()
    else:
        digest = hashlib.sha256(
            "\n".join(failures).encode()).hexdigest()
    harvest = None
    if failures:
        harvest = {"seed": unit.seed, "trace": trace.label,
                   "failures": failures[:8], "violations": violations[:8]}
    from repro.core.counters import counter_banks, merged_totals
    return UnitResult(
        uid=unit.uid, kind=unit.kind, ok=not failures, digest=digest,
        counts=cov.to_counts(), scenarios=len(trace.arrivals),
        failures=failures[:8], harvest=harvest,
        counters=merged_totals(counter_banks(target)))


def _serving_target(*, devices: int, max_slots: int, max_len: int,
                    prompt_pad: int, kv_pages, kv_page_size: int):
    """Fresh continuous-batching serving target on the smoke model —
    jax-lazy so non-serving workers never pay the import."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke
    from repro.models import init_params
    from repro.models.transformer import RunFlags
    from repro.serving import ClusterServingEngine, ServingEngine

    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    kw = dict(max_slots=max_slots, max_len=max_len, prompt_pad=prompt_pad,
              flags=RunFlags(attn_impl="chunked", q_chunk=16, kv_chunk=16),
              batching="continuous", kv_pages=kv_pages,
              kv_page_size=kv_page_size)
    if devices > 1:
        return ClusterServingEngine(cfg, params, n_devices=devices, **kw)
    return ServingEngine(cfg, params, **kw)


# ------------------------------------------------------ golden-trace regen
def _run_golden(unit: WorkUnit) -> UnitResult:
    import importlib
    try:
        mod = importlib.import_module("tests.test_golden_traces")
    except ModuleNotFoundError:
        # sequential in-process lane with only src/ on the path: the
        # golden suite lives at the repo root, one level above src/
        import sys
        from pathlib import Path

        import repro
        root = Path(next(iter(repro.__path__))).resolve().parents[1]
        if str(root) not in sys.path:
            sys.path.insert(0, str(root))
        mod = importlib.import_module("tests.test_golden_traces")
    name = unit.params["name"]
    run = mod.TRACES[name]()
    text = "\n".join(run.lines) + "\n"
    golden_path = mod.GOLDEN / f"{name}.trace"
    committed = golden_path.read_text() if golden_path.exists() else None
    ok = text == committed
    failures = [] if ok else [
        f"regenerated trace diverges from committed {golden_path.name} "
        f"({len(run.lines)} live lines vs "
        f"{len(committed.splitlines()) if committed else 0} golden)"]
    from repro.core.counters import counter_banks, merged_totals
    target = getattr(getattr(run, "recording", None), "target", None)
    return UnitResult(
        uid=unit.uid, kind=unit.kind, ok=ok,
        digest=hashlib.sha256(text.encode()).hexdigest(),
        counts={}, scenarios=1, failures=failures,
        counters=merged_totals(counter_banks(target))
        if target is not None else {})


EXECUTORS: Dict[str, Callable[[WorkUnit], UnitResult]] = {
    "fuzz_batch": _run_fuzz_batch,
    "sweep": _run_sweep,
    "golden": _run_golden,
    "serving": _run_serving_campaign,
}
