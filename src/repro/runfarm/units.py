"""Campaign work units — the sharding quantum of the run farm.

A unit is a **seed-closed job**: everything it needs is in ``(kind, seed,
params)``, all JSON-round-trippable, so the same unit executes identically
in the manager process, in a spawned worker, or on a remote host tomorrow.
Unit seeds derive from the campaign seed by the same construction as
``FaultPlan.fork`` (sha256 over ``"{seed}/{label}"``), so the stimulus a
unit generates depends only on its uid — never on which worker ran it,
in what order, or how many peers it had.

Uids are ``g<generation>/u<index>`` and sort lexicographically in
execution order; the campaign's final digest hashes ``(uid, digest)``
pairs in uid order, which is what makes the merged result independent of
worker count (the determinism bar in docs/runfarm.md).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Optional, Sequence


def fork_seed(seed: int, label: str) -> int:
    """Deterministic child seed — identical construction to
    ``FaultPlan.fork`` (core/fuzz.py), so unit seeds are order- and
    process-independent."""
    return int.from_bytes(
        hashlib.sha256(f"{seed}/{label}".encode()).digest()[:8], "little")


def unit_uid(gen: int, index: int) -> str:
    return f"g{gen:02d}/u{index:05d}"


@dataclasses.dataclass(frozen=True)
class WorkUnit:
    """One schedulable job: executed by ``runfarm.builtin.execute_unit``
    under the executor registered for ``kind``."""
    uid: str
    kind: str              # executor: fuzz_batch | sweep | golden | serving
    seed: int
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    parent: Optional[str] = None        # uid of the mutation parent, if any

    def payload_hash(self) -> str:
        """Identity of the unit's *inputs*; stored with its result record
        so a resumed campaign detects spec drift (same uid, different
        job) and re-runs instead of silently reusing a stale record."""
        blob = json.dumps({"kind": self.kind, "seed": self.seed,
                           "params": self.params, "parent": self.parent},
                          sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def to_json(self) -> dict:
        return {"uid": self.uid, "kind": self.kind, "seed": self.seed,
                "params": self.params, "parent": self.parent}

    @classmethod
    def from_json(cls, d: dict) -> "WorkUnit":
        return cls(uid=d["uid"], kind=d["kind"], seed=int(d["seed"]),
                   params=dict(d.get("params") or {}),
                   parent=d.get("parent"))


@dataclasses.dataclass
class UnitResult:
    """One executed unit's outcome, as shipped over the result queue and
    persisted (via ``record()``) to the JSONL store.  ``seconds`` is
    worker-side wall clock and is excluded from every digest — it is the
    only non-deterministic field."""
    uid: str
    kind: str
    ok: bool
    digest: str                         # deterministic per-unit witness
    counts: Dict[str, Dict[str, int]]   # sparse CoverageModel.to_counts()
    scenarios: int                      # work quantum for scenarios/sec
    seconds: float = 0.0
    failures: List[str] = dataclasses.field(default_factory=list)
    harvest: Optional[dict] = None      # shrunk repro / divergence bundle
    worker: int = -1
    # sampled performance-counter totals (core/counters.py), name ->
    # cumulative value summed over the unit's banks; merged fleet-wide in
    # uid order at the generation barrier, like coverage counts
    counters: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def record(self, payload_hash: str) -> dict:
        """The JSONL store record (one line, sort_keys canonical)."""
        rec = {"uid": self.uid, "kind": self.kind, "ok": self.ok,
               "digest": self.digest, "counts": self.counts,
               "scenarios": self.scenarios,
               "seconds": round(self.seconds, 6),
               "failures": self.failures, "payload": payload_hash,
               "worker": self.worker}
        if self.harvest is not None:
            rec["harvest"] = self.harvest
        if self.counters:
            rec["counters"] = {
                n: (round(v, 6) if isinstance(v, float) else v)
                for n, v in self.counters.items()}
        return rec


# ------------------------------------------------------- gen-0 builders
def fuzz_units(seed: int, n_scenarios: int, batch: int = 250,
               layers: Sequence[str] = ("registers",), gen: int = 0,
               start_index: int = 0, rates: Optional[Dict[str, float]] = None,
               bridge_ops: Optional[Sequence[int]] = None,
               mm_bug: Optional[Sequence[float]] = None,
               shrink_failures: bool = True) -> List[WorkUnit]:
    """Shard an ``n_scenarios`` ProtocolFuzzer campaign into batch units.

    Each unit fuzzes ``batch`` scenarios under its own forked fuzzer seed
    (scenario indices restart at 0 per unit — the seed, not the index,
    carries the entropy).  ``mm_bug=(i, j, delta)`` plants the known
    interpret-backend bug (core/fuzz.planted_bug_table) so harvesting has
    something to shrink."""
    params: Dict[str, Any] = {"layers": list(layers),
                              "shrink_failures": bool(shrink_failures)}
    if rates:
        params["rates"] = dict(rates)
    if bridge_ops is not None:
        params["bridge_ops"] = [int(bridge_ops[0]), int(bridge_ops[1])]
    if mm_bug is not None:
        params["mm_bug"] = [int(mm_bug[0]), int(mm_bug[1]),
                            float(mm_bug[2])]
    units = []
    i = 0
    while i * batch < n_scenarios:
        uid = unit_uid(gen, start_index + i)
        count = min(batch, n_scenarios - i * batch)
        units.append(WorkUnit(uid, "fuzz_batch", fork_seed(seed, uid),
                              params=dict(params, count=count)))
        i += 1
    return units


def sweep_units(seed: int, configs: Sequence[Dict[str, Any]],
                backends: Sequence[str] = ("oracle", "interpret"),
                gen: int = 0, start_index: int = 0,
                congestion_seed: int = 7,
                mm_bug: Optional[Sequence[float]] = None,
                configs_per_unit: int = 2) -> List[WorkUnit]:
    """Shard a CoVerifySession matmul sweep: each unit runs a slice of
    ``configs`` (every backend per config) as one in-process session with
    its own forked fault-plan seed."""
    units = []
    chunk = max(1, int(configs_per_unit))
    for i in range(0, len(configs), chunk):
        uid = unit_uid(gen, start_index + len(units))
        params: Dict[str, Any] = {
            "configs": [dict(c) for c in configs[i:i + chunk]],
            "backends": list(backends),
            "congestion_seed": int(congestion_seed)}
        if mm_bug is not None:
            params["mm_bug"] = [int(mm_bug[0]), int(mm_bug[1]),
                                float(mm_bug[2])]
        units.append(WorkUnit(uid, "sweep", fork_seed(seed, uid), params))
    return units


def golden_units(names: Sequence[str], gen: int = 0, start_index: int = 0
                 ) -> List[WorkUnit]:
    """One unit per golden trace: regenerate it and diff against the
    committed rendering (tests/golden/) — the farm's cheapest
    whole-stack integrity probe."""
    return [WorkUnit(unit_uid(gen, start_index + i), "golden", 0,
                     {"name": str(n)}) for i, n in enumerate(names)]


def serving_units(seed: int, traces: Sequence[Dict[str, Any]],
                  pools: Sequence[Dict[str, Any]] = (
                      {"kv_pages": 6, "kv_page_size": 8},),
                  devices: Sequence[int] = (1,), gen: int = 0,
                  start_index: int = 0,
                  max_ticks: int = 50_000) -> List[WorkUnit]:
    """Shard an open-loop serving SLO campaign: one unit per
    (arrival-trace spec x KV-pool geometry x device count).

    A trace spec is ``{"kind": "poisson"|"bursty", "params": {...}}``
    (serving/arrivals.ARRIVAL_KINDS); the trace SEED is the unit's own
    forked seed, so the arrival stimulus follows the uid and shards need
    no coordination — any worker regenerates the identical trace from the
    JSON params.  A pool spec may also override engine shape
    (``max_slots`` / ``max_len`` / ``prompt_pad``)."""
    units = []
    for t in traces:
        for pool in pools:
            for n in devices:
                uid = unit_uid(gen, start_index + len(units))
                params: Dict[str, Any] = {
                    "kind": str(t["kind"]),
                    "trace": dict(t.get("params") or {}),
                    "pool": dict(pool), "devices": int(n),
                    "max_ticks": int(max_ticks)}
                units.append(WorkUnit(uid, "serving",
                                      fork_seed(seed, uid), params))
    return units


def mutate_unit(parent: WorkUnit, j: int, uid: str) -> WorkUnit:
    """Default mutation: a child exploring near a productive seed — same
    stimulus shape (params copied), seed forked from the PARENT's seed, so
    the mutation lineage is itself deterministic and worker-independent."""
    return WorkUnit(uid, parent.kind, fork_seed(parent.seed, f"mut/{j}"),
                    params=dict(parent.params), parent=parent.uid)
