"""Campaign report assembly (the ``BENCH_runfarm.json`` payload).

The report is split into a ``deterministic`` section — final digest,
per-unit digest set, merged coverage, coverage trajectory — that must be
byte-identical across worker counts and kill/resume, and a ``timing``
section (scenarios/sec, per-worker utilization) that is honest wall-clock
measurement and never enters any digest or determinism gate.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional


def campaign_report(*, seed: int, workers: int, wall_seconds: float,
                    records: Dict[str, dict], uids: List[str],
                    coverage, trajectory: List[dict],
                    worker_stats: Dict[int, dict], skipped: int,
                    respawned: int, final_digest: str,
                    counter_totals: Optional[Dict[str, float]] = None
                    ) -> dict:
    recs = [records[u] for u in sorted(uids)]
    scenarios = sum(int(r.get("scenarios", 0)) for r in recs)
    busy = sum(float(w.get("busy_seconds", 0.0))
               for w in worker_stats.values())
    per_worker = {
        str(wid): {
            "units": int(w.get("units", 0)),
            "busy_seconds": round(float(w.get("busy_seconds", 0.0)), 3),
            "utilization": (round(float(w["busy_seconds"]) / wall_seconds, 4)
                            if wall_seconds > 0 else 0.0)}
        for wid, w in sorted(worker_stats.items())}
    return {
        "deterministic": {
            "seed": seed,
            "units": len(recs),
            "scenarios": scenarios,
            "final_digest": final_digest,
            "unit_digests": {r["uid"]: r["digest"] for r in recs},
            "failures": sum(1 for r in recs if not r.get("ok", True)),
            "harvested": sorted(r["uid"] for r in recs if r.get("harvest")),
            "coverage": coverage.summary() if coverage is not None else None,
            "trajectory": trajectory,
            # fleet-wide sampled-counter totals (core/counters.py):
            # merged by name in uid order, so byte-identical at any
            # worker count — part of the determinism-gated slice
            "counters": {
                n: (round(v, 6) if isinstance(v, float) else v)
                for n, v in sorted((counter_totals or {}).items())},
        },
        "timing": {
            "workers": workers,
            "wall_seconds": round(wall_seconds, 3),
            "scenarios_per_sec": (round(scenarios / wall_seconds, 1)
                                  if wall_seconds > 0 else None),
            "busy_seconds_total": round(busy, 3),
            "pool_utilization": (round(busy / (wall_seconds *
                                               max(1, workers)), 4)
                                 if wall_seconds > 0 and workers else None),
            "per_worker": per_worker,
            "units_resumed_from_store": skipped,
            "workers_respawned": respawned,
        },
    }


def write_report(path, report: dict) -> Path:
    out = Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    return out


def deterministic_view(report: dict) -> dict:
    """The determinism-gated slice of a report (what tests and the CI
    lane compare across worker counts / kill+resume)."""
    return report["deterministic"]
