"""Sharded, async, atomic checkpointing with resharding restore.

Layout (one directory per step, atomic rename commit):

    ckpt_dir/step_000123.tmp/ -> ckpt_dir/step_000123/
        meta.json              # step, leaf paths/shapes/dtypes, extras
        shard_00000/leaves.npz # per-"host" shard files

Single-process here, but the layout is per-host-shard exactly as a
multi-host run would write it (each host saves its addressable shards), so
restore-with-resharding (elastic rescale: train on mesh A, restore on mesh
B) is exercised for real — restore device_puts each leaf with the *target*
sharding, which is the whole trick.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat[0]:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        leaves.append((p, leaf))
    return leaves, flat[1]


class CheckpointManager:
    """Use as a context manager (``with CheckpointManager(...) as mgr:``)
    so the in-flight async write is always joined — and its error
    surfaced — before the process moves on; a bare instance must call
    ``wait()``/``close()`` itself.

    Failure contract: a checkpoint either commits completely (the atomic
    ``.tmp`` -> final rename) or leaves nothing visible — a write that
    dies mid-``npz`` removes its ``.tmp`` staging directory, and the
    exception is re-raised to the caller on the next ``save()``/``wait()``
    instead of dying silently on the worker thread (pre-fix, a crashing
    campaign could leave a truncated step directory and the training loop
    kept checkpointing into the void)."""

    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.wait()                 # flush + surface any write error
        else:                           # already unwinding: join the
            self._join()                # writer but don't mask the error

    def close(self) -> None:
        self.wait()

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, extras: Optional[dict] = None):
        # Snapshot to host memory synchronously (consistent point-in-time),
        # write to disk on a worker thread (compute/IO overlap).
        leaves, _ = _flatten(state)
        host = [(p, np.asarray(v)) for p, v in leaves]
        self.wait()
        if self.async_save:
            self._pending = threading.Thread(
                target=self._write_guarded, args=(step, host, extras or {}))
            self._pending.start()
        else:
            self._write(step, host, extras or {})

    def _write_guarded(self, step: int, host, extras: dict):
        try:
            self._write(step, host, extras)
        except BaseException as e:      # surfaced on the next wait()/save()
            self._error = e

    def _write(self, step: int, host, extras: dict):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        try:
            shard = tmp / "shard_00000"
            shard.mkdir(parents=True)
            np.savez(shard / "leaves.npz", **{p: v for p, v in host})
            meta = {
                "step": step,
                "leaves": {p: {"shape": list(v.shape),
                               "dtype": str(v.dtype)}
                           for p, v in host},
                "extras": extras,
            }
            (tmp / "meta.json").write_text(json.dumps(meta))
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)   # nothing partial
            raise
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                                   # atomic commit
        self._gc()

    def _join(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def wait(self):
        self._join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def list_steps(self):
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if p.is_dir() and not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `like`; if `shardings` is given the
        leaves are placed with the TARGET sharding (elastic reshard)."""
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "shard_00000" / "leaves.npz")
        leaves, treedef = _flatten(like)
        sh_leaves = None
        if shardings is not None:
            sh_leaves = [s for _, s in _flatten(shardings)[0]]
        out = []
        for i, (p, proto) in enumerate(leaves):
            arr = data[p]
            tgt_dtype = proto.dtype
            arr = arr.astype(tgt_dtype)
            if sh_leaves is not None:
                out.append(jax.device_put(arr, sh_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def extras(self, step: int) -> dict:
        meta = json.loads((self.dir / f"step_{step:08d}" / "meta.json")
                          .read_text())
        return meta.get("extras", {})


def load_checkpoint(directory, like: Any, shardings: Any = None,
                    step: Optional[int] = None):
    mgr = CheckpointManager(directory)
    s = step if step is not None else mgr.latest_step()
    if s is None:
        return None, None
    return mgr.restore(s, like, shardings), s
