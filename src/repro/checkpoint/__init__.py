from repro.checkpoint.manager import CheckpointManager, load_checkpoint

__all__ = ["CheckpointManager", "load_checkpoint"]
