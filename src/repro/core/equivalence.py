"""Three-way functional-equivalence checking with first-divergence
localization (the paper's "ensuring functional equivalence", §I/§IV-B).

oracle (ref.py jnp) ≡ interpret (Pallas interpret mode) ≡ compiled (XLA).
On mismatch the report pinpoints the leaf path, flat index, and values —
the co-verification analogue of dropping a waveform cursor on the first
diverging signal.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class Divergence:
    pair: Tuple[str, str]
    leaf_path: str
    index: Tuple[int, ...]
    lhs: float
    rhs: float
    max_abs_err: float
    rel_err: float


@dataclasses.dataclass
class EquivalenceReport:
    passed: bool
    tol: float
    backends: List[str]
    divergences: List[Divergence]

    def __str__(self) -> str:
        if self.passed:
            return f"EQUIVALENT across {self.backends} (tol={self.tol:g})"
        lines = [f"DIVERGENT (tol={self.tol:g}):"]
        for d in self.divergences:
            lines.append(
                f"  {d.pair[0]} vs {d.pair[1]} @ {d.leaf_path}{list(d.index)}"
                f": {d.lhs:.6g} vs {d.rhs:.6g} "
                f"(abs={d.max_abs_err:.3g}, rel={d.rel_err:.3g})")
        return "\n".join(lines)


def _leaf_paths(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) or "<root>"
        out.append((p, np.asarray(leaf, dtype=np.float64)
                    if np.issubdtype(np.asarray(leaf).dtype, np.floating)
                    else np.asarray(leaf).astype(np.float64)))
    return out


def compare(a: Any, b: Any, names: Tuple[str, str], tol: float
            ) -> Optional[Divergence]:
    for (pa, la), (_, lb) in zip(_leaf_paths(a), _leaf_paths(b)):
        if la.shape != lb.shape:
            return Divergence(names, pa, (), float("nan"), float("nan"),
                              float("inf"), float("inf"))
        diff = np.abs(la - lb)
        if diff.size == 0:
            continue
        scale = max(np.max(np.abs(la)), 1e-9)
        if np.max(diff) > tol * max(1.0, scale):
            idx = np.unravel_index(int(np.argmax(diff)), diff.shape)
            return Divergence(names, pa, tuple(int(i) for i in idx),
                              float(la[idx]), float(lb[idx]),
                              float(np.max(diff)),
                              float(np.max(diff) / scale))
    return None


def compare_outputs(outs: Dict[str, Any],
                    tol: float = 1e-4) -> EquivalenceReport:
    """Compare already-computed per-backend outputs, all vs the first.

    This is the comparison consumed by the CoVerifySession sweep scheduler
    (core/scheduler.py): each sweep group hands in the final DDR state per
    backend and gets back one localized report per group.
    """
    names = list(outs)
    divs: List[Divergence] = []
    base = names[0]
    for other in names[1:]:
        d = compare(outs[base], outs[other], (base, other), tol)
        if d is not None:
            divs.append(d)
    return EquivalenceReport(passed=not divs, tol=tol, backends=names,
                             divergences=divs)


def check_equivalence(fns: Dict[str, Callable], args: tuple,
                      tol: float = 1e-4) -> EquivalenceReport:
    """Run every backend on identical inputs and compare all vs the first."""
    return compare_outputs({n: fn(*args) for n, fn in fns.items()}, tol)
