"""Transaction records + profiling (paper Figs. 8 and 9).

A Transaction is one logical memory burst: a DMA tile fetch (kernel
BlockSpec-derived), a register access, or a host<->device transfer.  The
TransactionLog renders bandwidth-utilization timelines and address/time
heatmaps — the TPU-side analogue of FireBridge's AXI monitors.

The modeled-time hot path is batched (docs/performance.md): burst
splitting, fault perturbation, and link arbitration operate on
``BurstBatch`` column arrays, and the log holds arbitrated batches as
lazy segments — ``Transaction`` objects materialize only when something
actually reads ``txs``, and canonical lines / digests render straight
from the columns.  Everything stays bit-identical to the per-object
path; the differential tier (tests/test_simspeed.py) is the witness.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
from collections import defaultdict
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Transaction:
    time: float                 # issue time (cycles or seconds — caller's unit)
    engine: str                 # "dma_a", "host", "csr", ...
    kind: str                   # "read" | "write"
    addr: int
    nbytes: int
    tag: str = ""
    stall: float = 0.0          # stall time injected by the congestion model
    complete: float = 0.0       # completion time (filled by congestion model)
    # profiling attribution (core/profiler.py): the DoS component of
    # ``stall`` (filled by the congestion arbiter) and the min-issue delay
    # added by an injected dma_delay fault (filled by the fault plan).
    # Never rendered into canonical lines — golden traces are unaffected.
    dos: float = 0.0
    fault_delay: float = 0.0


# Column layout of one burst batch: every numeric Transaction field,
# including the profiling-attribution columns, so per-tx attribution
# survives vectorization unchanged.
BURST_DTYPE = np.dtype([
    ("time", np.float64), ("addr", np.int64), ("nbytes", np.int64),
    ("stall", np.float64), ("complete", np.float64),
    ("dos", np.float64), ("fault_delay", np.float64),
])


class BurstBatch:
    """One batch of link-level bursts as a structured array + string
    columns — the unit the vectorized hot path moves around instead of
    ``List[Transaction]``.

    ``rec`` is a structured numpy array (``BURST_DTYPE``); ``engine``,
    ``kind`` and ``tag`` are parallel Python lists (string columns in
    structured arrays cost more than they save at these batch sizes).

    Lifecycle contract: build (split) -> perturb (fault plan) ->
    arbitrate (stall/complete/dos filled in grant order) -> logged.
    Once logged a batch is immutable — the same invariant a logged
    ``Transaction`` already has — so ``materialize()`` may cache, and
    the log and the link timeline sharing one segment alias the same
    Transaction objects, exactly like per-object submission.
    """

    __slots__ = ("rec", "engine", "kind", "tag", "_txs")

    def __init__(self, rec: np.ndarray, engine: List[str], kind: List[str],
                 tag: List[str]) -> None:
        self.rec = rec
        self.engine = engine
        self.kind = kind
        self.tag = tag
        self._txs: Optional[List[Transaction]] = None

    def __len__(self) -> int:
        return len(self.engine)

    # ------------------------------------------------------------ builders
    @classmethod
    def from_transfer(cls, time: float, engine: str, kind: str, addr: int,
                      nbytes: int, tag: str, step: int) -> "BurstBatch":
        """``split_bursts`` over columns: one transfer -> its burst batch
        (at most ``step`` bytes per burst; 0 = never split)."""
        return cls.from_runs(time, engine, kind, [(addr, nbytes)], tag, step)

    @classmethod
    def from_runs(cls, time: float, engine: str, kind: str,
                  runs: Sequence[Tuple[int, int]], tag: str,
                  step: int) -> "BurstBatch":
        """One transfer leg over byte ``runs`` (strided inner-axis shards),
        each run burst-split like ``split_bursts``."""
        addrs: List[np.ndarray] = []
        lens: List[np.ndarray] = []
        for a, nb in runs:
            if step <= 0 or nb <= step:
                addrs.append(np.array([a], dtype=np.int64))
                lens.append(np.array([nb], dtype=np.int64))
            else:
                off = np.arange(0, nb, step, dtype=np.int64)
                addrs.append(a + off)
                lens.append(np.minimum(step, nb - off))
        a_col = addrs[0] if len(addrs) == 1 else np.concatenate(addrs)
        n_col = lens[0] if len(lens) == 1 else np.concatenate(lens)
        n = len(a_col)
        rec = np.zeros(n, dtype=BURST_DTYPE)
        rec["time"] = time
        rec["addr"] = a_col
        rec["nbytes"] = n_col
        return cls(rec, [engine] * n, [kind] * n, [tag] * n)

    @classmethod
    def from_tuples(cls, time: float,
                    txs: Sequence[Tuple[str, str, int, int]]) -> "BurstBatch":
        """A kernel's static burst list — (engine, kind, addr, nbytes)
        tuples sharing one min-issue time (bridge.log_burst_list)."""
        n = len(txs)
        rec = np.zeros(n, dtype=BURST_DTYPE)
        rec["time"] = time
        if n:
            rec["addr"] = [t[2] for t in txs]
            rec["nbytes"] = [t[3] for t in txs]
        return cls(rec, [t[0] for t in txs], [t[1] for t in txs], [""] * n)

    # ------------------------------------------- fault-plan mutation hooks
    def permute(self, perm: np.ndarray) -> None:
        """Reorder the batch (dma_reorder fault) — pre-arbitration only."""
        self.rec = self.rec[perm]
        ol = perm.tolist()
        self.engine = [self.engine[i] for i in ol]
        self.kind = [self.kind[i] for i in ol]
        self.tag = [self.tag[i] for i in ol]

    def split_row(self, i: int) -> None:
        """Split burst ``i`` into two half-bursts (dma_split fault).
        The halves are fresh rows (zero stall/complete/dos/fault_delay),
        matching the scalar path's freshly constructed Transactions."""
        r = self.rec
        nb = int(r["nbytes"][i])
        half = nb // 2
        rows = np.zeros(2, dtype=BURST_DTYPE)
        rows["time"] = r["time"][i]
        rows["addr"] = (int(r["addr"][i]), int(r["addr"][i]) + half)
        rows["nbytes"] = (half, nb - half)
        self.rec = np.concatenate([r[:i], rows, r[i + 1:]])
        self.engine[i:i + 1] = [self.engine[i]] * 2
        self.kind[i:i + 1] = [self.kind[i]] * 2
        self.tag[i:i + 1] = [self.tag[i]] * 2

    def delay(self, delay: float) -> None:
        """Bump every burst's min-issue time (dma_delay fault), keeping
        the stall-attribution bookkeeping column in sync."""
        self.rec["time"] += delay
        self.rec["fault_delay"] += delay

    # ------------------------------------------------------ materialization
    def materialize(self) -> List[Transaction]:
        """Transaction objects for this batch — built once, cached, so
        every reader (log, link timeline, profiler) aliases the same
        objects, exactly as per-object submission would."""
        if self._txs is None:
            r = self.rec
            self._txs = [
                Transaction(t, e, k, a, nb, tag, st, c, d, fd)
                for t, a, nb, st, c, d, fd, e, k, tag in zip(
                    r["time"].tolist(), r["addr"].tolist(),
                    r["nbytes"].tolist(), r["stall"].tolist(),
                    r["complete"].tolist(), r["dos"].tolist(),
                    r["fault_delay"].tolist(), self.engine, self.kind,
                    self.tag)]
        return self._txs

    def canonical_lines(self) -> List[str]:
        """Canonical renderings straight from the columns — a digest of a
        batch-built log never has to materialize Transaction objects."""
        r = self.rec
        out = []
        for t, a, nb, st, c, e, k, tag in zip(
                r["time"].tolist(), r["addr"].tolist(),
                r["nbytes"].tolist(), r["stall"].tolist(),
                r["complete"].tolist(), self.engine, self.kind, self.tag):
            line = (f"{t:.6f} {e} {k} {a:#x} {nb} stall={st:.6f} "
                    f"complete={c:.6f}")
            if tag:
                line += f" tag={tag}"
            out.append(line)
        return out


@dataclasses.dataclass
class OpMark:
    """One profiled operation window: which slice of a ``TransactionLog``
    (and which span of the modeled clock) belongs to one logical op — an
    accelerator launch, a fabric collective leg, a serving tick.  Recorded
    by the ``profile=`` hooks (bridge.py, fabric.py) and consumed by
    ``core/profiler.py`` for per-op data-movement attribution (paper §IV,
    Fig. 8)."""
    op: str                     # "mm@oracle", "all_reduce", "scatter", ...
    engine: str                 # owning engine/channel hint
    t0: float                   # modeled clock at op entry
    t1: float                   # modeled clock at op exit
    tx_lo: int                  # first owned tx index in the log
    tx_hi: int                  # one past the last owned tx index
    meta: str = ""              # phase detail (e.g. "reduce_scatter[0]")


@contextlib.contextmanager
def record_mark(marks: List[OpMark], log: "TransactionLog",
                now: Callable[[], float], op: str, engine: str = "",
                meta: str = ""):
    """THE op-mark recorder: capture the clock + log cursor around a
    block and append one ``OpMark``.  Shared by the bridge's ``mark`` and
    the fabric's ``_mark`` so the two cannot drift; callers gate on their
    own ``profile`` flag (a disabled profiler never reaches here).  Uses
    ``n_txs`` (a count, not the materialized list) so marking never
    flushes lazy batch segments."""
    t0, lo = now(), log.n_txs
    try:
        yield
    finally:
        marks.append(OpMark(op, engine, t0, now(), lo, log.n_txs, meta))


def split_bursts(time: float, engine: str, kind: str, addr: int,
                 nbytes: int, tag: str, step: int) -> List[Transaction]:
    """Split one transfer into link-level bursts of at most ``step`` bytes
    (0 = never split).  Object-path twin of ``BurstBatch.from_transfer``
    — the batched splitter the bridge/fabric/serving hot paths now use —
    kept as the reference the differential tier compares against."""
    if step <= 0 or nbytes <= step:
        return [Transaction(time, engine, kind, addr, nbytes, tag=tag)]
    return [Transaction(time, engine, kind, addr + off,
                        min(step, nbytes - off), tag=tag)
            for off in range(0, nbytes, step)]


class TransactionLog:
    """Burst log + two audit channels.

    ``violations`` records protocol breaches observed by the hardware side
    (unmapped access, RO write, doorbell-while-busy, ...).  ``faults``
    records *deliberately injected* perturbations from a fault plan
    (core/fuzz.py) — delayed/reordered/split bursts, healed bit flips,
    congestion perturbation.  Keeping the channels separate lets the fuzz
    harness assert that every injected fault was audited without the
    injection itself failing a sweep's ``passed`` check.

    The transaction stream is lazy: arbitrated ``BurstBatch`` segments
    are appended by ``log_batch`` and only materialized into Transaction
    objects when ``txs`` is actually read.  Canonicalization is lazy too
    — rendered lines and the running sha256 are cached append-only and
    invalidated on ``set_state`` (the one mutation that isn't an append),
    so repeated ``digest()`` calls cost only the new suffix.
    """

    def __init__(self) -> None:
        self._txs: List[Transaction] = []
        self._pending: List[BurstBatch] = []
        self._n_pending = 0
        self.violations: List[str] = []
        self.faults: List[str] = []
        # lazy canonicalization caches: rendered tx lines for a logical
        # prefix of the stream, the sha256 over exactly those lines, and
        # a keyed memo of the last full digest.  ``_epoch`` bumps on
        # set_state so a restored stream can never alias a stale key.
        self._lines: List[str] = []
        self._tx_hash = hashlib.sha256()
        self._digest_memo: Optional[Tuple[Tuple, str]] = None
        self._epoch = 0

    # ------------------------------------------------------- lazy segments
    @property
    def txs(self) -> List[Transaction]:
        """The materialized transaction stream.  Reading this flushes any
        pending batch segments into Transaction objects; hot paths that
        only need counts/lines use ``n_txs``/``lines_since`` instead."""
        if self._pending:
            self._flush()
        return self._txs

    @property
    def n_txs(self) -> int:
        """Logical transaction count — flush-free (cursor/marks hot path)."""
        return len(self._txs) + self._n_pending

    def _flush(self) -> None:
        for b in self._pending:
            self._txs.extend(b.materialize())
        self._pending.clear()
        self._n_pending = 0

    def log(self, tx: Transaction) -> None:
        if self._pending:
            self._flush()
        self._txs.append(tx)

    def extend(self, txs: Iterable[Transaction]) -> None:
        if self._pending:
            self._flush()
        self._txs.extend(txs)

    def log_batch(self, batch: BurstBatch) -> None:
        """Append one arbitrated burst batch as a lazy segment (the
        batched hot path's ``log``)."""
        self._pending.append(batch)
        self._n_pending += len(batch)

    def violation(self, msg: str) -> None:
        self.violations.append(msg)

    def fault(self, msg: str) -> None:
        """Audit one injected fault (never silently absorbed)."""
        self.faults.append(msg)

    def audit(self) -> Dict[str, int]:
        """Counts for the violation/fault audit channels."""
        return {"violations": len(self.violations), "faults": len(self.faults)}

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict:
        """Snapshot of the log for a replay checkpoint (core/replay.py).
        Logged entries are shared, not copied: a Transaction is mutated
        only BEFORE it is logged (congestion arbitration, fault perturb),
        so the list prefix is immutable and checkpointing stays O(n) per
        snapshot instead of O(history)."""
        return {"txs": list(self.txs),
                "violations": list(self.violations),
                "faults": list(self.faults)}

    def set_state(self, state: Dict) -> None:
        """Restore a snapshot IN PLACE — the log object keeps its identity,
        so a bridge + register file sharing one log stay wired after a
        checkpoint restore.  Entries are aliased under the same
        immutable-once-logged invariant as ``get_state`` — the restore
        path is the replay hot loop (bench_replay.py economics).  The
        restored stream may share no prefix with the cached rendering, so
        every canonicalization cache is invalidated here."""
        self._pending.clear()
        self._n_pending = 0
        self._txs[:] = state["txs"]
        self.violations[:] = state["violations"]
        self.faults[:] = state["faults"]
        self._lines = []
        self._tx_hash = hashlib.sha256()
        self._digest_memo = None
        self._epoch += 1

    def cursor(self) -> Tuple[int, int, int]:
        """(txs, violations, faults) lengths — a position in the stream,
        used by replay windows to attribute new entries to one timeline
        op.  Flush-free."""
        return (self.n_txs, len(self.violations), len(self.faults))

    def lines_since(self, cur: Tuple[int, int, int]) -> List[str]:
        """Canonical lines appended after ``cursor()`` returned ``cur``,
        in op-emission order (txs, then violations, then faults)."""
        nt, nv, nf = cur
        self._render()
        lines = list(self._lines[nt:])
        lines += [f"violation: {v}" for v in self.violations[nv:]]
        lines += [f"fault: {f}" for f in self.faults[nf:]]
        return lines

    # ------------------------------------------------- golden-trace format
    @staticmethod
    def canonical_line(t: Transaction) -> str:
        """Stable rendering of ONE transaction — the unit the golden-trace
        format, the replay window digests (core/replay.py), and the
        divergence reports all share, so a burst can never render two ways.

        Floats are fixed to 6 decimals so the text (and its digest) is
        identical across platforms and numpy versions.
        """
        line = (f"{t.time:.6f} {t.engine} {t.kind} {t.addr:#x} "
                f"{t.nbytes} stall={t.stall:.6f} "
                f"complete={t.complete:.6f}")
        if t.tag:
            line += f" tag={t.tag}"
        return line

    def _render(self) -> None:
        """Extend the append-only line cache (and its running sha256) to
        cover the whole logical stream — pending segments render straight
        from their columns, so this never materializes Transactions."""
        done = len(self._lines)
        new: List[str] = []
        if done < len(self._txs):
            new += [self.canonical_line(t) for t in self._txs[done:]]
            done = len(self._txs)
        pos = len(self._txs)
        for b in self._pending:
            end = pos + len(b)
            if done < end:
                lines = b.canonical_lines()
                new += lines[done - pos:] if done > pos else lines
                done = end
            pos = end
        for line in new:
            self._tx_hash.update(line.encode())
            self._tx_hash.update(b"\n")
        self._lines += new

    def canonical(self) -> List[str]:
        """Stable one-line-per-transaction rendering of the stream plus the
        audit channels — the golden-trace format (tests/golden/*.trace)."""
        self._render()
        lines = list(self._lines)
        lines += [f"violation: {v}" for v in self.violations]
        lines += [f"fault: {f}" for f in self.faults]
        return lines

    def digest(self) -> str:
        """sha256 over the canonical trace — the seeded-reproducibility
        witness used by the golden-trace regression tests and the fabric
        same-seed checks.  Digest-on-demand: the tx-line prefix hash is
        cached append-only, so a repeat digest costs only the lines added
        since the last one (tests/test_simspeed.py pins invalidation
        across log/extend/violation/fault/set_state)."""
        key = (self._epoch, self.n_txs, len(self.violations),
               len(self.faults))
        if self._digest_memo is not None and self._digest_memo[0] == key:
            return self._digest_memo[1]
        self._render()
        h = self._tx_hash.copy()
        for v in self.violations:
            h.update(f"violation: {v}".encode())
            h.update(b"\n")
        for f in self.faults:
            h.update(f"fault: {f}".encode())
            h.update(b"\n")
        out = h.hexdigest()
        self._digest_memo = (key, out)
        return out

    # ------------------------------------------------------------ queries
    def total_bytes(self, engine: Optional[str] = None) -> int:
        return sum(t.nbytes for t in self.txs
                   if engine is None or t.engine == engine)

    def engines(self) -> List[str]:
        return sorted({t.engine for t in self.txs})

    def total_stalls(self, engine: Optional[str] = None) -> float:
        return sum(t.stall for t in self.txs
                   if engine is None or t.engine == engine)

    # ------------------------------------------------------- Fig 8 analogue
    def bandwidth_timeline(self, n_buckets: int = 50,
                           by_engine: bool = True
                           ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Returns (bucket_edges, {engine: bytes_per_bucket})."""
        if not self.txs:
            return np.zeros(1), {}
        stamp = lambda t: t.complete if t.complete else t.time
        t_end = max(stamp(t) for t in self.txs) or 1.0
        edges = np.linspace(0.0, t_end, n_buckets + 1)
        out: Dict[str, np.ndarray] = defaultdict(
            lambda: np.zeros(n_buckets))
        for t in self.txs:
            b = min(int(stamp(t) / t_end * n_buckets), n_buckets - 1)
            out[t.engine if by_engine else "all"][b] += t.nbytes
        return edges, dict(out)

    # ------------------------------------------------------- Fig 9 analogue
    def heatmap(self, addr_bins: int = 32, time_bins: int = 64,
                kind: Optional[str] = None) -> np.ndarray:
        """(addr_bins, time_bins) access-count heatmap."""
        txs = [t for t in self.txs if kind is None or t.kind == kind]
        hm = np.zeros((addr_bins, time_bins))
        if not txs:
            return hm
        t_end = max(t.time for t in txs) or 1.0
        a_end = max(t.addr + t.nbytes for t in txs) or 1
        for t in txs:
            ai = min(int(t.addr / a_end * addr_bins), addr_bins - 1)
            ti = min(int(t.time / t_end * time_bins), time_bins - 1)
            hm[ai, ti] += t.nbytes
        return hm

    def render_heatmap(self, addr_bins: int = 24, time_bins: int = 64,
                       kind: Optional[str] = None) -> str:
        """ASCII heatmap (density ramp) for terminal/benchmark output."""
        hm = self.heatmap(addr_bins, time_bins, kind)
        ramp = " .:-=+*#%@"
        mx = hm.max() or 1.0
        lines = []
        for row in hm[::-1]:                       # high addresses on top
            lines.append("".join(
                ramp[min(int(v / mx * (len(ramp) - 1)), len(ramp) - 1)]
                for v in row))
        return "\n".join(lines)

    def summary(self) -> Dict[str, dict]:
        out = {}
        for e in self.engines():
            txs = [t for t in self.txs if t.engine == e]
            out[e] = {
                "transactions": len(txs),
                "bytes": sum(t.nbytes for t in txs),
                "reads": sum(1 for t in txs if t.kind == "read"),
                "writes": sum(1 for t in txs if t.kind == "write"),
                "stall": sum(t.stall for t in txs),
            }
        return out
