"""Transaction records + profiling (paper Figs. 8 and 9).

A Transaction is one logical memory burst: a DMA tile fetch (kernel
BlockSpec-derived), a register access, or a host<->device transfer.  The
TransactionLog renders bandwidth-utilization timelines and address/time
heatmaps — the TPU-side analogue of FireBridge's AXI monitors.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
from collections import defaultdict
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Transaction:
    time: float                 # issue time (cycles or seconds — caller's unit)
    engine: str                 # "dma_a", "host", "csr", ...
    kind: str                   # "read" | "write"
    addr: int
    nbytes: int
    tag: str = ""
    stall: float = 0.0          # stall time injected by the congestion model
    complete: float = 0.0       # completion time (filled by congestion model)
    # profiling attribution (core/profiler.py): the DoS component of
    # ``stall`` (filled by the congestion arbiter) and the min-issue delay
    # added by an injected dma_delay fault (filled by the fault plan).
    # Never rendered into canonical lines — golden traces are unaffected.
    dos: float = 0.0
    fault_delay: float = 0.0


@dataclasses.dataclass
class OpMark:
    """One profiled operation window: which slice of a ``TransactionLog``
    (and which span of the modeled clock) belongs to one logical op — an
    accelerator launch, a fabric collective leg, a serving tick.  Recorded
    by the ``profile=`` hooks (bridge.py, fabric.py) and consumed by
    ``core/profiler.py`` for per-op data-movement attribution (paper §IV,
    Fig. 8)."""
    op: str                     # "mm@oracle", "all_reduce", "scatter", ...
    engine: str                 # owning engine/channel hint
    t0: float                   # modeled clock at op entry
    t1: float                   # modeled clock at op exit
    tx_lo: int                  # first owned tx index in the log
    tx_hi: int                  # one past the last owned tx index
    meta: str = ""              # phase detail (e.g. "reduce_scatter[0]")


@contextlib.contextmanager
def record_mark(marks: List[OpMark], log: "TransactionLog",
                now: Callable[[], float], op: str, engine: str = "",
                meta: str = ""):
    """THE op-mark recorder: capture the clock + log cursor around a
    block and append one ``OpMark``.  Shared by the bridge's ``mark`` and
    the fabric's ``_mark`` so the two cannot drift; callers gate on their
    own ``profile`` flag (a disabled profiler never reaches here)."""
    t0, lo = now(), len(log.txs)
    try:
        yield
    finally:
        marks.append(OpMark(op, engine, t0, now(), lo, len(log.txs), meta))


def split_bursts(time: float, engine: str, kind: str, addr: int,
                 nbytes: int, tag: str, step: int) -> List[Transaction]:
    """Split one transfer into link-level bursts of at most ``step`` bytes
    (0 = never split).  The ONE splitter shared by device-local DDR
    accesses (bridge.py), the fabric links (fabric.py), and the
    cluster-serving host channel (serving/cluster.py), so burst semantics
    cannot drift between the traces they produce."""
    if step <= 0 or nbytes <= step:
        return [Transaction(time, engine, kind, addr, nbytes, tag=tag)]
    return [Transaction(time, engine, kind, addr + off,
                        min(step, nbytes - off), tag=tag)
            for off in range(0, nbytes, step)]


class TransactionLog:
    """Burst log + two audit channels.

    ``violations`` records protocol breaches observed by the hardware side
    (unmapped access, RO write, doorbell-while-busy, ...).  ``faults``
    records *deliberately injected* perturbations from a fault plan
    (core/fuzz.py) — delayed/reordered/split bursts, healed bit flips,
    congestion perturbation.  Keeping the channels separate lets the fuzz
    harness assert that every injected fault was audited without the
    injection itself failing a sweep's ``passed`` check.
    """

    def __init__(self) -> None:
        self.txs: List[Transaction] = []
        self.violations: List[str] = []
        self.faults: List[str] = []

    def log(self, tx: Transaction) -> None:
        self.txs.append(tx)

    def extend(self, txs: Iterable[Transaction]) -> None:
        self.txs.extend(txs)

    def violation(self, msg: str) -> None:
        self.violations.append(msg)

    def fault(self, msg: str) -> None:
        """Audit one injected fault (never silently absorbed)."""
        self.faults.append(msg)

    def audit(self) -> Dict[str, int]:
        """Counts for the violation/fault audit channels."""
        return {"violations": len(self.violations), "faults": len(self.faults)}

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict:
        """Snapshot of the log for a replay checkpoint (core/replay.py).
        Logged entries are shared, not copied: a Transaction is mutated
        only BEFORE it is logged (congestion arbitration, fault perturb),
        so the list prefix is immutable and checkpointing stays O(n) per
        snapshot instead of O(history)."""
        return {"txs": list(self.txs),
                "violations": list(self.violations),
                "faults": list(self.faults)}

    def set_state(self, state: Dict) -> None:
        """Restore a snapshot IN PLACE — the log object keeps its identity,
        so a bridge + register file sharing one log stay wired after a
        checkpoint restore.  Entries are aliased under the same
        immutable-once-logged invariant as ``get_state`` — the restore
        path is the replay hot loop (bench_replay.py economics)."""
        self.txs[:] = state["txs"]
        self.violations[:] = state["violations"]
        self.faults[:] = state["faults"]

    def cursor(self) -> Tuple[int, int, int]:
        """(txs, violations, faults) lengths — a position in the stream,
        used by replay windows to attribute new entries to one timeline
        op."""
        return (len(self.txs), len(self.violations), len(self.faults))

    def lines_since(self, cur: Tuple[int, int, int]) -> List[str]:
        """Canonical lines appended after ``cursor()`` returned ``cur``,
        in op-emission order (txs, then violations, then faults)."""
        nt, nv, nf = cur
        lines = [self.canonical_line(t) for t in self.txs[nt:]]
        lines += [f"violation: {v}" for v in self.violations[nv:]]
        lines += [f"fault: {f}" for f in self.faults[nf:]]
        return lines

    # ------------------------------------------------- golden-trace format
    @staticmethod
    def canonical_line(t: Transaction) -> str:
        """Stable rendering of ONE transaction — the unit the golden-trace
        format, the replay window digests (core/replay.py), and the
        divergence reports all share, so a burst can never render two ways.

        Floats are fixed to 6 decimals so the text (and its digest) is
        identical across platforms and numpy versions.
        """
        line = (f"{t.time:.6f} {t.engine} {t.kind} {t.addr:#x} "
                f"{t.nbytes} stall={t.stall:.6f} "
                f"complete={t.complete:.6f}")
        if t.tag:
            line += f" tag={t.tag}"
        return line

    def canonical(self) -> List[str]:
        """Stable one-line-per-transaction rendering of the stream plus the
        audit channels — the golden-trace format (tests/golden/*.trace)."""
        lines = [self.canonical_line(t) for t in self.txs]
        lines += [f"violation: {v}" for v in self.violations]
        lines += [f"fault: {f}" for f in self.faults]
        return lines

    def digest(self) -> str:
        """sha256 over the canonical trace — the seeded-reproducibility
        witness used by the golden-trace regression tests and the fabric
        same-seed checks."""
        h = hashlib.sha256()
        for line in self.canonical():
            h.update(line.encode())
            h.update(b"\n")
        return h.hexdigest()

    # ------------------------------------------------------------ queries
    def total_bytes(self, engine: Optional[str] = None) -> int:
        return sum(t.nbytes for t in self.txs
                   if engine is None or t.engine == engine)

    def engines(self) -> List[str]:
        return sorted({t.engine for t in self.txs})

    def total_stalls(self, engine: Optional[str] = None) -> float:
        return sum(t.stall for t in self.txs
                   if engine is None or t.engine == engine)

    # ------------------------------------------------------- Fig 8 analogue
    def bandwidth_timeline(self, n_buckets: int = 50,
                           by_engine: bool = True
                           ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Returns (bucket_edges, {engine: bytes_per_bucket})."""
        if not self.txs:
            return np.zeros(1), {}
        stamp = lambda t: t.complete if t.complete else t.time
        t_end = max(stamp(t) for t in self.txs) or 1.0
        edges = np.linspace(0.0, t_end, n_buckets + 1)
        out: Dict[str, np.ndarray] = defaultdict(
            lambda: np.zeros(n_buckets))
        for t in self.txs:
            b = min(int(stamp(t) / t_end * n_buckets), n_buckets - 1)
            out[t.engine if by_engine else "all"][b] += t.nbytes
        return edges, dict(out)

    # ------------------------------------------------------- Fig 9 analogue
    def heatmap(self, addr_bins: int = 32, time_bins: int = 64,
                kind: Optional[str] = None) -> np.ndarray:
        """(addr_bins, time_bins) access-count heatmap."""
        txs = [t for t in self.txs if kind is None or t.kind == kind]
        hm = np.zeros((addr_bins, time_bins))
        if not txs:
            return hm
        t_end = max(t.time for t in txs) or 1.0
        a_end = max(t.addr + t.nbytes for t in txs) or 1
        for t in txs:
            ai = min(int(t.addr / a_end * addr_bins), addr_bins - 1)
            ti = min(int(t.time / t_end * time_bins), time_bins - 1)
            hm[ai, ti] += t.nbytes
        return hm

    def render_heatmap(self, addr_bins: int = 24, time_bins: int = 64,
                       kind: Optional[str] = None) -> str:
        """ASCII heatmap (density ramp) for terminal/benchmark output."""
        hm = self.heatmap(addr_bins, time_bins, kind)
        ramp = " .:-=+*#%@"
        mx = hm.max() or 1.0
        lines = []
        for row in hm[::-1]:                       # high addresses on top
            lines.append("".join(
                ramp[min(int(v / mx * (len(ramp) - 1)), len(ramp) - 1)]
                for v in row))
        return "\n".join(lines)

    def summary(self) -> Dict[str, dict]:
        out = {}
        for e in self.engines():
            txs = [t for t in self.txs if t.engine == e]
            out[e] = {
                "transactions": len(txs),
                "bytes": sum(t.nbytes for t in txs),
                "reads": sum(1 for t in txs if t.kind == "read"),
                "writes": sum(1 for t in txs if t.kind == "write"),
                "stall": sum(t.stall for t in txs),
            }
        return out
