"""Seeded fault-injection + randomized-stimulus co-verification (the
paper's randomized memory bridges and register-level protocol testing,
§IV, turned into a reusable harness).

Three layers of hostile stimulus, one reproducibility contract:

* **bridge** — device-side DMA bursts are delayed, reordered, and split;
  ``dev_read`` data suffers transient bit flips that an audited ECC-style
  retry must heal; the congestion config is perturbed.  All of it happens
  while the same firmware runs against the oracle / interpret / compiled
  backends, and the differential checker asserts the final DDR state stays
  equivalent — faults may only perturb *timing*, never *function*.
* **registers** — randomized read/write sequences against a CSR map with
  RO/W1C/doorbell semantics, illegal-access storms, doorbell-while-busy
  races, and W1C edge patterns, differentially checked against a golden
  shadow model that predicts every read value and every violation message.
* **serving** — randomized submit streams through the serving engine's CSR
  protocol: shuffled order, duplicate request ids, zero/max
  ``max_new_tokens``, prompt lengths straddling the pad buckets.

Everything derives from one seed through a ``FaultPlan``: the same seed
produces the identical fault trace, the identical transaction log, and the
identical report digest — so any failing scenario is a one-line repro, and
``ProtocolFuzzer.shrink`` minimizes it to its shortest failing op prefix.

Every injected fault is audited in ``TransactionLog.faults`` (never
silently absorbed); every provoked protocol violation must show up in
``TransactionLog.violations`` exactly as predicted.
"""
from __future__ import annotations

import copy
import dataclasses
import hashlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bridge import FireBridge
from repro.core.congestion import CongestionConfig
from repro.core.coverage import CoverageModel
from repro.core.equivalence import compare_outputs
from repro.core.registers import RO, W1C, RegisterFile
from repro.core.transactions import BurstBatch, Transaction, TransactionLog

# P(inject) per opportunity, by fault kind (bridge layer).
DEFAULT_RATES: Dict[str, float] = {
    "dma_delay": 0.20,          # bursts issued late (min-issue time bumped)
    "dma_reorder": 0.20,        # burst batch permuted
    "dma_split": 0.20,          # one burst split into two half-bursts
    "bitflip_read": 0.15,       # transient flip on dev_read, retry heals
    "congestion_perturb": 0.50,  # link parameters jittered (timing only)
}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault, as recorded in the plan's reproducible trace."""
    scenario: int               # owning scenario index (-1 = standalone)
    layer: str                  # "bridge" | "registers" | "serving"
    kind: str                   # taxonomy key (DEFAULT_RATES / stimulus kind)
    detail: str

    def key(self) -> Tuple:
        return (self.scenario, self.layer, self.kind, self.detail)


class FaultPlan:
    """Seeded, forkable fault-injection plan (the harness's one RNG root).

    A plan owns a ``numpy`` Generator and a fault-rate table.  The bridge
    calls its hooks (``perturb_congestion``, ``perturb_bursts``,
    ``flip_read``) at each injection opportunity; every injected fault is
    appended to ``events`` *and* audited in the bridge's
    ``TransactionLog.faults`` — the trace and the log reproduce exactly
    under the same seed and call sequence.

    ``fork(label)`` derives a child plan whose seed depends only on
    ``(seed, label)`` — NOT on parent RNG state — so concurrent sweep
    cells and per-backend runs stay deterministic regardless of execution
    order.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 scenario: int = -1) -> None:
        self.seed = int(seed)
        self.scenario = scenario
        self.rates = dict(DEFAULT_RATES)
        if rates:
            self.rates.update(rates)
        self.rng = np.random.default_rng(np.random.SeedSequence(self.seed))
        self.events: List[FaultEvent] = []

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> dict:
        """RNG stream position + injected-event trace for a replay
        checkpoint (core/replay.py): a restored plan injects the identical
        remaining fault stream."""
        return {"rng": copy.deepcopy(self.rng.bit_generator.state),
                "events": list(self.events)}

    def set_state(self, state: dict) -> None:
        self.rng.bit_generator.state = copy.deepcopy(state["rng"])
        self.events[:] = list(state["events"])

    def fork(self, label: str, scenario: Optional[int] = None) -> "FaultPlan":
        child = int.from_bytes(
            hashlib.sha256(f"{self.seed}/{label}".encode()).digest()[:8],
            "little")
        return FaultPlan(child, rates=self.rates,
                         scenario=self.scenario if scenario is None
                         else scenario)

    def _inject(self, layer: str, kind: str, detail: str,
                log: Optional[TransactionLog]) -> FaultEvent:
        ev = FaultEvent(self.scenario, layer, kind, detail)
        self.events.append(ev)
        if log is not None:
            log.fault(f"[{kind}] {detail}")
        return ev

    # ------------------------------------------------------- bridge hooks
    def perturb_congestion(self, cfg: CongestionConfig,
                           log: Optional[TransactionLog]
                           ) -> CongestionConfig:
        """Maybe jitter the link parameters (timing-only fault)."""
        if self.rng.random() >= self.rates.get("congestion_perturb", 0.0):
            return cfg
        new = cfg.perturbed(self.rng)
        self._inject(
            "bridge", "congestion_perturb",
            f"link {cfg.link_bytes_per_cycle:.0f}->"
            f"{new.link_bytes_per_cycle:.0f} B/cyc, "
            f"dos {cfg.dos_prob:.2f}->{new.dos_prob:.2f}, "
            f"burst {cfg.max_burst_bytes}->{new.max_burst_bytes}", log)
        return new

    def perturb_bursts(self, txs: List[Transaction],
                       log: Optional[TransactionLog]) -> List[Transaction]:
        """Maybe delay / reorder / split one device burst batch."""
        out = list(txs)
        if not out:
            return out
        r = self.rng
        tag = out[0].tag or out[0].engine
        if len(out) > 1 and r.random() < self.rates["dma_reorder"]:
            perm = r.permutation(len(out))
            out = [out[int(i)] for i in perm]
            self._inject("bridge", "dma_reorder",
                         f"{tag}: permuted {len(out)} bursts", log)
        if r.random() < self.rates["dma_split"]:
            i = int(r.integers(len(out)))
            tx = out[i]
            if tx.nbytes > 1:
                half = tx.nbytes // 2
                out[i:i + 1] = [
                    Transaction(tx.time, tx.engine, tx.kind, tx.addr, half,
                                tag=tx.tag),
                    Transaction(tx.time, tx.engine, tx.kind, tx.addr + half,
                                tx.nbytes - half, tag=tx.tag)]
                self._inject("bridge", "dma_split",
                             f"{tag}: burst @{tx.addr:#x} {tx.nbytes}B -> "
                             f"{half}+{tx.nbytes - half}", log)
        if r.random() < self.rates["dma_delay"]:
            delay = float(r.integers(1, 400))
            for tx in out:
                tx.time += delay
                tx.fault_delay += delay     # stall-attribution bookkeeping
            self._inject("bridge", "dma_delay",
                         f"{tag}: +{delay:.0f} cycles min-issue", log)
        return out

    def perturb_batch(self, batch: "BurstBatch",
                      log: Optional[TransactionLog]) -> "BurstBatch":
        """``perturb_bursts`` over a ``BurstBatch`` — the batched hot
        path's injection hook.  Draw-for-draw identical RNG consumption
        and byte-identical audit strings, so a batch-built stream
        reproduces the scalar fault trace exactly (the faulty_fuzz golden
        trace and tests/test_simspeed.py are the witnesses)."""
        n = len(batch)
        if not n:
            return batch
        r = self.rng
        tag = batch.tag[0] or batch.engine[0]
        if n > 1 and r.random() < self.rates["dma_reorder"]:
            batch.permute(r.permutation(n))
            self._inject("bridge", "dma_reorder",
                         f"{tag}: permuted {n} bursts", log)
        if r.random() < self.rates["dma_split"]:
            i = int(r.integers(len(batch)))
            nb = int(batch.rec["nbytes"][i])
            if nb > 1:
                half = nb // 2
                addr = int(batch.rec["addr"][i])
                batch.split_row(i)
                self._inject("bridge", "dma_split",
                             f"{tag}: burst @{addr:#x} {nb}B -> "
                             f"{half}+{nb - half}", log)
        if r.random() < self.rates["dma_delay"]:
            delay = float(r.integers(1, 400))
            batch.delay(delay)
            self._inject("bridge", "dma_delay",
                         f"{tag}: +{delay:.0f} cycles min-issue", log)
        return batch

    def flip_read(self, data: np.ndarray, tag: str,
                  log: Optional[TransactionLog]) -> bool:
        """Maybe flip one bit of a dev_read payload in place.  Returns True
        when injected; the bridge must then retry (and the retry heals)."""
        if data.nbytes == 0 or self.rng.random() >= self.rates["bitflip_read"]:
            return False
        flat = data.reshape(-1).view(np.uint8)
        byte = int(self.rng.integers(flat.size))
        bit = int(self.rng.integers(8))
        flat[byte] ^= np.uint8(1 << bit)
        self._inject("bridge", "bitflip_read",
                     f"{tag}: byte {byte} bit {bit} flipped (retry healed)",
                     log)
        return True


# --------------------------------------------------------------- scenarios
@dataclasses.dataclass
class Scenario:
    """One randomized fault scenario: a layer plus a pre-generated op list.

    Ops are materialized at generation time (from the scenario's forked
    RNG) so a failing scenario can be re-executed on any *prefix* of its
    ops — the shrinking contract."""
    index: int
    layer: str
    ops: List[Tuple]

    @property
    def label(self) -> str:
        return f"scn{self.index}"


@dataclasses.dataclass
class ScenarioResult:
    index: int
    layer: str
    ok: bool
    failures: List[str]
    faults: List[FaultEvent]
    violations: List[str]
    digest: str                 # sha256 over ops + tx streams + audits
    n_txs: int


@dataclasses.dataclass
class FuzzReport:
    """Outcome of one fuzz run; ``digest`` is the seeded-reproducibility
    witness (same seed => identical digest, fault trace, and logs).
    ``coverage`` accumulates functional-coverage bins across the run
    (core/coverage.py) — the acceptance gate is 100% of the protocol
    bins."""
    seed: int
    results: List[ScenarioResult]
    coverage: Optional[CoverageModel] = None

    @property
    def passed(self) -> bool:
        return all(r.ok for r in self.results)

    def failures(self) -> List[ScenarioResult]:
        return [r for r in self.results if not r.ok]

    def fault_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.results:
            for ev in r.faults:
                out[ev.kind] = out.get(ev.kind, 0) + 1
        return out

    @property
    def digest(self) -> str:
        h = hashlib.sha256()
        for r in self.results:
            h.update(r.digest.encode())
        return h.hexdigest()

    def summary(self) -> dict:
        layers: Dict[str, int] = {}
        for r in self.results:
            layers[r.layer] = layers.get(r.layer, 0) + 1
        return {
            "seed": self.seed,
            "scenarios": len(self.results),
            "by_layer": layers,
            "faults": self.fault_counts(),
            "violations_audited": sum(len(r.violations)
                                      for r in self.results),
            "transactions": sum(r.n_txs for r in self.results),
            "passed": self.passed,
            "failures": [f"scn{r.index}[{r.layer}]: {r.failures[0]}"
                         for r in self.failures()][:8],
            "digest": self.digest[:16],
            "coverage": (self.coverage.summary()
                         if self.coverage is not None else None),
        }


def _digest(*parts: Any) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(repr(p).encode())
    return h.hexdigest()


def _tx_tuples(log: TransactionLog) -> List[Tuple]:
    return [(round(t.time, 6), t.engine, t.kind, t.addr, t.nbytes,
             round(t.stall, 6), round(t.complete, 6)) for t in log.txs]


# ------------------------------------------------ register-layer golden model
_JOB_TICKS = 6          # doorbell job duration, in CSR access ticks

_CTRL, _STATUS, _INT, _DOORBELL, _DATA = 0x00, 0x04, 0x08, 0x0C, 0x10
_UNMAPPED = (0x40, 0x44, 0x80, 0x100)


class _FuzzDevice:
    """Synthetic accelerator control plane for register-protocol fuzzing:
    RW CTRL/DATA, RO STATUS (bit0 = busy, refreshed on read), W1C INT
    (bit0 set on job completion), and a DOORBELL that starts a
    ``_JOB_TICKS``-tick job — ringing it mid-job is a protocol violation
    (the doorbell-while-busy race)."""

    def __init__(self, log: TransactionLog) -> None:
        self.csr = RegisterFile("fuzz.csr", log)
        self.csr.define("CTRL", _CTRL)
        self.csr.define("STATUS", _STATUS, access=RO, on_read=self.tick)
        self.csr.define("INT", _INT, access=W1C)
        self.csr.define("DOORBELL", _DOORBELL, on_write=self.ring)
        self.csr.define("DATA", _DATA)
        self.busy_until = -1.0

    def tick(self) -> None:
        if self.csr.hw_get("STATUS") & 1 and self.csr.time >= self.busy_until:
            self.csr.hw_set("STATUS", 0)
            self.csr.hw_set("INT", self.csr.hw_get("INT") | 1)

    def ring(self, _data: int) -> None:
        self.tick()
        if self.csr.hw_get("STATUS") & 1:
            self.csr.log.violation("DOORBELL while busy (job in flight)")
            return
        self.busy_until = self.csr.time + _JOB_TICKS
        self.csr.hw_set("STATUS", 1)


class _ShadowDevice:
    """Golden model of ``_FuzzDevice`` + its RegisterFile protocol: predicts
    every read value, every poll count, and every violation message.  Any
    disagreement with the real device is a fuzz failure."""

    def __init__(self) -> None:
        self.time = 0.0
        self.val = {_CTRL: 0, _STATUS: 0, _INT: 0, _DOORBELL: 0, _DATA: 0}
        self.busy_until = -1.0
        self.violations: List[str] = []

    def tick(self) -> None:
        if self.val[_STATUS] & 1 and self.time >= self.busy_until:
            self.val[_STATUS] = 0
            self.val[_INT] |= 1

    def read(self, addr: int) -> int:
        self.time += 1
        if addr not in self.val:
            self.violations.append(f"read from unmapped address {addr:#x}")
            return 0xDEADBEEF
        if addr == _STATUS:
            self.tick()
        return self.val[addr]

    def write(self, addr: int, data: int) -> None:
        self.time += 1
        data &= 0xFFFFFFFF
        if addr not in self.val:
            self.violations.append(f"write to unmapped address {addr:#x}")
            return
        if addr == _STATUS:
            self.violations.append(
                f"write to read-only register STATUS @ {addr:#x}")
            return
        if addr == _INT:
            self.val[_INT] &= ~data & 0xFFFFFFFF
            return
        self.val[addr] = data
        if addr == _DOORBELL:
            self.tick()
            if self.val[_STATUS] & 1:
                self.violations.append("DOORBELL while busy (job in flight)")
            else:
                self.busy_until = self.time + _JOB_TICKS
                self.val[_STATUS] = 1

    def poll(self, addr: int, name: str, mask: int, value: int,
             max_reads: int) -> int:
        for n in range(1, max_reads + 1):
            if (self.read(addr) & mask) == value:
                return n
        self.violations.append(f"poll timeout on {name} mask={mask:#x}")
        return -1


# ------------------------------------------------------------- the fuzzer
class ProtocolFuzzer:
    """Randomized fault-injection co-verification harness.

    Usage::

        fz = ProtocolFuzzer(seed=0)
        report = fz.run(200)
        assert report.passed
        report2 = fz.run(200)          # same seed, fresh pass
        assert report2.digest == report.digest

    Scenarios round-robin over the enabled layers; each scenario's ops and
    faults derive from ``fork(seed, scenario-label)`` so runs reproduce
    bit-for-bit.  ``shrink`` minimizes a failing scenario to its shortest
    failing op prefix.
    """

    LAYERS = ("bridge", "registers", "serving", "arrivals")
    SIZES = (32, 48, 64)        # matmul sizes for bridge scenarios
    TILE = 16

    def __init__(self, seed: int = 0,
                 layers: Sequence[str] = ("bridge", "registers"),
                 rates: Optional[Dict[str, float]] = None,
                 backends: Tuple[str, ...] = ("oracle", "interpret",
                                              "compiled"),
                 congestion: Optional[CongestionConfig] = None,
                 engine_factory: Optional[Callable[[], Any]] = None,
                 mm_table: Optional[dict] = None,
                 coverage: Optional[CoverageModel] = None,
                 tol: float = 1e-3,
                 bridge_ops: Tuple[int, int] = (1, 4)) -> None:
        unknown = set(layers) - set(self.LAYERS)
        if unknown:
            raise ValueError(f"unknown fuzz layers: {sorted(unknown)}")
        self.seed = int(seed)
        # [lo, hi) launch count per bridge scenario — the debug-iteration
        # benchmark raises it to make long shrinkable scenarios
        self.bridge_ops = (int(bridge_ops[0]), int(bridge_ops[1]))
        self.layers = tuple(layers)
        self.plan = FaultPlan(seed, rates=rates)
        # functional-coverage accumulator (core/coverage.py): every
        # scenario feeds protocol/burst/congestion/fault bins into it
        self.coverage = coverage if coverage is not None else CoverageModel()
        self.backends = tuple(backends)
        self.congestion = congestion if congestion is not None else \
            CongestionConfig(dos_prob=0.05, seed=seed)
        self.tol = tol
        # mm_table overrides the bridge-layer backend table — the hook the
        # tests and the --shrink demo use to plant a known-buggy backend
        self._table: Optional[dict] = mm_table
        self._engine: Any = None
        self._engine_factory = engine_factory

    # ------------------------------------------------------- lazy backends
    def _matmul_table(self) -> dict:
        if self._table is None:
            from repro.kernels.systolic_matmul.sweep import matmul_backends
            self._table = matmul_backends(tile=self.TILE)
        return self._table

    def _serving_engine(self) -> Any:
        if self._engine is None:
            factory = self._engine_factory or _default_engine
            self._engine = factory()
        return self._engine

    # --------------------------------------------------------- generation
    def scenario(self, i: int) -> Scenario:
        layer = self.layers[i % len(self.layers)]
        rng = self.plan.fork(f"gen/{i}").rng
        gen = {"bridge": self._gen_bridge, "registers": self._gen_registers,
               "serving": self._gen_serving,
               "arrivals": self._gen_arrivals}[layer]
        return Scenario(i, layer, gen(rng))

    def _gen_bridge(self, rng: np.random.Generator) -> List[Tuple]:
        return [("launch", int(rng.choice(self.SIZES)))
                for _ in range(int(rng.integers(*self.bridge_ops)))]

    def _gen_registers(self, rng: np.random.Generator) -> List[Tuple]:
        ops: List[Tuple] = []
        kinds = ["w_ctrl", "w_data", "w_ro", "w_unmapped", "r_mapped",
                 "r_unmapped", "w1c", "doorbell", "poll_idle", "poll_never"]
        weights = np.array([2, 2, 1, 1, 3, 1, 2, 3, 2, 1], float)
        weights /= weights.sum()
        for _ in range(int(rng.integers(6, 28))):
            k = str(rng.choice(kinds, p=weights))
            if k in ("w_ctrl", "w_data", "doorbell"):
                ops.append((k, int(rng.integers(0, 2 ** 32))))
            elif k == "w_ro":
                ops.append((k, int(rng.integers(0, 2 ** 32))))
            elif k == "w_unmapped":
                ops.append((k, int(rng.choice(_UNMAPPED)),
                            int(rng.integers(0, 2 ** 32))))
            elif k == "r_mapped":
                ops.append((k, int(rng.choice(
                    (_CTRL, _STATUS, _INT, _DOORBELL, _DATA)))))
            elif k == "r_unmapped":
                ops.append((k, int(rng.choice(_UNMAPPED))))
            elif k == "w1c":
                ops.append((k, int(rng.integers(0, 4))))
            elif k == "poll_idle":
                # enough reads to outlive a job most of the time; sometimes
                # deliberately too few (forced timeout while busy)
                ops.append((k, int(rng.choice((2, _JOB_TICKS + 4)))))
            else:                                   # poll_never
                ops.append((k, int(rng.integers(2, 5))))
        return ops

    def _kv_budget(self, ln: int) -> int:
        """Max max_new_tokens a prompt of length ln can take: prefill fills
        the padded bucket, each decode appends one KV entry.  Derived from
        the engine's own _pad_len so predictor and implementation cannot
        drift."""
        eng = self._serving_engine()
        return max(1, eng.max_len - eng._pad_len(max(1, ln)) + 1)

    def _gen_serving(self, rng: np.random.Generator) -> List[Tuple]:
        eng = self._serving_engine()
        pad, max_len = eng.prompt_pad, eng.max_len
        ops: List[Tuple] = []
        rid = 0
        kinds = ["ok", "ok", "pad_straddle", "dup_rid", "zero_maxnew",
                 "max_maxnew", "bad_len", "over_budget"]
        for _ in range(int(rng.integers(2, 7))):
            k = str(rng.choice(kinds))
            ln = int(rng.integers(2, max_len + 1))
            budget = self._kv_budget(ln)
            mx = int(rng.integers(1, min(8, budget) + 1))
            if k == "pad_straddle":
                ln = int(np.clip(pad + int(rng.integers(-1, 2)), 1, max_len))
                mx = int(rng.integers(1, min(8, self._kv_budget(ln)) + 1))
            elif k == "zero_maxnew":
                mx = 0
            elif k == "max_maxnew":
                mx = budget                 # the full remaining KV budget
            elif k == "bad_len":
                ln = int(rng.choice((0, max_len + 5)))
            elif k == "over_budget":
                mx = budget + int(rng.integers(1, 5))
            if k == "dup_rid" and rid > 0:
                use = int(rng.integers(0, rid))
            else:
                k = "ok" if k == "dup_rid" else k
                use = rid
                rid += 1
            prompt = tuple(int(x) for x in
                           rng.integers(0, eng.cfg.vocab_size,
                                        max(1, min(ln, max_len))))
            ops.append((k, use, ln, mx, prompt))
        return ops

    def _gen_arrivals(self, rng: np.random.Generator) -> List[Tuple]:
        """Hostile open-loop arrival stream + a randomized KV page-pool
        geometry.  Op 0 is the pool config; each following op is one
        arrival ``(kind, rid, time, prompt, max_new)``.  Kinds: "ok"
        (feasible, Poisson-ish gap), "burst" (feasible, zero gap — lands
        simultaneously with its predecessor), "infeasible" (worst-case
        footprint exceeds the WHOLE pool — must be rejected at the
        doorbell, never deferred forever).  The op list shrinks by prefix
        like every other layer (the pool config op always survives)."""
        eng = self._serving_engine()
        max_len, pad = eng.max_len, eng.prompt_pad
        page_size = int(rng.choice((4, 8)))
        n_pages = int(rng.integers(2, 7))
        pool_entries = n_pages * page_size
        cap = min(pool_entries, max_len)
        ln_cap = max(1, (cap // pad) * pad)     # pad_len(ln_cap) <= cap
        ops: List[Tuple] = [("pool", n_pages, page_size)]
        kinds = ["ok", "ok", "ok", "burst", "infeasible"]
        t, rid = 0.0, 0
        for _ in range(int(rng.integers(2, 9))):
            k = str(rng.choice(kinds))
            t = round(t + (0.0 if k == "burst"
                           else float(rng.exponential(150.0))), 6)
            ln = int(rng.integers(1, ln_cap + 1))
            pl = eng._pad_len(ln)
            if k == "infeasible":
                # footprint pl + mx - 1 in (pool_entries, max_len]: pool-
                # infeasible but inside the engine's KV capacity, so the
                # rejection exercised is the PAGE-POOL one
                lo, hi = pool_entries - pl + 2, max_len - pl + 1
                if pool_entries >= max_len or lo < 1 or lo > hi:
                    k = "ok"
                else:
                    mx = int(rng.integers(lo, hi + 1))
            if k != "infeasible":
                budget = cap - pl + 1
                mx = int(rng.integers(1, min(6, budget) + 1))
            prompt = tuple(int(x) for x in
                           rng.integers(1, eng.cfg.vocab_size, ln))
            ops.append((k, rid, t, prompt, mx))
            rid += 1
        return ops

    # ---------------------------------------------------------- execution
    def run_scenario(self, scn: Scenario) -> ScenarioResult:
        run = {"bridge": self._run_bridge, "registers": self._run_registers,
               "serving": self._run_serving,
               "arrivals": self._run_arrivals}[scn.layer]
        return run(scn)

    def _cover_log(self, log: TransactionLog) -> None:
        """Feed one run's transaction stream into the burst-size and
        congestion coverage bins."""
        for tx in log.txs:
            self.coverage.hit_burst(tx.nbytes)
            self.coverage.hit_congestion(tx.stall)

    def _run_bridge(self, scn: Scenario) -> ScenarioResult:
        table = self._matmul_table()
        from repro.kernels.systolic_matmul import ops as mm_ops
        outs: Dict[str, Dict[str, np.ndarray]] = {}
        faults: List[FaultEvent] = []
        failures: List[str] = []
        streams: List[Tuple] = []
        n_txs = 0
        violations: List[str] = []
        for backend in self.backends:
            plan = self.plan.fork(f"{scn.label}/{backend}",
                                  scenario=scn.index)
            fb = FireBridge(congestion=self.congestion, fault_plan=plan)
            fb.register_op("mm", **table)
            for j, (_, size) in enumerate(scn.ops):
                rng = np.random.default_rng(size * 1009 + j)
                a = rng.normal(size=(size, size)).astype(np.float32)
                b = rng.normal(size=(size, size)).astype(np.float32)
                fb.mem.alloc(f"a{j}", a.shape, np.float32)
                fb.mem.alloc(f"b{j}", b.shape, np.float32)
                fb.mem.alloc(f"c{j}", (size, size), np.float32)
                fb.mem.host_write(f"a{j}", a)
                fb.mem.host_write(f"b{j}", b)
                fb.launch("mm", backend, [f"a{j}", f"b{j}"], [f"c{j}"],
                          engine="mm",
                          burst_list=lambda s=size: mm_ops.transactions(
                              s, s, s, bm=self.TILE, bn=self.TILE,
                              bk=self.TILE, dtype_bytes=4))
            outs[backend] = {n: b.array.copy()
                             for n, b in fb.mem.buffers.items()}
            self._cover_log(fb.log)
            for ev in plan.events:
                self.coverage.hit("fault_kind", ev.kind)
            if len(fb.log.faults) != len(plan.events):
                failures.append(
                    f"audit mismatch on {backend}: {len(plan.events)} "
                    f"injected vs {len(fb.log.faults)} audited")
            faults.extend(plan.events)
            violations.extend(f"[{backend}] {v}" for v in fb.log.violations)
            streams.append((backend, _tx_tuples(fb.log),
                            list(fb.log.faults)))
            n_txs += len(fb.log.txs)
        if violations:
            failures.append(f"unexpected protocol violations: {violations}")
        eq = compare_outputs(outs, tol=self.tol)
        if not eq.passed:
            failures.append(f"backend divergence under faults: {eq}")
        return ScenarioResult(
            scn.index, "bridge", not failures, failures, faults, violations,
            _digest(scn.ops, streams, [e.key() for e in faults]), n_txs)

    def _run_registers(self, scn: Scenario) -> ScenarioResult:
        log = TransactionLog()
        dev = _FuzzDevice(log)
        shadow = _ShadowDevice()
        plan = self.plan.fork(f"{scn.label}/regs", scenario=scn.index)
        failures: List[str] = []
        faults: List[FaultEvent] = []

        def expect(kind: str, detail: str) -> None:
            faults.append(plan._inject("registers", kind, detail, log))

        for op in scn.ops:
            k = op[0]
            if k in ("w_ctrl", "w_data"):
                addr = _CTRL if k == "w_ctrl" else _DATA
                dev.csr.fb_write_32(addr, op[1])
                shadow.write(addr, op[1])
            elif k == "w_ro":
                before = len(shadow.violations)
                dev.csr.fb_write_32(_STATUS, op[1])
                shadow.write(_STATUS, op[1])
                if len(shadow.violations) > before:
                    expect("ro_write", f"STATUS <- {op[1]:#x}")
            elif k == "w_unmapped":
                dev.csr.fb_write_32(op[1], op[2])
                shadow.write(op[1], op[2])
                expect("illegal_write", f"{op[1]:#x} <- {op[2]:#x}")
            elif k == "r_mapped":
                got = dev.csr.fb_read_32(op[1])
                want = shadow.read(op[1])
                if got != want:
                    failures.append(
                        f"read {op[1]:#x}: device {got:#x} != shadow "
                        f"{want:#x}")
            elif k == "r_unmapped":
                got = dev.csr.fb_read_32(op[1])
                want = shadow.read(op[1])
                expect("illegal_read", f"{op[1]:#x}")
                if got != want:
                    failures.append(
                        f"unmapped read {op[1]:#x}: device {got:#x} != "
                        f"shadow {want:#x}")
            elif k == "w1c":
                dev.csr.fb_write_32(_INT, op[1])
                shadow.write(_INT, op[1])
                self.coverage.hit("protocol", "w1c_clear")
            elif k == "doorbell":
                before = len(shadow.violations)
                dev.csr.fb_write_32(_DOORBELL, op[1])
                shadow.write(_DOORBELL, op[1])
                if len(shadow.violations) > before:
                    expect("doorbell_busy", "rang DOORBELL mid-job")
                else:
                    self.coverage.hit("protocol", "doorbell_ok")
            elif k in ("poll_idle", "poll_never"):
                mask, value = (1, 0) if k == "poll_idle" else (2, 2)
                before = len(shadow.violations)
                got = dev.csr.poll("STATUS", mask, value, max_reads=op[1])
                want = shadow.poll(_STATUS, "STATUS", mask, value, op[1])
                if len(shadow.violations) > before:
                    expect("poll_timeout",
                           f"mask={mask:#x} after {op[1]} reads")
                else:
                    self.coverage.hit("protocol", "poll_ok")
                if got != want:
                    failures.append(
                        f"poll({k}): device returned {got}, shadow {want}")
        # violation-path protocol bins come from the recorded expectations
        for ev in faults:
            self.coverage.hit("protocol", ev.kind)
        self._cover_log(log)
        if list(log.violations) != shadow.violations:
            failures.append(
                f"violation audit mismatch: device {log.violations} != "
                f"shadow-predicted {shadow.violations}")
        return ScenarioResult(
            scn.index, "registers", not failures, failures, faults,
            list(log.violations),
            _digest(scn.ops, _tx_tuples(log), list(log.violations),
                    [e.key() for e in faults]), len(log.txs))

    def _run_serving(self, scn: Scenario) -> ScenarioResult:
        eng = self._serving_engine()
        plan = self.plan.fork(f"{scn.label}/serve", scenario=scn.index)
        # explicit storm/unpaged overrides: the shared engine may have run
        # an arrivals scenario (continuous + paged) just before
        eng.reset(fault_plan=plan, batching="storm", kv_pages=None)
        failures: List[str] = []
        expected_viol: List[str] = []
        accepted: Dict[int, int] = {}       # rid -> max_new_tokens

        # stimulus events go to plan.events (the single fault trace, which
        # bridge hooks also append to in op order); the result's faults
        # list is built from it once, after the run
        def expect(kind: str, detail: str, msg: str) -> None:
            plan._inject("serving", kind, detail, None)
            expected_viol.append(msg)

        max_len = eng.max_len
        for kind, rid, ln, mx, prompt in scn.ops:
            eng.mem.buffers["prompt_in"].array[:len(prompt)] = prompt
            eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_ID"), rid)
            eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_LEN"), ln)
            eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_MAXNEW"), mx)
            eng.csr.fb_write_32(eng.csr.addr_of("DOORBELL"), 1)
            pl = eng._pad_len(max(1, ln))
            if ln <= 0 or ln > eng.max_len:
                expect("bad_len", f"rid {rid} len {ln}",
                       f"SUBMIT_LEN out of range: {ln}")
            elif mx <= 0:
                expect("zero_maxnew", f"rid {rid}",
                       f"SUBMIT_MAXNEW must be positive: {mx} "
                       f"(request {rid})")
            elif rid in accepted:
                # no scheduler ticks happen between submissions, so an
                # accepted rid is still in flight here
                expect("dup_rid", f"rid {rid}",
                       f"duplicate SUBMIT_ID {rid}: request still in "
                       f"flight")
            elif pl + mx - 1 > max_len:
                expect("over_budget", f"rid {rid} pl {pl} mx {mx}",
                       f"request {rid} exceeds KV capacity: padded prompt "
                       f"{pl} + {mx} new tokens > max_len {max_len}")
            else:
                accepted[rid] = mx
                if kind == "max_maxnew":
                    plan._inject("serving", "max_maxnew",
                                 f"rid {rid} mx={mx}", None)
                elif kind == "pad_straddle":
                    plan._inject("serving", "pad_straddle",
                                 f"rid {rid} len {ln}", None)
                else:
                    self.coverage.hit("serving", "ok")
        eng.run_until_done()
        for ev in plan.events:
            if ev.layer == "serving":
                self.coverage.hit("serving", ev.kind)
            elif ev.layer == "bridge":
                self.coverage.hit("fault_kind", ev.kind)
        self._cover_log(eng.mem.log)
        faults = list(plan.events)
        n_bridge = sum(1 for e in faults if e.layer == "bridge")
        if len(eng.mem.log.faults) != n_bridge:
            failures.append(
                f"audit mismatch: {n_bridge} bridge faults injected vs "
                f"{len(eng.mem.log.faults)} audited")
        if list(eng.csr.log.violations) != expected_viol:
            failures.append(
                f"violation audit mismatch: engine {eng.csr.log.violations} "
                f"!= predicted {expected_viol}")
        if eng.completed != len(accepted):
            failures.append(f"completed {eng.completed} != accepted "
                            f"{len(accepted)}")
        if eng.csr.hw_get("COMPLETED") != len(accepted) & 0xFFFFFFFF:
            failures.append("COMPLETED CSR out of sync")
        for rid, mx in accepted.items():
            req = eng.requests.get(rid)
            if req is None or not req.done:
                failures.append(f"accepted rid {rid} never completed")
                continue
            if len(req.out_tokens) != mx:
                failures.append(
                    f"rid {rid}: {len(req.out_tokens)} tokens emitted, "
                    f"max_new_tokens={mx}")
        for rid in set(r for _, r, *_ in scn.ops) - set(accepted):
            if rid in eng.requests:
                failures.append(f"rejected rid {rid} leaked into requests")
        tokens = [(rid, tuple(eng.requests[rid].out_tokens))
                  for rid in sorted(accepted) if rid in eng.requests]
        return ScenarioResult(
            scn.index, "serving", not failures, failures, faults,
            list(eng.csr.log.violations),
            _digest(scn.ops, _tx_tuples(eng.mem.log), tokens,
                    list(eng.csr.log.violations),
                    [e.key() for e in faults]), len(eng.mem.log.txs))

    def _run_arrivals(self, scn: Scenario) -> ScenarioResult:
        """Open-loop admission-control differential: drive the scenario's
        hostile arrival stream through a continuous-batching paged engine
        and check the paging invariants — every feasible request retires
        with exactly its token budget, every pool-infeasible request is
        rejected at the doorbell (logged violation, never a silent drop or
        an admission livelock), and after the drain every page is back in
        the free pool."""
        from repro.serving.arrivals import replayed_trace, run_open_loop
        eng = self._serving_engine()
        plan = self.plan.fork(f"{scn.label}/arrivals", scenario=scn.index)
        _, n_pages, page_size = scn.ops[0]
        eng.reset(fault_plan=plan, batching="continuous",
                  kv_pages=n_pages, kv_page_size=page_size,
                  kv_leak_every=0)
        failures: List[str] = []
        feasible: Dict[int, int] = {}       # rid -> max_new_tokens
        infeasible: List[int] = []
        entries = []
        for kind, rid, t, prompt, mx in scn.ops[1:]:
            entries.append((rid, t, prompt, mx))
            if kind == "infeasible":
                infeasible.append(rid)
            else:
                feasible[rid] = mx
        trace = replayed_trace(entries)
        try:
            run_open_loop(eng, trace, max_ticks=5_000)
        except RuntimeError as e:           # admission livelock / no drain
            failures.append(f"open-loop run did not drain: {e}")
        pool = eng.kv_pool
        self.coverage.hit("arrivals", "replay")
        if pool.deferrals:
            self.coverage.hit("arrivals", "deferred", pool.deferrals)
        if pool.peak_in_use == pool.n_pages:
            self.coverage.hit("arrivals", "pool_full")
        viols = list(eng.csr.log.violations)
        rejected = [v for v in viols if "exceeds KV page pool" in v]
        if infeasible:
            self.coverage.hit("arrivals", "infeasible_reject",
                              len(rejected))
        if len(rejected) != len(infeasible):
            failures.append(
                f"{len(infeasible)} pool-infeasible requests, "
                f"{len(rejected)} doorbell rejections: {viols}")
        if len(viols) != len(rejected):
            failures.append(f"unexpected protocol violations: {viols}")
        for rid, mx in feasible.items():
            req = eng.requests.get(rid)
            if req is None or not req.done:
                failures.append(f"feasible rid {rid} never completed")
            elif len(req.out_tokens) != mx:
                failures.append(
                    f"rid {rid}: {len(req.out_tokens)} tokens emitted, "
                    f"max_new_tokens={mx}")
            elif not (req.t_submit <= req.t_admit <= req.t_first
                      <= req.t_done):
                failures.append(
                    f"rid {rid}: non-monotone lifecycle stamps "
                    f"{req.t_submit}/{req.t_admit}/{req.t_first}/"
                    f"{req.t_done}")
        for rid in infeasible:
            if rid in eng.requests:
                failures.append(f"infeasible rid {rid} leaked into the "
                                f"request table")
        if pool.n_free != pool.n_pages:
            failures.append(f"page leak after drain: {pool.n_free}/"
                            f"{pool.n_pages} free")
        if pool.pages:
            failures.append(f"requests still hold pages after drain: "
                            f"{sorted(pool.pages)}")
        self._cover_log(eng.mem.log)
        faults = list(plan.events)
        for ev in faults:
            if ev.layer == "bridge":
                self.coverage.hit("fault_kind", ev.kind)
        tokens = [(rid, tuple(eng.requests[rid].out_tokens))
                  for rid in sorted(feasible) if rid in eng.requests]
        return ScenarioResult(
            scn.index, "arrivals", not failures, failures, faults, viols,
            _digest(scn.ops, _tx_tuples(eng.mem.log), tokens, viols,
                    [e.key() for e in faults]), len(eng.mem.log.txs))

    # ------------------------------------------------------------ driving
    def run(self, n_scenarios: int) -> FuzzReport:
        results = [self.run_scenario(self.scenario(i))
                   for i in range(n_scenarios)]
        return FuzzReport(self.seed, results, coverage=self.coverage)

    def shrink(self, scn: Scenario, use_replay: bool = True,
               checkpoint_every: int = 4) -> Tuple[Scenario, ScenarioResult]:
        """Minimize a failing scenario to its shortest failing op prefix.

        Execution is deterministic given the seed, so a prefix replays
        identically up to its truncation point.  For bridge scenarios the
        candidate prefixes are materialized by **checkpointed window
        replay** (core/replay.py): each backend's full scenario is
        recorded ONCE with a checkpoint every ``checkpoint_every``
        launches, and prefix-k state is restored from the nearest
        checkpoint instead of re-executing ops 1..k from scratch — O(n)
        total ops instead of the old full-re-run-per-prefix O(n²)
        (measured in benchmarks/bench_replay.py).  The winning prefix is
        then re-run once through ``run_scenario`` for an authoritative
        ``ScenarioResult``.  ``use_replay=False`` (and the register/
        serving layers, whose op cost is trivial) keep the linear re-run
        lane."""
        if use_replay and scn.layer == "bridge" and len(scn.ops) > 1:
            got = self._shrink_bridge_replay(scn, max(1, checkpoint_every))
            if got is not None:
                return got
        for k in range(1, len(scn.ops) + 1):
            sub = Scenario(scn.index, scn.layer, scn.ops[:k])
            res = self.run_scenario(sub)
            if not res.ok:
                return sub, res
        return scn, self.run_scenario(scn)

    # ---------------------------------------------- replay-backed shrinking
    _BRIDGE_EVENTS_PER_OP = 6       # alloc x3 + host_write x2 + launch

    def _record_bridge_scenario(self, scn: Scenario, backend: str,
                                checkpoint_every: int):
        """Record one backend's run of a bridge scenario as a replayable
        timeline, checkpointing every ``checkpoint_every`` scenario ops.
        The event stream mirrors ``_run_bridge`` exactly (same buffer
        names, same fault-plan fork, same burst lists), so prefix state
        restored from a checkpoint is bit-identical to a fresh prefix
        re-run."""
        from repro.core import replay as rp
        from repro.kernels.systolic_matmul import ops as mm_ops
        table = self._matmul_table()

        def factory():
            plan = self.plan.fork(f"{scn.label}/{backend}",
                                  scenario=scn.index)
            fb = FireBridge(congestion=self.congestion, fault_plan=plan)
            fb.register_op("mm", **table)
            return fb

        def program(rec):
            for j, (_, size) in enumerate(scn.ops):
                rng = np.random.default_rng(size * 1009 + j)
                a = rng.normal(size=(size, size)).astype(np.float32)
                b = rng.normal(size=(size, size)).astype(np.float32)
                rec.do("alloc", f"a{j}", a.shape, np.float32)
                rec.do("alloc", f"b{j}", b.shape, np.float32)
                rec.do("alloc", f"c{j}", (size, size), np.float32)
                rec.do("host_write", f"a{j}", a)
                rec.do("host_write", f"b{j}", b)
                rec.do("launch", "mm", backend, (f"a{j}", f"b{j}"),
                       (f"c{j}",), "mm",
                       lambda s=size: mm_ops.transactions(
                           s, s, s, bm=self.TILE, bn=self.TILE,
                           bk=self.TILE, dtype_bytes=4), {})
                if (j + 1) % checkpoint_every == 0:
                    rec.checkpoint()

        sess = rp.DebugSession(factory, checkpoint_interval=0,
                               label=f"{scn.label}/{backend}")
        return sess, sess.record(program)

    def _shrink_bridge_replay(self, scn: Scenario, checkpoint_every: int
                              ) -> Optional[Tuple[Scenario, ScenarioResult]]:
        """Find the shortest failing launch prefix via checkpointed prefix
        replay + binary search; None defers to the linear lane (e.g. a
        failure mode the prefix probe cannot see).

        The probe (cross-backend output divergence or a logged violation
        in the prefix state) is MONOTONE in prefix length — a diverged
        buffer stays diverged and the violation list only grows — so the
        shortest failing prefix is found in O(log n) probes, each
        restored from the nearest checkpoint instead of re-executed from
        time zero."""
        recs = {b: self._record_bridge_scenario(scn, b, checkpoint_every)
                for b in self.backends}
        per_op = self._BRIDGE_EVENTS_PER_OP

        def probe(k: int) -> bool:
            outs: Dict[str, Dict[str, np.ndarray]] = {}
            bad = False
            for backend, (sess, rec) in recs.items():
                fb = sess.replay(rec, k * per_op, k * per_op).target
                outs[backend] = {n: b.array.copy()
                                 for n, b in fb.mem.buffers.items()}
                bad = bad or bool(fb.log.violations)
            return bad or not compare_outputs(outs, tol=self.tol).passed

        n = len(scn.ops)
        if not probe(n):
            return None                       # invisible to the probe —
        lo, hi = 0, n                         # defer to the linear lane
        while hi - lo > 1:                    # invariant: probe(hi) fails
            mid = (lo + hi) // 2
            if probe(mid):
                hi = mid
            else:
                lo = mid
        sub = Scenario(scn.index, scn.layer, scn.ops[:hi])
        res = self.run_scenario(sub)          # authoritative re-check
        if not res.ok:
            return sub, res
        return None                          # probe/result disagree —
                                             # defer to the linear lane


def planted_bug_table(tile: int = ProtocolFuzzer.TILE,
                      index: Tuple[int, int] = (1, 2),
                      delta: float = 1.0) -> dict:
    """Matmul backend table with a known interpret-mode divergence at
    ``index`` — the planted bug used to demonstrate/verify that the fuzz
    differential check catches and shrinks real backend disagreements
    (examples/fuzz_protocol.py --inject-bug and tests/test_fuzz.py)."""
    from repro.kernels.systolic_matmul.sweep import matmul_backends
    table = matmul_backends(tile=tile)
    good = table["interpret"]

    def buggy(a, b):
        out = np.array(good(a, b))
        out[index] += delta
        return out
    return dict(table, interpret=buggy)


def _default_engine():
    """Small smoke-config serving engine for the serving fuzz layer (built
    once per fuzzer; jitted prefill/decode are reused across scenarios via
    ``ServingEngine.reset``)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke
    from repro.models import init_params
    from repro.models.transformer import RunFlags
    from repro.serving.engine import ServingEngine
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return ServingEngine(cfg, params, max_slots=3, max_len=32, prompt_pad=8,
                         flags=RunFlags(attn_impl="chunked", q_chunk=16,
                                        kv_chunk=16))


def run_fuzz(seed: int = 0, n_scenarios: int = 50,
             layers: Sequence[str] = ("bridge", "registers"),
             **kw) -> FuzzReport:
    """One-call fuzz run: ``run_fuzz(0, 200, layers=(...,"serving"))``."""
    return ProtocolFuzzer(seed=seed, layers=layers, **kw).run(n_scenarios)
