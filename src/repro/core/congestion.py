"""Memory-congestion emulator (paper §IV-C).

The paper randomizes AXI handshake signals to stress protocol handling.  The
TPU-side adaptation replays a transaction stream through a parameterized
shared-link model with seeded random denial-of-service: engines contend for
interconnect bandwidth, acquire stalls, and the resulting per-engine stall
statistics are the Fig. 8 "memory stalls" series.  Deterministic under a
seed, so congestion regressions are testable.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

from repro.core.transactions import Transaction, TransactionLog


@dataclasses.dataclass(frozen=True)
class CongestionConfig:
    link_bytes_per_cycle: float = 128.0     # shared interconnect width
    base_latency: float = 40.0              # cycles per burst (DDR-ish)
    dos_prob: float = 0.0                   # P(denial-of-service per tx)
    dos_stall: float = 200.0                # cycles withheld on DoS
    per_engine_issue_gap: float = 1.0       # min cycles between issues
    seed: int = 0
    # interconnect arbitration priority per engine (higher wins when
    # contending; ties round-robin) — the paper's "input DMA was given
    # higher priority" experiment (Fig. 8).
    priorities: tuple = ()                  # of (engine, prio) pairs


@dataclasses.dataclass
class CongestionResult:
    makespan: float
    per_engine_stall: Dict[str, float]
    per_engine_busy: Dict[str, float]
    link_utilization: float
    timeline: List[Transaction]

    def summary(self) -> dict:
        return {
            "makespan": self.makespan,
            "link_utilization": round(self.link_utilization, 4),
            "stalls": {k: round(v, 1) for k, v in
                       sorted(self.per_engine_stall.items())},
        }


def simulate(txs: List[Transaction], cfg: CongestionConfig,
             log: Optional[TransactionLog] = None) -> CongestionResult:
    """Replay transactions through one shared link, round-robin arbitration.

    Transactions must be in per-engine program order; `time` fields are used
    as minimum issue times (0 = ASAP).  Mutates tx.stall/tx.complete.
    """
    rng = np.random.default_rng(cfg.seed)
    queues: Dict[str, List[Transaction]] = defaultdict(list)
    for t in txs:
        queues[t.engine].append(t)
    heads = {e: 0 for e in queues}
    ready = {e: 0.0 for e in queues}
    link_free = 0.0
    busy: Dict[str, float] = defaultdict(float)
    stall: Dict[str, float] = defaultdict(float)
    total_bytes = 0
    done: List[Transaction] = []

    prio = dict(cfg.priorities)
    engines = sorted(queues, key=lambda e: (-prio.get(e, 0), e))
    rr = 0
    while any(heads[e] < len(queues[e]) for e in engines):
        # highest-priority engine with pending work; ties round-robin
        pending = [e for e in engines if heads[e] < len(queues[e])]
        top = max(prio.get(e, 0) for e in pending)
        cand = [e for e in pending if prio.get(e, 0) == top]
        e = cand[rr % len(cand)]
        rr += 1
        tx = queues[e][heads[e]]
        heads[e] += 1
        issue = max(ready[e], tx.time)
        start = max(issue, link_free)
        wait = start - issue
        dos = 0.0
        if cfg.dos_prob > 0 and rng.random() < cfg.dos_prob:
            dos = cfg.dos_stall
        xfer = cfg.base_latency + tx.nbytes / cfg.link_bytes_per_cycle
        tx.stall = wait + dos
        tx.complete = start + dos + xfer
        link_free = tx.complete
        ready[e] = tx.complete + cfg.per_engine_issue_gap
        busy[e] += xfer
        stall[e] += tx.stall
        total_bytes += tx.nbytes
        done.append(tx)
        if log is not None:
            log.log(tx)

    makespan = max((t.complete for t in done), default=0.0)
    util = (total_bytes / cfg.link_bytes_per_cycle) / makespan if makespan else 0.0
    return CongestionResult(
        makespan=makespan,
        per_engine_stall=dict(stall),
        per_engine_busy=dict(busy),
        link_utilization=util,
        timeline=done,
    )


def collective_stream_to_txs(collectives, time_scale: float = 1.0
                             ) -> List[Transaction]:
    """Adapt an hlo_profiler collective stream into congestion-model
    transactions (engine = collective kind): stress-replays the compiled
    program's communication schedule under contention."""
    txs = []
    t = 0.0
    for c in collectives:
        for r in range(min(c.multiplier, 1000)):    # cap replay length
            txs.append(Transaction(t, c.kind, "read", 0, c.bytes_moved))
            t += time_scale
    return txs
