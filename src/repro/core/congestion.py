"""Memory-congestion emulation: shared-link contention model (paper §IV-C).

The paper randomizes AXI handshake signals to stress protocol handling.  The
TPU-side adaptation pushes a transaction stream through a parameterized
shared-link model with seeded random denial-of-service: engines contend for
interconnect bandwidth, acquire stalls, and the resulting per-engine stall
statistics are the Fig. 8 "memory stalls" series.  Deterministic under a
seed, so congestion regressions are testable.

Two entry points share one arbitration core:

* ``LinkModel`` — the *online* model.  A ``MemoryBridge`` constructed with a
  ``CongestionConfig`` owns one and routes every device access and burst
  list through it as the firmware runs, so ``bridge.time``, per-engine
  stalls, and makespan reflect Fig. 8 semantics live, with no post-hoc
  replay step.
* ``simulate`` — the *offline* replay.  Feeds a complete recorded stream
  through a fresh ``LinkModel`` in one batch; used for what-if re-runs of a
  logged stream under a different link configuration.

Feeding a stream to ``simulate`` and submitting the same stream as a single
``LinkModel.submit`` batch produce identical timing — they are the same
loop (see tests/test_core_bridge.py::test_online_matches_offline_replay).

Arbitration is vectorized (docs/performance.md): grant order is computed
in closed form per round-robin phase, DoS draws and transfer latencies in
one numpy pass per batch, and only the serial timing recurrence remains a
(lean) Python loop — bit-identical to the retained ``_submit_scalar``
reference, witnessed by the differential tier (tests/test_simspeed.py).
"""
from __future__ import annotations

import copy
import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

from repro.core.transactions import BurstBatch, Transaction, TransactionLog


@dataclasses.dataclass(frozen=True)
class CongestionConfig:
    """Shared-interconnect parameters (paper §IV-C / Fig. 8).

    ``priorities`` reproduces the paper's "input DMA was given higher
    priority" experiment: higher values win arbitration when contending;
    ties fall back to round-robin.  ``dos_prob``/``dos_stall`` are the
    seeded denial-of-service injection (the AXI-handshake randomization
    analogue).  ``max_burst_bytes`` splits whole-buffer device transfers
    into link-level bursts so a large ``dev_read`` contends at burst
    granularity rather than monopolizing the link in one transaction.
    """
    link_bytes_per_cycle: float = 128.0     # shared interconnect width
    base_latency: float = 40.0              # cycles per burst (DDR-ish)
    dos_prob: float = 0.0                   # P(denial-of-service per tx)
    dos_stall: float = 200.0                # cycles withheld on DoS
    per_engine_issue_gap: float = 1.0       # min cycles between issues
    seed: int = 0
    # interconnect arbitration priority per engine (higher wins when
    # contending; ties round-robin) — the paper's "input DMA was given
    # higher priority" experiment (Fig. 8).
    priorities: tuple = ()                  # of (engine, prio) pairs
    # split device transfers into bursts of at most this many bytes when
    # routed through the online link (0 = never split).
    max_burst_bytes: int = 4096

    def perturbed(self, rng: "np.random.Generator") -> "CongestionConfig":
        """Seeded jitter of the link parameters — the fault plan's
        ``congestion_perturb`` kind (core/fuzz.py).

        Bandwidth/latency scale by [0.5, 2.0), DoS probability jitters
        upward, burst granularity halves or doubles, and the DoS seed is
        re-drawn.  Timing-only: functional DDR contents are unaffected, so
        backend equivalence must survive any perturbation.
        """
        return dataclasses.replace(
            self,
            link_bytes_per_cycle=max(
                1.0, self.link_bytes_per_cycle * float(rng.uniform(0.5, 2.0))),
            base_latency=self.base_latency * float(rng.uniform(0.5, 2.0)),
            dos_prob=float(np.clip(self.dos_prob + rng.uniform(0.0, 0.2),
                                   0.0, 0.9)),
            per_engine_issue_gap=self.per_engine_issue_gap
            * float(rng.uniform(0.5, 2.0)),
            max_burst_bytes=max(256, int(self.max_burst_bytes
                                         * float(rng.choice([0.5, 1.0, 2.0])))),
            seed=int(rng.integers(0, 2 ** 31)),
        )


@dataclasses.dataclass
class CongestionResult:
    """Per-run link statistics — the Fig. 8 stall/utilization series."""
    makespan: float
    per_engine_stall: Dict[str, float]
    per_engine_busy: Dict[str, float]
    link_utilization: float
    timeline: List[Transaction]

    def summary(self) -> dict:
        return {
            "makespan": self.makespan,
            "link_utilization": round(self.link_utilization, 4),
            "stalls": {k: round(v, 1) for k, v in
                       sorted(self.per_engine_stall.items())},
        }


class LinkModel:
    """Stateful shared-link arbiter — the online congestion model (§IV-C).

    One instance models one interconnect.  ``submit`` arbitrates a batch of
    transactions (a kernel burst list, or a single device access) against
    the link state left by every earlier batch: per-engine ready times, the
    link-free horizon, the round-robin pointer, and the seeded DoS stream
    all persist across submissions, so firmware-program-order contention is
    modeled exactly as it happens.

    Within a batch, arbitration is priority-then-round-robin per engine,
    identical to the paper's interconnect arbiter; per-engine program order
    is always preserved.  Mutates each transaction's ``stall``/``complete``
    fields in place.

    Three submission paths, one arbitration semantics:

    * ``_submit_scalar`` — the original per-burst Python loop, retained
      verbatim as the differential reference (tests/test_simspeed.py).
    * ``submit`` — the vectorized object path over ``List[Transaction]``.
    * ``submit_batch`` — the array path over a ``BurstBatch``; appends the
      arbitrated batch as a lazy segment to the timeline and the log.
    """

    def __init__(self, cfg: CongestionConfig) -> None:
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        self._prio = dict(cfg.priorities)
        self._link_free = 0.0
        self._ready: Dict[str, float] = defaultdict(float)
        self._busy: Dict[str, float] = defaultdict(float)
        self._stall: Dict[str, float] = defaultdict(float)
        self._total_bytes = 0
        # running DoS total, folded per grant in grant order — the same
        # float sequence the profiler folds per channel, so the counter
        # layer's dos_cycles is bit-exact against stall attribution
        self._dos_total = 0.0
        self._rr = 0
        self._timeline: List[Transaction] = []
        self._tl_pending: List[BurstBatch] = []

    @property
    def now(self) -> float:
        """Link-free horizon: completion time of the last transfer."""
        return self._link_free

    @property
    def timeline(self) -> List[Transaction]:
        """Arbitration-order transaction timeline.  Batch-submitted
        segments materialize on first read (profiler/result paths); the
        hot path appends lazily."""
        if self._tl_pending:
            for b in self._tl_pending:
                self._timeline.extend(b.materialize())
            self._tl_pending.clear()
        return self._timeline

    # ------------------------------------------------------- scalar reference
    def _submit_scalar(self, txs: List[Transaction],
                       log: Optional[TransactionLog] = None) -> float:
        """The original per-burst arbitration loop, retained verbatim as
        the bit-exactness reference for the vectorized paths.  Semantics
        documentation lives here: ``submit``/``submit_batch`` must match
        this loop's output (and RNG/rr side effects) exactly."""
        cfg = self.cfg
        queues: Dict[str, List[Transaction]] = defaultdict(list)
        for t in txs:
            queues[t.engine].append(t)
        heads = {e: 0 for e in queues}
        engines = sorted(queues, key=lambda e: (-self._prio.get(e, 0), e))
        last = self._link_free
        while any(heads[e] < len(queues[e]) for e in engines):
            # highest-priority engine with pending work; ties round-robin
            pending = [e for e in engines if heads[e] < len(queues[e])]
            top = max(self._prio.get(e, 0) for e in pending)
            cand = [e for e in pending if self._prio.get(e, 0) == top]
            e = cand[self._rr % len(cand)]
            self._rr += 1
            tx = queues[e][heads[e]]
            heads[e] += 1
            issue = max(self._ready[e], tx.time)
            start = max(issue, self._link_free)
            wait = start - issue
            dos = 0.0
            if cfg.dos_prob > 0 and self._rng.random() < cfg.dos_prob:
                dos = cfg.dos_stall
            xfer = cfg.base_latency + tx.nbytes / cfg.link_bytes_per_cycle
            tx.stall = wait + dos
            tx.dos = dos            # DoS component, for stall attribution
            tx.complete = start + dos + xfer
            self._link_free = tx.complete
            self._ready[e] = tx.complete + cfg.per_engine_issue_gap
            self._busy[e] += xfer
            self._stall[e] += tx.stall
            self._dos_total += dos
            self._total_bytes += tx.nbytes
            self.timeline.append(tx)
            last = tx.complete
            if log is not None:
                log.log(tx)
        return last

    # ------------------------------------------------------ vectorized core
    def _grant_order(self, n: int,
                     by_eng: Dict[str, List[int]]) -> Optional[np.ndarray]:
        """Grant order for one batch as source indices, advancing the
        round-robin pointer exactly as the scalar loop does.

        Grant order is timing-independent (priority, round-robin pointer,
        and per-engine queue lengths fully determine it), so it can be
        computed in closed form: within a candidate set of size ``k`` at
        round-robin phase ``r``, the engine at position ``p`` is granted
        at steps ``(p - r) % k, +k, +2k, ...`` until the first engine
        empties, which ends the phase.  Returns None for the single-engine
        fast path (grant order = program order; note the scalar loop still
        advances ``_rr`` once per grant even then)."""
        prio = self._prio
        if len(by_eng) == 1:
            self._rr += n
            return None
        engines = sorted(by_eng, key=lambda e: (-prio.get(e, 0), e))
        order = np.empty(n, dtype=np.int64)
        base = 0
        rr = self._rr
        gi = 0
        while gi < len(engines):
            # one priority group at a time, strictly descending
            p0 = prio.get(engines[gi], 0)
            gj = gi
            while gj < len(engines) and prio.get(engines[gj], 0) == p0:
                gj += 1
            group = engines[gi:gj]
            gi = gj
            rem = [len(by_eng[e]) for e in group]
            cons = [0] * len(group)
            cand = list(range(len(group)))
            while cand:
                k = len(cand)
                r = rr % k
                # phase length: steps until the first candidate empties
                best = None
                for pos, ci in enumerate(cand):
                    s_p = (pos - r) % k
                    end = s_p + (rem[ci] - 1) * k
                    if best is None or end < best:
                        best = end
                L = best + 1
                nxt = []
                for pos, ci in enumerate(cand):
                    s_p = (pos - r) % k
                    g = 0 if L <= s_p else (L - 1 - s_p) // k + 1
                    if g:
                        ids = by_eng[group[ci]]
                        order[base + s_p: base + s_p + g * k: k] = \
                            ids[cons[ci]:cons[ci] + g]
                        cons[ci] += g
                        rem[ci] -= g
                    if rem[ci]:
                        nxt.append(ci)
                base += L
                rr += L
                cand = nxt
        self._rr = rr
        return order

    def _dos_draws(self, n: int) -> Optional[List[float]]:
        """One DoS draw per grant, in grant order — ``Generator.random(n)``
        consumes the bit stream identically to n scalar ``random()`` calls,
        so the RNG state matches the scalar loop after every batch."""
        cfg = self.cfg
        if cfg.dos_prob <= 0:
            return None
        hits = self._rng.random(n) < cfg.dos_prob
        if not hits.any():
            return None     # all-zero stalls: callers may skip the column
        return np.where(hits, cfg.dos_stall, 0.0).tolist()

    def submit(self, txs: List[Transaction],
               log: Optional[TransactionLog] = None) -> float:
        """Arbitrate one batch of transactions through the shared link.

        Transactions must be in per-engine program order; ``time`` fields
        are minimum issue times (0 = ASAP).  Returns the completion time of
        the last transaction in the batch.

        Vectorized object path: grant order + DoS draws + transfer
        latencies are computed per batch; the serial timing recurrence
        (each burst's start depends on the previous completion) runs over
        plain floats in the exact scalar FP-operation order, so results
        are bit-identical to ``_submit_scalar``.
        """
        cfg = self.cfg
        n = len(txs)
        if n == 0:
            return self._link_free
        by_eng: Dict[str, List[int]] = {}
        for i, t in enumerate(txs):
            e = t.engine
            if e in by_eng:
                by_eng[e].append(i)
            else:
                by_eng[e] = [i]
        order = self._grant_order(n, by_eng)
        granted = list(txs) if order is None \
            else [txs[i] for i in order.tolist()]
        dos_l = self._dos_draws(n) or [0.0] * n
        xfer_l = (cfg.base_latency +
                  np.array([t.nbytes for t in granted], dtype=np.float64)
                  / cfg.link_bytes_per_cycle).tolist()
        link_free = self._link_free
        gap = cfg.per_engine_issue_gap
        ready, busy, stall_acc = self._ready, self._busy, self._stall
        dos_total = self._dos_total
        total = 0
        for i, tx in enumerate(granted):
            e = tx.engine
            r = ready[e]
            t = tx.time
            issue = r if r >= t else t
            start = issue if issue >= link_free else link_free
            d = dos_l[i]
            x = xfer_l[i]
            st = (start - issue) + d
            comp = start + d + x
            tx.stall = st
            tx.dos = d
            tx.complete = comp
            link_free = comp
            ready[e] = comp + gap
            busy[e] += x
            stall_acc[e] += st
            dos_total += d
            total += tx.nbytes
        self._link_free = link_free
        self._dos_total = dos_total
        self._total_bytes += total
        self.timeline.extend(granted)
        if log is not None:
            log.extend(granted)
        return link_free

    def submit_batch(self, batch: BurstBatch,
                     log: Optional[TransactionLog] = None) -> float:
        """Array path: arbitrate one ``BurstBatch`` through the link.

        Same semantics as ``submit`` but end-to-end over columns — the
        batch is permuted into grant order in place, the recurrence runs
        over plain floats pulled from the columns, results are written
        back per column, and the batch is appended as a *lazy* segment to
        the timeline and ``log`` (shared, so materialized Transaction
        objects alias between the two exactly as object submission does).
        Returns the completion time of the last burst.
        """
        cfg = self.cfg
        n = len(batch)
        if n == 0:
            return self._link_free
        eng = batch.engine
        if len(set(eng)) == 1:
            # single-engine fast path — same rr bookkeeping as the scalar
            # loop (one advance per grant) without the index-map build
            self._rr += n
        else:
            by_eng: Dict[str, List[int]] = {}
            for i, e in enumerate(eng):
                if e in by_eng:
                    by_eng[e].append(i)
                else:
                    by_eng[e] = [i]
            order = self._grant_order(n, by_eng)
            if order is not None:
                batch.permute(order)
                eng = batch.engine
        rec = batch.rec
        dos_l = self._dos_draws(n)
        # transfer latency over plain floats: same IEEE ops per element as
        # the numpy column expression, cheaper at real batch sizes
        lbpc = cfg.link_bytes_per_cycle
        bl = cfg.base_latency
        nb_l = rec["nbytes"].tolist()
        xfer_l = [bl + nb / lbpc for nb in nb_l]
        times_l = rec["time"].tolist()
        link_free = self._link_free
        gap = cfg.per_engine_issue_gap
        ready, busy, stall_acc = self._ready, self._busy, self._stall
        stall_l = [0.0] * n
        comp_l = [0.0] * n
        if dos_l is None:
            for i in range(n):
                e = eng[i]
                r = ready[e]
                t = times_l[i]
                issue = r if r >= t else t
                start = issue if issue >= link_free else link_free
                x = xfer_l[i]
                st = start - issue
                comp = start + x
                stall_l[i] = st
                comp_l[i] = comp
                link_free = comp
                ready[e] = comp + gap
                busy[e] += x
                stall_acc[e] += st
        else:
            dos_total = self._dos_total
            for i in range(n):
                e = eng[i]
                r = ready[e]
                t = times_l[i]
                issue = r if r >= t else t
                start = issue if issue >= link_free else link_free
                d = dos_l[i]
                x = xfer_l[i]
                st = (start - issue) + d
                comp = start + d + x
                stall_l[i] = st
                comp_l[i] = comp
                link_free = comp
                ready[e] = comp + gap
                busy[e] += x
                stall_acc[e] += st
                dos_total += d
            # the no-DoS branch skips the fold: x + 0.0 == x bitwise, so
            # the accumulated value is identical to the scalar reference
            self._dos_total = dos_total
            rec["dos"] = dos_l
        rec["stall"] = stall_l
        rec["complete"] = comp_l
        self._link_free = link_free
        self._total_bytes += sum(nb_l)
        # lazy append: ordering vs already-materialized entries is safe
        # because every object-path extend goes through the flushing
        # ``timeline`` property first
        self._tl_pending.append(batch)
        if log is not None:
            log.log_batch(batch)
        return link_free

    # ------------------------------------------------------ counter probes
    # Read-only accessors for the always-on counter layer
    # (core/counters.py).  The per-engine folds are summed in sorted-
    # engine order so the probe is deterministic and, each term being a
    # non-decreasing non-negative fold, monotone across samples.
    def counter_bytes(self) -> int:
        return self._total_bytes

    def counter_busy(self) -> float:
        busy = self._busy
        t = 0.0
        for e in sorted(busy):
            t += busy[e]
        return t

    def counter_stall(self) -> float:
        stall = self._stall
        t = 0.0
        for e in sorted(stall):
            t += stall[e]
        return t

    def counter_dos(self) -> float:
        return self._dos_total

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> dict:
        """Snapshot of the arbiter for a replay checkpoint
        (core/replay.py): RNG stream position, link-free horizon,
        per-engine ready/busy/stall, the round-robin pointer, and the
        timeline (so ``result()`` stays correct after a restore).  A
        restored link arbitrates future batches bit-identically to the
        original run.  Timeline entries are shared, not copied — a
        transaction is mutated only before arbitration, so the logged
        prefix is immutable and checkpointing stays O(n) per snapshot."""
        return {
            "rng": copy.deepcopy(self._rng.bit_generator.state),
            "link_free": self._link_free,
            "ready": dict(self._ready),
            "busy": dict(self._busy),
            "stall": dict(self._stall),
            "total_bytes": self._total_bytes,
            "dos_total": self._dos_total,
            "rr": self._rr,
            "timeline": list(self.timeline),
        }

    def set_state(self, state: dict) -> None:
        self._rng.bit_generator.state = copy.deepcopy(state["rng"])
        self._link_free = state["link_free"]
        self._ready = defaultdict(float, state["ready"])
        self._busy = defaultdict(float, state["busy"])
        self._stall = defaultdict(float, state["stall"])
        self._total_bytes = state["total_bytes"]
        self._dos_total = state.get("dos_total", 0.0)
        self._rr = state["rr"]
        # restored entries are aliased, not re-copied: transactions are
        # immutable once arbitrated (mutation happens pre-submit), and the
        # restore path is the replay hot loop
        self._tl_pending.clear()
        self._timeline[:] = state["timeline"]

    def result(self) -> CongestionResult:
        """Snapshot the Fig. 8 statistics accumulated so far."""
        makespan = max((t.complete for t in self.timeline), default=0.0)
        util = ((self._total_bytes / self.cfg.link_bytes_per_cycle)
                / makespan if makespan else 0.0)
        return CongestionResult(
            makespan=makespan,
            per_engine_stall=dict(self._stall),
            per_engine_busy=dict(self._busy),
            link_utilization=util,
            timeline=list(self.timeline),
        )


def simulate(txs: List[Transaction], cfg: CongestionConfig,
             log: Optional[TransactionLog] = None) -> CongestionResult:
    """Offline replay (§IV-C): a recorded stream through a fresh link.

    Transactions must be in per-engine program order; ``time`` fields are
    used as minimum issue times (0 = ASAP).  Mutates tx.stall/tx.complete.
    Identical timing to submitting the same stream as one ``LinkModel``
    batch — both run the same arbitration core.
    """
    lm = LinkModel(cfg)
    lm.submit(txs, log)
    return lm.result()


def collective_stream_to_txs(collectives, time_scale: float = 1.0
                             ) -> List[Transaction]:
    """Adapt an hlo_profiler collective stream into congestion-model
    transactions (engine = collective kind): stress-replays the compiled
    program's communication schedule under contention."""
    txs = []
    t = 0.0
    for c in collectives:
        for r in range(min(c.multiplier, 1000)):    # cap replay length
            txs.append(Transaction(t, c.kind, "read", 0, c.bytes_moved))
            t += time_scale
    return txs
