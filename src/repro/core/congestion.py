"""Memory-congestion emulation: shared-link contention model (paper §IV-C).

The paper randomizes AXI handshake signals to stress protocol handling.  The
TPU-side adaptation pushes a transaction stream through a parameterized
shared-link model with seeded random denial-of-service: engines contend for
interconnect bandwidth, acquire stalls, and the resulting per-engine stall
statistics are the Fig. 8 "memory stalls" series.  Deterministic under a
seed, so congestion regressions are testable.

Two entry points share one arbitration core:

* ``LinkModel`` — the *online* model.  A ``MemoryBridge`` constructed with a
  ``CongestionConfig`` owns one and routes every device access and burst
  list through it as the firmware runs, so ``bridge.time``, per-engine
  stalls, and makespan reflect Fig. 8 semantics live, with no post-hoc
  replay step.
* ``simulate`` — the *offline* replay.  Feeds a complete recorded stream
  through a fresh ``LinkModel`` in one batch; used for what-if re-runs of a
  logged stream under a different link configuration.

Feeding a stream to ``simulate`` and submitting the same stream as a single
``LinkModel.submit`` batch produce identical timing — they are the same
loop (see tests/test_core_bridge.py::test_online_matches_offline_replay).
"""
from __future__ import annotations

import copy
import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

from repro.core.transactions import Transaction, TransactionLog


@dataclasses.dataclass(frozen=True)
class CongestionConfig:
    """Shared-interconnect parameters (paper §IV-C / Fig. 8).

    ``priorities`` reproduces the paper's "input DMA was given higher
    priority" experiment: higher values win arbitration when contending;
    ties fall back to round-robin.  ``dos_prob``/``dos_stall`` are the
    seeded denial-of-service injection (the AXI-handshake randomization
    analogue).  ``max_burst_bytes`` splits whole-buffer device transfers
    into link-level bursts so a large ``dev_read`` contends at burst
    granularity rather than monopolizing the link in one transaction.
    """
    link_bytes_per_cycle: float = 128.0     # shared interconnect width
    base_latency: float = 40.0              # cycles per burst (DDR-ish)
    dos_prob: float = 0.0                   # P(denial-of-service per tx)
    dos_stall: float = 200.0                # cycles withheld on DoS
    per_engine_issue_gap: float = 1.0       # min cycles between issues
    seed: int = 0
    # interconnect arbitration priority per engine (higher wins when
    # contending; ties round-robin) — the paper's "input DMA was given
    # higher priority" experiment (Fig. 8).
    priorities: tuple = ()                  # of (engine, prio) pairs
    # split device transfers into bursts of at most this many bytes when
    # routed through the online link (0 = never split).
    max_burst_bytes: int = 4096

    def perturbed(self, rng: "np.random.Generator") -> "CongestionConfig":
        """Seeded jitter of the link parameters — the fault plan's
        ``congestion_perturb`` kind (core/fuzz.py).

        Bandwidth/latency scale by [0.5, 2.0), DoS probability jitters
        upward, burst granularity halves or doubles, and the DoS seed is
        re-drawn.  Timing-only: functional DDR contents are unaffected, so
        backend equivalence must survive any perturbation.
        """
        return dataclasses.replace(
            self,
            link_bytes_per_cycle=max(
                1.0, self.link_bytes_per_cycle * float(rng.uniform(0.5, 2.0))),
            base_latency=self.base_latency * float(rng.uniform(0.5, 2.0)),
            dos_prob=float(np.clip(self.dos_prob + rng.uniform(0.0, 0.2),
                                   0.0, 0.9)),
            per_engine_issue_gap=self.per_engine_issue_gap
            * float(rng.uniform(0.5, 2.0)),
            max_burst_bytes=max(256, int(self.max_burst_bytes
                                         * float(rng.choice([0.5, 1.0, 2.0])))),
            seed=int(rng.integers(0, 2 ** 31)),
        )


@dataclasses.dataclass
class CongestionResult:
    """Per-run link statistics — the Fig. 8 stall/utilization series."""
    makespan: float
    per_engine_stall: Dict[str, float]
    per_engine_busy: Dict[str, float]
    link_utilization: float
    timeline: List[Transaction]

    def summary(self) -> dict:
        return {
            "makespan": self.makespan,
            "link_utilization": round(self.link_utilization, 4),
            "stalls": {k: round(v, 1) for k, v in
                       sorted(self.per_engine_stall.items())},
        }


class LinkModel:
    """Stateful shared-link arbiter — the online congestion model (§IV-C).

    One instance models one interconnect.  ``submit`` arbitrates a batch of
    transactions (a kernel burst list, or a single device access) against
    the link state left by every earlier batch: per-engine ready times, the
    link-free horizon, the round-robin pointer, and the seeded DoS stream
    all persist across submissions, so firmware-program-order contention is
    modeled exactly as it happens.

    Within a batch, arbitration is priority-then-round-robin per engine,
    identical to the paper's interconnect arbiter; per-engine program order
    is always preserved.  Mutates each transaction's ``stall``/``complete``
    fields in place.
    """

    def __init__(self, cfg: CongestionConfig) -> None:
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        self._prio = dict(cfg.priorities)
        self._link_free = 0.0
        self._ready: Dict[str, float] = defaultdict(float)
        self._busy: Dict[str, float] = defaultdict(float)
        self._stall: Dict[str, float] = defaultdict(float)
        self._total_bytes = 0
        self._rr = 0
        self.timeline: List[Transaction] = []

    @property
    def now(self) -> float:
        """Link-free horizon: completion time of the last transfer."""
        return self._link_free

    def submit(self, txs: List[Transaction],
               log: Optional[TransactionLog] = None) -> float:
        """Arbitrate one batch of transactions through the shared link.

        Transactions must be in per-engine program order; ``time`` fields
        are minimum issue times (0 = ASAP).  Returns the completion time of
        the last transaction in the batch.
        """
        cfg = self.cfg
        queues: Dict[str, List[Transaction]] = defaultdict(list)
        for t in txs:
            queues[t.engine].append(t)
        heads = {e: 0 for e in queues}
        engines = sorted(queues, key=lambda e: (-self._prio.get(e, 0), e))
        last = self._link_free
        while any(heads[e] < len(queues[e]) for e in engines):
            # highest-priority engine with pending work; ties round-robin
            pending = [e for e in engines if heads[e] < len(queues[e])]
            top = max(self._prio.get(e, 0) for e in pending)
            cand = [e for e in pending if self._prio.get(e, 0) == top]
            e = cand[self._rr % len(cand)]
            self._rr += 1
            tx = queues[e][heads[e]]
            heads[e] += 1
            issue = max(self._ready[e], tx.time)
            start = max(issue, self._link_free)
            wait = start - issue
            dos = 0.0
            if cfg.dos_prob > 0 and self._rng.random() < cfg.dos_prob:
                dos = cfg.dos_stall
            xfer = cfg.base_latency + tx.nbytes / cfg.link_bytes_per_cycle
            tx.stall = wait + dos
            tx.dos = dos            # DoS component, for stall attribution
            tx.complete = start + dos + xfer
            self._link_free = tx.complete
            self._ready[e] = tx.complete + cfg.per_engine_issue_gap
            self._busy[e] += xfer
            self._stall[e] += tx.stall
            self._total_bytes += tx.nbytes
            self.timeline.append(tx)
            last = tx.complete
            if log is not None:
                log.log(tx)
        return last

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> dict:
        """Snapshot of the arbiter for a replay checkpoint
        (core/replay.py): RNG stream position, link-free horizon,
        per-engine ready/busy/stall, the round-robin pointer, and the
        timeline (so ``result()`` stays correct after a restore).  A
        restored link arbitrates future batches bit-identically to the
        original run.  Timeline entries are shared, not copied — a
        transaction is mutated only before arbitration, so the logged
        prefix is immutable and checkpointing stays O(n) per snapshot."""
        return {
            "rng": copy.deepcopy(self._rng.bit_generator.state),
            "link_free": self._link_free,
            "ready": dict(self._ready),
            "busy": dict(self._busy),
            "stall": dict(self._stall),
            "total_bytes": self._total_bytes,
            "rr": self._rr,
            "timeline": list(self.timeline),
        }

    def set_state(self, state: dict) -> None:
        self._rng.bit_generator.state = copy.deepcopy(state["rng"])
        self._link_free = state["link_free"]
        self._ready = defaultdict(float, state["ready"])
        self._busy = defaultdict(float, state["busy"])
        self._stall = defaultdict(float, state["stall"])
        self._total_bytes = state["total_bytes"]
        self._rr = state["rr"]
        # restored entries are aliased, not re-copied: transactions are
        # immutable once arbitrated (mutation happens pre-submit), and the
        # restore path is the replay hot loop
        self.timeline[:] = state["timeline"]

    def result(self) -> CongestionResult:
        """Snapshot the Fig. 8 statistics accumulated so far."""
        makespan = max((t.complete for t in self.timeline), default=0.0)
        util = ((self._total_bytes / self.cfg.link_bytes_per_cycle)
                / makespan if makespan else 0.0)
        return CongestionResult(
            makespan=makespan,
            per_engine_stall=dict(self._stall),
            per_engine_busy=dict(self._busy),
            link_utilization=util,
            timeline=list(self.timeline),
        )


def simulate(txs: List[Transaction], cfg: CongestionConfig,
             log: Optional[TransactionLog] = None) -> CongestionResult:
    """Offline replay (§IV-C): a recorded stream through a fresh link.

    Transactions must be in per-engine program order; ``time`` fields are
    used as minimum issue times (0 = ASAP).  Mutates tx.stall/tx.complete.
    Identical timing to submitting the same stream as one ``LinkModel``
    batch — both run the same arbitration core.
    """
    lm = LinkModel(cfg)
    lm.submit(txs, log)
    return lm.result()


def collective_stream_to_txs(collectives, time_scale: float = 1.0
                             ) -> List[Transaction]:
    """Adapt an hlo_profiler collective stream into congestion-model
    transactions (engine = collective kind): stress-replays the compiled
    program's communication schedule under contention."""
    txs = []
    t = 0.0
    for c in collectives:
        for r in range(min(c.multiplier, 1000)):    # cap replay length
            txs.append(Transaction(t, c.kind, "read", 0, c.bytes_moved))
            t += time_scale
    return txs
