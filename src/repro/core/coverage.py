"""Functional-coverage model over co-verification stimulus (the paper's
"did the randomized testing actually exercise the protocol?" question,
turned into explicit coverage bins the way RTL verification closes
coverage before signoff).

Groups and bins are *declared up front* — a hit on an unknown bin raises,
so the bin set cannot silently drift from the stimulus generators:

  protocol    — register-protocol events (doorbell-while-busy, W1C clear
                edges, RO writes, unmapped accesses, poll outcomes)
  burst_size  — transaction-size buckets (CSR words up to >4K DMA bursts)
  congestion  — link arbitration states seen by transactions
  fault_kind  — injected bridge-fault taxonomy (mirrors
                fuzz.DEFAULT_RATES; tests/test_coverage.py pins the two
                sets together)
  fabric      — multi-device interconnect operations (core/fabric.py)
  serving     — serving-submit protocol outcomes (fuzz serving layer)
  arrivals    — open-loop arrival/admission outcomes (serving/arrivals.py
                process shapes + KV-pool admission-control events)
  topology    — interconnect shape a fabric run routed through
                (crossbar default or a core/topology.py builder)
  hops        — switch-hop count per routed journey (h0 = endpoints on
                one switch, h3plus = deep routes)
  credit_stall— credit-based flow control outcomes at switch ports
                (granted immediately vs. waited for a credit)

``ProtocolFuzzer`` feeds it while scenarios run and ``FabricCluster``
feeds it from fabric transfers; the fuzz acceptance run must reach 100%
of the protocol bins, and ``report()`` names any hole.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

PROTOCOL_BINS = ("doorbell_ok", "doorbell_busy", "ro_write", "w1c_clear",
                 "illegal_read", "illegal_write", "poll_ok", "poll_timeout")
# (bin name, inclusive upper bound in bytes); None = unbounded
BURST_BUCKETS: Tuple[Tuple[str, Optional[int]], ...] = (
    ("le_64B", 64), ("le_1KB", 1024), ("le_4KB", 4096), ("gt_4KB", None))
CONGESTION_BINS = ("free", "stalled")
FAULT_BINS = ("dma_delay", "dma_reorder", "dma_split", "bitflip_read",
              "congestion_perturb")
FABRIC_BINS = ("dev_copy", "scatter", "broadcast", "gather", "all_reduce")
SERVING_BINS = ("ok", "bad_len", "zero_maxnew", "dup_rid", "over_budget",
                "max_maxnew", "pad_straddle")
# open-loop arrival-process outcomes (serving/arrivals.py): which process
# shapes ran, whether admission control ever deferred, whether the pool
# saturated, and whether a doorbell-time infeasible request was rejected
ARRIVALS_BINS = ("poisson", "bursty", "replay", "deferred", "pool_full",
                 "infeasible_reject")
# crossbar plus core/topology.py's TOPOLOGY_KINDS (tests pin the two sets)
TOPOLOGY_BINS = ("crossbar", "ring", "torus2d", "fat_tree")
HOP_BINS = ("h0", "h1", "h2", "h3plus")
CREDIT_BINS = ("granted", "waited")

GROUPS: Dict[str, Tuple[str, ...]] = {
    "protocol": PROTOCOL_BINS,
    "burst_size": tuple(name for name, _ in BURST_BUCKETS),
    "congestion": CONGESTION_BINS,
    "fault_kind": FAULT_BINS,
    "fabric": FABRIC_BINS,
    "serving": SERVING_BINS,
    "arrivals": ARRIVALS_BINS,
    "topology": TOPOLOGY_BINS,
    "hops": HOP_BINS,
    "credit_stall": CREDIT_BINS,
}


class CoverageModel:
    """Hit counters over the declared coverage groups.

    ``hit()`` is thread-safe: one model may be shared as the sink of
    concurrent sweep cells / fuzz scenarios on a thread pool
    (``CoVerifySession.run``), where the unguarded ``counts[g][b] += n``
    read-modify-write used to lose updates between the load and the
    store.  The lock is intentionally per-model and held only for the
    increment; cross-process campaigns (repro/runfarm) instead give every
    worker a private model and ``merge()`` them deterministically."""

    def __init__(self) -> None:
        self.counts: Dict[str, Dict[str, int]] = {
            g: {b: 0 for b in bins} for g, bins in GROUPS.items()}
        self._lock = threading.Lock()

    # locks are not picklable; a model shipped across processes (runfarm
    # result records) re-grows a fresh one on arrival
    def __getstate__(self) -> dict:
        state = dict(self.__dict__)
        del state["_lock"]
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._lock = threading.Lock()

    # ------------------------------------------------------------- feeding
    def hit(self, group: str, bin_name: str, n: int = 1) -> None:
        """Record ``n`` hits; unknown group/bin raises (drift guard)."""
        bins = self.counts.get(group)
        if bins is None:
            raise KeyError(f"unknown coverage group {group!r}")
        if bin_name not in bins:
            raise KeyError(
                f"unknown bin {bin_name!r} in group {group!r} "
                f"(declared: {sorted(bins)})")
        with self._lock:
            bins[bin_name] += n

    def hit_burst(self, nbytes: int) -> None:
        """Bucket one transaction by burst size."""
        for name, bound in BURST_BUCKETS:
            if bound is None or nbytes <= bound:
                self.hit("burst_size", name)
                return

    def hit_congestion(self, stall: float) -> None:
        """Bucket one arbitrated transaction by its congestion outcome."""
        self.hit("congestion", "stalled" if stall > 0 else "free")

    def hit_hops(self, n_hops: int) -> None:
        """Bucket one routed journey by its switch-hop count."""
        self.hit("hops", f"h{n_hops}" if n_hops < 3 else "h3plus")

    def merge(self, other: "CoverageModel") -> "CoverageModel":
        for g, bins in other.counts.items():
            for b, n in bins.items():
                if n:
                    self.hit(g, b, n)
        return self

    # --------------------------------------------------- (de)serialization
    def to_counts(self) -> Dict[str, Dict[str, int]]:
        """Sparse JSON-friendly snapshot: only nonzero bins, for the
        runfarm's per-unit result records (one line of JSON per unit)."""
        with self._lock:
            return {g: {b: n for b, n in bins.items() if n}
                    for g, bins in self.counts.items()
                    if any(bins.values())}

    @classmethod
    def from_counts(cls, counts: Dict[str, Dict[str, int]]
                    ) -> "CoverageModel":
        model = cls()
        for g, bins in counts.items():
            for b, n in bins.items():
                model.hit(g, b, int(n))
        return model

    def merge_counts(self, counts: Dict[str, Dict[str, int]]) -> List[str]:
        """Merge a sparse snapshot; returns the ``group.bin`` names this
        merge newly covered (count 0 -> >0) — the signal the runfarm's
        coverage-guided scheduler prioritizes seeds by."""
        new: List[str] = []
        for g in sorted(counts):
            for b in sorted(counts[g]):
                n = int(counts[g][b])
                if n:
                    if self.counts[g][b] == 0:
                        new.append(f"{g}.{b}")
                    self.hit(g, b, n)
        return new

    # ------------------------------------------------------------- queries
    def percent(self, group: str) -> float:
        bins = self.counts[group]
        return 100.0 * sum(1 for n in bins.values() if n) / len(bins)

    def covered(self, group: str) -> bool:
        return all(n > 0 for n in self.counts[group].values())

    def holes(self, group: Optional[str] = None) -> List[str]:
        """Uncovered bins as ``group.bin`` names (all groups by default)."""
        groups = [group] if group is not None else sorted(self.counts)
        return [f"{g}.{b}" for g in groups
                for b, n in self.counts[g].items() if n == 0]

    def summary(self) -> Dict[str, dict]:
        return {g: {"percent": round(self.percent(g), 1),
                    "hits": sum(bins.values()),
                    "holes": self.holes(g)}
                for g, bins in self.counts.items()}

    def report(self, groups: Optional[List[str]] = None) -> str:
        """Human-readable coverage table; every hole is named explicitly
        (an unexercised bin that hides is a bin that never closes)."""
        names = groups if groups is not None else sorted(self.counts)
        lines = ["coverage (group: covered/total = percent [hits])"]
        all_holes: List[str] = []
        for g in names:
            bins = self.counts[g]
            cov = sum(1 for n in bins.values() if n)
            lines.append(f"  {g:12s} {cov}/{len(bins)} = "
                         f"{self.percent(g):5.1f}%  "
                         f"[{sum(bins.values())} hits]")
            all_holes += self.holes(g)
        if all_holes:
            lines.append("  UNCOVERED: " + ", ".join(all_holes))
        else:
            lines.append("  no uncovered bins")
        return "\n".join(lines)
