"""Modeled packet/flit switch layer (FireSim ``switch.cc``/``flit.h``
idiom on the congestion core).

A ``Topology`` (core/topology.py) is pure structure; this module is the
*state*: one ``SwitchPort`` per directed inter-switch link, each owning

* a ``LinkModel`` (core/congestion.py) — flit arbitration rides the same
  vectorized ``submit_batch`` pipeline as every other modeled channel,
  with its own seeded DoS stream, so per-hop stalls come out of the one
  arbitration core the differential tier already gates bit-exactly; and
* a **credit window** — credit-based flow control a la FireSim: the port
  models ``credits`` ingress-buffer slots downstream.  A flit batch may
  not enter the port until a slot frees, i.e. until the oldest
  still-in-flight flit among the last ``credits`` completes.  The wait is
  accounted separately (``credit_stall``) from arbitration stalls, and
  the window is part of ``get_state``/``set_state`` so time-travel replay
  restores flow-control state exactly.

Flit framing: a transfer leg reaching a switch hop is re-burst at
``topology.flit_bytes`` granularity (``BurstBatch.from_runs`` with the
flit step), so a 4 KB DMA leg contends at the switch as a train of flits
rather than one monolithic transfer — finer-grained interleaving than
the endpoint links' ``max_burst_bytes`` framing.

The credit window keeps only the ``credits`` *largest* in-flight
completion times: the gate is "wait until the oldest of the last
``credits`` flits completes", and any entry older than those can never
be the gate, so the truncation is exact, not an approximation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core.congestion import CongestionConfig, LinkModel
from repro.core.topology import Topology

__all__ = ["SwitchPort", "SwitchFabric"]

# switch-port DoS streams are decorrelated from the endpoint links, which
# use seed..seed+n_devices (core/fabric.py): a shared stream would stall
# every hop of a journey at the same draws — artificially coherent
# contention across the network
_PORT_SEED_BASE = 1009


class SwitchPort:
    """One switch egress port: flit arbitration + credit flow control."""

    def __init__(self, label: str, cfg: CongestionConfig,
                 credits: int) -> None:
        self.label = label
        self.link = LinkModel(cfg)
        self.credits = max(1, credits)
        # completion times of the newest `credits` flits through the port,
        # sorted ascending — the credit window
        self._inflight: List[float] = []
        self.credit_stall = 0.0
        self.credit_waits = 0
        self.credit_grants = 0

    def acquire(self, ready: float) -> float:
        """Earliest time a flit batch arriving at ``ready`` may enter the
        port: immediately if a credit is free, else when the oldest
        windowed flit completes.  Accounts the wait as credit stall."""
        win = self._inflight
        if len(win) >= self.credits and win[0] > ready:
            issue = win[0]
            self.credit_stall += issue - ready
            self.credit_waits += 1
            return issue
        self.credit_grants += 1
        return ready

    def release(self, completions: List[float]) -> None:
        """Fold a submitted batch's per-flit completion times into the
        credit window (keeping the ``credits`` largest is exact — see
        module docstring)."""
        merged = sorted(self._inflight + completions)
        self._inflight = merged[-self.credits:]

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict[str, Any]:
        return {
            "link": self.link.get_state(),
            "inflight": list(self._inflight),
            "credit_stall": self.credit_stall,
            "credit_waits": self.credit_waits,
            "credit_grants": self.credit_grants,
        }

    def set_state(self, state: Dict[str, Any]) -> None:
        self.link.set_state(state["link"])
        self._inflight = list(state["inflight"])
        self.credit_stall = state["credit_stall"]
        self.credit_waits = state["credit_waits"]
        self.credit_grants = state["credit_grants"]


class SwitchFabric:
    """The routed interconnect's modeled state: every switch port of a
    ``Topology``, plus endpoint→route resolution (``'h'`` = the host
    staging DDR, attached at ``topology.host_attach``)."""

    def __init__(self, topology: Topology,
                 link_config: CongestionConfig) -> None:
        self.topology = topology
        self.ports = [
            SwitchPort(topology.edge_label(k),
                       dataclasses.replace(
                           link_config,
                           seed=link_config.seed + _PORT_SEED_BASE + k),
                       topology.credits)
            for k in range(len(topology.edges))]

    # -------------------------------------------------------------- routing
    def _switch_of(self, endpoint) -> int:
        if endpoint == "h":
            return self.topology.host_attach
        return self.topology.attach[endpoint]

    def route_ports(self, src, dst) -> List[SwitchPort]:
        """Switch ports along the static route between two endpoints
        (device index or ``'h'``), in traversal order."""
        return [self.ports[k] for k in self.topology.route_switches(
            self._switch_of(src), self._switch_of(dst))]

    # ---------------------------------------------------------- diagnostics
    def labeled_links(self) -> Iterator[Tuple[str, LinkModel]]:
        """(label, LinkModel) per port — profiler channels / link_stats."""
        for p in self.ports:
            yield p.label, p.link

    def total_credit_stall(self) -> float:
        return sum(p.credit_stall for p in self.ports)

    def port_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-hop readout: arbitration stall, credit stall, and traffic
        per switch port (bench_fabric_scaling's per-hop columns)."""
        out: Dict[str, Dict[str, float]] = {}
        for p in self.ports:
            r = p.link.result()
            out[p.label] = {
                "stall": sum(r.per_engine_stall.values()),
                "credit_stall": p.credit_stall,
                "busy": sum(r.per_engine_busy.values()),
                "flits": len(r.timeline),
            }
        return out

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict[str, Any]:
        return {"ports": [p.get_state() for p in self.ports]}

    def set_state(self, state: Dict[str, Any]) -> None:
        for p, s in zip(self.ports, state["ports"]):
            p.set_state(s)
