"""Time-travel replay & divergence-bisection debug engine (the paper's
headline 50x *debug iteration* speedup, §I/§V, made concrete).

Detecting a hardware/firmware divergence is cheap in this repo (golden
traces, equivalence groups, fuzz storms); *localizing* one used to mean a
full re-run from time zero.  FERIVer and ZynqParrot both showed that
checkpointed, window-scoped re-execution is what makes cycle-accurate
co-verification usable for debugging at scale — this module is that layer:

* **Timeline** — a ``DebugSession`` records a co-verification run as a
  deterministic sequence of ``TimelineEvent``s (bridge transactions,
  register-protocol accesses, serving scheduler ticks, fabric transfers —
  fault injections and congestion/link evolution ride along because they
  are functions of the replayed state).
* **Checkpoints** — at configurable transaction-boundary intervals the
  session snapshots FULL target state via the ``get_state``/``set_state``
  hooks grown on every stateful layer (bridge DDR + alloc cursor + clock,
  ``LinkModel`` arbiter + DoS RNG stream, ``FaultPlan`` RNG + event trace,
  CSR values + protocol clock, serving caches/slots/queues, every fabric
  port).
* **Window replay** — ``replay(rec, lo, hi)`` restores the nearest
  checkpoint at or before ``lo`` and re-executes events up to ``hi``.
  Because every RNG stream and clock is restored, the regenerated window
  is **bit-identical** to the original run — witnessed by
  ``TransactionLog.digest()``: a full-range replay rebuilds logs whose
  digests equal the original's exactly, and any window's canonical lines
  equal the recording's stored slice.
* **Bisection** — ``bisect_divergence(run_a, run_b)`` localizes the first
  divergent transaction between two recordings of the same timeline
  (e.g. oracle vs interpret, live vs last-known-good) WITHOUT a full
  re-run: it binary-searches the stored checkpoints (free probes — the
  snapshots are already in the recording), then replays only the one
  divergent window on each side and walks the two regenerated streams in
  lockstep.  Total cost: O(log N) probe comparisons + 2 window replays,
  comfortably inside the ``ceil(log2(N)) + 2`` replay budget the
  regression tests enforce by instrumentation (``DebugSession.replays``).

Two divergence modes are handled uniformly:

* **trace** divergence — the transaction streams differ (timing, order,
  addresses): first differing canonical line, named with its owning event.
* **state** divergence — the streams agree but DDR/CSR/token state
  differs (a wrong writeback value, the planted-bug case): checkpoints
  are compared by *functional fingerprint* (buffer contents, register
  values, request state — timing excluded, so legitimately
  timing-perturbed runs don't false-positive), and the lockstep window
  walk names the first event after which the fingerprints part.

Consumers: ``CoVerifySession`` attaches a ``DivergenceReport`` to failing
sweep cells, ``tests/test_golden_traces.py`` replays the window around a
trace mismatch and prints surrounding device state,
``ProtocolFuzzer.shrink`` replays candidate prefixes from the nearest
checkpoint instead of re-executing whole scenarios, and
``record_serving_storm`` records/replays serving-engine storms.
"""
from __future__ import annotations

import bisect as _bisect
import dataclasses
import hashlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bridge import FireBridge
from repro.core.fabric import FabricCluster
from repro.core.transactions import TransactionLog

__all__ = [
    "TimelineEvent", "Checkpoint", "OpTrace", "Recording", "ReplayWindow",
    "DebugSession", "Recorder", "RecordingBridge", "DivergenceReport",
    "bisect_divergence", "record_serving_storm", "serving_storm_program",
    "record_open_loop", "open_loop_program",
    "apply_event", "target_logs", "state_summary", "window_report",
]


def _hash_lines(lines: List[str]) -> str:
    """THE line-stream digest: one definition shared by recordings and
    replay windows, so the bit-identity contract
    (``ReplayWindow.digest() == Recording.window_digest(lo, hi)``) can
    never drift on formatting."""
    h = hashlib.sha256()
    for line in lines:
        h.update(line.encode())
        h.update(b"\n")
    return h.hexdigest()


# ---------------------------------------------------------------- timeline
@dataclasses.dataclass
class TimelineEvent:
    """One deterministic timeline op: a (kind, args) pair that
    ``apply_event`` can re-execute against a restored target.  Events live
    in memory for the session's lifetime — args may hold arrays and
    burst-list callables."""
    kind: str
    args: Tuple = ()

    def brief(self) -> str:
        """Short human rendering for divergence reports."""
        parts = []
        for a in self.args:
            if isinstance(a, np.ndarray):
                parts.append(f"ndarray{a.shape}")
            elif callable(a):
                parts.append("<fn>")
            elif isinstance(a, (dict, list, tuple)) and len(str(a)) > 40:
                parts.append(f"{type(a).__name__}[{len(a)}]")
            else:
                parts.append(repr(a))
        return f"{self.kind}({', '.join(parts)})"


@dataclasses.dataclass
class Checkpoint:
    """Full target state after ``op_index`` events (``get_state`` dict),
    plus two precomputed identities: ``fingerprint`` covers all
    architectural state (timing included, trace excluded) and
    ``func_fingerprint`` covers functional state only (buffers, CSR
    values, request/token state) — the bisection probe."""
    op_index: int
    state: Dict[str, Any]
    fingerprint: str
    func_fingerprint: str


@dataclasses.dataclass
class OpTrace:
    """One replayed event's observable footprint: the canonical lines it
    emitted, the functional fingerprint after it, and a small state
    summary for divergence reports."""
    op_index: int
    event: TimelineEvent
    lines: List[str]
    func_fingerprint: str
    summary: Dict[str, Any]


class Recording:
    """One recorded run: the event timeline, sparse full-state
    checkpoints, the per-op canonical-line stream, and the final
    digests.  ``replays`` counts how many window replays have been run
    against it — the instrumentation the bisection budget tests read."""

    def __init__(self, label: str, interval: int) -> None:
        self.label = label
        self.interval = interval
        self.events: List[TimelineEvent] = []
        self.checkpoints: List[Checkpoint] = []
        self.preamble: List[str] = []       # construction-time lines
        self.lines: List[str] = []          # op-emitted lines, in op order
        self.line_marks: List[int] = [0]    # lines after i ops (len n+1)
        # per-log cumulative transaction counts after i ops (len n+1 each)
        self.tx_marks: List[List[int]] = []
        self.log_digest = ""                # combined TransactionLog.digest()
        self.final_fingerprint = ""
        self.final_func_fingerprint = ""
        self.replays = 0
        # the live target as record() left it (state = op boundary n_ops);
        # replays build/restore their own target via the session factory
        self.target: Any = None

    @property
    def n_ops(self) -> int:
        return len(self.events)

    def digest(self) -> str:
        """sha256 over the full recorded line stream (preamble + ops)."""
        return _hash_lines(self.preamble + self.lines)

    def op_lines(self, i: int) -> List[str]:
        """Canonical lines emitted by event ``i``."""
        return self.lines[self.line_marks[i]:self.line_marks[i + 1]]

    def window_lines(self, lo: int, hi: int) -> List[str]:
        """Canonical lines emitted by events ``[lo, hi)`` — what a replay
        of that window must reproduce bit-identically."""
        return self.lines[self.line_marks[lo]:self.line_marks[hi]]

    def window_digest(self, lo: int, hi: int) -> str:
        return _hash_lines(self.window_lines(lo, hi))

    def nearest_checkpoint(self, op: int) -> Checkpoint:
        """Last checkpoint at or before op boundary ``op`` (checkpoint 0
        always exists — the freshly constructed target)."""
        best = self.checkpoints[0]
        for ck in self.checkpoints:
            if ck.op_index <= op:
                best = ck
        return best

    def op_of_tx(self, log_index: int, tx_index: int) -> int:
        """Map transaction ``tx_index`` of log ``log_index`` to the event
        that emitted it (-1 = emitted during target construction)."""
        marks = self.tx_marks[log_index]
        if tx_index < marks[0]:
            return -1
        return min(_bisect.bisect_right(marks, tx_index) - 1,
                   self.n_ops - 1)


@dataclasses.dataclass
class ReplayWindow:
    """Outcome of one window replay: per-op traces for ``[lo, hi)`` and
    the live target left at state ``hi`` (ready for inspection)."""
    lo: int
    hi: int
    ops: List[OpTrace]
    target: Any
    from_checkpoint: int

    @property
    def lines(self) -> List[str]:
        return [ln for t in self.ops for ln in t.lines]

    def digest(self) -> str:
        return _hash_lines(self.lines)


# ------------------------------------------------------- state inspection
def _is_cluster_serving(target: Any) -> bool:
    return hasattr(target, "engines") and hasattr(target, "csr")


def _is_serving(target: Any) -> bool:
    return hasattr(target, "slots") and hasattr(target, "step")


def target_logs(target: Any) -> List[TransactionLog]:
    """The target's transaction logs in canonical order (the order golden
    trace files concatenate them)."""
    if isinstance(target, FireBridge):
        return [target.log]
    if isinstance(target, FabricCluster):
        return [target.log] + [d.log for d in target.devices]
    if _is_cluster_serving(target):
        return [target.log] + [e.mem.log for e in target.engines]
    if _is_serving(target):
        return [target.mem.log]
    raise TypeError(f"no replay log mapping for {type(target).__name__}")


def _hash_update(h: "hashlib._Hash", v: Any) -> None:
    if isinstance(v, np.ndarray):
        h.update(f"nd{v.shape}{v.dtype}".encode())
        h.update(np.ascontiguousarray(v).tobytes())
    elif isinstance(v, (bytes, bytearray)):
        h.update(bytes(v))
    elif isinstance(v, float):
        h.update(np.float64(v).tobytes())
    elif isinstance(v, dict):
        for k in sorted(v, key=str):
            h.update(str(k).encode())
            _hash_update(h, v[k])
    elif isinstance(v, (list, tuple)):
        for x in v:
            _hash_update(h, x)
    elif isinstance(v, (set, frozenset)):
        for x in sorted(repr(y) for y in v):
            h.update(x.encode())
    elif dataclasses.is_dataclass(v) and not isinstance(v, type):
        for f in dataclasses.fields(v):
            h.update(f.name.encode())
            _hash_update(h, getattr(v, f.name))
    elif hasattr(v, "tobytes"):            # np scalars, jax arrays
        h.update(np.asarray(v).tobytes())
    else:
        h.update(repr(v).encode())


# state-dict keys that are trace/history, never replay-relevant identity
# ("counters" is the sampled CounterBank stream — derived observation of
# the other state, bit-identically regenerated by replay, so including it
# would only double-count what the log/timing keys already witness)
_TRACE_KEYS = frozenset({"log", "timeline", "counters"})
# additionally excluded from the FUNCTIONAL fingerprint: anything timing-
# or stimulus-stream-shaped, so runs that legitimately differ in timing
# (per-backend fault forks, perturbed congestion) only diverge
# functionally when data actually differs
_TIMING_KEYS = _TRACE_KEYS | frozenset({
    "time", "link", "host_link", "ports", "switch", "rng", "fault_plan",
    "link_plan", "next", "rr", "written", "clock"})
# keys whose subtrees hold USER data (buffer names, register addresses,
# request ids) — exclusion must stop at their boundary, or a buffer that
# happens to be named "time"/"link" would silently vanish from every
# fingerprint
_DATA_KEYS = frozenset({"buffers", "vals", "cache", "requests", "slots",
                        "pending", "placement"})


def _fingerprint(state: Dict[str, Any], exclude: frozenset) -> str:
    h = hashlib.sha256()

    def walk(v: Any, structural: bool) -> None:
        if isinstance(v, dict):
            for k in sorted(v, key=str):
                if structural and str(k) in exclude:
                    continue
                h.update(str(k).encode())
                walk(v[k], structural and str(k) not in _DATA_KEYS)
        elif isinstance(v, (list, tuple)):
            for x in v:
                walk(x, structural)
        else:
            _hash_update(h, v)

    walk(state, True)
    return h.hexdigest()


def state_fingerprint(state: Dict[str, Any]) -> str:
    """Architectural identity of a ``get_state`` snapshot (trace history
    excluded; clocks, RNG streams, and data all included)."""
    return _fingerprint(state, _TRACE_KEYS)


def functional_fingerprint(state: Dict[str, Any]) -> str:
    """Functional identity only: DDR/buffer contents, CSR values, request
    and token state.  Timing, RNG streams, and logs excluded — the
    bisection probe for data divergences under timing-perturbed runs."""
    return _fingerprint(state, _TIMING_KEYS)


def state_summary(target: Any) -> Dict[str, Any]:
    """Small human-facing excerpt of the target's architectural state —
    what a divergence report prints as "surrounding device state"."""
    def bufs(mem, prefix=""):
        return {f"{prefix}{n}": hashlib.sha256(
                    np.ascontiguousarray(b.array).tobytes()).hexdigest()[:12]
                for n, b in sorted(mem.buffers.items())}

    if isinstance(target, FireBridge):
        return {"time": round(target.mem.time, 6),
                "buffers": bufs(target.mem),
                "csr": {r.name: target.csr.hw_get(r.name)
                        for r in target.csr._by_addr.values()},
                "faults": len(target.log.faults),
                "violations": len(target.log.violations)}
    if isinstance(target, FabricCluster):
        out = {"time": round(target.time, 6), "buffers": bufs(target.host,
                                                             "host/")}
        for i, d in enumerate(target.devices):
            out["buffers"].update(bufs(d.mem, f"d{i}/"))
        out["violations"] = len(target.violations)
        return out
    if _is_cluster_serving(target):
        out = {"time": round(target.time, 6), "buffers": bufs(target.mem),
               "completed": target.completed,
               "tokens": {rid: list(r.out_tokens)
                          for rid, r in sorted(target.requests.items())},
               "violations": len(target.violations)}
        pools = {f"e{i}": e.kv_pool.n_free
                 for i, e in enumerate(target.engines)
                 if getattr(e, "kv_pool", None) is not None}
        if pools:
            out["kv_free_pages"] = pools
        return out
    if _is_serving(target):
        out = {"time": round(target.mem.time, 6),
               "buffers": bufs(target.mem),
               "completed": target.completed,
               "tokens": {rid: list(r.out_tokens)
                          for rid, r in sorted(target.requests.items())},
               "violations": len(target.mem.log.violations)}
        if getattr(target, "kv_pool", None) is not None:
            out["kv_free_pages"] = {"e0": target.kv_pool.n_free}
        return out
    raise TypeError(f"no replay summary for {type(target).__name__}")


# --------------------------------------------------------- event execution
def _apply_bridge(fb: FireBridge, ev: TimelineEvent) -> Any:
    k, a = ev.kind, ev.args
    if k == "alloc":
        return fb.mem.alloc(a[0], a[1], a[2])
    if k == "host_write":
        return fb.mem.host_write(a[0], a[1])
    if k == "host_read":
        return fb.mem.host_read(a[0])
    if k == "dev_read":
        return fb.mem.dev_read(a[0], engine=a[1])
    if k == "dev_write":
        return fb.mem.dev_write(a[0], a[1], engine=a[2])
    if k == "log_burst_list":
        return fb.mem.log_burst_list(list(a[0]), base_time=a[1])
    if k == "launch":
        op, backend, in_bufs, out_bufs, engine, burst_list, kw = a
        return fb.launch(op, backend, list(in_bufs), list(out_bufs),
                         engine=engine, burst_list=burst_list, **kw)
    if k == "csr_write":
        return fb.csr.fb_write_32(a[0], a[1])
    if k == "csr_read":
        return fb.csr.fb_read_32(a[0])
    if k == "poll":
        return fb.csr.poll(a[0], a[1], a[2], max_reads=a[3],
                           strict=a[4] if len(a) > 4 else False)
    raise ValueError(f"unknown bridge event kind {k!r}")


def _apply_fabric(fab: FabricCluster, ev: TimelineEvent) -> Any:
    k, a = ev.kind, ev.args
    if k == "host_alloc":
        return fab.host.alloc(a[0], a[1], a[2])
    if k == "host_write":
        return fab.host.host_write(a[0], a[1])
    if k == "dev_alloc":
        return fab.devices[a[0]].mem.alloc(a[1], a[2], a[3])
    if k == "dev_host_write":
        return fab.devices[a[0]].mem.host_write(a[1], a[2])
    if k == "alloc_sharded":
        return fab.alloc_sharded(a[0], a[1], a[2], axis=a[3])
    if k == "scatter":
        return fab.scatter(a[0], axis=a[1])
    if k == "broadcast":
        return fab.broadcast(a[0])
    if k == "gather":
        return fab.gather(a[0], axis=a[1])
    if k == "all_reduce":
        return fab.all_reduce(a[0], op=a[1])
    if k == "dev_copy":
        return fab.dev_copy(a[0], a[1], a[2], dst_name=a[3])
    if k == "collect_replicated":
        return fab.collect_replicated(a[0])
    if k == "launch":
        dev, op, backend, in_bufs, out_bufs, kw = a
        return fab.launch(dev, op, backend, list(in_bufs), list(out_bufs),
                          **kw)
    raise ValueError(f"unknown fabric event kind {k!r}")


def _apply_serving(eng: Any, ev: TimelineEvent) -> Any:
    k, a = ev.kind, ev.args
    if k == "host_poke":
        data = np.asarray(a[1])
        eng.mem.buffers[a[0]].array[:data.size] = data
        return None
    if k == "csr_write":
        return eng.csr.fb_write_32(eng.csr.addr_of(a[0]), a[1])
    if k == "csr_read":
        return eng.csr.fb_read_32(eng.csr.addr_of(a[0]))
    if k == "poll":
        return eng.csr.poll(a[0], a[1], a[2], max_reads=a[3],
                            strict=a[4] if len(a) > 4 else False)
    if k == "step":
        return eng.step()
    if k == "advance":
        return eng.advance_clock(a[0])
    raise ValueError(f"unknown serving event kind {k!r}")


def apply_event(target: Any, ev: TimelineEvent) -> Any:
    """Execute ONE timeline event against a live target.  Record and
    replay both funnel through here, so the two cannot drift."""
    if ev.kind == "call":                  # escape hatch: fn(target, *args)
        return ev.args[0](target, *ev.args[1:])
    if isinstance(target, FireBridge):
        return _apply_bridge(target, ev)
    if isinstance(target, FabricCluster):
        return _apply_fabric(target, ev)
    if _is_cluster_serving(target) or _is_serving(target):
        return _apply_serving(target, ev)
    raise TypeError(f"no replay apply for {type(target).__name__}")


# ------------------------------------------------------------- the session
class Recorder:
    """Handed to a recording program: ``do(kind, *args)`` executes one
    event against the live target AND appends it to the recording (with
    line/tx attribution and interval checkpointing).  ``checkpoint()``
    forces a transaction-boundary checkpoint right now."""

    def __init__(self, session: "DebugSession", target: Any,
                 rec: Recording) -> None:
        self.session = session
        self.target = target
        self.rec = rec
        self.logs = target_logs(target)
        self._cursors = [log.cursor() for log in self.logs]
        # construction-time lines (e.g. congestion_perturb at bridge init)
        for log in self.logs:
            rec.preamble.extend(log.lines_since((0, 0, 0)))
        rec.tx_marks = [[log.n_txs] for log in self.logs]
        self.checkpoint()

    def do(self, kind: str, *args: Any) -> Any:
        ev = TimelineEvent(kind, args)
        out = self.session.apply(self.target, ev)
        self.session.ops_applied += 1
        self.rec.events.append(ev)
        for li, log in enumerate(self.logs):
            self.rec.lines.extend(log.lines_since(self._cursors[li]))
            self._cursors[li] = log.cursor()
            self.rec.tx_marks[li].append(log.n_txs)
        self.rec.line_marks.append(len(self.rec.lines))
        n = self.rec.n_ops
        if self.session.interval and n % self.session.interval == 0:
            self.checkpoint()
        return out

    def checkpoint(self) -> Checkpoint:
        n = self.rec.n_ops
        if self.rec.checkpoints and self.rec.checkpoints[-1].op_index == n:
            return self.rec.checkpoints[-1]
        state = self.target.get_state()
        ck = Checkpoint(n, state, state_fingerprint(state),
                        functional_fingerprint(state))
        self.rec.checkpoints.append(ck)
        return ck


class DebugSession:
    """Record a deterministic co-verification run; replay any window of it
    bit-identically.

    ``factory()`` builds a structurally complete target (ops registered,
    CSRs defined, congestion/fault plan installed from their seeds) in its
    INITIAL state; ``apply(target, event)`` executes one timeline event
    (default: ``apply_event``).  ``checkpoint_interval`` is the op count
    between automatic full-state snapshots (0 = only the initial one and
    explicit ``Recorder.checkpoint()`` calls).

    ``replays`` / ``ops_applied`` are instrumentation counters: the
    bisection budget tests assert on the former, the shrink/benchmark
    economics on the latter.
    """

    def __init__(self, factory: Callable[[], Any],
                 apply: Optional[Callable[[Any, TimelineEvent], Any]] = None,
                 checkpoint_interval: int = 8,
                 label: str = "run") -> None:
        self.factory = factory
        self.apply = apply or apply_event
        self.interval = checkpoint_interval
        self.label = label
        self.replays = 0
        self.ops_applied = 0

    # ----------------------------------------------------------- recording
    def record(self, program: Any) -> Recording:
        """Run ``program`` against a fresh target, recording the timeline.

        ``program`` is either a callable taking the ``Recorder`` (drive
        events via ``rec.do``/``rec.checkpoint``; ``rec.target`` is the
        live object for read-only inspection) or a plain sequence of
        ``TimelineEvent``s / ``(kind, *args)`` tuples.
        """
        target = self.factory()
        rec = Recording(self.label, self.interval)
        recorder = Recorder(self, target, rec)
        if callable(program):
            program(recorder)
        else:
            for ev in program:
                if isinstance(ev, TimelineEvent):
                    recorder.do(ev.kind, *ev.args)
                else:
                    recorder.do(ev[0], *ev[1:])
        final = recorder.checkpoint()
        rec.final_fingerprint = final.fingerprint
        rec.final_func_fingerprint = final.func_fingerprint
        h = hashlib.sha256()
        for log in recorder.logs:
            h.update(log.digest().encode())
        rec.log_digest = h.hexdigest()
        rec.target = target
        return rec

    # ------------------------------------------------------------- replay
    def replay(self, rec: Recording, lo: int, hi: int) -> ReplayWindow:
        """Re-execute events ``[lo, hi)`` from the nearest checkpoint at
        or before ``lo``; returns per-op traces plus the live target left
        at op boundary ``hi``.  ``lo == hi`` replays nothing but still
        materializes the target's state at that boundary (the prefix-
        restore primitive the fuzz shrinker uses).  Bit-identity contract:
        ``ReplayWindow.lines == rec.window_lines(lo, hi)``.
        """
        if not (0 <= lo <= hi <= rec.n_ops):
            raise ValueError(f"window [{lo}, {hi}) outside "
                             f"[0, {rec.n_ops}]")
        ck = rec.nearest_checkpoint(lo)
        target = self.factory()
        target.set_state(ck.state)
        self.replays += 1
        rec.replays += 1
        logs = target_logs(target)
        cursors = [log.cursor() for log in logs]
        ops: List[OpTrace] = []
        for i in range(ck.op_index, hi):
            ev = rec.events[i]
            self.apply(target, ev)
            self.ops_applied += 1
            lines: List[str] = []
            for li, log in enumerate(logs):
                lines.extend(log.lines_since(cursors[li]))
                cursors[li] = log.cursor()
            if i >= lo:
                state = target.get_state()
                ops.append(OpTrace(i, ev, lines,
                                   functional_fingerprint(state),
                                   state_summary(target)))
        return ReplayWindow(lo, hi, ops, target, ck.op_index)


# -------------------------------------------------------- firmware tracing
class _RecordingMem:
    """Memory-bridge facade that records every state-mutating call as a
    timeline event (reads of ``buffers`` pass through untouched)."""

    def __init__(self, rec: Recorder) -> None:
        self._rec = rec

    def alloc(self, name, shape, dtype):
        return self._rec.do("alloc", name, shape, dtype)

    def host_write(self, name, data):
        return self._rec.do("host_write", name, np.asarray(data).copy())

    def host_read(self, name):
        return self._rec.do("host_read", name)

    def dev_read(self, name, engine="dma"):
        return self._rec.do("dev_read", name, engine)

    def dev_write(self, name, data, engine="dma"):
        return self._rec.do("dev_write", name, np.asarray(data).copy(),
                            engine)

    def log_burst_list(self, txs, base_time=None):
        return self._rec.do("log_burst_list", list(txs), base_time)

    def __getattr__(self, attr):
        return getattr(self._rec.target.mem, attr)


class _RecordingCsr:
    """CSR facade: protocol accesses become timeline events; map queries
    (``addr_of``, ``hw_get``) pass through."""

    def __init__(self, rec: Recorder) -> None:
        self._rec = rec

    def fb_write_32(self, addr, data):
        return self._rec.do("csr_write", addr, data)

    def fb_read_32(self, addr):
        return self._rec.do("csr_read", addr)

    def poll(self, name, mask, value, max_reads=10_000, strict=False):
        return self._rec.do("poll", name, mask, value, max_reads, strict)

    def __getattr__(self, attr):
        return getattr(self._rec.target.csr, attr)


class RecordingBridge:
    """FireBridge facade for recording an OPAQUE firmware callable: hand
    this to ``firmware(fb, op, backend, **config)`` instead of the bridge
    and every bridge-level call it makes lands on the timeline — the hook
    ``CoVerifySession`` uses to turn a failing sweep cell into a
    replayable recording without changing the firmware."""

    def __init__(self, rec: Recorder) -> None:
        self._rec = rec
        self._mem = _RecordingMem(rec)
        self._csr = _RecordingCsr(rec)

    @property
    def mem(self):
        return self._mem

    @property
    def csr(self):
        return self._csr

    def launch(self, op, backend, in_bufs, out_bufs, engine="accel",
               burst_list=None, **kw):
        return self._rec.do("launch", op, backend, tuple(in_bufs),
                            tuple(out_bufs), engine, burst_list, dict(kw))

    def __getattr__(self, attr):
        return getattr(self._rec.target, attr)


# ------------------------------------------------------------ serving storm
def serving_storm_program(reqs: Sequence[Tuple[int, Sequence[int], int]],
                          max_ticks: int = 10_000) -> Callable:
    """Build a recording program for a serving storm: each request is a
    ``(rid, prompt, max_new_tokens)`` triple driven through the CSR
    protocol (prompt poke, SUBMIT_*, DOORBELL — one checkpoint per
    submission), then scheduler ticks until drained."""

    def program(rec: Recorder) -> None:
        eng = rec.target
        for rid, prompt, mx in reqs:
            rec.do("host_poke", "prompt_in", np.asarray(prompt, np.int32))
            rec.do("csr_write", "SUBMIT_ID", int(rid))
            rec.do("csr_write", "SUBMIT_LEN", len(prompt))
            rec.do("csr_write", "SUBMIT_MAXNEW", int(mx))
            rec.do("csr_write", "DOORBELL", 1)
            rec.checkpoint()
        pending = (eng._n_pending if _is_cluster_serving(eng)
                   else lambda: len(eng.pending))
        for _ in range(max_ticks):
            if not pending() and not eng._n_active():
                break
            rec.do("step")

    return program


def record_serving_storm(session: DebugSession,
                         reqs: Sequence[Tuple[int, Sequence[int], int]],
                         max_ticks: int = 10_000) -> Recording:
    """Record a serving storm (single engine or cluster — same CSR
    surface) as a replayable timeline."""
    return session.record(serving_storm_program(reqs, max_ticks))


def open_loop_program(trace: Any, max_ticks: int = 200_000) -> Callable:
    """Build a recording program for an open-loop serving run: the
    arrival trace is driven through ``serving.arrivals.drive_open_loop``
    — the SAME decision loop the live driver uses, with ``rec.do`` as the
    event sink — so a recorded run and a live run of one trace emit
    identical timelines (submissions, idle-gap ``advance`` events,
    scheduler ticks)."""
    from repro.serving.arrivals import drive_open_loop

    def program(rec: Recorder) -> None:
        drive_open_loop(rec.do, rec.target, trace, max_ticks)
        rec.checkpoint()

    return program


def record_open_loop(session: DebugSession, trace: Any,
                     max_ticks: int = 200_000) -> Recording:
    """Record an open-loop serving run (single engine or cluster in
    continuous-batching mode) as a replayable timeline."""
    return session.record(open_loop_program(trace, max_ticks))


# ---------------------------------------------------------------- bisection
@dataclasses.dataclass
class DivergenceReport:
    """Where two runs of one timeline first part ways.

    ``kind`` is "trace" (the transaction streams differ — ``line_a`` /
    ``line_b`` hold the first differing canonical lines) or "state" (the
    streams agree but functional state diverged — ``detail`` names the
    first differing leaf).  ``op_index``/``event`` name the divergent
    transaction-boundary op; ``state_a``/``state_b`` are the device-state
    summaries right after it; ``n_replays`` is the instrumented window-
    replay count this localization consumed.
    """
    label_a: str
    label_b: str
    kind: str
    op_index: int
    event: str
    line_index: Optional[int]
    line_a: Optional[str]
    line_b: Optional[str]
    detail: str
    window: Tuple[int, int]
    n_replays: int
    context_a: List[str]
    context_b: List[str]
    state_a: Dict[str, Any]
    state_b: Dict[str, Any]

    def render(self) -> str:
        out = [f"divergence: {self.label_a} vs {self.label_b}",
               f"  first divergent op: #{self.op_index} {self.event} "
               f"({self.kind} divergence)",
               f"  localized via window replay [{self.window[0]}, "
               f"{self.window[1]}) in {self.n_replays} replay(s)"]
        if self.kind == "trace":
            out += [f"  line {self.line_index}:",
                    f"    {self.label_a}: {self.line_a}",
                    f"    {self.label_b}: {self.line_b}"]
        else:
            out.append(f"  {self.detail}")
        if self.context_a:
            out.append(f"  replayed window ({self.label_a}):")
            out += [f"    {ln}" for ln in self.context_a[-6:]]
        out.append(f"  device state after op ({self.label_a} | "
                   f"{self.label_b}):")
        for k in sorted(set(self.state_a) | set(self.state_b)):
            va, vb = self.state_a.get(k), self.state_b.get(k)
            mark = " " if va == vb else "*"
            out.append(f"   {mark}{k}: {va!r} | {vb!r}")
        return "\n".join(out)

    def save(self, path) -> None:
        """Write the rendered report + full replayed window as a debug
        bundle (what CI uploads on tier-1 failure)."""
        from pathlib import Path
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        body = [self.render(), "", f"window lines ({self.label_a}):"]
        body += self.context_a
        body += ["", f"window lines ({self.label_b}):"]
        body += self.context_b
        p.write_text("\n".join(body) + "\n")


def _first_diff(a: List[str], b: List[str]) -> Optional[int]:
    for i in range(min(len(a), len(b))):
        if a[i] != b[i]:
            return i
    return None if len(a) == len(b) else min(len(a), len(b))


def _state_diff_note(sa: Dict[str, Any], sb: Dict[str, Any]) -> str:
    for k in sorted(set(sa) | set(sb)):
        va, vb = sa.get(k), sb.get(k)
        if isinstance(va, dict) and isinstance(vb, dict):
            for kk in sorted(set(va) | set(vb)):
                if va.get(kk) != vb.get(kk):
                    return (f"first differing state leaf: {k}/{kk} = "
                            f"{va.get(kk)!r} vs {vb.get(kk)!r}")
        elif va != vb:
            return f"first differing state leaf: {k} = {va!r} vs {vb!r}"
    return "states differ (fingerprint level)"


def bisect_divergence(session_a: DebugSession, rec_a: Recording,
                      session_b: DebugSession, rec_b: Recording
                      ) -> Optional[DivergenceReport]:
    """Localize the first divergent transaction between two recordings of
    the same timeline in O(log N) checkpoint probes + 2 window replays.

    Checkpoint probes compare the stored functional fingerprints (binary
    search — no re-execution); the per-op line streams give the trace
    candidate for free.  Only the ONE divergent window is then replayed on
    each side, and the two regenerated streams are walked in lockstep to
    name the first event whose emitted lines or functional state differ.
    Returns None when the runs are identical.

    Requires both recordings to cover the same op timeline (same event
    count and checkpoint schedule) — the supported debug scenarios record
    the same firmware/program against two configurations.
    """
    n = min(rec_a.n_ops, rec_b.n_ops)
    base_replays = rec_a.replays + rec_b.replays

    # ---- construction-time divergence (different fault-plan forks /
    # perturbed configs): the streams part before the first op — report
    # the preamble line diff directly, with op-0 state for context
    if rec_a.preamble != rec_b.preamble:
        d = _first_diff(rec_a.preamble, rec_b.preamble)
        wa = session_a.replay(rec_a, 0, min(1, n))
        wb = session_b.replay(rec_b, 0, min(1, n))
        pick = lambda p: p[d] if d < len(p) else "<stream ended>"
        return DivergenceReport(
            rec_a.label, rec_b.label, "preamble", 0,
            rec_a.events[0].brief() if n else "<construction>", d,
            pick(rec_a.preamble), pick(rec_b.preamble),
            "construction-time divergence (fault-plan fork / perturbed "
            "config) precedes the first timeline op", (0, min(1, n)),
            rec_a.replays + rec_b.replays - base_replays,
            wa.lines, wb.lines,
            wa.ops[-1].summary if wa.ops else {},
            wb.ops[-1].summary if wb.ops else {})

    # ---- trace candidate: first op whose emitted lines differ (free)
    trace_op: Optional[int] = None
    for i in range(n):
        if rec_a.op_lines(i) != rec_b.op_lines(i):
            trace_op = i
            break

    # ---- state candidate: binary-search the COMMON stored checkpoints
    # (free probes — snapshots already in the recordings) for the first
    # functional-fingerprint divergence
    a_by_op = {c.op_index: c for c in rec_a.checkpoints if c.op_index <= n}
    b_by_op = {c.op_index: c for c in rec_b.checkpoints if c.op_index <= n}
    common = sorted(set(a_by_op) & set(b_by_op))    # 0 is always present

    def fp_differs(op: int) -> bool:
        return (a_by_op[op].func_fingerprint
                != b_by_op[op].func_fingerprint)

    state_window: Optional[Tuple[int, int]] = None
    if common:
        if fp_differs(common[0]):
            state_window = (0, max(common[0], 1))
        elif fp_differs(common[-1]):
            lo_i, hi_i = 0, len(common) - 1     # invariant: lo ==, hi !=
            while hi_i - lo_i > 1:
                mid = (lo_i + hi_i) // 2
                if fp_differs(common[mid]):
                    hi_i = mid
                else:
                    lo_i = mid
            state_window = (common[lo_i], common[hi_i])
        elif rec_a.final_func_fingerprint != rec_b.final_func_fingerprint:
            state_window = (common[-1], n)      # un-checkpointed tail

    # ---- choose the earliest divergent window.  A state divergence is
    # only known to lie somewhere in (state_lo, state_hi]; if the first
    # trace difference sits beyond state_lo, the true first divergence
    # may be a silent state change before it — so the window must open
    # at state_lo and close at the trace candidate (the lockstep walk
    # checks both lines and fingerprints, whichever comes first wins).
    if trace_op is None and state_window is None:
        if (rec_a.digest() == rec_b.digest()
                and rec_a.final_func_fingerprint
                == rec_b.final_func_fingerprint
                and rec_a.n_ops == rec_b.n_ops):
            return None
        # length mismatch beyond the common prefix
        lo = max((op for op in common if op <= n), default=0)
        hi = n
    elif trace_op is not None and (state_window is None
                                   or trace_op <= state_window[0]):
        lo = rec_a.nearest_checkpoint(trace_op).op_index
        hi = min(trace_op + 1, n)
    elif trace_op is not None:
        lo = state_window[0]
        hi = min(state_window[1], trace_op + 1)
    else:
        lo, hi = state_window

    # ---- replay ONLY the divergent window, once per run (2 replays)
    wa = session_a.replay(rec_a, lo, hi)
    wb = session_b.replay(rec_b, lo, hi)

    report: Optional[DivergenceReport] = None
    for ta, tb in zip(wa.ops, wb.ops):
        d = _first_diff(ta.lines, tb.lines)
        if d is not None:
            report = DivergenceReport(
                rec_a.label, rec_b.label, "trace", ta.op_index,
                ta.event.brief(),
                len(rec_a.preamble) + rec_a.line_marks[ta.op_index] + d,
                ta.lines[d] if d < len(ta.lines) else "<stream ended>",
                tb.lines[d] if d < len(tb.lines) else "<stream ended>",
                "", (lo, hi), 0, [], [], ta.summary, tb.summary)
            break
        if ta.func_fingerprint != tb.func_fingerprint:
            report = DivergenceReport(
                rec_a.label, rec_b.label, "state", ta.op_index,
                ta.event.brief(), None, None, None,
                _state_diff_note(ta.summary, tb.summary),
                (lo, hi), 0, [], [], ta.summary, tb.summary)
            break
    if report is None and rec_a.n_ops != rec_b.n_ops:
        i = min(rec_a.n_ops, rec_b.n_ops)
        longer = rec_a if rec_a.n_ops > rec_b.n_ops else rec_b
        report = DivergenceReport(
            rec_a.label, rec_b.label, "length", i,
            longer.events[i].brief() if i < longer.n_ops else "<end>",
            None, None, None,
            f"timelines diverge in length: {rec_a.n_ops} vs "
            f"{rec_b.n_ops} ops", (lo, hi), 0, [], [],
            wa.ops[-1].summary if wa.ops else {},
            wb.ops[-1].summary if wb.ops else {})
    if report is None:
        # defensive: the chosen window showed nothing observable (e.g. a
        # divergence the functional probe abstracts away) — linear-scan
        # the common-checkpoint windows end to end
        cks = common if common else [0]
        if cks[-1] != n:
            cks = cks + [n]
        for j in range(len(cks) - 1):
            wa = session_a.replay(rec_a, cks[j], cks[j + 1])
            wb = session_b.replay(rec_b, cks[j], cks[j + 1])
            for ta, tb in zip(wa.ops, wb.ops):
                if (ta.lines != tb.lines
                        or ta.func_fingerprint != tb.func_fingerprint):
                    d = _first_diff(ta.lines, tb.lines)
                    report = DivergenceReport(
                        rec_a.label, rec_b.label,
                        "trace" if d is not None else "state",
                        ta.op_index, ta.event.brief(), None,
                        None if d is None else ta.lines[d:d + 1][0]
                        if d < len(ta.lines) else "<stream ended>",
                        None if d is None else tb.lines[d:d + 1][0]
                        if d < len(tb.lines) else "<stream ended>",
                        _state_diff_note(ta.summary, tb.summary),
                        (cks[j], cks[j + 1]), 0, [], [],
                        ta.summary, tb.summary)
                    break
            if report is not None:
                break
        if report is None:
            return None
    report.context_a = wa.lines
    report.context_b = wb.lines
    report.n_replays = (rec_a.replays + rec_b.replays) - base_replays
    return report


def window_report(session: DebugSession, rec: Recording, op_index: int,
                  context: int = 2) -> str:
    """Replay the window around one op and render its transactions plus
    the device state right after it — what the golden-trace tests print
    when a committed trace diverges."""
    lo = max(0, op_index - context)
    hi = min(rec.n_ops, op_index + context + 1)
    w = session.replay(rec, lo, hi)
    out = [f"replayed window [{lo}, {hi}) of {rec.label!r} "
           f"(from checkpoint @op {w.from_checkpoint}):"]
    for t in w.ops:
        mark = ">>" if t.op_index == op_index else "  "
        out.append(f"{mark} op #{t.op_index}: {t.event.brief()}")
        out += [f"     {ln}" for ln in t.lines]
        if t.op_index == op_index:
            out.append("     device state after op:")
            for k, v in sorted(t.summary.items()):
                out.append(f"       {k}: {v!r}")
    return "\n".join(out)
