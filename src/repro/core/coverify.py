"""High-level co-verification driver — the user-facing FireBridge API.

One call takes a kernel (hardware) + oracle (golden model) + firmware
(host-side data movement / register protocol) through the full paper flow:

  1. firmware runs against the ORACLE backend        ("early model")
  2. firmware runs against the INTERPRET backend     ("RTL simulation")
  3. firmware runs against the COMPILED backend      ("deployment")
  4. three-way equivalence on final DDR state
  5. transaction profiling + optional online congestion emulation (§IV-C)
  6. register-protocol violation audit

The measured wall-clock of (2)+(4) is one "debug iteration" in the Fig. 5
reproduction (benchmarks/bench_debug_iteration.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.bridge import FireBridge
from repro.core.congestion import CongestionConfig, CongestionResult
from repro.core.equivalence import EquivalenceReport, compare_outputs
from repro.core.transactions import TransactionLog


@dataclasses.dataclass
class CoverifyResult:
    equivalence: EquivalenceReport
    iteration_seconds: Dict[str, float]
    tx_summary: dict
    protocol_violations: List[str]
    congestion: Optional[CongestionResult] = None

    @property
    def passed(self) -> bool:
        return self.equivalence.passed and not self.protocol_violations


def coverify(firmware: Callable[[FireBridge, str], None],
             ops: Dict[str, dict],
             backends=("oracle", "interpret", "compiled"),
             tol: float = 1e-3,
             congestion: Optional[CongestionConfig] = None) -> CoverifyResult:
    """Run `firmware(bridge, backend)` once per backend on fresh bridges and
    diff the final DDR contents.

    `ops`: {name: dict(oracle=fn, interpret=fn, compiled=fn, burst_list=fn)}
    registered on each bridge before firmware runs.

    With `congestion` set, each bridge runs with the online link model
    (paper §IV-C) so stalls/makespan are produced during the launch; the
    returned `congestion` field is the last backend's live statistics.
    """
    final_state: Dict[str, dict] = {}
    iter_s: Dict[str, float] = {}
    last_bridge: Optional[FireBridge] = None
    violations: List[str] = []

    for be in backends:
        fb = FireBridge(congestion=congestion)
        for name, fns in ops.items():
            fb.register_op(name, **fns)
        t0 = time.perf_counter()
        firmware(fb, be)
        iter_s[be] = time.perf_counter() - t0
        final_state[be] = {n: b.array.copy() for n, b in fb.mem.buffers.items()}
        violations.extend(f"[{be}] {v}" for v in fb.log.violations)
        last_bridge = fb

    eq = compare_outputs(final_state, tol=tol)

    cong = None
    if congestion is not None and last_bridge is not None:
        cong = last_bridge.congestion_stats()

    return CoverifyResult(
        equivalence=eq,
        iteration_seconds=iter_s,
        tx_summary=last_bridge.log.summary() if last_bridge else {},
        protocol_violations=violations,
        congestion=cong,
    )
