"""Off-chip data-movement profiling engine (paper §IV, Figs. 8 and 9).

The paper names off-chip data-movement profiling as one of the three
capabilities a co-verification bridge must provide (§I, alongside memory
congestion emulation and register-level protocol testing).  This module is
that third pillar as a first-class subsystem: a ``DataMovementProfiler``
consumes the transaction streams and link-arbiter state an instrumented
target already carries — a ``FireBridge``/``MemoryBridge``, a
``FabricCluster``, a ``ServingEngine``/``ClusterServingEngine``, or a
replayed ``Recording`` (core/replay.py) — and produces:

* **Exhaustive stall attribution** — every modeled cycle of every channel
  is classified into exactly one category (the taxonomy below), and the
  per-category breakdown sums *exactly* to the channel's modeled
  completion time (``bridge.time`` for the DDR channel) — the closure
  property the regression tests assert.
* **Per-channel / per-engine / per-op timelines** — the Fig. 8 series
  (per-DMA-engine stalls and busy cycles, link utilization) plus per-op
  attribution from the ``profile=`` op marks recorded at launch and
  collective boundaries.
* **Chrome-trace / Perfetto JSON export** — one track per DMA channel,
  fabric port, and serving engine; a stall slice plus a transfer slice
  per burst; bandwidth counter tracks; byte-identical under the same
  seed.  Load the file at https://ui.perfetto.dev (schema documented in
  docs/profiling.md and enforced by ``validate_trace``).
* **Roofline placement** — ``RooflinePlacement`` puts a kernel or a whole
  program on the roofline from its modeled time terms
  (benchmarks/roofline.py renders its tables through it).

Stall-attribution taxonomy (one wall partition per channel):

  ``transfer``       link busy moving a burst, no competing burst waiting
  ``contention``     link busy while >=1 other burst waits for it (the
                     Fig. 8 "memory stalls" source)
  ``serialization``  link idle: next burst's engine still in its
                     per-engine issue gap
  ``dos``            link withheld by the seeded denial-of-service
                     injection (§IV-C)
  ``fault_delay``    link idle: pending burst's min-issue time pushed by
                     an injected ``dma_delay`` fault (core/fuzz.py)
  ``compute``        link idle with no burst submitted — firmware/backend
                     compute with no DMA outstanding (compute overlap)

Closure is by construction: the idle/dos/contention categories are
measured, ``transfer`` is defined as the remainder to the channel horizon,
and an internal consistency check (``ChannelProfile.residual``) verifies
the remainder against the sum of modeled burst transfer times.
"""
from __future__ import annotations

import dataclasses
import json
import math
from collections import defaultdict
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.core.bridge import FireBridge, MemoryBridge
from repro.core.congestion import CongestionConfig, LinkModel
from repro.core.fabric import FabricCluster
from repro.core.transactions import OpMark, Transaction, TransactionLog

__all__ = [
    "CATEGORIES", "StallBreakdown", "EngineStats", "ChannelProfile",
    "DataMovementProfiler", "RooflinePlacement", "profile_recording",
    "profile_window", "validate_trace", "SCHEMA_VERSION",
]

# the exhaustive wall-partition categories, in taxonomy order
CATEGORIES = ("transfer", "contention", "serialization", "dos",
              "fault_delay", "compute")

SCHEMA_VERSION = 1


# --------------------------------------------------------------- breakdown
@dataclasses.dataclass
class StallBreakdown:
    """Exhaustive per-category cycle attribution of one channel (§IV-C).

    ``cycles`` maps every category in ``CATEGORIES`` to modeled cycles;
    the values sum exactly to ``total`` (the channel's modeled completion
    time — ``bridge.time`` for a device DDR channel)."""
    total: float
    cycles: Dict[str, float]

    @classmethod
    def close(cls, total: float, measured: Dict[str, float],
              remainder: str = "transfer") -> "StallBreakdown":
        """Build a closed breakdown: measured categories as given, the
        ``remainder`` category defined as ``total - sum(measured)`` so the
        partition sums exactly to ``total`` by construction."""
        cycles = {c: 0.0 for c in CATEGORIES}
        cycles.update(measured)
        cycles[remainder] = total - sum(v for c, v in cycles.items()
                                        if c != remainder)
        # float fix-up: re-summing in category order can drift by an ulp.
        # Walk the largest category (whose ulp is within one ulp of the
        # total's, so each step moves the fold by at most one ulp) until
        # the left-fold sum equals ``total`` bit-exactly — the closure
        # property the regression tests assert.  The adjustment is a few
        # ulps at most: semantically zero cycles.
        carrier = max(CATEGORIES, key=lambda c: abs(cycles[c]))
        for _ in range(128):
            s = 0.0
            for c in CATEGORIES:
                s += cycles[c]
            if s == total:
                break
            cycles[carrier] = math.nextafter(
                cycles[carrier], math.inf if s < total else -math.inf)
        return cls(total, cycles)

    def fractions(self) -> Dict[str, float]:
        t = self.total or 1.0
        return {c: self.cycles[c] / t for c in CATEGORIES}

    def rows(self) -> List[str]:
        """category,cycles,percent rows (taxonomy order)."""
        out = []
        for c in CATEGORIES:
            v = self.cycles[c]
            out.append(f"{c},{v:.0f},{100.0 * v / (self.total or 1.0):.1f}")
        return out


@dataclasses.dataclass
class EngineStats:
    """Per-engine Fig. 8 series on one channel."""
    transactions: int = 0
    bytes: int = 0
    busy: float = 0.0           # modeled transfer cycles
    contention: float = 0.0     # wait-for-link cycles (stall minus DoS)
    dos: float = 0.0
    fault_delay: float = 0.0
    # fold of tx.stall in grant order — BIT-exactly the arbiter's own
    # per-engine stall accumulator (``CongestionResult.per_engine_stall``
    # and the ``stall_cycles`` counter probe fold the same terms in the
    # same order), where ``contention + dos`` re-associates the sum
    grant_stall: float = 0.0

    @property
    def stall(self) -> float:
        """wait + DoS — matches ``CongestionResult.per_engine_stall``."""
        return self.contention + self.dos


@dataclasses.dataclass
class ChannelProfile:
    """One profiled channel (§IV-C): a shared DDR link, a fabric port,
    the host↔fabric channel, a fast-path logical-clock bridge, or a CSR
    protocol clock (§IV-A) — the unit of the paper's per-interconnect
    Fig. 8 readout.

    ``kind`` is "link" (congestion-arbitrated), "clock" (fast-path
    logical clock), or "csr" (register-protocol clock).  ``horizon`` is
    the channel's modeled completion time; ``breakdown`` partitions
    ``[0, horizon)`` exhaustively.  ``residual`` is the internal
    consistency check: |closing remainder - independently summed transfer
    cycles| (should be ~0; float noise only)."""
    name: str
    kind: str
    horizon: float
    breakdown: StallBreakdown
    engines: Dict[str, EngineStats]
    txs: List[Transaction]
    cfg: Optional[CongestionConfig]
    residual: float

    @property
    def total_bytes(self) -> int:
        return sum(e.bytes for e in self.engines.values())

    @property
    def utilization(self) -> float:
        """Link-bandwidth utilization over the horizon (Fig. 8) — matches
        ``CongestionResult.link_utilization`` for link channels."""
        if self.kind != "link" or not self.horizon:
            return 0.0
        return (self.total_bytes
                / self.cfg.link_bytes_per_cycle) / self.horizon


def _merged(intervals: List[Tuple[float, float]]
            ) -> List[Tuple[float, float]]:
    out: List[List[float]] = []
    for a, b in sorted(intervals):
        if out and a <= out[-1][1]:
            out[-1][1] = max(out[-1][1], b)
        else:
            out.append([a, b])
    return [(a, b) for a, b in out]


def _overlap(busy: List[Tuple[float, float]],
             waits: List[Tuple[float, float]]) -> float:
    """Total length of ``busy`` covered by the union of ``waits`` (both
    sorted; busy intervals are link-serialized and disjoint)."""
    tot, j = 0.0, 0
    for a, b in busy:
        while j < len(waits) and waits[j][1] <= a:
            j += 1
        k = j
        while k < len(waits) and waits[k][0] < b:
            tot += max(0.0, min(b, waits[k][1]) - max(a, waits[k][0]))
            k += 1
    return tot


def _profile_link(name: str, link: LinkModel) -> ChannelProfile:
    """Attribute a congestion-arbitrated channel (§IV-C): walk the link's
    arbitration-order timeline reconstructing each burst's issue/start
    from its recorded fields, classify every idle gap (compute vs
    fault-delay vs serialization, layered by what was holding the burst
    back), overlay waiting demand onto busy time (contention), and close
    the partition with the transfer remainder."""
    cfg = link.cfg
    idle = {"compute": 0.0, "serialization": 0.0, "fault_delay": 0.0}
    dos_total = 0.0
    busy: List[Tuple[float, float]] = []
    waits: List[Tuple[float, float]] = []
    engines: Dict[str, EngineStats] = defaultdict(EngineStats)
    xfer_sum = 0.0
    prev_free = 0.0
    # one property read: materializes any lazy batch segments exactly once
    # (per-tx dos/fault_delay attribution columns survive vectorization)
    timeline = link.timeline
    for tx in timeline:
        xfer = cfg.base_latency + tx.nbytes / cfg.link_bytes_per_cycle
        start = tx.complete - tx.dos - xfer
        wait = tx.stall - tx.dos
        issue = start - wait
        if issue > prev_free:
            # layered gap attribution: below the batch's submit time the
            # firmware had not produced the burst yet (compute overlap);
            # between submit and the fault-pushed min-issue time the link
            # idled on an injected dma_delay; the rest is the engine's
            # issue-gap serialization
            base = tx.time - tx.fault_delay
            c_end = min(issue, max(prev_free, base))
            f_end = min(issue, max(c_end, tx.time))
            idle["compute"] += max(0.0, c_end - prev_free)
            idle["fault_delay"] += max(0.0, f_end - c_end)
            idle["serialization"] += max(0.0, issue - f_end)
        dos_total += tx.dos
        if wait > 0.0:
            waits.append((issue, start))
        busy.append((start + tx.dos, tx.complete))
        prev_free = tx.complete
        e = engines[tx.engine]
        e.transactions += 1
        e.bytes += tx.nbytes
        e.busy += xfer
        e.contention += wait
        e.dos += tx.dos
        e.fault_delay += tx.fault_delay
        e.grant_stall += tx.stall
        xfer_sum += xfer
    contended = _overlap(busy, _merged(waits))
    total = link.now
    bd = StallBreakdown.close(total, dict(idle, dos=dos_total,
                                          contention=contended))
    residual = abs(bd.cycles["transfer"] + contended - xfer_sum)
    return ChannelProfile(name, "link", total, bd, dict(engines),
                          list(timeline), cfg, residual)


def _profile_clock(name: str, mem: MemoryBridge,
                   exclude_engines: frozenset) -> ChannelProfile:
    """Attribute a fast-path (congestion-free) bridge: one logical cycle
    of transfer per access; clock jumps beyond that are fault delay (up
    to the burst's recorded ``fault_delay``) and compute overlap
    (min-issue times ahead of the clock)."""
    txs = [t for t in mem.log.txs if t.engine not in exclude_engines]
    idle = {"compute": 0.0, "fault_delay": 0.0}
    engines: Dict[str, EngineStats] = defaultdict(EngineStats)
    prev = 0.0
    for tx in txs:
        seg = tx.time - prev
        extra = max(0.0, seg - 1.0)
        f = min(extra, tx.fault_delay)
        idle["fault_delay"] += f
        idle["compute"] += extra - f
        prev = tx.time
        e = engines[tx.engine]
        e.transactions += 1
        e.bytes += tx.nbytes
        e.busy += min(seg, 1.0)
        e.fault_delay += tx.fault_delay
    total = mem.time
    bd = StallBreakdown.close(total, idle)
    residual = abs(bd.cycles["transfer"]
                   - sum(e.busy for e in engines.values()))
    return ChannelProfile(name, "clock", total, bd, dict(engines), txs,
                          None, residual)


def _profile_csr(name: str, csr: Any) -> ChannelProfile:
    """Attribute a register-protocol clock (§IV-A): every ``fb_read_32``/
    ``fb_write_32`` is one protocol tick of pure transfer."""
    txs = [t for t in csr.log.txs if t.engine == csr.name]
    engines: Dict[str, EngineStats] = defaultdict(EngineStats)
    for tx in txs:
        e = engines[tx.engine]
        e.transactions += 1
        e.bytes += tx.nbytes
        e.busy += 1.0
    total = float(csr.time)
    bd = StallBreakdown.close(total, {})
    residual = abs(bd.cycles["transfer"]
                   - sum(e.busy for e in engines.values()))
    return ChannelProfile(name, "csr", total, bd, dict(engines), txs,
                          None, residual)


def _bridge_channels(prefix: str, fb: FireBridge) -> List[ChannelProfile]:
    mem, csr = fb.mem, fb.csr
    if mem.link is not None:
        ddr = _profile_link(f"{prefix}ddr", mem.link)
    else:
        ddr = _profile_clock(f"{prefix}ddr", mem, frozenset({csr.name}))
    return [ddr, _profile_csr(f"{prefix}csr", csr)]


def _is_cluster_serving(target: Any) -> bool:
    return hasattr(target, "engines") and hasattr(target, "csr")


def _is_serving(target: Any) -> bool:
    return hasattr(target, "slots") and hasattr(target, "step")


def _request_spans(engines) -> List[dict]:
    """Completed continuous-batching request lifecycles as (queue,
    prefill, decode) spans in modeled cycles.  Captured at profiler
    construction — the profiler does not retain its target — from the
    ``(device, engine)`` pairs given.  Storm-mode requests carry no
    admission stamps (t_admit == -1) and are skipped, so legacy serving
    profiles are unchanged."""
    spans = []
    for dev, eng in engines:
        for rid, req in eng.requests.items():
            if req.t_admit < 0 or req.t_done < 0:
                continue                # storm-mode or still in flight
            spans.append({"rid": int(rid), "device": int(dev),
                          "t_submit": float(req.t_submit),
                          "t_admit": float(req.t_admit),
                          "t_first": float(req.t_first),
                          "t_done": float(req.t_done),
                          "tokens": len(req.out_tokens)})
    return sorted(spans, key=lambda s: (s["t_submit"], s["rid"]))


# ------------------------------------------------------------ the profiler
class DataMovementProfiler:
    """Off-chip data-movement profiler (paper §IV, the third pillar).

    Build one over any instrumented target and read the report::

        fb = FireBridge(congestion=cfg, profile=True)
        ... firmware runs ...
        prof = DataMovementProfiler(fb)        # or fb.profiler()
        prof.breakdown()["ddr"].cycles         # closes to fb.mem.time
        prof.save_perfetto("run.trace.json")   # open in ui.perfetto.dev

    Accepted targets: ``FireBridge``/``MemoryBridge`` (one DDR channel +
    the CSR protocol clock), ``FabricCluster`` (host↔fabric channel,
    every port, every device), ``ServingEngine`` / ``ClusterServingEngine``
    (prompt-upload vs token-writeback traffic), and — via
    ``profile_recording`` — any replayed ``Recording``.
    """

    def __init__(self, target: Any, label: str = "run") -> None:
        self.label = label
        self.channels: List[ChannelProfile] = []
        self.marks: List[Tuple[TransactionLog, OpMark]] = []
        # serving targets only: completed request lifecycles (see
        # _request_spans); empty for bridge/fabric targets
        self.requests: List[dict] = []
        # resolve eagerly and do NOT retain the target: channels/marks
        # alias only logs and link timelines, so a profiled sweep cell
        # does not pin its bridge's DDR buffers for the report's lifetime
        self._resolve(target)
        self._by_name = {c.name: c for c in self.channels}
        # sampled counter streams (core/counters.py), snapshotted as
        # plain tuples — bank probes close over the target, so retaining
        # the banks themselves would break the no-pin discipline above
        from repro.core.counters import counter_banks as _banks_of
        self.counter_tracks: List[Tuple[str, List[Tuple[str, str]],
                                        List[float], List[tuple]]] = [
            (b.name, [(s.name, s.unit) for s in b.specs],
             list(b.stream.times), list(b.stream.rows))
            for b in _banks_of(target)]

    # ---------------------------------------------------------- resolution
    def _resolve(self, target: Any) -> None:
        if isinstance(target, FabricCluster):
            self.channels.append(_profile_link("fabric/host",
                                               target.host_link))
            for i, p in enumerate(target.ports):
                self.channels.append(_profile_link(f"fabric/port{i}", p))
            if target.switch is not None:
                # routed fabric: one channel (and Perfetto track) per
                # switch port — per-hop contention attribution
                for label, link in target.switch.labeled_links():
                    self.channels.append(
                        _profile_link(f"fabric/{label}", link))
            for i, d in enumerate(target.devices):
                self.channels.extend(_bridge_channels(f"d{i}/", d))
                self.marks.extend((d.log, m) for m in d.mem.marks)
            self.marks.extend((target.log, m) for m in target.marks)
            self._primary_log = target.log
            return
        if isinstance(target, FireBridge):
            self.channels.extend(_bridge_channels("", target))
            self.marks.extend((target.log, m) for m in target.mem.marks)
            self._primary_log = target.log
            return
        if isinstance(target, MemoryBridge):
            if target.link is not None:
                self.channels.append(_profile_link("ddr", target.link))
            else:
                self.channels.append(_profile_clock("ddr", target,
                                                    frozenset()))
            self.marks.extend((target.log, m) for m in target.marks)
            self._primary_log = target.log
            return
        if _is_cluster_serving(target):
            self.channels.append(_profile_link("host", target.host_link))
            self.channels.append(_profile_csr("csr", target.csr))
            sw = getattr(target, "switch", None)
            if sw is not None:
                for label, link in sw.labeled_links():
                    self.channels.append(_profile_link(f"sw/{label}",
                                                       link))
            for i, eng in enumerate(target.engines):
                if eng.mem.link is not None:
                    self.channels.append(
                        _profile_link(f"e{i}/ddr", eng.mem.link))
                else:
                    self.channels.append(_profile_clock(
                        f"e{i}/ddr", eng.mem, frozenset({eng.csr.name})))
                self.channels.append(_profile_csr(f"e{i}/csr", eng.csr))
            self.requests = _request_spans(enumerate(target.engines))
            self._primary_log = target.log
            return
        if _is_serving(target):
            if target.mem.link is not None:
                self.channels.append(_profile_link("ddr", target.mem.link))
            else:
                self.channels.append(_profile_clock(
                    "ddr", target.mem, frozenset({target.csr.name})))
            self.channels.append(_profile_csr("csr", target.csr))
            self.requests = _request_spans([(0, target)])
            self._primary_log = target.mem.log
            return
        raise TypeError(f"no profiling mapping for "
                        f"{type(target).__name__}")

    # ------------------------------------------------------------- queries
    def channel(self, name: str) -> ChannelProfile:
        return self._by_name[name]

    def breakdown(self) -> Dict[str, StallBreakdown]:
        """Per-channel exhaustive stall attribution; each breakdown sums
        exactly to its channel's modeled completion time."""
        return {c.name: c.breakdown for c in self.channels}

    def attribution(self) -> Dict[str, float]:
        """Category cycles summed over every channel (the sweep-report
        columns).  Per-channel closure still holds individually."""
        out = {c: 0.0 for c in CATEGORIES}
        for ch in self.channels:
            for c in CATEGORIES:
                out[c] += ch.breakdown.cycles[c]
        return out

    def utilization(self) -> float:
        """Primary-channel link utilization (0.0 for fast-path runs)."""
        return self.channels[0].utilization if self.channels else 0.0

    def engine_rows(self) -> List[str]:
        """Fig. 8 per-engine series, one CSV row per (channel, engine)."""
        rows = ["channel,engine,transactions,bytes,busy_cycles,"
                "contention_cycles,dos_cycles,fault_delay_cycles"]
        for ch in self.channels:
            for e in sorted(ch.engines):
                s = ch.engines[e]
                rows.append(f"{ch.name},{e},{s.transactions},{s.bytes},"
                            f"{s.busy:.0f},{s.contention:.0f},{s.dos:.0f},"
                            f"{s.fault_delay:.0f}")
        return rows

    def op_rows(self) -> List[str]:
        """Per-op attribution from the ``profile=`` op marks: bytes moved,
        stall/DoS/fault cycles, and modeled span per launch or collective
        leg (the Fig. 8 per-operation view)."""
        rows = ["op,meta,transactions,bytes,stall_cycles,dos_cycles,"
                "fault_delay_cycles,span_cycles"]
        for log, m in self.marks:
            txs = log.txs[m.tx_lo:m.tx_hi]
            rows.append(
                f"{m.op},{m.meta},{len(txs)},"
                f"{sum(t.nbytes for t in txs)},"
                f"{sum(t.stall for t in txs):.0f},"
                f"{sum(t.dos for t in txs):.0f},"
                f"{sum(t.fault_delay for t in txs):.0f},"
                f"{m.t1 - m.t0:.0f}")
        return rows

    def serving_rows(self) -> List[str]:
        """Prompt-upload vs token-writeback attribution for serving
        targets: upload = device-bound reads/writes (``h->e*`` /
        ``serve_dma`` reads), writeback = host-bound token rows.  The two
        directions contend on one channel — their stall split is the
        serving Fig. 8 readout."""
        up = EngineStats()
        back = EngineStats()
        # cluster targets: the shared host channel is where uploads and
        # writebacks contend — counting device-local serve_dma traffic
        # too would double-book every token row
        chans = ([self._by_name["host"]] if "host" in self._by_name
                 else self.channels)
        for ch in chans:
            for name, s in ch.engines.items():
                if ch.kind == "csr":
                    continue
                dest = (back if ("->h" in name or name.endswith("_wr"))
                        else up)
                if name == "serve_dma":
                    # single engine: reads fetch prompts, writes stream
                    # token rows back — split by kind
                    for tx in ch.txs:
                        if tx.engine != name:
                            continue
                        d = up if tx.kind == "read" else back
                        d.transactions += 1
                        d.bytes += tx.nbytes
                        d.contention += tx.stall - tx.dos
                        d.dos += tx.dos
                    continue
                dest.transactions += s.transactions
                dest.bytes += s.bytes
                dest.busy += s.busy
                dest.contention += s.contention
                dest.dos += s.dos
        rows = ["direction,transactions,bytes,stall_cycles"]
        rows.append(f"prompt_upload,{up.transactions},{up.bytes},"
                    f"{up.stall:.0f}")
        rows.append(f"token_writeback,{back.transactions},{back.bytes},"
                    f"{back.stall:.0f}")
        return rows

    def request_rows(self) -> List[str]:
        """Per-request lifecycle rows for continuous-batching serving
        targets — the latency-SLO tier's raw material: one CSV row per
        completed request with its queue/prefill/decode boundary stamps
        (modeled cycles) and generated token count."""
        rows = ["rid,device,t_submit,t_admit,t_first,t_done,tokens"]
        for s in self.requests:
            rows.append(f"{s['rid']},{s['device']},{s['t_submit']:.1f},"
                        f"{s['t_admit']:.1f},{s['t_first']:.1f},"
                        f"{s['t_done']:.1f},{s['tokens']}")
        return rows

    def bandwidth_timeline(self, n_buckets: int = 50,
                           by_engine: bool = True):
        """Bucketed bandwidth-utilization series of the primary log —
        the Fig. 8 timeline (delegates to
        ``TransactionLog.bandwidth_timeline``)."""
        return self._primary_log.bandwidth_timeline(n_buckets, by_engine)

    def roofline(self, flops_by_op: Dict[str, float], peak_flops: float,
                 mem_bw: float) -> List["RooflinePlacement"]:
        """Place each profiled op on the roofline: compute time from the
        caller-supplied FLOP counts, memory time from the bytes the op's
        marked transactions actually moved."""
        out = []
        for log, m in self.marks:
            if m.op not in flops_by_op:
                continue
            fl = flops_by_op[m.op]
            by = sum(t.nbytes for t in log.txs[m.tx_lo:m.tx_hi])
            out.append(RooflinePlacement(
                m.op, {"compute": fl / peak_flops, "memory": by / mem_bw},
                ideal_s=fl / peak_flops))
        return out

    # ------------------------------------------------------------- export
    def to_perfetto(self) -> dict:
        """Chrome-trace JSON (Perfetto-loadable): one process per channel,
        one thread per engine, a ``stall`` + transfer slice per burst,
        bandwidth counter tracks, per-op slices, and the per-channel
        stall attribution + horizons in ``otherData`` (schema in
        docs/profiling.md; checked by ``validate_trace``).  Modeled
        cycles are exported as microseconds (1 cycle = 1 us).
        Byte-identical under the same seed."""
        ev: List[dict] = []
        for pid, ch in enumerate(self.channels, start=1):
            ev.append({"ph": "M", "name": "process_name", "pid": pid,
                       "args": {"name": f"{self.label}/{ch.name}"}})
            engines = sorted(ch.engines)
            tids = {e: i + 1 for i, e in enumerate(engines)}
            for e in engines:
                ev.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tids[e], "args": {"name": e}})
            for tx in ch.txs:
                tid = tids[tx.engine]
                if ch.kind == "link":
                    xfer = (ch.cfg.base_latency
                            + tx.nbytes / ch.cfg.link_bytes_per_cycle)
                    start = tx.complete - tx.dos - xfer
                    if tx.stall > 0.0:
                        ev.append({
                            "ph": "X", "cat": "stall", "name": "stall",
                            "ts": round(start - (tx.stall - tx.dos), 6),
                            "dur": round(tx.stall, 6),
                            "pid": pid, "tid": tid,
                            "args": {"dos": round(tx.dos, 6),
                                     "fault_delay": round(tx.fault_delay,
                                                          6)}})
                    ts, dur = start + tx.dos, xfer
                else:
                    ts, dur = tx.time - 1.0, 1.0
                ev.append({
                    "ph": "X", "cat": tx.kind,
                    "name": tx.tag or f"{tx.kind} {tx.nbytes}B",
                    "ts": round(ts, 6), "dur": round(dur, 6),
                    "pid": pid, "tid": tid,
                    "args": {"bytes": tx.nbytes,
                             "addr": f"{tx.addr:#x}"}})
            # bandwidth counter track (bytes per cycle per bucket)
            if ch.txs and ch.horizon > 0:
                n = 32
                width = ch.horizon / n
                buckets = [0.0] * n
                for tx in ch.txs:
                    stamp = tx.complete if tx.complete else tx.time
                    b = min(int(stamp / ch.horizon * n), n - 1)
                    buckets[b] += tx.nbytes
                for b, v in enumerate(buckets):
                    ev.append({"ph": "C", "name": "bandwidth",
                               "pid": pid, "ts": round(b * width, 6),
                               "args": {"bytes_per_cycle":
                                        round(v / width, 6)}})
        if self.marks:
            pid = len(self.channels) + 1
            ev.append({"ph": "M", "name": "process_name", "pid": pid,
                       "args": {"name": f"{self.label}/ops"}})
            ev.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": 1, "args": {"name": "ops"}})
            for _, m in self.marks:
                ev.append({"ph": "X", "cat": "op",
                           "name": m.meta and f"{m.op}:{m.meta}" or m.op,
                           "ts": round(m.t0, 6),
                           "dur": round(max(m.t1 - m.t0, 1e-6), 6),
                           "pid": pid, "tid": 1,
                           "args": {"transactions": m.tx_hi - m.tx_lo}})
        if self.requests:
            # per-request lifecycle tracks (continuous-batching serving):
            # one thread per request, queue/prefill/decode slices
            pid = len(self.channels) + 1 + (1 if self.marks else 0)
            ev.append({"ph": "M", "name": "process_name", "pid": pid,
                       "args": {"name": f"{self.label}/requests"}})
            for tid, s in enumerate(self.requests, start=1):
                ev.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid,
                           "args": {"name": f"rid{s['rid']}"
                                            f"@d{s['device']}"}})
                if s["t_admit"] > s["t_submit"]:
                    ev.append({"ph": "X", "cat": "queue", "name": "queue",
                               "ts": round(s["t_submit"], 6),
                               "dur": round(s["t_admit"] - s["t_submit"],
                                            6),
                               "pid": pid, "tid": tid,
                               "args": {"rid": s["rid"]}})
                ev.append({"ph": "X", "cat": "prefill", "name": "prefill",
                           "ts": round(s["t_admit"], 6),
                           "dur": round(max(s["t_first"] - s["t_admit"],
                                            1e-6), 6),
                           "pid": pid, "tid": tid,
                           "args": {"rid": s["rid"]}})
                ev.append({"ph": "X", "cat": "decode", "name": "decode",
                           "ts": round(s["t_first"], 6),
                           "dur": round(max(s["t_done"] - s["t_first"],
                                            1e-6), 6),
                           "pid": pid, "tid": tid,
                           "args": {"rid": s["rid"],
                                    "tokens": s["tokens"]}})
        if any(times for _, _, times, _ in self.counter_tracks):
            # sampled performance-counter tracks (core/counters.py): one
            # process per bank, one "C" series per counter
            pid = (len(self.channels) + 1 + (1 if self.marks else 0)
                   + (1 if self.requests else 0))
            for bank, cols, times, rows in self.counter_tracks:
                if not times:
                    continue
                ev.append({"ph": "M", "name": "process_name", "pid": pid,
                           "args": {"name":
                                    f"{self.label}/counters/{bank}"}})
                for t, row in zip(times, rows):
                    for (cname, unit), v in zip(cols, row):
                        ev.append({"ph": "C", "name": cname, "pid": pid,
                                   "ts": round(t, 6),
                                   "args": {unit: round(float(v), 6)}})
                pid += 1
        return {
            "traceEvents": ev,
            "displayTimeUnit": "ms",
            "otherData": {
                "label": self.label,
                "schema_version": SCHEMA_VERSION,
                "attribution": {c.name: {k: round(v, 6) for k, v in
                                         c.breakdown.cycles.items()}
                                for c in self.channels},
                "horizons": {c.name: round(c.horizon, 6)
                             for c in self.channels},
            },
        }

    def save_perfetto(self, path) -> Path:
        """Write the Chrome-trace JSON deterministically (sorted keys,
        compact separators): same seed ⇒ byte-identical file."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_perfetto(), sort_keys=True,
                                separators=(",", ":")) + "\n")
        return p

    def summary(self) -> dict:
        prim = self.channels[0]
        return {
            "label": self.label,
            "channels": len(self.channels),
            "transactions": sum(len(c.txs) for c in self.channels),
            "bytes": sum(c.total_bytes for c in self.channels),
            "horizon": round(prim.horizon, 1),
            "utilization": round(prim.utilization, 4),
            "attribution": {k: round(v, 1)
                            for k, v in self.attribution().items()},
        }


# ----------------------------------------------------- recording profiling
def profile_window(target: Any, rec: Any, lo: int, hi: int
                   ) -> Dict[str, Dict[str, float]]:
    """Per-engine data-movement totals (the Fig. 8 series, §IV) for the
    transactions that recording ops ``[lo, hi)`` emitted on ``target``
    (the original run's target, or the target a window replay left
    behind — the two are bit-identical by the replay contract, which the
    regression tests exploit).

    Only per-transaction attribution is reported (bytes, stall, DoS,
    fault delay) — the wall-partition categories need the full horizon
    and are reported by ``DataMovementProfiler`` on full-range targets.
    """
    from repro.core import replay as rp
    out: Dict[str, Dict[str, float]] = {}
    for li, log in enumerate(rp.target_logs(target)):
        marks = rec.tx_marks[li]
        for tx in log.txs[marks[lo]:marks[hi]]:
            e = out.setdefault(tx.engine, {
                "transactions": 0.0, "bytes": 0.0, "stall": 0.0,
                "dos": 0.0, "fault_delay": 0.0})
            e["transactions"] += 1
            e["bytes"] += tx.nbytes
            e["stall"] += tx.stall
            e["dos"] += tx.dos
            e["fault_delay"] += tx.fault_delay
    return out


def profile_recording(session: Any, rec: Any,
                      label: Optional[str] = None) -> DataMovementProfiler:
    """Profile a recorded run after the fact (core/replay.py): replay the
    full timeline (bit-identical by the replay contract) and profile the
    regenerated target — so any recording, including the committed golden
    traces, can produce Fig. 8 attribution and a Perfetto trace on
    demand."""
    w = session.replay(rec, 0, rec.n_ops)
    return DataMovementProfiler(w.target, label=label or rec.label)


# ------------------------------------------------------------ trace schema
_REQUIRED = {
    "M": {"name", "ph", "pid", "args"},
    "X": {"name", "cat", "ph", "ts", "dur", "pid", "tid", "args"},
    "C": {"name", "ph", "ts", "pid", "args"},
}


def validate_trace(trace: dict) -> List[str]:
    """Validate an exported Chrome-trace object against the documented
    event schema (docs/profiling.md): required keys per phase, numeric
    non-negative timestamps, and the closure property — every channel's
    attribution must sum exactly to its recorded horizon.  Returns a list
    of problems (empty = valid)."""
    errs: List[str] = []
    if set(trace) != {"traceEvents", "displayTimeUnit", "otherData"}:
        errs.append(f"top-level keys {sorted(trace)} != "
                    f"['displayTimeUnit', 'otherData', 'traceEvents']")
        return errs
    for i, ev in enumerate(trace["traceEvents"]):
        ph = ev.get("ph")
        req = _REQUIRED.get(ph)
        if req is None:
            errs.append(f"event {i}: unknown phase {ph!r}")
            continue
        missing = req - set(ev)
        if missing:
            errs.append(f"event {i} ({ph}): missing {sorted(missing)}")
            continue
        if ph in ("X", "C") and (not isinstance(ev["ts"], (int, float))
                                 or ev["ts"] < -1e-6):
            errs.append(f"event {i}: bad ts {ev['ts']!r}")
        if ph == "X" and (not isinstance(ev["dur"], (int, float))
                          or ev["dur"] < 0):
            errs.append(f"event {i}: bad dur {ev['dur']!r}")
        if not isinstance(ev.get("args"), dict):
            errs.append(f"event {i}: args must be a dict")
    other = trace["otherData"]
    for key in ("label", "schema_version", "attribution", "horizons"):
        if key not in other:
            errs.append(f"otherData missing {key!r}")
            return errs
    for name, cyc in other["attribution"].items():
        if set(cyc) != set(CATEGORIES):
            errs.append(f"channel {name}: categories {sorted(cyc)} != "
                        f"{sorted(CATEGORIES)}")
            continue
        total = other["horizons"].get(name)
        if total is None:
            errs.append(f"channel {name}: no recorded horizon")
        elif not math.isclose(sum(cyc.values()), total, abs_tol=1e-5):
            errs.append(f"channel {name}: attribution sums to "
                        f"{sum(cyc.values())}, horizon is {total}")
    return errs


# ---------------------------------------------------------------- roofline
@dataclasses.dataclass(frozen=True)
class RooflinePlacement:
    """One kernel or program placed on the roofline (paper §V context:
    which modeled term — compute, memory, collective — bounds it).

    ``terms`` maps bound name -> modeled seconds (or cycles; any one
    unit); ``ideal_s`` is the useful-FLOP time at peak, so
    ``roofline_frac`` is the attainable fraction of peak under the
    dominant bound.  benchmarks/roofline.py renders its tables through
    this placement."""
    name: str
    terms: Dict[str, float]
    ideal_s: float = 0.0

    @property
    def dominant(self) -> str:
        return max(self.terms, key=self.terms.get)

    @property
    def limit_s(self) -> float:
        return max(self.terms.values())

    @property
    def roofline_frac(self) -> float:
        return self.ideal_s / self.limit_s if self.limit_s else 0.0
