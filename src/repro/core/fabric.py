"""Multi-device co-verification fabric (paper §IV-C scaled out).

The paper's end state is verifying firmware that orchestrates *several*
subsystems over a shared memory fabric; FireSim showed the same move for
cycle-accurate simulation — many simulated nodes joined by a *modeled*
network.  ``FabricCluster`` is that shape here: N independent
``FireBridge`` devices (each with its own DDR, CSR space, transaction log,
and optionally its own online congestion link and forked fault plan)
joined by a modeled interconnect built from ``core/congestion.py``
pieces:

* one ``LinkModel`` per device **port** (the device's bidirectional fabric
  attachment — transfers from and to the device contend on it, the way tx
  and rx DMA contend on a NIC), and
* one shared **host↔fabric DMA channel** that every scatter/gather and
  cluster-serving token writeback must cross.

With ``topology=None`` (the default) the ports hang off one implicit
zero-hop crossbar: a transfer is a read leg on the source attachment and
a write leg on the destination attachment, both issued at the fabric
clock.  With a ``Topology`` (core/topology.py — ring / 2D-torus /
fat-tree) installed, every transfer instead travels a **multi-hop
journey** through the modeled switch graph (core/switch.py): the source
leg, then one flit-framed, credit-flow-controlled switch hop per link on
the static route (store-and-forward — each hop issues at the previous
hop's completion), then the destination leg.  Inter-device stalls become
placement-dependent, the profiler attributes contention per hop, and
``all_reduce`` switches to a hierarchical tree that exploits switch
locality.  The crossbar path is byte-for-byte unchanged — the five
pre-topology golden traces pin it.

Every fabric transfer — ``dev_copy``, ``scatter``/``broadcast``/
``gather`` of sharded buffers, and the ``all_reduce`` collective —
is split into link-level bursts, arbitrated through the port models
(advancing the fabric clock and accumulating per-link stall statistics),
logged in the fabric ``TransactionLog``, and routed through a forked
fault plan when one is installed.  Same seed ⇒ identical fabric + device
transaction streams, witnessed by ``digest()``.

``sharded_launch`` runs one accelerator op sharded across the cluster
using the ``sharding/specs.py`` fabric layouts (scatter the sharded
inputs, broadcast the replicated ones, device-local launches, gather the
output) — the gathered result is bit-identical to the single-device run
because the layouts never split a reduction axis.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.bridge import FireBridge, MemoryBridge
from repro.core.congestion import (CongestionConfig, CongestionResult,
                                   LinkModel)
from repro.core.counters import (CounterBank, CounterSpec,
                                 register_link_counters,
                                 register_switch_port_counters)
from repro.core.switch import SwitchFabric
from repro.core.topology import Topology, build_topology
from repro.core.transactions import (BurstBatch, OpMark, Transaction,
                                     TransactionLog, record_mark)

# Default fabric-link parameters: an inter-device serdes link is narrower
# and longer-latency than the device-local DDR interface modeled by the
# bridge's own CongestionConfig defaults.
FABRIC_LINK = CongestionConfig(link_bytes_per_cycle=64.0, base_latency=100.0,
                               max_burst_bytes=4096)


def shard_runs(shape: Tuple[int, ...], itemsize: int, axis: int,
               lo: int, hi: int) -> List[Tuple[int, int]]:
    """Byte-level (offset, length) runs a shard ``[lo, hi)`` along ``axis``
    occupies inside the C-ordered host buffer.

    For axis 0 a shard is one contiguous run; for inner axes the shard's
    rows interleave through the buffer, so the host-side DMA legs must be
    logged as ``prod(shape[:axis])`` strided runs — otherwise the
    transaction stream attributes traffic to addresses the data never
    touches (Fig. 9 heatmaps, golden traces)."""
    outer = int(np.prod(shape[:axis], dtype=np.int64)) if axis else 1
    inner = int(np.prod(shape[axis + 1:], dtype=np.int64)) * itemsize
    stride = shape[axis] * inner
    run_len = (hi - lo) * inner
    if run_len == 0:
        return []
    return [(o * stride + lo * inner, run_len) for o in range(outer)]


class FabricCluster:
    """N FireBridge devices behind a modeled interconnect (§IV-C at scale).

    ``congestion`` configures each device's *local* memory link (as for a
    single ``FireBridge``); ``link_config`` configures the fabric ports and
    the host↔fabric channel (defaults to ``FABRIC_LINK``).  ``fault_plan``
    is forked once per device and once for the fabric links, so the whole
    cluster reproduces from one seed regardless of device count.
    ``coverage`` (core/coverage.py) observes fabric operations, burst
    sizes, and link congestion states when provided.

    ``topology`` routes inter-device and host traffic through a modeled
    switch graph instead of the implicit crossbar: a ``Topology``
    instance (core/topology.py), or a builder name (``"ring"``,
    ``"torus2d"``, ``"fat_tree"``) applied to ``n_devices``.  ``None``
    keeps crossbar timing bit-exactly (golden traces).
    """

    def __init__(self, n_devices: int, *, name: str = "fab",
                 congestion: Optional[CongestionConfig] = None,
                 link_config: Optional[CongestionConfig] = None,
                 fault_plan=None, coverage=None,
                 profile: bool = False, topology=None) -> None:
        if n_devices < 1:
            raise ValueError(f"need at least one device, got {n_devices}")
        self.n = n_devices
        self.name = name
        self.log = TransactionLog()            # fabric interconnect log
        self.coverage = coverage
        # data-movement profiling (core/profiler.py): fabric transfers and
        # collective legs are op-marked so the profiler can attribute
        # bytes/stalls per collective step (all_reduce leg attribution)
        self.profile = profile
        self.marks: List[OpMark] = []
        self.link_config = link_config if link_config is not None \
            else FABRIC_LINK
        self.fault_plan = (fault_plan.fork(f"{name}/links")
                           if fault_plan is not None else None)
        # device-local DDR links get distinct DoS seeds (device 0 keeps
        # the caller's seed, so it times identically to a standalone
        # bridge); without the reseed every device would stall at the
        # same points — artificially synchronized cross-device timing
        self.devices = [
            FireBridge(f"{name}{i}",
                       congestion=(dataclasses.replace(
                           congestion, seed=congestion.seed + i)
                           if congestion is not None else None),
                       fault_plan=(fault_plan.fork(f"{name}/dev{i}")
                                   if fault_plan is not None else None),
                       profile=profile)
            for i in range(n_devices)]
        lc = self.link_config
        # distinct DoS streams per link, all derived from one seed
        self.host_link = LinkModel(lc)
        self.ports = [LinkModel(dataclasses.replace(lc, seed=lc.seed + 1 + i))
                      for i in range(n_devices)]
        # routed interconnect (core/switch.py): None = implicit crossbar
        if isinstance(topology, str):
            topology = build_topology(topology, n_devices)
        if topology is not None and topology.n_devices != n_devices:
            raise ValueError(
                f"topology {topology.kind!r} describes "
                f"{topology.n_devices} devices, cluster has {n_devices}")
        self.topology: Optional[Topology] = topology
        self.switch = (SwitchFabric(topology, lc)
                       if topology is not None else None)
        if coverage is not None:
            coverage.hit("topology",
                         topology.kind if topology is not None
                         else "crossbar")
        # host-side staging DDR (firmware-visible; host accesses are free,
        # crossing the fabric is not)
        self.host = MemoryBridge(self.log)
        self.time = 0.0
        # always-on sampled counters (core/counters.py): one bank per
        # fabric channel — the shared host link, every device port, and
        # (routed) every switch port with its credit flow-control
        # counters.  Probes only read arbiter state; ticks happen after
        # an issue completes, so timing/logs are unaffected.
        self._counter_banks: List[CounterBank] = []
        hb = CounterBank("fabric/host")
        register_link_counters(hb, self.host_link)
        hb.register(CounterSpec("transactions", "events"),
                    lambda: self.log.n_txs)
        hb.register(CounterSpec("faults", "events"),
                    lambda: len(self.log.faults))
        self._counter_banks.append(hb)
        for i, port in enumerate(self.ports):
            pb = CounterBank(f"fabric/port{i}")
            register_link_counters(pb, port)
            self._counter_banks.append(pb)
        if self.switch is not None:
            for sp in self.switch.ports:
                sb = CounterBank(f"fabric/sw:{sp.label}")
                register_switch_port_counters(sb, sp)
                self._counter_banks.append(sb)

    # ------------------------------------------------------------- devices
    def register_op(self, op: str, **table) -> None:
        """Register one op's backend table on every device."""
        for d in self.devices:
            d.register_op(op, **table)

    def launch(self, dev: int, op: str, backend: str, in_bufs: List[str],
               out_bufs: List[str], **kw) -> None:
        """Device-local accelerator launch (see FireBridge.launch)."""
        self.devices[dev].launch(op, backend, in_bufs, out_bufs, **kw)

    def _dev_alloc(self, dev: int, name: str, shape, dtype):
        """Allocate (or reuse, on exact shape/dtype match) a device buffer."""
        mem = self.devices[dev].mem
        buf = mem.buffers.get(name)
        if buf is not None:
            if buf.array.shape != tuple(shape) or buf.array.dtype != dtype:
                raise ValueError(
                    f"device {dev} buffer {name!r} exists with shape "
                    f"{buf.array.shape}/{buf.array.dtype}, need "
                    f"{tuple(shape)}/{np.dtype(dtype)}")
            return buf
        return mem.alloc(name, shape, dtype)

    def alloc_sharded(self, name: str, shape, dtype,
                      axis: Optional[int] = 0) -> None:
        """Allocate ``name`` on every device: split along ``axis``
        (np.array_split bounds), or full-shape replicas when axis is None."""
        if axis is None:
            for i in range(self.n):
                self._dev_alloc(i, name, shape, dtype)
            return
        for i, (lo, hi) in enumerate(self._shard_bounds(shape[axis])):
            sh = tuple(shape[:axis]) + (hi - lo,) + tuple(shape[axis + 1:])
            self._dev_alloc(i, name, sh, dtype)

    # --------------------------------------------------------------- links
    def _leg(self, link: LinkModel, engine: str, kind: str, addr: int,
             nbytes: int, tag: str,
             runs: Optional[List[Tuple[int, int]]] = None
             ) -> Optional[Tuple[LinkModel, BurstBatch]]:
        """Build one fabric transfer leg as a burst batch — no submission
        yet.  A launch's legs are all built against the same fabric clock
        (``self.time`` only advances after the issuing op's leg loop) and
        then issued together by ``_issue_legs``.  ``runs`` overrides the
        single contiguous (addr, nbytes) range with a list of strided
        byte runs (inner-axis shards of a host buffer).  Returns None for
        an empty leg (nothing moves, no burst, no fault draw — matches
        all_reduce's degenerate skip)."""
        rl = [(a, nb) for a, nb in (runs if runs is not None
                                    else [(addr, nbytes)]) if nb > 0]
        if not rl:
            return None
        return (link, BurstBatch.from_runs(
            self.time, engine, kind, rl, tag,
            self.link_config.max_burst_bytes))

    def _issue_legs(self, legs: List[Optional[Tuple[LinkModel, BurstBatch]]]
                    ) -> float:
        """Issue one launch's legs in build order: each leg's batch is
        fault-perturbed, arbitrated on its own link, and logged.  Per-link
        submission order and batch boundaries are identical to per-leg
        issuing, so arbitration streams (and golden traces) are unchanged
        — only the Python orchestration is batched."""
        done = self.time
        for leg in legs:
            if leg is None:
                continue
            link, batch = leg
            if self.fault_plan is not None:
                batch = self.fault_plan.perturb_batch(batch, self.log)
            d = link.submit_batch(batch, self.log)
            if d > done:
                done = d
            if self.coverage is not None:
                for nb, st in zip(batch.rec["nbytes"].tolist(),
                                  batch.rec["stall"].tolist()):
                    self.coverage.hit_burst(nb)
                    self.coverage.hit_congestion(st)
        self._tick_counters(done)
        return done

    # ------------------------------------------------------ routed journeys
    def _journey(self, src, dst, engine: str, src_runs, dst_runs,
                 src_tag: str, dst_tag: str):
        """Hop list for one routed transfer unit between endpoints (device
        index or ``'h'`` for the host staging DDR): the source-attachment
        read leg, one flit-framed switch hop per link on the static route
        (carrying the destination byte runs), and the destination-
        attachment write leg.  Hop = (link, engine, kind, runs, tag,
        burst step, SwitchPort-or-None).  Returns None when nothing moves
        (mirrors ``_leg``'s empty-leg skip)."""
        src_runs = [(a, nb) for a, nb in src_runs if nb > 0]
        dst_runs = [(a, nb) for a, nb in dst_runs if nb > 0]
        if not src_runs or not dst_runs:
            return None
        mb = self.link_config.max_burst_bytes
        src_link = self.host_link if src == "h" else self.ports[src]
        dst_link = self.host_link if dst == "h" else self.ports[dst]
        hops = [(src_link, engine, "read", src_runs, src_tag, mb, None)]
        for p in self.switch.route_ports(src, dst):
            hops.append((p.link, engine, "flit", dst_runs, dst_tag,
                         self.topology.flit_bytes, p))
        hops.append((dst_link, engine, "write", dst_runs, dst_tag, mb,
                     None))
        return hops

    def _issue_journeys(self, journeys) -> float:
        """Issue routed journeys wave by wave: wave k carries every
        journey's k-th hop, each hop's batch issuing at that journey's
        previous-hop completion (store-and-forward).  Journeys therefore
        pipeline — journey B's source leg contends with journey A's
        source leg, not with A's deepest hop — and shared switch ports
        arbitrate the flit trains of every journey crossing them.  Switch
        hops additionally pay credit-based flow control before entering
        the port (core/switch.py)."""
        cov = self.coverage
        js = [j for j in journeys if j is not None]
        if cov is not None:
            for j in js:
                cov.hit_hops(len(j) - 2)
        done = self.time
        ready = [self.time] * len(js)
        for k in range(max((len(j) for j in js), default=0)):
            for ji, j in enumerate(js):
                if k >= len(j):
                    continue
                link, engine, kind, runs, tag, step, port = j[k]
                t = ready[ji]
                if port is not None:
                    t_in = port.acquire(t)
                    if cov is not None:
                        cov.hit("credit_stall",
                                "waited" if t_in > t else "granted")
                    t = t_in
                batch = BurstBatch.from_runs(t, engine, kind, runs, tag,
                                             step)
                if self.fault_plan is not None:
                    batch = self.fault_plan.perturb_batch(batch, self.log)
                d = link.submit_batch(batch, self.log)
                if port is not None:
                    port.release(batch.rec["complete"].tolist())
                ready[ji] = d
                if d > done:
                    done = d
                if cov is not None:
                    for nb, st in zip(batch.rec["nbytes"].tolist(),
                                      batch.rec["stall"].tolist()):
                        cov.hit_burst(nb)
                        cov.hit_congestion(st)
        self._tick_counters(done)
        return done

    def _cover(self, op: str) -> None:
        if self.coverage is not None:
            self.coverage.hit("fabric", op)

    def _tick_counters(self, now: float) -> None:
        """Sample every fabric bank up to ``now`` — called after each
        issue wave, i.e. at the points the fabric clock advances."""
        for b in self._counter_banks:
            b.tick(now)

    def counter_banks(self) -> List[CounterBank]:
        """All cluster banks in stable order (fabric channels first, then
        each device's DDR bank) — the counter-diff oracle's unit."""
        return (list(self._counter_banks)
                + [d.mem.counters for d in self.devices])

    def _mark(self, op: str, meta: str = ""):
        """Attribute the fabric transactions logged inside the block to
        one collective/transfer op (core/profiler.py); no-op unless
        constructed with ``profile=True``."""
        if not self.profile:
            return contextlib.nullcontext()
        return record_mark(self.marks, self.log, lambda: self.time, op,
                           "fabric", meta)

    # ----------------------------------------------------------- transfers
    def dev_copy(self, src_dev: int, dst_dev: int, name: str,
                 dst_name: Optional[str] = None) -> float:
        """Device-to-device transfer: read leg on the source port, write
        leg on the destination port, both congestion-timed."""
        dst_name = dst_name or name
        sbuf = self.devices[src_dev].mem.buffers[name]
        dbuf = self._dev_alloc(dst_dev, dst_name, sbuf.array.shape,
                               sbuf.array.dtype)
        eng = f"d{src_dev}->d{dst_dev}"
        with self._mark("dev_copy", name):
            if self.switch is None:
                done = self._issue_legs([
                    self._leg(self.ports[src_dev], eng, "read", sbuf.addr,
                              sbuf.nbytes, name),
                    self._leg(self.ports[dst_dev], eng, "write", dbuf.addr,
                              dbuf.nbytes, dst_name)])
            else:
                done = self._issue_journeys([self._journey(
                    src_dev, dst_dev, eng, [(sbuf.addr, sbuf.nbytes)],
                    [(dbuf.addr, dbuf.nbytes)], name, dst_name)])
            self.time = max(self.time, done)
        np.copyto(dbuf.array, sbuf.array)
        self._cover("dev_copy")
        return done

    def _shard_bounds(self, dim: int) -> List[Tuple[int, int]]:
        """Per-device [lo, hi) index bounds along a dim of size ``dim``
        (np.array_split semantics)."""
        sizes = [len(ix) for ix in np.array_split(np.arange(dim), self.n)]
        bounds, lo = [], 0
        for s in sizes:
            bounds.append((lo, lo + s))
            lo += s
        return bounds

    def scatter(self, name: str, axis: int = 0) -> float:
        """Split a host buffer across devices along ``axis`` (np.array_split
        bounds); every shard crosses the shared host channel (contending)
        plus its device port.  Host-side legs are logged at the shard's
        true (strided, for inner axes) byte runs."""
        hbuf = self.host.buffers[name]
        shards = np.array_split(hbuf.array, self.n, axis=axis)
        bounds = self._shard_bounds(hbuf.array.shape[axis])
        with self._mark("scatter", name):
            legs, journeys, moves = [], [], []
            for i, (sh, (lo, hi)) in enumerate(zip(shards, bounds)):
                buf = self._dev_alloc(i, name, sh.shape, hbuf.array.dtype)
                eng = f"h->d{i}"
                runs = [(hbuf.addr + off, nb) for off, nb in
                        shard_runs(hbuf.array.shape, hbuf.array.itemsize,
                                   axis, lo, hi)]
                if self.switch is None:
                    legs.append(self._leg(self.host_link, eng, "read", 0,
                                          0, name, runs=runs))
                    legs.append(self._leg(self.ports[i], eng, "write",
                                          buf.addr, sh.nbytes, name))
                else:
                    journeys.append(self._journey(
                        "h", i, eng, runs, [(buf.addr, sh.nbytes)],
                        name, name))
                moves.append((buf, sh))
            done = (self._issue_legs(legs) if self.switch is None
                    else self._issue_journeys(journeys))
            for buf, sh in moves:
                np.copyto(buf.array, sh)
            self.time = max(self.time, done)
        self._cover("scatter")
        return done

    def broadcast(self, name: str) -> float:
        """Replicate a host buffer onto every device; the N copies contend
        on the shared host channel."""
        hbuf = self.host.buffers[name]
        with self._mark("broadcast", name):
            legs, journeys, moves = [], [], []
            for i in range(self.n):
                buf = self._dev_alloc(i, name, hbuf.array.shape,
                                      hbuf.array.dtype)
                eng = f"h->d{i}"
                if self.switch is None:
                    legs.append(self._leg(self.host_link, eng, "read",
                                          hbuf.addr, hbuf.nbytes, name))
                    legs.append(self._leg(self.ports[i], eng, "write",
                                          buf.addr, buf.nbytes, name))
                else:
                    journeys.append(self._journey(
                        "h", i, eng, [(hbuf.addr, hbuf.nbytes)],
                        [(buf.addr, buf.nbytes)], name, name))
                moves.append(buf)
            done = (self._issue_legs(legs) if self.switch is None
                    else self._issue_journeys(journeys))
            for buf in moves:
                np.copyto(buf.array, hbuf.array)
            self.time = max(self.time, done)
        self._cover("broadcast")
        return done

    def gather(self, name: str, axis: int = 0) -> float:
        """Collect per-device shards of ``name`` back into the host buffer
        (allocated on first gather), concatenated along ``axis``."""
        shards = [self.devices[i].mem.buffers[name] for i in range(self.n)]
        out = (np.concatenate([b.array for b in shards], axis=axis)
               if self.n > 1 else shards[0].array.copy())
        hbuf = self.host.buffers.get(name)
        if hbuf is None:
            hbuf = self.host.alloc(name, out.shape, out.dtype)
        if hbuf.array.shape != out.shape:
            raise ValueError(
                f"gather({name!r}, axis={axis}): shards assemble to "
                f"{out.shape}, host buffer is {hbuf.array.shape}")
        bounds = self._shard_bounds(out.shape[axis])
        with self._mark("gather", name):
            legs, journeys = [], []
            for i, (b, (lo, hi)) in enumerate(zip(shards, bounds)):
                eng = f"d{i}->h"
                runs = [(hbuf.addr + off, nb) for off, nb in
                        shard_runs(out.shape, hbuf.array.itemsize, axis,
                                   lo, hi)]
                if self.switch is None:
                    legs.append(self._leg(self.ports[i], eng, "read",
                                          b.addr, b.nbytes, name))
                    legs.append(self._leg(self.host_link, eng, "write", 0,
                                          0, name, runs=runs))
                else:
                    journeys.append(self._journey(
                        i, "h", eng, [(b.addr, b.nbytes)], runs,
                        name, name))
            done = (self._issue_legs(legs) if self.switch is None
                    else self._issue_journeys(journeys))
            self.time = max(self.time, done)
        np.copyto(hbuf.array, out)
        self._cover("gather")
        return done

    # ---------------------------------------------------------- collective
    def all_reduce(self, name: str, op: str = "sum") -> float:
        """Ring all-reduce over every device's ``name`` buffer: N-1
        reduce-scatter steps then N-1 all-gather steps.  Each step moves
        one chunk per device to its ring neighbour, so every port carries
        a tx and an rx leg simultaneously — the legs contend on the port
        link, which is where the modeled inter-device stalls come from.

        The accumulation order per chunk is fixed by the ring, so results
        (and the transaction-log digest) reproduce exactly run-to-run.

        With a topology installed the collective instead runs
        **hierarchically** (``_all_reduce_routed``): members reduce onto
        their switch-local leader, leaders tree-reduce across the
        network, then the result tree- and locally-broadcasts back —
        the locality-exploiting shape the routed interconnect rewards.
        """
        if op not in ("sum", "max"):
            raise ValueError(f"unsupported all_reduce op {op!r}")
        bufs = [self.devices[i].mem.buffers[name] for i in range(self.n)]
        shape = bufs[0].array.shape
        for i, b in enumerate(bufs):
            if b.array.shape != shape:
                raise ValueError(
                    f"all_reduce({name!r}): device {i} shard {b.array.shape}"
                    f" != device 0 shard {shape}")
        self._cover("all_reduce")
        if self.n == 1:
            return self.time
        flat = [b.array.reshape(-1) for b in bufs]
        itemsize = bufs[0].array.itemsize
        combine = (lambda a, b: a + b) if op == "sum" else np.maximum
        if self.switch is not None:
            return self._all_reduce_routed(name, bufs, flat, combine)
        splits = np.array_split(np.arange(flat[0].size), self.n)
        bounds = [(int(ix[0]), int(ix[-1]) + 1) if len(ix) else (0, 0)
                  for ix in splits]

        def step(chunk_of: Callable[[int], int], reduce_leg: bool) -> None:
            sends, legs = [], []
            for i in range(self.n):
                j = (i + 1) % self.n
                lo, hi = bounds[chunk_of(i)]
                if lo == hi:        # degenerate chunk (more devices than
                    continue        # elements): nothing moves, no burst
                nbytes = (hi - lo) * itemsize
                eng = f"d{i}->d{j}"
                legs.append(self._leg(self.ports[i], eng, "read",
                                      bufs[i].addr + lo * itemsize,
                                      nbytes, name))
                legs.append(self._leg(self.ports[j], eng, "write",
                                      bufs[j].addr + lo * itemsize,
                                      nbytes, name))
                sends.append((j, lo, hi, flat[i][lo:hi].copy()))
            self.time = max(self.time, self._issue_legs(legs))
            for j, lo, hi, data in sends:
                if reduce_leg:
                    flat[j][lo:hi] = combine(flat[j][lo:hi], data)
                else:
                    flat[j][lo:hi] = data

        # one op mark per ring leg: the profiler's all_reduce attribution
        # (which reduce-scatter / all-gather step paid which stalls)
        for s in range(self.n - 1):             # reduce-scatter
            with self._mark("all_reduce", f"reduce_scatter[{s}]"):
                step(lambda i, s=s: (i - s) % self.n, True)
        for s in range(self.n - 1):             # all-gather
            with self._mark("all_reduce", f"all_gather[{s}]"):
                step(lambda i, s=s: (i + 1 - s) % self.n, False)
        return self.time

    def _all_reduce_routed(self, name: str, bufs, flat,
                           combine: Callable) -> float:
        """Hierarchical all_reduce over the switch graph, four phases:
        switch-local members reduce onto their group leader
        (``local_reduce``), leaders tree-reduce across the network with
        stride doubling (``tree_reduce``), the result walks back down the
        tree (``tree_bcast``), and leaders rebroadcast locally
        (``local_bcast``).  Every transfer is a full-buffer routed
        journey; within a round no device is both sender and receiver,
        and combines apply in pair-list order, so results and digests
        reproduce exactly."""
        groups = self.topology.groups()
        leaders = [g[0] for g in groups]

        def xfer(pairs: List[Tuple[int, int]], label: str,
                 reduce_leg: bool) -> None:
            if not pairs:
                return
            with self._mark("all_reduce", label):
                journeys = [self._journey(
                    s, d, f"d{s}->d{d}", [(bufs[s].addr, bufs[s].nbytes)],
                    [(bufs[d].addr, bufs[d].nbytes)], name, name)
                    for s, d in pairs]
                self.time = max(self.time, self._issue_journeys(journeys))
                for s, d in pairs:
                    if reduce_leg:
                        flat[d][:] = combine(flat[d], flat[s])
                    else:
                        flat[d][:] = flat[s]

        max_members = max(len(g) for g in groups)
        for r in range(1, max_members):         # members -> leaders
            xfer([(g[r], g[0]) for g in groups if len(g) > r],
                 f"local_reduce[{r - 1}]", True)
        stride, rnd = 1, 0                      # leaders tree-reduce
        while stride < len(leaders):
            xfer([(leaders[i], leaders[i - stride])
                  for i in range(stride, len(leaders), 2 * stride)],
                 f"tree_reduce[{rnd}]", True)
            stride *= 2
            rnd += 1
        rnd = 0                                 # tree broadcast back down
        while stride > 1:
            stride //= 2
            xfer([(leaders[i - stride], leaders[i])
                  for i in range(stride, len(leaders), 2 * stride)],
                 f"tree_bcast[{rnd}]", False)
            rnd += 1
        for r in range(1, max_members):         # leaders -> members
            xfer([(g[0], g[r]) for g in groups if len(g) > r],
                 f"local_bcast[{r - 1}]", False)
        return self.time

    def collect_replicated(self, name: str, src_dev: int = 0) -> float:
        """Pull one device's replica of ``name`` back to the host buffer
        (allocated on first collect) — the writeback leg for ops whose
        output is replicated rather than sharded (sharded_launch)."""
        buf = self.devices[src_dev].mem.buffers[name]
        if name not in self.host.buffers:
            self.host.alloc(name, buf.array.shape, buf.array.dtype)
        eng = f"d{src_dev}->h"
        with self._mark("collect_replicated", name):
            if self.switch is None:
                done = self._issue_legs([
                    self._leg(self.ports[src_dev], eng, "read", buf.addr,
                              buf.nbytes, name),
                    self._leg(self.host_link, eng, "write",
                              self.host.buffers[name].addr, buf.nbytes,
                              name)])
            else:
                done = self._issue_journeys([self._journey(
                    src_dev, "h", eng, [(buf.addr, buf.nbytes)],
                    [(self.host.buffers[name].addr, buf.nbytes)],
                    name, name)])
            self.time = max(self.time, done)
        np.copyto(self.host.buffers[name].array, buf.array)
        return done

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict[str, Any]:
        """Whole-cluster snapshot at a transaction boundary
        (core/replay.py): every device bridge, the host staging DDR (whose
        transaction log IS the fabric log), every port arbiter, the shared
        host channel, the fabric clock, and the fabric-level fault plan."""
        return {
            "devices": [d.get_state() for d in self.devices],
            "host": self.host.get_state(),
            "host_link": self.host_link.get_state(),
            "ports": [p.get_state() for p in self.ports],
            "switch": (self.switch.get_state()
                       if self.switch is not None else None),
            "time": self.time,
            "fault_plan": (self.fault_plan.get_state()
                           if self.fault_plan is not None else None),
            "counters": [b.get_state() for b in self._counter_banks],
        }

    def set_state(self, state: Dict[str, Any]) -> None:
        for d, s in zip(self.devices, state["devices"]):
            d.set_state(s)
        self.host.set_state(state["host"])
        self.host_link.set_state(state["host_link"])
        for p, s in zip(self.ports, state["ports"]):
            p.set_state(s)
        if self.switch is not None and state.get("switch") is not None:
            self.switch.set_state(state["switch"])
        self.time = state["time"]
        if state["fault_plan"] is not None:
            self.fault_plan.set_state(state["fault_plan"])
        for b, s in zip(self._counter_banks, state.get("counters") or []):
            b.set_state(s)

    # --------------------------------------------------------- diagnostics
    def link_stats(self) -> Dict[str, CongestionResult]:
        """Per-link Fig. 8 statistics: the host channel, every device
        port, and (routed fabrics) every switch port as ``sw:a->b``."""
        out = {"host": self.host_link.result()}
        for i, p in enumerate(self.ports):
            out[f"d{i}"] = p.result()
        if self.switch is not None:
            for label, link in self.switch.labeled_links():
                out[f"sw:{label}"] = link.result()
        return out

    def total_link_stall(self) -> float:
        return sum(sum(r.per_engine_stall.values())
                   for r in self.link_stats().values())

    def profiler(self, label: Optional[str] = None):
        """Data-movement profile of the whole cluster (core/profiler.py):
        one channel per fabric port plus the shared host channel and every
        device's DDR/CSR, with per-collective-leg op attribution."""
        from repro.core.profiler import DataMovementProfiler
        return DataMovementProfiler(self, label=label or self.name)

    def device_congestion(self) -> Optional[CongestionResult]:
        """Merged per-device DDR-link statistics (engines prefixed
        ``d{i}/``), or None when the devices run congestion-free — so
        cross-scale sweeps keep reporting device-local memory stalls, not
        just fabric-link stalls."""
        per = [(i, r) for i, d in enumerate(self.devices)
               if (r := d.congestion_stats()) is not None]
        if not per:
            return None
        stall = {f"d{i}/{e}": v for i, r in per
                 for e, v in r.per_engine_stall.items()}
        busy = {f"d{i}/{e}": v for i, r in per
                for e, v in r.per_engine_busy.items()}
        makespan = max(r.makespan for _, r in per)
        util = sum(r.link_utilization for _, r in per) / len(per)
        timeline = [t for _, r in per for t in r.timeline]
        return CongestionResult(makespan=makespan, per_engine_stall=stall,
                                per_engine_busy=busy, link_utilization=util,
                                timeline=timeline)

    @property
    def violations(self) -> List[str]:
        out = list(self.log.violations)
        for i, d in enumerate(self.devices):
            out += [f"[d{i}] {v}" for v in d.log.violations]
        return out

    def fault_events(self) -> List:
        """Every fault injected anywhere in the cluster (fabric links plus
        per-device plans), for CellResult/fuzz auditing."""
        evs = list(self.fault_plan.events) if self.fault_plan else []
        for d in self.devices:
            if d.mem.fault_plan is not None:
                evs += list(d.mem.fault_plan.events)
        return evs

    def outputs(self) -> Dict[str, np.ndarray]:
        """Host-visible final state (the cross-scale equivalence surface)."""
        return {n: b.array.copy() for n, b in self.host.buffers.items()}

    def digest(self) -> str:
        """sha256 over the fabric log and every device log — the same-seed
        reproducibility witness for multi-device runs."""
        h = hashlib.sha256()
        h.update(self.log.digest().encode())
        for d in self.devices:
            h.update(d.log.digest().encode())
        return h.hexdigest()


def sharded_launch(fab: FabricCluster, op: str, backend: str, *,
                   inputs: Dict[str, np.ndarray],
                   output: Tuple[str, Tuple[int, ...], Any],
                   specs: Dict[str, Any],
                   burst_list: Optional[Callable] = None) -> None:
    """Run one op sharded across the cluster via sharding/specs.py layouts.

    ``specs`` maps buffer name -> PartitionSpec; dims named "fabric" are
    scattered across devices, unsharded inputs are broadcast, and the
    output is gathered back to the host.  ``burst_list(dev, shapes)``
    derives the device-local DMA burst list from that device's shard
    shapes.  Because the layouts never split a reduction axis, the
    gathered result is bit-identical to the single-device run.
    """
    from repro.sharding.specs import fabric_shard_axis

    for name, arr in inputs.items():
        arr = np.asarray(arr)
        if name not in fab.host.buffers:
            fab.host.alloc(name, arr.shape, arr.dtype)
        fab.host.host_write(name, arr)
        ax = fabric_shard_axis(specs[name])
        if ax is None:
            fab.broadcast(name)
        else:
            fab.scatter(name, axis=ax)

    oname, oshape, odtype = output
    oax = fabric_shard_axis(specs[oname])
    fab.alloc_sharded(oname, oshape, odtype, axis=oax)
    for i in range(fab.n):
        shapes = {n: fab.devices[i].mem.buffers[n].array.shape
                  for n in list(inputs) + [oname]}
        bl = ((lambda i=i, shapes=shapes: burst_list(i, shapes))
              if burst_list is not None else None)
        fab.launch(i, op, backend, list(inputs), [oname], burst_list=bl)

    if oax is not None:
        fab.gather(oname, axis=oax)
    else:                      # replicated output: device 0's copy crosses
        fab.collect_replicated(oname)
