"""Post-SPMD HLO text profiler: the FireBridge "bus transaction monitor"
adapted to XLA, and the engine behind §Roofline.

Why text parsing: ``compiled.cost_analysis()`` counts while-loop (scan) bodies
exactly ONCE — a 40-layer scanned model reports ~1 layer of FLOPs.  This
module parses ``compiled.as_text()`` (post-SPMD, so shapes are per-device and
GSPMD-inserted collectives are visible), builds the computation call graph,
extracts trip counts from while-condition constants, and multiplies per-op
costs through the graph.  It also emits the per-op collective "transaction
stream" consumed by the congestion emulator (core/congestion.py) and the
§Perf diagnostics (duplicate all-gathers, layout-change copies, ...).

Cost models (documented methodology — see EXPERIMENTS.md §Roofline):
  * FLOPs: 2 * out_elems * contracted_elems for every ``dot`` (+ conv),
    trip-multiplied.  Elementwise flops are excluded (matmul-dominated
    workloads; cost_analysis() is reported alongside for reference).
  * HBM traffic: for every non-free op, operand+result bytes at fusion
    granularity (XLA fusions are memory-bound kernels whose HBM traffic is
    their operands+outputs).  dynamic-(update-)slice counts slice bytes only
    (XLA performs them in place).
  * Collective bytes per device: ring formulas — all-reduce 2(g-1)/g * n,
    all-gather/reduce-scatter/all-to-all (g-1)/g * n, collective-permute n.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "iota",
    "while", "conditional", "call", "custom-call",
}


def _type_bytes_elems(type_str: str) -> Tuple[int, int]:
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool = False

    @property
    def result_bytes(self) -> int:
        return _type_bytes_elems(self.type_str)[0]

    @property
    def result_elems(self) -> int:
        return _type_bytes_elems(self.type_str)[1]

    def result_dims(self) -> List[int]:
        m = _SHAPE_RE.search(self.type_str)
        if not m:
            return []
        return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class CollectiveRecord:
    kind: str
    op_name: str
    computation: str
    shape: str
    bytes_full: int          # tensor bytes (per device view)
    bytes_moved: int         # ring-model bytes over the wire per device
    group_size: int
    multiplier: int

    @property
    def total_bytes(self) -> int:
        return self.bytes_moved * self.multiplier


@dataclasses.dataclass
class DotRecord:
    op_name: str
    computation: str
    shape: str
    flops: float             # per execution
    multiplier: int
    jax_path: str            # from metadata op_name (source attribution)

    @property
    def total_flops(self) -> float:
        return self.flops * self.multiplier


@dataclasses.dataclass
class Profile:
    flops: float
    traffic_bytes: float
    collective_bytes: float
    collectives: List[CollectiveRecord]
    dot_count: int
    warnings: List[str]
    per_comp_mult: Dict[str, int]
    dots: List[DotRecord] = dataclasses.field(default_factory=list)

    def top_dots(self, n: int = 15) -> List[DotRecord]:
        return sorted(self.dots, key=lambda d: -d.total_flops)[:n]

    def top_collectives(self, n: int = 15) -> List[CollectiveRecord]:
        return sorted(self.collectives, key=lambda c: -c.total_bytes)[:n]

    def collective_summary(self) -> Dict[str, Tuple[int, float]]:
        agg: Dict[str, List[float]] = defaultdict(lambda: [0, 0.0])
        for c in self.collectives:
            agg[c.kind][0] += c.multiplier
            agg[c.kind][1] += c.total_bytes
        return {k: (int(v[0]), v[1]) for k, v in agg.items()}


def _parse_computations(text: str) -> Dict[str, Tuple[List[Op], bool]]:
    comps: Dict[str, Tuple[List[Op], bool]] = {}
    cur: Optional[str] = None
    ops: List[Op] = []
    is_entry = False
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    is_entry = line.lstrip().startswith("ENTRY")
                    ops = []
            continue
        if line.strip() == "}":
            comps[cur] = (ops, is_entry)
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, tstr, opcode, rest = m.groups()
            operand_refs = re.findall(r"%([\w.\-]+)", rest)
            ops.append(Op(name=name, type_str=tstr, opcode=opcode,
                          operands=operand_refs, attrs=rest,
                          is_root="ROOT" in line[:12]))
    return comps


def _trip_count(cond_ops: List[Op]) -> int:
    best = 1
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.attrs or "")
            # attrs holds text after "constant(" already split; reconstruct:
            if not m:
                m = re.search(r"^(\d+)\)", op.attrs)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _group_size(attrs: str, world: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return world


def _dot_flops(op: Op, by_name: Dict[str, Op], warnings: List[str]) -> float:
    out_elems = op.result_elems
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    lhs = by_name.get(op.operands[0]) if op.operands else None
    if lhs is None or m is None:
        warnings.append(f"dot {op.name}: missing lhs shape; counted 2*out")
        return 2.0 * out_elems
    dims = lhs.result_dims()
    contracted = 1
    if m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(dims):
                contracted *= dims[idx]
    return 2.0 * out_elems * contracted


def _op_traffic(op: Op, by_name: Dict[str, Op]) -> int:
    oc = op.opcode
    if oc in _FREE_OPS or oc in _COLLECTIVES:
        return 0
    if oc in ("dynamic-update-slice",):
        upd = by_name.get(op.operands[1]) if len(op.operands) > 1 else None
        return 2 * (upd.result_bytes if upd else 0)
    if oc in ("dynamic-slice", "copy", "transpose", "broadcast", "convert"):
        return 2 * op.result_bytes
    # general: operands + result
    total = op.result_bytes
    for o in op.operands:
        src = by_name.get(o)
        if src is not None:
            total += src.result_bytes
    return total


def profile_hlo(text: str, world_size: int) -> Profile:
    comps = _parse_computations(text)
    warnings: List[str] = []
    entry = None
    for name, (_, is_entry) in comps.items():
        if is_entry:
            entry = name
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # call graph edges
    flops_mult: Dict[str, float] = defaultdict(float)
    bytes_mult: Dict[str, float] = defaultdict(float)
    flops_mult[entry] = 1.0
    bytes_mult[entry] = 1.0

    # process in BFS order from entry
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        comp = order[i]
        i += 1
        ops, _ = comps.get(comp, ([], False))
        fm, bm = flops_mult[comp], bytes_mult[comp]
        for op in ops:
            a = op.attrs
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", a)
                mc = re.search(r"condition=%?([\w.\-]+)", a)
                if mb and mc:
                    trip = _trip_count(comps.get(mc.group(1), ([], False))[0])
                    for child, mult_f, mult_b in (
                            (mb.group(1), fm * trip, bm * trip),
                            (mc.group(1), 0.0, 0.0)):
                        flops_mult[child] += mult_f
                        bytes_mult[child] += mult_b
                        if child not in seen:
                            seen.add(child)
                            order.append(child)
            elif op.opcode == "fusion":
                mf = re.search(r"calls=%?([\w.\-]+)", a)
                if mf:
                    child = mf.group(1)
                    flops_mult[child] += fm     # dots inside fusions count
                    # bytes counted at the callsite, not inside
                    if child not in seen:
                        seen.add(child)
                        order.append(child)
            elif op.opcode in ("call", "async-start"):
                mf = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", a)
                if mf:
                    child = mf.group(1)
                    flops_mult[child] += fm
                    bytes_mult[child] += bm
                    if child not in seen:
                        seen.add(child)
                        order.append(child)
            elif op.opcode == "conditional":
                for mf in re.finditer(
                        r"(?:true_computation|false_computation|branch_computations=\{[^}]*)=?%?([\w.\-]+)", a):
                    child = mf.group(1)
                    if child in comps:
                        flops_mult[child] += fm
                        bytes_mult[child] += bm
                        if child not in seen:
                            seen.add(child)
                            order.append(child)

    total_flops = 0.0
    total_traffic = 0.0
    total_coll = 0.0
    dot_count = 0
    coll_records: List[CollectiveRecord] = []
    dot_records: List[DotRecord] = []

    for comp, (ops, _) in comps.items():
        fm = flops_mult.get(comp, 0.0)
        bm = bytes_mult.get(comp, 0.0)
        if fm == 0 and bm == 0:
            continue
        by_name = {op.name: op for op in ops}
        for op in ops:
            oc = op.opcode
            base = oc.replace("-start", "")
            if base in _COLLECTIVES and not oc.endswith("-done"):
                g = _group_size(op.attrs, world_size)
                if base == "all-gather":
                    nb = op.result_bytes
                    moved = nb * (g - 1) // max(g, 1)
                elif base == "reduce-scatter":
                    src = by_name.get(op.operands[0]) if op.operands else None
                    nb = src.result_bytes if src else op.result_bytes * g
                    moved = nb * (g - 1) // max(g, 1)
                elif base == "all-reduce":
                    nb = op.result_bytes
                    moved = 2 * nb * (g - 1) // max(g, 1)
                elif base == "all-to-all":
                    nb = op.result_bytes
                    moved = nb * (g - 1) // max(g, 1)
                else:  # collective-permute
                    nb = op.result_bytes
                    moved = nb
                rec = CollectiveRecord(
                    kind=base, op_name=op.name, computation=comp,
                    shape=op.type_str, bytes_full=nb, bytes_moved=moved,
                    group_size=g, multiplier=int(max(bm, fm)))
                coll_records.append(rec)
                total_coll += rec.total_bytes
                continue
            if oc in ("dot", "convolution"):
                dot_count += 1
                if fm:
                    fl = _dot_flops(op, by_name, warnings)
                    total_flops += fm * fl
                    mpath = re.search(r'op_name="([^"]*)"', op.attrs)
                    dot_records.append(DotRecord(
                        op_name=op.name, computation=comp, shape=op.type_str,
                        flops=fl, multiplier=int(fm),
                        jax_path=mpath.group(1) if mpath else ""))
                if bm:
                    total_traffic += bm * _op_traffic(op, by_name)
                continue
            if bm:
                total_traffic += bm * _op_traffic(op, by_name)

    return Profile(flops=total_flops, traffic_bytes=total_traffic,
                   collective_bytes=total_coll, collectives=coll_records,
                   dot_count=dot_count, warnings=warnings,
                   per_comp_mult={k: int(v) for k, v in flops_mult.items()},
                   dots=dot_records)


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e target constants per the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achievable if the program ran at
        the max(terms) bound: ideal_compute_time / bound_time."""
        ideal = self.model_flops / PEAK_FLOPS_BF16
        return ideal / self.bound_s if self.bound_s else 0.0


def roofline(profile: Profile, model_flops_per_device: float,
             n_links: int = 1) -> RooflineTerms:
    return RooflineTerms(
        compute_s=profile.flops / PEAK_FLOPS_BF16,
        memory_s=profile.traffic_bytes / HBM_BW,
        collective_s=profile.collective_bytes / (n_links * ICI_BW_PER_LINK),
        model_flops=model_flops_per_device,
        hlo_flops=profile.flops,
    )
