"""Memory-mapped register file + the fb_read_32/fb_write_32 protocol
(paper §IV-A).

The register file is the control plane of every "accelerator" in this repo:
the serving engine, the co-verification examples, and the protocol fuzz
tests all drive hardware-style CSRs through these two calls.  Accesses are
transaction-logged; protocol violations (unmapped address, RO write,
doorbell-while-busy) are recorded rather than raised, so randomized
protocol tests can assert on them — the software analogue of the paper's
"register-level protocol testing".
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.transactions import Transaction, TransactionLog

RO = "ro"
RW = "rw"
W1C = "w1c"          # write-1-to-clear (interrupt/status style)
DOORBELL = "doorbell"  # write triggers an action callback


@dataclasses.dataclass
class Register:
    name: str
    addr: int
    access: str = RW
    reset: int = 0
    on_write: Optional[Callable[[int], None]] = None   # doorbell action
    # invoked before each fb_read_32 returns, so hardware can refresh
    # status bits the moment firmware looks at them (poll-driven devices)
    on_read: Optional[Callable[[], None]] = None


class RegisterFile:
    """32-bit register space with FireBridge access semantics."""

    def __init__(self, name: str = "csr",
                 log: Optional[TransactionLog] = None) -> None:
        self.name = name
        self.log = log if log is not None else TransactionLog()
        self._by_addr: Dict[int, Register] = {}
        self._val: Dict[int, int] = {}
        self.time = 0.0

    def define(self, name: str, addr: int, access: str = RW, reset: int = 0,
               on_write: Optional[Callable[[int], None]] = None,
               on_read: Optional[Callable[[], None]] = None) -> Register:
        if addr in self._by_addr:
            raise ValueError(f"register address collision at {addr:#x}")
        if addr % 4:
            raise ValueError(f"register {name} not 4-byte aligned: {addr:#x}")
        reg = Register(name, addr, access, reset, on_write, on_read)
        self._by_addr[addr] = reg
        self._val[addr] = reset & 0xFFFFFFFF
        return reg

    def addr_of(self, name: str) -> int:
        for r in self._by_addr.values():
            if r.name == name:
                return r.addr
        raise KeyError(name)

    # ------------------------------------------------------------ protocol
    def fb_read_32(self, addr: int) -> int:
        self.time += 1
        self.log.log(Transaction(self.time, self.name, "read", addr, 4))
        reg = self._by_addr.get(addr)
        if reg is None:
            self.log.violation(f"read from unmapped address {addr:#x}")
            return 0xDEADBEEF
        if reg.on_read is not None:
            reg.on_read()
        return self._val[addr]

    def fb_write_32(self, addr: int, data: int) -> None:
        self.time += 1
        self.log.log(Transaction(self.time, self.name, "write", addr, 4))
        reg = self._by_addr.get(addr)
        data &= 0xFFFFFFFF
        if reg is None:
            self.log.violation(f"write to unmapped address {addr:#x}")
            return
        if reg.access == RO:
            self.log.violation(
                f"write to read-only register {reg.name} @ {addr:#x}")
            return
        if reg.access == W1C:
            self._val[addr] &= ~data & 0xFFFFFFFF
        else:
            self._val[addr] = data
        if reg.on_write is not None:
            reg.on_write(data)

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict:
        """Register values + protocol clock for a replay checkpoint
        (core/replay.py).  The register *map* (define() calls, hooks) is
        structure, not state — a restored file must already have it."""
        return {"vals": dict(self._val), "time": self.time}

    def set_state(self, state: Dict) -> None:
        self._val.clear()
        self._val.update(state["vals"])
        self.time = state["time"]

    # ------------------------------------------------- hardware-side access
    def hw_set(self, name: str, value: int) -> None:
        """Hardware-side status update (not a bus transaction)."""
        self._val[self.addr_of(name)] = value & 0xFFFFFFFF

    def hw_get(self, name: str) -> int:
        return self._val[self.addr_of(name)]

    def poll(self, name: str, mask: int, value: int,
             max_reads: int = 10_000, strict: bool = False) -> int:
        """Poll a status register until (reg & mask) == value.

        Returns the number of reads on success.  On timeout a violation is
        recorded and -1 is returned — distinguishable from a success on the
        final read, which returns ``max_reads`` — or, with ``strict=True``,
        ``TimeoutError`` is raised instead.
        """
        addr = self.addr_of(name)
        for n in range(1, max_reads + 1):
            if (self.fb_read_32(addr) & mask) == value:
                return n
        self.log.violation(f"poll timeout on {name} mask={mask:#x}")
        if strict:
            raise TimeoutError(
                f"poll timeout on {name} mask={mask:#x} value={value:#x} "
                f"after {max_reads} reads")
        return -1
