"""FireBridge core: the paper's contribution as a composable subsystem.

  registers     — fb_read_32/fb_write_32 CSR protocol (paper §IV-A)
  transactions  — burst log + bandwidth/heatmap profiling (Figs. 8, 9)
  bridge        — DDR memory bridge + multi-backend accelerator launch (§IV)
  congestion    — seeded interconnect contention / DoS emulator, online
                  LinkModel + offline replay (§IV-C)
  equivalence   — oracle ≡ interpret ≡ compiled checking w/ localization
  coverify      — one-call co-verification driver (debug-iteration unit)
  scheduler     — batched multi-backend sweep scheduler (Fig. 5 at scale)
  fuzz          — seeded fault injection + randomized protocol stimulus
                  with differential checking and trace shrinking
  hlo_profiler  — compiled-HLO transaction extraction + roofline terms
"""
from repro.core.bridge import Buffer, FireBridge, MemoryBridge
from repro.core.congestion import (CongestionConfig, CongestionResult,
                                   LinkModel, simulate)
from repro.core.coverify import CoverifyResult, coverify
from repro.core.equivalence import (EquivalenceReport, check_equivalence,
                                    compare_outputs)
from repro.core.fuzz import (FaultEvent, FaultPlan, FuzzReport,
                             ProtocolFuzzer, run_fuzz)
from repro.core.registers import DOORBELL, RO, RW, W1C, RegisterFile
from repro.core.scheduler import (CellResult, CoVerifySession, SweepCell,
                                  SweepReport, run_sequential)
from repro.core.transactions import Transaction, TransactionLog

__all__ = [
    "Buffer", "FireBridge", "MemoryBridge", "CongestionConfig",
    "CongestionResult", "LinkModel", "simulate", "CoverifyResult",
    "coverify", "EquivalenceReport", "check_equivalence", "compare_outputs",
    "FaultEvent", "FaultPlan", "FuzzReport", "ProtocolFuzzer", "run_fuzz",
    "RegisterFile", "RO", "RW", "W1C", "DOORBELL", "CellResult",
    "CoVerifySession", "SweepCell", "SweepReport", "run_sequential",
    "Transaction", "TransactionLog",
]
