"""FireBridge core: the paper's contribution as a composable subsystem.

  registers     — fb_read_32/fb_write_32 CSR protocol (paper §IV-A)
  transactions  — burst log + bandwidth/heatmap profiling (Figs. 8, 9)
  bridge        — DDR memory bridge + multi-backend accelerator launch (§IV)
  congestion    — seeded interconnect contention / DoS emulator, online
                  LinkModel + offline replay (§IV-C)
  equivalence   — oracle ≡ interpret ≡ compiled checking w/ localization
  coverify      — one-call co-verification driver (debug-iteration unit)
  scheduler     — batched multi-backend sweep scheduler (Fig. 5 at scale)
  fabric        — multi-device cluster with modeled interconnect: per-port
                  links + shared host channel, sharded launches, ring
                  all_reduce (FireSim-style scale-out)
  topology      — switched-interconnect shapes (ring / 2D-torus / fat
                  tree) with static routing tables
  switch        — modeled flit switch layer: per-port arbitration +
                  credit-based flow control over the topology graph
  coverage      — functional-coverage bins over protocol/burst/congestion/
                  fault/fabric stimulus, fed by fuzz + fabric
  fuzz          — seeded fault injection + randomized protocol stimulus
                  with differential checking and trace shrinking
  profiler      — off-chip data-movement profiling: exhaustive stall
                  attribution closing to bridge.time, per-op/-engine
                  timelines, Perfetto export, roofline placement (§IV)
  replay        — time-travel debug engine: timeline recording, full-state
                  checkpoints at transaction boundaries, bit-identical
                  window replay, divergence bisection in O(log N) probes
                  + 2 window replays
  hlo_profiler  — compiled-HLO transaction extraction + roofline terms
"""
from repro.core.bridge import Buffer, FireBridge, MemoryBridge
from repro.core.congestion import (CongestionConfig, CongestionResult,
                                   LinkModel, simulate)
from repro.core.coverage import CoverageModel
from repro.core.coverify import CoverifyResult, coverify
from repro.core.equivalence import (EquivalenceReport, check_equivalence,
                                    compare_outputs)
from repro.core.fabric import FABRIC_LINK, FabricCluster, sharded_launch
from repro.core.fuzz import (FaultEvent, FaultPlan, FuzzReport,
                             ProtocolFuzzer, run_fuzz)
from repro.core.profiler import (CATEGORIES, DataMovementProfiler,
                                 RooflinePlacement, StallBreakdown,
                                 profile_recording, profile_window,
                                 validate_trace)
from repro.core.registers import DOORBELL, RO, RW, W1C, RegisterFile
from repro.core.replay import (DebugSession, DivergenceReport, Recording,
                               RecordingBridge, ReplayWindow,
                               bisect_divergence, record_serving_storm)
from repro.core.scheduler import (CellResult, CoVerifySession, SweepCell,
                                  SweepReport, run_sequential)
from repro.core.switch import SwitchFabric, SwitchPort
from repro.core.topology import (TOPOLOGY_KINDS, Topology, build_topology,
                                 fat_tree, ring, torus2d)
from repro.core.transactions import Transaction, TransactionLog

__all__ = [
    "Buffer", "FireBridge", "MemoryBridge", "CongestionConfig",
    "CongestionResult", "LinkModel", "simulate", "CoverageModel",
    "CoverifyResult", "coverify", "EquivalenceReport", "check_equivalence",
    "compare_outputs", "FABRIC_LINK", "FabricCluster", "sharded_launch",
    "FaultEvent", "FaultPlan", "FuzzReport", "ProtocolFuzzer", "run_fuzz",
    "RegisterFile", "RO", "RW", "W1C", "DOORBELL", "CellResult",
    "CoVerifySession", "SweepCell", "SweepReport", "run_sequential",
    "Transaction", "TransactionLog", "DebugSession", "DivergenceReport",
    "Recording", "RecordingBridge", "ReplayWindow", "bisect_divergence",
    "record_serving_storm", "CATEGORIES", "DataMovementProfiler",
    "RooflinePlacement", "StallBreakdown", "profile_recording",
    "profile_window", "validate_trace", "Topology", "build_topology",
    "ring", "torus2d", "fat_tree", "TOPOLOGY_KINDS", "SwitchFabric",
    "SwitchPort",
]
