"""Batched multi-backend co-verification scheduler (paper §V / Fig. 5).

One debug iteration in the paper is: edit firmware, re-simulate, re-check
equivalence.  At sweep scale — many ops x backends x configs — running
those iterations one at a time leaves the simulator idle while Python sets
up the next cell and recompiles backends it has already compiled.  The
``CoVerifySession`` scheduler batches the sweep:

* a sweep **cell** is one ``(op, backend, config)`` triple, executed as
  firmware against a fresh ``FireBridge`` (optionally with the online
  congestion link, §IV-C);
* backend callables are registered **once per session** and shared across
  every cell, so jitted/compiled executables are cached across the sweep
  instead of re-traced per iteration (the FireSim-style "build once, run
  many" economy);
* independent cells run **concurrently** on a thread pool — interpret-mode
  Pallas, XLA, and NumPy all release the GIL during compute, so
  oracle/interpret/compiled cells overlap on wall-clock;
* results are grouped by ``(op, config)`` and diffed across backends via
  ``equivalence.compare_outputs``, producing a structured ``SweepReport``
  with per-cell timing, stall statistics, and localized divergences.

benchmarks/bench_debug_iteration.py measures this scheduler against the
sequential per-op loop on a >=8-cell sweep (the Fig. 5 batched lane).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.bridge import FireBridge
from repro.core.congestion import CongestionConfig, CongestionResult
from repro.core.coverage import CoverageModel
from repro.core.equivalence import EquivalenceReport, compare_outputs
from repro.core.fabric import FabricCluster
from repro.core.fuzz import FaultEvent, FaultPlan


def _freeze(v: Any) -> Tuple:
    """Structural, hashable identity of one config value.

    ``repr`` is NOT identity here: equal numpy arrays are distinct objects
    (and large ones truncate to "..." making *unequal* arrays collide), and
    dataclasses with equal fields repr differently once they hold arrays.
    Hash by structure instead — ndarray by shape/dtype/content digest,
    dataclasses and containers recursively — so equal-valued configs land
    in the same cross-backend equivalence group.
    """
    if isinstance(v, np.ndarray):
        return ("ndarray", v.shape, str(v.dtype),
                hashlib.sha256(np.ascontiguousarray(v).tobytes())
                .hexdigest())
    if isinstance(v, np.generic):
        # bit-pattern identity, not value identity: item() would make
        # NaN-valued configs unequal to themselves and silently split
        # their equivalence group
        return ("npscalar", str(v.dtype), v.tobytes())
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return (type(v).__name__,
                tuple((f.name, _freeze(getattr(v, f.name)))
                      for f in dataclasses.fields(v)))
    if isinstance(v, dict):
        return ("dict", tuple(sorted((str(k), _freeze(x))
                                     for k, x in v.items())))
    if isinstance(v, (list, tuple)):
        return (type(v).__name__, tuple(_freeze(x) for x in v))
    if isinstance(v, (set, frozenset)):
        return ("set", tuple(sorted(repr(_freeze(x)) for x in v)))
    return (type(v).__name__, repr(v))


def _config_key(config: Dict[str, Any]) -> Tuple:
    """Hashable identity of a cell config (for cross-backend grouping)."""
    return tuple(sorted((k, _freeze(v)) for k, v in config.items()))


@dataclasses.dataclass
class SweepCell:
    """One sweep point: run ``op`` on ``backend`` with ``config`` kwargs.

    Cells sharing ``(op, config)`` across different backends form one
    equivalence group — the paper's golden-model / RTL-sim / deployment
    triangle (Fig. 1) evaluated at one design point.

    ``fault_plan`` is the randomized-stimulus sweep axis (core/fuzz.py):
    when set, the cell's bridge runs fault-injected — each cell forks its
    own deterministic child plan, so concurrent cells reproduce exactly.

    ``devices`` is the scale-out sweep axis: cells with devices > 1 run on
    a ``FabricCluster`` (core/fabric.py) and their gathered host state is
    equivalence-checked against the single-device cells of the same
    ``(op, config)`` group — outputs must match across scales, while the
    modeled link statistics are reported per scale.

    ``topology`` is the interconnect sweep axis riding on ``devices``: a
    core/topology.py builder name (or Topology instance) routes the
    fabric cell through a switched network instead of the crossbar.  It
    stays out of the ``(op, config)`` group key — a 2D-torus 8-device
    run diffs against the same 1-device oracle, because routing may
    reshape *timing*, never gathered results.
    """
    op: str
    backend: str
    config: Dict[str, Any] = dataclasses.field(default_factory=dict)
    congestion: Optional[CongestionConfig] = None
    fault_plan: Optional[FaultPlan] = None
    devices: int = 1
    topology: Optional[Any] = None
    # open-loop serving lane (serving/arrivals.py): when set, the cell is
    # an open-loop serving run driven by this ArrivalTrace instead of a
    # firmware run — outputs are the generated token streams, which join
    # the same cross-backend/cross-scale equivalence machinery
    serving: Optional[Any] = None

    @property
    def _topo_kind(self) -> Optional[str]:
        if self.topology is None:
            return None
        return (self.topology if isinstance(self.topology, str)
                else self.topology.kind)

    @property
    def label(self) -> str:
        cfg = ",".join(f"{k}={v}" for k, v in sorted(self.config.items()))
        dev = f"x{self.devices}dev" if self.devices > 1 else ""
        topo = f"@{self._topo_kind}" if self.topology is not None else ""
        return f"{self.op}[{cfg}]@{self.backend}{dev}{topo}"

    @property
    def timing_label(self) -> str:
        """Backend-FREE cell identity: the fault-fork label for serving
        cells, so one configuration's fault stream — and therefore its SLO
        rows and log digest — is identical across backends (the
        determinism tier in tests/test_serving_slo.py diffs them)."""
        cfg = ",".join(f"{k}={v}" for k, v in sorted(self.config.items()))
        return f"{self.op}[{cfg}]x{self.devices}dev"

    @property
    def group_member(self) -> str:
        """Key of this cell inside its (op, config) equivalence group."""
        if self.devices == 1 and self.topology is None:
            return self.backend
        member = f"{self.backend}@{self.devices}dev"
        if self.topology is not None:
            member += f"@{self._topo_kind}"
        return member


@dataclasses.dataclass
class CellResult:
    """Outcome of one executed cell."""
    cell: SweepCell
    outputs: Dict[str, np.ndarray]      # final DDR state, buffer name -> arr
    seconds: float                      # wall-clock of the firmware run
    bridge_time: float                  # modeled cycles (congestion-aware)
    congestion: Optional[CongestionResult]
    violations: List[str]
    error: Optional[str] = None
    faults: List[FaultEvent] = dataclasses.field(default_factory=list)
    # per-link Fig. 8 statistics when the cell ran on a FabricCluster
    links: Optional[Dict[str, CongestionResult]] = None
    # data-movement profile (core/profiler.py) when the session ran with
    # profile=True: per-channel stall attribution closing to bridge_time,
    # exportable to Perfetto via SweepReport.save_traces
    profile: Optional[Any] = None
    # the cell's PRIVATE functional-coverage model when the session has a
    # coverage sink: each cell feeds its own model so concurrent cells
    # cannot interleave, and run() merges them in cell order at join —
    # the merged result is identical at any max_workers
    coverage: Optional[CoverageModel] = None
    # latency-SLO report (serving/slo.py) when the cell was an open-loop
    # serving run: p50/p99 TTFT + inter-token latency in modeled cycles,
    # surfaced as extra to_rows columns
    slo: Optional[Any] = None
    # sampled performance-counter identity (core/counters.py): dict with
    # ``digest`` (full stream, comparable among cells sharing
    # ``timing_key``), ``functional`` (scale/backend-invariant digest of
    # functional-scope totals), ``totals`` (name -> cumulative value,
    # summed over banks), and ``timing_key`` — the counter-diff oracle's
    # raw material (None when the cell errored)
    counters: Optional[Dict[str, Any]] = None

    @property
    def link_stall(self) -> float:
        """Total modeled inter-device + host-channel stall cycles."""
        return sum(sum(r.per_engine_stall.values())
                   for r in (self.links or {}).values())

    @property
    def utilization(self) -> Optional[float]:
        """Primary-channel link-bandwidth utilization (None unprofiled)."""
        return (self.profile.utilization()
                if self.profile is not None else None)

    @property
    def attribution(self) -> Optional[Dict[str, float]]:
        """Stall-attribution cycles summed over the cell's channels
        (None unprofiled)."""
        return (self.profile.attribution()
                if self.profile is not None else None)


@dataclasses.dataclass
class SweepReport:
    """Structured sweep outcome (consumed by callers + benchmarks).

    ``equivalence`` holds one localized report per ``(op, config)`` group
    (cross-backend diff of final DDR state, §IV-B); ``passed`` requires
    every group equivalent, no cell errors, no protocol violations.

    ``divergences`` maps each failing group to a minimal
    ``replay.DivergenceReport``: the scheduler re-records the two
    divergent cells as replayable timelines and bisects them, so a failing
    sweep hands back the first divergent transaction + surrounding device
    state instead of just "these backends disagree" (the time-travel debug
    loop, core/replay.py).
    """
    cells: List[CellResult]
    equivalence: Dict[str, EquivalenceReport]
    wall_seconds: float
    divergences: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # merged functional coverage across all cells (deterministic cell-order
    # merge of the per-cell private models) when the session has a sink
    coverage: Optional[CoverageModel] = None
    # counter-diff oracle verdicts (core/counters.py): group label ->
    # {pair, kind, totals} for every group whose sampled counter streams
    # (same timing key) or functional totals (any scale) disagree — the
    # cheap pre-check that fires before the full output diff and
    # escalates into the replay-bisection lane
    counter_mismatches: Dict[str, Any] = dataclasses.field(
        default_factory=dict)

    @property
    def passed(self) -> bool:
        return (all(r.error is None and not r.violations for r in self.cells)
                and all(e.passed for e in self.equivalence.values())
                and not self.counter_mismatches)

    def summary(self) -> dict:
        return {
            "cells": len(self.cells),
            "groups": len(self.equivalence),
            "passed": self.passed,
            "wall_seconds": round(self.wall_seconds, 3),
            "cell_seconds_sum": round(sum(r.seconds for r in self.cells), 3),
            "failures": [g for g, e in self.equivalence.items()
                         if not e.passed] +
                        [r.cell.label for r in self.cells if r.error],
            "divergences": {g: (f"op #{d.op_index} {d.event} ({d.kind}, "
                                f"{d.n_replays} replays)"
                                if hasattr(d, "op_index") else str(d))
                            for g, d in self.divergences.items()},
            "counter_mismatches": {
                g: f"{m['kind']} mismatch: {m['pair'][0]} vs {m['pair'][1]}"
                for g, m in self.counter_mismatches.items()},
        }

    def to_rows(self, wall: bool = True) -> List[str]:
        """CSV-ish rows for benchmark output.  The utilization and
        per-category stall-attribution columns are filled when the session
        ran with ``profile=True`` (core/profiler.py), "-" otherwise.

        ``wall=False`` renders the wall-clock ``seconds`` column as "-",
        leaving only modeled/deterministic quantities — rows are then
        byte-identical at any ``max_workers`` (and across runs), which is
        what the run-farm digests and the ordering-determinism regression
        test compare."""
        from repro.core.profiler import CATEGORIES
        # SLO columns appear only when the sweep contains open-loop serving
        # cells — pure-compute sweeps keep today's schema byte-identically
        with_slo = any(r.slo is not None for r in self.cells)
        header = ("cell,backend,devices,seconds,bridge_cycles,stall_cycles,"
                  "link_stall_cycles,utilization,"
                  + ",".join(f"{c}_cycles" for c in CATEGORIES))
        if with_slo:
            header += ",p50_ttft,p99_ttft,p50_itl,p99_itl,tok_per_kcyc"
        rows = [header + ",status"]
        for r in self.cells:
            stall = (sum(r.congestion.per_engine_stall.values())
                     if r.congestion else 0.0)
            status = "error" if r.error else "ok"
            if r.profile is not None:
                att = r.attribution
                prof_cols = (f"{r.utilization:.4f},"
                             + ",".join(f"{att[c]:.0f}"
                                        for c in CATEGORIES))
            else:
                prof_cols = "-," + ",".join("-" for _ in CATEGORIES)
            if with_slo:
                if r.slo is not None:
                    s = r.slo
                    prof_cols += (f",{s.p50_ttft():.1f},{s.p99_ttft():.1f},"
                                  f"{s.p50_itl():.1f},{s.p99_itl():.1f},"
                                  f"{s.tokens_per_kcycle():.3f}")
                else:
                    prof_cols += ",-,-,-,-,-"
            secs = f"{r.seconds:.3f}" if wall else "-"
            rows.append(f"{r.cell.op},{r.cell.backend},{r.cell.devices},"
                        f"{secs},{r.bridge_time:.0f},{stall:.0f},"
                        f"{r.link_stall:.0f},{prof_cols},{status}")
        return rows

    def save_traces(self, out_dir) -> List[Any]:
        """Write one Perfetto/Chrome-trace JSON per profiled cell under
        ``out_dir`` (requires a ``profile=True`` session); returns the
        written paths.  Load any of them at https://ui.perfetto.dev."""
        from pathlib import Path
        out = Path(out_dir)
        paths = []
        for r in self.cells:
            if r.profile is None:
                continue
            fname = "".join(ch if ch.isalnum() or ch in "._-" else "_"
                            for ch in r.cell.label) + ".trace.json"
            paths.append(r.profile.save_perfetto(out / fname))
        return paths

    def scaling(self) -> List[str]:
        """Cross-scale comparison rows: modeled cycles, link stalls, and
        wall-clock per (op, backend, devices) — the devices-sweep readout
        (benchmarks/bench_fabric_scaling.py)."""
        rows = ["op,backend,devices,bridge_cycles,link_stall_cycles,wall_s"]
        for r in sorted(self.cells, key=lambda r: (r.cell.op, r.cell.backend,
                                                   r.cell.devices)):
            rows.append(f"{r.cell.op},{r.cell.backend},{r.cell.devices},"
                        f"{r.bridge_time:.0f},{r.link_stall:.0f},"
                        f"{r.seconds:.3f}")
        return rows


class CoVerifySession:
    """Batched co-verification sweep scheduler (Fig. 5 batched lane).

    Usage::

        sess = CoVerifySession(firmware)
        sess.register_op("mm", oracle=..., interpret=..., compiled=...)
        sess.add_sweep("mm", backends=("oracle", "interpret"),
                       configs=[{"size": 64}, {"size": 128}])
        report = sess.run(max_workers=4)

    ``firmware(fb, op, backend, **config)`` is the host-side program (data
    movement + CSR protocol + ``fb.launch``); it runs unmodified against
    every backend — the paper's equivalence guarantee.  Backend callables
    are registered once and shared across all cells, so XLA compilation is
    cached across the sweep; cells execute concurrently on a thread pool.
    """

    def __init__(self, firmware: Callable[..., None],
                 congestion: Optional[CongestionConfig] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 fabric_firmware: Optional[Callable[..., None]] = None,
                 link_config: Optional[CongestionConfig] = None,
                 profile: bool = False,
                 coverage: Optional[CoverageModel] = None) -> None:
        self.firmware = firmware
        self.congestion = congestion
        self.fault_plan = fault_plan
        # functional-coverage sink (core/coverage.py).  Cells never write
        # to it concurrently: each cell feeds a PRIVATE model and run()
        # merges them into this sink in cell order after the pool joins,
        # so the merged counts are exact and identical at any max_workers
        # (the thread-pool lost-update fix rode along as a lock inside
        # CoverageModel.hit for externally shared sinks).
        self.coverage = coverage
        # with ``profile`` every cell's bridge/cluster records op marks and
        # CellResult.profile carries the data-movement profile
        # (core/profiler.py): utilization + stall-attribution columns in
        # to_rows, Perfetto export via SweepReport.save_traces
        self.profile = profile
        # scale-out lane (core/fabric.py): when ``fabric_firmware`` is set,
        # or a cell carries devices > 1, the cell runs on a FabricCluster
        # with ``link_config`` fabric links; ``fabric_firmware(fab, op,
        # backend, **config)`` takes the cluster where single-device
        # firmware takes the bridge.  With only ``firmware`` given, it must
        # itself accept the cluster for devices > 1 cells.
        self.fabric_firmware = fabric_firmware
        self.link_config = link_config
        self._ops: Dict[str, Dict[str, Any]] = {}
        self.cells: List[SweepCell] = []
        # open-loop serving lane (register_serving/add_serving_cell)
        self._serving_factory: Optional[Callable[..., Any]] = None

    # ------------------------------------------------------------- setup
    def register_op(self, name: str, *, oracle: Callable,
                    interpret: Optional[Callable] = None,
                    compiled: Optional[Callable] = None,
                    burst_list: Optional[Callable] = None) -> None:
        """Register one accelerator op's backend table, shared by every
        cell in the sweep (the compiled-executable cache)."""
        self._ops[name] = dict(oracle=oracle, interpret=interpret,
                               compiled=compiled, burst_list=burst_list)

    def register_serving(self, factory: Callable[..., Any]) -> None:
        """Register the serving-target builder for open-loop serving
        cells: ``factory(backend, devices, fault_plan)`` returns a
        continuous-batching ``ServingEngine`` (devices == 1) or
        ``ClusterServingEngine`` — typically sharing one jitted
        prefill/decode pair across all cells, like ``register_op``
        shares backend executables."""
        self._serving_factory = factory

    def add_serving_cell(self, backend: str, trace: Any, *,
                         devices: int = 1,
                         config: Optional[Dict[str, Any]] = None,
                         fault_plan: Optional[FaultPlan] = None
                         ) -> SweepCell:
        """Append one open-loop serving cell: drive ``trace`` (an
        ``ArrivalTrace``) against the registered serving target on
        ``backend`` at ``devices`` scale.  Cells sharing a trace join one
        equivalence group — generated token streams must match across
        backends AND device counts — and each cell's ``CellResult.slo``
        carries the latency-SLO report (extra ``to_rows`` columns)."""
        if self._serving_factory is None:
            raise RuntimeError("no serving factory registered "
                               "(call register_serving first)")
        cfg = dict(config or {})
        cfg.setdefault("trace", trace.label)
        cell = SweepCell("serving", backend, cfg, None,
                         fault_plan or self.fault_plan, devices=devices,
                         serving=trace)
        self.cells.append(cell)
        return cell

    def add_cell(self, op: str, backend: str,
                 config: Optional[Dict[str, Any]] = None,
                 congestion: Optional[CongestionConfig] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 devices: int = 1, topology=None) -> SweepCell:
        """Append one ``(op, backend, config)`` cell to the sweep;
        ``devices > 1`` runs it sharded on a FabricCluster, and
        ``topology`` routes that cluster through a switched interconnect
        (builder name or Topology instance, core/topology.py)."""
        if op not in self._ops:
            raise KeyError(f"op {op!r} not registered")
        cell = SweepCell(op, backend, dict(config or {}),
                         congestion or self.congestion,
                         fault_plan or self.fault_plan,
                         devices=devices, topology=topology)
        self.cells.append(cell)
        return cell

    def add_sweep(self, op: str, backends: Tuple[str, ...],
                  configs: List[Dict[str, Any]],
                  devices: Tuple[int, ...] = (1,),
                  topologies: Tuple[Optional[Any], ...] = (None,)
                  ) -> List[SweepCell]:
        """Cross-product convenience: one cell per (backend, config,
        device count, topology).  Topologies only apply to multi-device
        counts — the 1-device oracle always runs crossbar, once."""
        return [self.add_cell(op, be, cfg, devices=n, topology=t)
                for cfg in configs for be in backends for n in devices
                for t in (topologies if n > 1 else (None,))]

    # ----------------------------------------------------------- execute
    def _run_cell(self, cell: SweepCell) -> CellResult:
        if cell.serving is not None:
            return self._run_serving_cell(cell)
        # each cell forks its own child plan keyed by the cell label, so
        # thread-pool scheduling order cannot perturb the fault stream
        plan = (cell.fault_plan.fork(cell.label)
                if cell.fault_plan is not None else None)
        if cell.devices > 1 or self.fabric_firmware is not None:
            return self._run_fabric_cell(cell, plan)
        cov = CoverageModel() if self.coverage is not None else None
        fb = FireBridge(congestion=cell.congestion, fault_plan=plan,
                        profile=self.profile)
        fb.register_op(cell.op, **self._ops[cell.op])
        t0 = time.perf_counter()
        err: Optional[str] = None
        try:
            self.firmware(fb, cell.op, cell.backend, **cell.config)
        except Exception as e:            # cell failure must not kill sweep
            err = f"{type(e).__name__}: {e}"
        dt = time.perf_counter() - t0
        if cov is not None:
            self._feed_coverage(cov, fb.log, plan)
        return CellResult(
            cell=cell,
            outputs={n: b.array.copy() for n, b in fb.mem.buffers.items()},
            seconds=dt,
            bridge_time=fb.mem.time,
            congestion=fb.congestion_stats(),
            violations=list(fb.log.violations),
            error=err,
            faults=list(plan.events) if plan is not None else [],
            profile=fb.profiler(cell.label) if self.profile else None,
            coverage=cov,
            counters=(self._cell_counters(
                fb, cell, cell.label if plan is not None else None)
                if err is None else None),
        )

    @staticmethod
    def _cell_counters(target: Any, cell: SweepCell,
                       fork_label: Optional[str]) -> Dict[str, Any]:
        """Counter-diff oracle payload of one finished cell
        (core/counters.py).  ``timing_key`` gates the full-stream digest
        comparison: streams are only required to be identical among cells
        with the same device count, topology, congestion seed, and fault
        fork (firmware cells fork their fault stream by the
        backend-DEPENDENT label, so fault-injected firmware streams
        legitimately differ per backend; serving cells fork by the
        backend-free timing label and stay comparable).  The functional
        digest has no such gate — retired tokens/requests/doorbells are
        invariant across backends AND scales."""
        from repro.core import counters as cc
        banks = cc.counter_banks(target)
        return {
            "digest": cc.merged_digest(banks),
            "totals": cc.merged_totals(banks),
            "functional": cc.functional_digest(banks),
            "timing_key": (cell.devices, cell._topo_kind,
                           repr(cell.congestion), fork_label),
        }

    @staticmethod
    def _feed_coverage(cov: CoverageModel, log, plan: Optional[FaultPlan],
                       ) -> None:
        """Feed one finished cell's transaction stream + fault trace into
        its private coverage model (burst/congestion/fault-kind bins)."""
        for tx in log.txs:
            cov.hit_burst(tx.nbytes)
            cov.hit_congestion(tx.stall)
        for ev in (plan.events if plan is not None else []):
            if ev.layer == "bridge":
                cov.hit("fault_kind", ev.kind)

    def _run_serving_cell(self, cell: SweepCell) -> CellResult:
        """One open-loop serving cell: build the target via the registered
        factory, drive the arrival trace through the shared decision loop,
        and collect the SLO report.  The fault plan forks by the
        backend-FREE ``timing_label`` — one configuration has ONE fault
        stream, so SLO rows and log digests are comparable across
        backends (the determinism tier's contract)."""
        from repro.core.replay import target_logs
        from repro.serving.arrivals import run_open_loop
        from repro.serving.slo import SLOReport
        trace = cell.serving
        plan = (cell.fault_plan.fork(cell.timing_label)
                if cell.fault_plan is not None else None)
        cov = CoverageModel() if self.coverage is not None else None
        t0 = time.perf_counter()
        err: Optional[str] = None
        slo = None
        target = self._serving_factory(cell.backend, cell.devices, plan)
        try:
            run_open_loop(target, trace)
            slo = SLOReport.from_run(trace, target, label=cell.label)
        except Exception as e:            # cell failure must not kill sweep
            err = f"{type(e).__name__}: {e}"
        dt = time.perf_counter() - t0
        violations = (list(target.violations)
                      if hasattr(target, "violations")
                      else list(target.mem.log.violations))
        if cov is not None:
            for log in target_logs(target):
                for tx in log.txs:
                    cov.hit_burst(tx.nbytes)
                    cov.hit_congestion(tx.stall)
            self._feed_arrival_coverage(cov, trace, target, violations)
        # the equivalence payload: every completed request's token stream,
        # compared exactly across backends and device counts
        outputs = {f"tokens[{rid}]": np.asarray(req.out_tokens, np.int64)
                   for rid, req in sorted(target.requests.items())
                   if req.done}
        return CellResult(
            cell=cell,
            outputs=outputs,
            seconds=dt,
            bridge_time=float(target.clock),
            congestion=target.congestion_stats(),
            violations=violations,
            error=err,
            faults=list(plan.events) if plan is not None else [],
            profile=target.profiler(cell.label) if self.profile else None,
            coverage=cov,
            slo=slo,
            counters=(self._cell_counters(
                target, cell,
                cell.timing_label if plan is not None else None)
                if err is None else None),
        )

    @staticmethod
    def _feed_arrival_coverage(cov: CoverageModel, trace: Any, target: Any,
                               violations: List[str]) -> None:
        """Arrival/admission coverage bins of one serving cell."""
        cov.hit("arrivals", trace.kind)
        engines = getattr(target, "engines", None) or [target]
        pools = [e.kv_pool for e in engines
                 if getattr(e, "kv_pool", None) is not None]
        deferrals = sum(p.deferrals for p in pools)
        if deferrals:
            cov.hit("arrivals", "deferred", deferrals)
        if any(p.peak_in_use == p.n_pages for p in pools):
            cov.hit("arrivals", "pool_full")
        if any("exceeds KV page pool" in v for v in violations):
            cov.hit("arrivals", "infeasible_reject")

    def _run_fabric_cell(self, cell: SweepCell,
                         plan: Optional[FaultPlan]) -> CellResult:
        """One cell on a FabricCluster: the firmware shards the op across
        ``cell.devices`` devices and the *host-visible gathered state* is
        what enters the cross-scale equivalence group."""
        cov = CoverageModel() if self.coverage is not None else None
        fab = FabricCluster(cell.devices, congestion=cell.congestion,
                            link_config=self.link_config, fault_plan=plan,
                            profile=self.profile, topology=cell.topology,
                            coverage=cov)
        fab.register_op(cell.op, **self._ops[cell.op])
        fw = self.fabric_firmware or self.firmware
        t0 = time.perf_counter()
        err: Optional[str] = None
        try:
            fw(fab, cell.op, cell.backend, **cell.config)
        except Exception as e:            # cell failure must not kill sweep
            err = f"{type(e).__name__}: {e}"
        dt = time.perf_counter() - t0
        if cov is not None:
            for ev in fab.fault_events():
                if ev.layer == "bridge":
                    cov.hit("fault_kind", ev.kind)
        return CellResult(
            cell=cell,
            outputs=fab.outputs(),
            seconds=dt,
            bridge_time=max([fab.time]
                            + [d.mem.time for d in fab.devices]),
            congestion=fab.device_congestion(),
            violations=fab.violations,
            error=err,
            faults=fab.fault_events(),
            links=fab.link_stats(),
            profile=fab.profiler(cell.label) if self.profile else None,
            coverage=cov,
            counters=(self._cell_counters(
                fab, cell, cell.label if plan is not None else None)
                if err is None else None),
        )

    def run(self, max_workers: Optional[int] = None,
            tol: float = 1e-3, bisect_failures: bool = True) -> SweepReport:
        """Execute every cell (concurrently) and cross-check backends.

        Cells are independent, so they are dispatched to a thread pool;
        results are then grouped by ``(op, config)`` and the final DDR
        state is diffed across backends with first-divergence localization
        (equivalence.compare_outputs, §IV-B).

        With ``bisect_failures`` (default), every failing equivalence
        group is re-recorded as a replayable timeline and bisected
        (core/replay.py): the report's ``divergences`` then names the
        first divergent transaction and the device state around it, at
        the cost of re-running only the two divergent cells — the
        debug-iteration path that used to require a manual full re-run.
        """
        t0 = time.perf_counter()
        if max_workers == 1 or len(self.cells) <= 1:
            results = [self._run_cell(c) for c in self.cells]
        else:
            # ex.map preserves submission order, so `results` is in cell
            # order regardless of which thread finishes first — report
            # rows, equivalence groups, divergence attachments, and the
            # coverage merge below are completion-order independent
            with ThreadPoolExecutor(max_workers=max_workers) as ex:
                results = list(ex.map(self._run_cell, self.cells))
        wall = time.perf_counter() - t0
        if self.coverage is not None:
            # deterministic join: merge each cell's private model into the
            # session sink in cell order (never concurrently)
            for r in results:
                if r.coverage is not None:
                    self.coverage.merge(r.coverage)

        groups: Dict[Tuple, Dict[str, Dict[str, np.ndarray]]] = {}
        members: Dict[Tuple, Dict[str, SweepCell]] = {}
        res_groups: Dict[Tuple, Dict[str, CellResult]] = {}
        labels: Dict[Tuple, str] = {}
        for r in results:
            # devices is intentionally NOT part of the key: cells at
            # different scales join one group, so the sweep diffs the
            # 4-device gathered state against the single-device oracle
            key = (r.cell.op, _config_key(r.cell.config))
            groups.setdefault(key, {})[r.cell.group_member] = r.outputs
            members.setdefault(key, {})[r.cell.group_member] = r.cell
            res_groups.setdefault(key, {})[r.cell.group_member] = r
            cfg = ",".join(f"{k}={v}"
                           for k, v in sorted(r.cell.config.items()))
            labels[key] = f"{r.cell.op}[{cfg}]"
        # counter-diff oracle pre-check (core/counters.py): digest
        # comparisons are O(1) against the full element-wise output diff
        # below, so a divergent group is flagged — and handed to the
        # bisection lane — before the expensive comparison even runs
        divergences: Dict[str, Any] = {}
        counter_mismatches: Dict[str, Any] = {}
        for key, rs in res_groups.items():
            mismatch = self._counter_precheck(rs)
            if mismatch is None:
                continue
            counter_mismatches[labels[key]] = mismatch
            if bisect_failures:
                a, b = mismatch["pair"]
                try:
                    divergences[labels[key]] = self._bisect_cells(
                        members[key][a], members[key][b])
                except Exception as e:   # localization is best-effort —
                    divergences[labels[key]] = (   # never fail the sweep
                        f"bisect unavailable: {type(e).__name__}: {e}")
        eq = {labels[k]: compare_outputs(outs, tol=tol)
              for k, outs in groups.items() if len(outs) > 1}
        if bisect_failures:
            for key, outs in groups.items():
                rep = eq.get(labels[key])
                if rep is None or rep.passed or not rep.divergences:
                    continue
                if labels[key] in divergences:
                    continue            # already localized by the oracle
                pair = rep.divergences[0].pair
                cells = members[key]
                try:
                    divergences[labels[key]] = self._bisect_cells(
                        cells[pair[0]], cells[pair[1]])
                except Exception as e:   # localization is best-effort —
                    divergences[labels[key]] = (   # never fail the sweep
                        f"bisect unavailable: {type(e).__name__}: {e}")
        return SweepReport(cells=results, equivalence=eq, wall_seconds=wall,
                           divergences=divergences, coverage=self.coverage,
                           counter_mismatches=counter_mismatches)

    @staticmethod
    def _counter_precheck(rs: Dict[str, "CellResult"]
                          ) -> Optional[Dict[str, Any]]:
        """Counter-diff oracle over one equivalence group: full-stream
        digests must agree among cells sharing a timing key; functional
        digests must agree across ALL members (any backend, any scale).
        Returns a mismatch record ({pair, kind, totals}) or None."""
        with_c = sorted((m, r) for m, r in rs.items()
                        if r.counters is not None)
        if len(with_c) < 2:
            return None
        pair: Optional[Tuple[str, str]] = None
        kind = ""
        by_tk: Dict[Tuple, List[Tuple[str, CellResult]]] = {}
        for m, r in with_c:
            by_tk.setdefault(r.counters["timing_key"], []).append((m, r))
        for peers in by_tk.values():
            ref_m, ref_r = peers[0]
            for m, r in peers[1:]:
                if r.counters["digest"] != ref_r.counters["digest"]:
                    pair, kind = (ref_m, m), "stream"
                    break
            if pair is not None:
                break
        if pair is None:
            ref_m, ref_r = with_c[0]
            for m, r in with_c[1:]:
                if r.counters["functional"] != ref_r.counters["functional"]:
                    pair, kind = (ref_m, m), "functional"
                    break
        if pair is None:
            return None
        return {"pair": pair, "kind": kind,
                "totals": {m: rs[m].counters["totals"] for m in pair}}

    def _bisect_cells(self, cell_a: SweepCell, cell_b: SweepCell,
                      checkpoint_interval: int = 8):
        """Re-record two divergent single-device cells as deterministic
        timelines and bisect them to the first divergent transaction
        (core/replay.py).  The firmware runs unmodified behind a
        ``RecordingBridge`` facade, and each recording rebuilds the cell's
        exact fault-plan fork and congestion link, so the recorded runs
        reproduce the sweep's bit-for-bit."""
        from repro.core import replay as rp
        if cell_a.serving is not None and cell_b.serving is not None:
            # open-loop serving cells replay through the shared decision
            # loop; the recording's factory rebuilds the exact
            # backend-free fault fork the sweep ran with
            def record_serving(cell: SweepCell):
                def factory():
                    plan = (cell.fault_plan.fork(cell.timing_label)
                            if cell.fault_plan is not None else None)
                    return self._serving_factory(cell.backend,
                                                 cell.devices, plan)
                sess = rp.DebugSession(
                    factory, label=cell.label,
                    checkpoint_interval=checkpoint_interval)
                return sess, rp.record_open_loop(sess, cell.serving)

            sa, ra = record_serving(cell_a)
            sb, rb = record_serving(cell_b)
            return rp.bisect_divergence(sa, ra, sb, rb)
        if cell_a.devices != 1 or cell_b.devices != 1 \
                or self.fabric_firmware is not None:
            raise ValueError("divergence bisection covers single-device "
                             "cells (fabric timelines differ per scale)")

        def record(cell: SweepCell):
            def factory():
                plan = (cell.fault_plan.fork(cell.label)
                        if cell.fault_plan is not None else None)
                fb = FireBridge(congestion=cell.congestion, fault_plan=plan)
                fb.register_op(cell.op, **self._ops[cell.op])
                return fb
            sess = rp.DebugSession(factory, label=cell.label,
                                   checkpoint_interval=checkpoint_interval)
            rec = sess.record(lambda r: self.firmware(
                rp.RecordingBridge(r), cell.op, cell.backend,
                **cell.config))
            return sess, rec

        sa, ra = record(cell_a)
        sb, rb = record(cell_b)
        return rp.bisect_divergence(sa, ra, sb, rb)


def run_sequential(session: CoVerifySession, tol: float = 1e-3
                   ) -> SweepReport:
    """The pre-batching baseline: execute the same cells one at a time on
    fresh per-cell state (no thread pool).  Kept as the comparison lane for
    bench_debug_iteration.py's Fig. 5 sweep measurement."""
    return session.run(max_workers=1, tol=tol)
