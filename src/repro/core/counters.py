"""Always-on AutoCounter-style sampled performance counters (ROADMAP 5).

FireSim leaves AutoCounter/TracerV instrumentation compiled into every
simulation: out-of-band counters sampled on a fixed interval, cheap
enough to stay on across a whole run-farm campaign.  This module is the
modeled-time analogue: every bridge / fabric link / switch port /
serving engine registers a ``CounterBank`` of named counters, and the
bank samples them into an append-only columnar ``CounterStream`` each
time the owner's modeled clock crosses an interval boundary.

Design rules (each one is load-bearing for a regression tier):

* **Counters never perturb the model.**  A probe only reads state the
  owner already maintains; sampling happens after the owner's clock has
  advanced.  Timing, RNG draws and transaction logs are bit-identical
  with counters on or off — the seven golden traces are the witness.
* **Sampling is boundary-based.**  ``tick(now)`` emits one row per
  interval boundary crossed since the last tick (boundaries at k*I,
  computed by multiplication, never accumulation), every row carrying
  the values probed at tick time.  Tick times depend only on the model,
  not on the interval, so a stream sampled at 2I is exactly the
  even-boundary subsequence of the stream sampled at I
  (tests/test_counters.py::test_sampling_interval_invariance).
* **Same lazy-digest discipline as ``TransactionLog``.**  Canonical
  lines and the running sha256 are cached append-only; ``set_state``
  (the one non-append mutation) invalidates them and bumps an epoch so
  a restored stream can never alias a stale memo.
* **Two digest scopes** mirror replay's state/functional fingerprint
  split.  ``digest()`` covers the full sampled stream and is invariant
  across backends at a fixed device count (modeled timing is
  backend-invariant).  Counters declared ``scope="functional"``
  (tokens retired, requests retired, doorbells) have cumulative totals
  that are additionally invariant across 1/2/4 devices;
  ``functional_digest`` hashes those totals summed by name across
  banks.  Together they form the counter-diff oracle wired into
  ``CoVerifySession`` — a digest comparison that runs before (and is
  far cheaper than) full output/trace comparison.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

# Default sampling interval in modeled cycles.  A power of two so that
# coarser test intervals (2x, 4x) hit bit-identical boundary values.
DEFAULT_INTERVAL = 256.0

# Module-level always-on switch.  Only the A/B overhead benchmark
# (benchmarks/bench_counters.py) turns sampling off; everything else
# runs with counters on, which is the point of the instrument.
_ENABLED = True


@contextlib.contextmanager
def sampling_disabled():
    """Turn off counter sampling for the duration of the block — the
    counters-off arm of the overhead benchmark.  Banks still exist and
    owned counters still increment (they are plain int adds on state the
    owner carries anyway); only the per-tick sampling stops."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = False
    try:
        yield
    finally:
        _ENABLED = prev


@dataclasses.dataclass(frozen=True)
class CounterSpec:
    """One declared counter.

    ``unit`` is documentation + dtype: ``cycles`` counters are floats
    (modeled time), everything else is an integer count.  ``scope``
    selects the digest a counter participates in: ``timing`` counters
    are per-run/per-scale (stall cycles, KV pages), ``functional``
    counters have scale-invariant cumulative totals (tokens retired).
    ``monotone`` declares that samples never decrease — asserted for
    every monotone counter by the hypothesis property tier; gauges like
    KV pages in use opt out."""
    name: str
    unit: str = "events"            # events | bytes | cycles | pages | tokens
    scope: str = "timing"           # timing | functional
    monotone: bool = True

    @property
    def is_float(self) -> bool:
        return self.unit == "cycles"


class CounterStream:
    """Append-only columnar sample stream with an incremental digest.

    Rows are (boundary_time, values...) tuples appended by the owning
    bank's ``tick``.  Rendering and hashing follow ``TransactionLog``'s
    lazy-digest discipline exactly: ``_lines``/``_hash`` cover a prefix
    and extend append-only; ``set_state`` clears them and bumps
    ``_epoch`` so the keyed digest memo can never serve a stale value.
    """

    def __init__(self, specs: Tuple[CounterSpec, ...]) -> None:
        self.specs = specs
        self.times: List[float] = []
        self.rows: List[Tuple] = []
        self._lines: List[str] = []
        self._hash = hashlib.sha256()
        self._digest_memo: Optional[Tuple[Tuple, str]] = None
        self._epoch = 0

    @property
    def n_samples(self) -> int:
        return len(self.times)

    def append(self, boundary: float, values: Tuple) -> None:
        self.times.append(boundary)
        self.rows.append(values)

    # ------------------------------------------------- canonical rendering
    def _fmt(self, values: Tuple) -> str:
        return " ".join(
            f"{v:.6f}" if s.is_float else str(v)
            for s, v in zip(self.specs, values))

    def _render(self) -> None:
        done = len(self._lines)
        for t, row in zip(self.times[done:], self.rows[done:]):
            line = f"{t:.6f} {self._fmt(row)}"
            self._hash.update(line.encode())
            self._hash.update(b"\n")
            self._lines.append(line)

    def canonical(self) -> List[str]:
        """Stable one-line-per-sample rendering (floats fixed to 6
        decimals, like ``TransactionLog.canonical_line``) — the golden
        counter-corpus format (tests/golden/*.counters)."""
        self._render()
        return list(self._lines)

    def digest(self) -> str:
        """sha256 over the canonical stream — the counter-diff oracle's
        per-stream witness.  Digest-on-demand: repeat calls cost only
        the samples appended since the last one."""
        key = (self._epoch, len(self.times))
        if self._digest_memo is not None and self._digest_memo[0] == key:
            return self._digest_memo[1]
        self._render()
        out = self._hash.hexdigest()
        self._digest_memo = (key, out)
        return out

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict[str, Any]:
        return {"times": list(self.times), "rows": list(self.rows)}

    def set_state(self, state: Dict[str, Any]) -> None:
        self.times[:] = state["times"]
        self.rows[:] = state["rows"]
        self._lines = []
        self._hash = hashlib.sha256()
        self._digest_memo = None
        self._epoch += 1


class CounterBank:
    """A named set of counters sampled on one modeled clock.

    Counters are either *probed* (a zero-argument callable reading state
    the owner already maintains — link byte totals, KV pool occupancy)
    or *owned* (event counters the owner bumps via ``inc`` — doorbells,
    tokens retired; owned values live in the bank so they ride
    ``get_state``/``set_state`` with everything else).

    ``tick(now)`` is the only hot-path entry: one multiply + compare
    when no boundary was crossed, otherwise a single probe pass shared
    by every row emitted (a clock jump over k boundaries yields k rows
    with identical values — sample-and-hold, which keeps the coarser-
    interval stream an exact subsequence of the finer one).
    """

    def __init__(self, name: str, interval: float = DEFAULT_INTERVAL) -> None:
        if interval <= 0:
            raise ValueError(f"counter interval must be > 0, got {interval}")
        self.name = name
        self.interval = float(interval)
        self.specs: List[CounterSpec] = []
        self._probes: List[Optional[Callable[[], Any]]] = []
        self._owned: Dict[str, Any] = {}
        self._k = 1                       # next boundary is interval * _k
        self.stream = CounterStream(())

    # ------------------------------------------------------- registration
    def register(self, spec: CounterSpec,
                 probe: Optional[Callable[[], Any]] = None) -> None:
        """Declare one counter.  Registration happens once, at owner
        construction, before any sampling — the stream's column layout
        is frozen by the first tick."""
        assert self.stream.n_samples == 0, "register before first sample"
        self.specs.append(spec)
        self._probes.append(probe)
        if probe is None:
            self._owned[spec.name] = 0.0 if spec.is_float else 0
        self.stream.specs = tuple(self.specs)

    def set_interval(self, interval: float) -> None:
        """Retarget the sampling interval — only before any samples
        exist (the boundary sequence k*I must be single-valued)."""
        assert self.stream.n_samples == 0, "set_interval before first sample"
        if interval <= 0:
            raise ValueError(f"counter interval must be > 0, got {interval}")
        self.interval = float(interval)

    def inc(self, name: str, by: Any = 1) -> None:
        """Bump an owned event counter (doorbells, tokens retired)."""
        self._owned[name] += by

    # ------------------------------------------------------------ sampling
    def _sample(self) -> Tuple:
        return tuple(
            (self._owned[s.name] if p is None else
             (float(p()) if s.is_float else int(p())))
            for s, p in zip(self.specs, self._probes))

    def tick(self, now: float) -> None:
        """Sample every interval boundary crossed up to ``now``."""
        b = self.interval * self._k
        if now < b or not _ENABLED:
            return
        vals = self._sample()
        append = self.stream.append
        while b <= now:
            append(b, vals)
            self._k += 1
            b = self.interval * self._k

    # ------------------------------------------------------------- queries
    def value(self, name: str) -> Any:
        """Current (un-sampled) value of one counter."""
        for s, p in zip(self.specs, self._probes):
            if s.name == name:
                return (self._owned[name] if p is None else
                        (float(p()) if s.is_float else int(p())))
        raise KeyError(name)

    def totals(self) -> Dict[str, Any]:
        """Current value of every counter — the end-of-run summary the
        run-farm aggregates fleet-wide."""
        return {s.name: self.value(s.name) for s in self.specs}

    def functional_totals(self) -> Dict[str, Any]:
        return {s.name: self.value(s.name) for s in self.specs
                if s.scope == "functional"}

    def spec(self, name: str) -> CounterSpec:
        for s in self.specs:
            if s.name == name:
                return s
        raise KeyError(name)

    # ------------------------------------------------- golden-corpus format
    def canonical(self) -> List[str]:
        """Header (bank identity + column declarations) followed by the
        sample stream — the committed ``tests/golden/*.counters`` unit."""
        head = [f"bank {self.name} interval={self.interval:.6f}",
                "columns " + " ".join(
                    f"{s.name}:{s.unit}:{s.scope}" for s in self.specs)]
        return head + self.stream.canonical()

    def digest(self) -> str:
        h = hashlib.sha256()
        h.update(f"{self.name}|{self.interval:.6f}|".encode())
        h.update(",".join(s.name for s in self.specs).encode())
        h.update(b"|")
        h.update(self.stream.digest().encode())
        return h.hexdigest()

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict[str, Any]:
        return {"owned": dict(self._owned), "k": self._k,
                "stream": self.stream.get_state()}

    def set_state(self, state: Dict[str, Any]) -> None:
        self._owned.update(state["owned"])
        self._k = state["k"]
        self.stream.set_state(state["stream"])


# --------------------------------------------------------------------------
# Shared bank builders — one vocabulary for every link-backed channel, so
# the same counter names mean the same thing on a bridge DDR link, a
# fabric port and a switch hop (the Perfetto counter tracks and the fleet
# summaries merge by name).
# --------------------------------------------------------------------------

def register_link_counters(bank: CounterBank, link) -> None:
    """Counters probing an online ``LinkModel``: byte/stall/busy totals
    the arbiter already folds in grant order (core/congestion.py), so a
    probe is a dict-sum, never a timeline walk.  The per-engine folds
    are summed in sorted-engine order — the bit-exact twin of the
    profiler's ``EngineStats.grant_stall`` fold (tests/test_counters.py
    ::test_counter_closure_against_profiler)."""
    bank.register(CounterSpec("bytes_moved", "bytes"),
                  lambda: link.counter_bytes())
    bank.register(CounterSpec("busy_cycles", "cycles"),
                  lambda: link.counter_busy())
    bank.register(CounterSpec("stall_cycles", "cycles"),
                  lambda: link.counter_stall())
    bank.register(CounterSpec("dos_cycles", "cycles"),
                  lambda: link.counter_dos())
    bank.register(CounterSpec("cycles", "cycles"), lambda: link.now)


def register_switch_port_counters(bank: CounterBank, port) -> None:
    """Credit flow-control counters on one switch port (core/switch.py):
    grants/waits are plain ints the port already counts, credit_stall is
    its exact float accumulator."""
    register_link_counters(bank, port.link)
    bank.register(CounterSpec("credit_grants", "events"),
                  lambda: port.credit_grants)
    bank.register(CounterSpec("credit_waits", "events"),
                  lambda: port.credit_waits)
    bank.register(CounterSpec("credit_stall_cycles", "cycles"),
                  lambda: port.credit_stall)


# --------------------------------------------------------------------------
# Multi-bank helpers — the counter-diff oracle's unit of comparison is a
# target's ordered bank list, mirroring replay.target_logs.
# --------------------------------------------------------------------------

def counter_banks(target) -> List[CounterBank]:
    """Every counter bank a co-verification target owns, in a stable
    order (the owner defines it via ``counter_banks()``).  Mirrors
    ``replay.target_logs`` dispatch; targets predating the counter layer
    simply contribute no banks."""
    fn = getattr(target, "counter_banks", None)
    if callable(fn):
        return list(fn())
    bank = getattr(target, "counters", None)
    return [bank] if isinstance(bank, CounterBank) else []


def merged_digest(banks: Iterable[CounterBank]) -> str:
    """One digest over an ordered bank list — the full-stream side of
    the counter-diff oracle (backend-invariant at fixed scale)."""
    h = hashlib.sha256()
    for b in banks:
        h.update(b.digest().encode())
        h.update(b"\n")
    return h.hexdigest()


def merged_totals(banks: Iterable[CounterBank]) -> Dict[str, Any]:
    """ALL counter totals summed by name across banks — the per-unit
    counter summary the run farm merges fleet-wide (uid order, like
    coverage) and the sweep scheduler attaches to every cell."""
    out: Dict[str, Any] = {}
    for b in banks:
        for name, v in b.totals().items():
            out[name] = out.get(name, 0) + v
    return out


def functional_totals(banks: Iterable[CounterBank]) -> Dict[str, Any]:
    """Functional-scope counter totals summed by name across banks —
    every engine's tokens land in one ``tokens_retired`` total, which is
    what makes the result invariant across 1/2/4 devices."""
    out: Dict[str, Any] = {}
    for b in banks:
        for name, v in b.functional_totals().items():
            out[name] = out.get(name, 0) + v
    return out


def functional_digest(banks: Iterable[CounterBank]) -> str:
    """Digest of the functional totals — the cross-scale side of the
    counter-diff oracle."""
    h = hashlib.sha256()
    for name, v in sorted(functional_totals(banks).items()):
        h.update(f"{name}={v}\n".encode())
    return h.hexdigest()


@dataclasses.dataclass
class CounterDiff:
    """First divergence between two counter streams, plus the number of
    scalar comparisons spent finding it — the economics the planted-bug
    test pins against full trace diffing."""
    bank: str
    sample: int                 # row index of first divergence (-1: length)
    counter: str                # column name ("" for structural diffs)
    a: Any
    b: Any
    comparisons: int

    def render(self) -> str:
        return (f"counter divergence: bank={self.bank} sample={self.sample} "
                f"counter={self.counter} a={self.a!r} b={self.b!r} "
                f"({self.comparisons} comparisons)")


def diff_streams(banks_a: Iterable[CounterBank],
                 banks_b: Iterable[CounterBank]
                 ) -> Tuple[Optional[CounterDiff], int]:
    """Locate the first divergent sample between two bank lists.

    Returns ``(diff, comparisons)`` where ``diff`` is None when the
    streams are identical.  Comparisons are counted per scalar value so
    the oracle's cost is measurable against a full trace-line diff.
    """
    comparisons = 0
    la, lb = list(banks_a), list(banks_b)
    for a, b in zip(la, lb):
        comparisons += 1
        if a.name != b.name:
            return CounterDiff(a.name, -1, "", a.name, b.name,
                               comparisons), comparisons
        names = [s.name for s in a.specs]
        for i, (ta, ra) in enumerate(zip(a.stream.times, a.stream.rows)):
            if i >= b.stream.n_samples:
                break
            tb, rb = b.stream.times[i], b.stream.rows[i]
            comparisons += 1
            if ta != tb:
                return CounterDiff(a.name, i, "time", ta, tb,
                                   comparisons), comparisons
            for name, va, vb in zip(names, ra, rb):
                comparisons += 1
                if va != vb:
                    return CounterDiff(a.name, i, name, va, vb,
                                       comparisons), comparisons
        comparisons += 1
        if a.stream.n_samples != b.stream.n_samples:
            return CounterDiff(a.name, min(a.stream.n_samples,
                                           b.stream.n_samples), "",
                               a.stream.n_samples, b.stream.n_samples,
                               comparisons), comparisons
    comparisons += 1
    if len(la) != len(lb):
        return CounterDiff("", -1, "", len(la), len(lb),
                           comparisons), comparisons
    return None, comparisons
