"""The FireBridge memory bridge (paper §IV, Fig. 3).

Host-side firmware sees "DDR" as plain arrays (idiomatic-C-style pointer
access in the paper; NumPy views here).  The accelerator side — a Pallas
kernel in interpret mode ("RTL sim"), its jnp oracle ("golden model"), or
the compiled XLA executable ("deployment") — accesses the same buffers
through the bridge, which logs every burst as a Transaction.  The SAME
firmware function runs unmodified against every backend; that is the
paper's equivalence guarantee, checked by core/equivalence.py.

Congestion is *online* (paper §IV-C): construct the bridge with a
``CongestionConfig`` and every device access and kernel burst list is
arbitrated through a shared ``LinkModel`` as the firmware runs, so
``bridge.time`` advances by modeled transfer latency and per-engine stall
statistics (Fig. 8) accumulate during ``launch()`` — no post-hoc replay
step.  Without a config the original fast path is preserved (one logical
cycle per access).

Fault injection is also online: construct the bridge with a ``FaultPlan``
(core/fuzz.py) and device-side bursts may be delayed/reordered/split, the
congestion config perturbed, and ``dev_read`` data transiently bit-flipped
behind an audited ECC-style retry — the paper's randomized memory bridge
(§IV).  Every injected fault is recorded in ``log.faults``.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.congestion import (CongestionConfig, CongestionResult,
                                   LinkModel)
from repro.core.counters import (CounterBank, CounterSpec,
                                 register_link_counters)
from repro.core.registers import RegisterFile
from repro.core.transactions import (BurstBatch, OpMark, Transaction,
                                     TransactionLog, record_mark)


@dataclasses.dataclass
class Buffer:
    """One named DDR allocation (paper Fig. 3 "shared memory region")."""
    name: str
    addr: int
    array: np.ndarray

    @property
    def nbytes(self) -> int:
        return self.array.nbytes


class MemoryBridge:
    """Host DDR pool with transaction-logged accelerator access (§IV).

    With ``congestion`` set, device-side accesses route through the online
    ``LinkModel``: large transfers are split into ``max_burst_bytes``
    bursts, the link arbitrates them against every other engine's traffic,
    and ``self.time`` advances to the modeled completion time.  Host-side
    accesses (``host_read``/``host_write``) stay free — the paper's
    firmware dereferencing plain DDR pointers.
    """

    PAGE = 4096

    def __init__(self, log: Optional[TransactionLog] = None,
                 congestion: Optional[CongestionConfig] = None,
                 fault_plan: Optional["FaultPlan"] = None,
                 profile: bool = False) -> None:
        self.log = log if log is not None else TransactionLog()
        self._next = 0x1000_0000                    # DDR base
        self.buffers: Dict[str, Buffer] = {}
        self.time = 0.0
        self.fault_plan = fault_plan
        if fault_plan is not None and congestion is not None:
            congestion = fault_plan.perturb_congestion(congestion, self.log)
        self.congestion = congestion
        self.link: Optional[LinkModel] = (
            LinkModel(congestion) if congestion is not None else None)
        # data-movement profiling (core/profiler.py): with ``profile`` the
        # ``mark`` context manager attributes logged bursts to named ops.
        # Marks are metadata, not replayable state — deliberately excluded
        # from get_state/set_state.
        self.profile = profile
        self.marks: List[OpMark] = []
        # always-on sampled counters (core/counters.py, ROADMAP 5).
        # Probes only read state the bridge/link already maintain, so
        # timing and the transaction log are bit-identical with the bank
        # present — the golden traces are the witness.
        self.counters = CounterBank("ddr")
        self.counters.register(CounterSpec("transactions", "events"),
                               lambda: self.log.n_txs)
        if self.link is not None:
            register_link_counters(self.counters, self.link)
        else:
            self.counters.register(CounterSpec("bytes_moved", "bytes"))
            self.counters.register(CounterSpec("cycles", "cycles"),
                                   lambda: self.time)
        self.counters.register(CounterSpec("violations", "events"),
                               lambda: len(self.log.violations))
        self.counters.register(CounterSpec("faults", "events"),
                               lambda: len(self.log.faults))

    def mark(self, op: str, engine: str = "", meta: str = ""):
        """Attribute every transaction logged inside the block to one
        profiled op (core/profiler.py per-op timelines).  No-op unless the
        bridge was constructed with ``profile=True``, so the fast path
        stays mark-free."""
        if not self.profile:
            return contextlib.nullcontext()
        return record_mark(self.marks, self.log, lambda: self.time, op,
                           engine, meta)

    def alloc(self, name: str, shape, dtype) -> Buffer:
        """Reserve a page-aligned DDR region for ``name``."""
        if name in self.buffers:
            raise ValueError(
                f"buffer {name!r} already allocated at "
                f"{self.buffers[name].addr:#x}; re-alloc would silently "
                f"shadow it (free-list reuse is not modeled)")
        arr = np.zeros(shape, dtype)
        size = -(-arr.nbytes // self.PAGE) * self.PAGE
        buf = Buffer(name, self._next, arr)
        self._next += size
        self.buffers[name] = buf
        return buf

    # Firmware-side access: plain numpy (paper: dereferencing C pointers).
    def host_write(self, name: str, data) -> None:
        buf = self.buffers[name]
        arr = np.asarray(data, buf.array.dtype)
        if arr.shape != buf.array.shape:
            raise ValueError(
                f"host_write to {name!r}: data shape {arr.shape} != buffer "
                f"shape {buf.array.shape} (refusing silent broadcast)")
        np.copyto(buf.array, arr)

    def host_read(self, name: str) -> np.ndarray:
        return self.buffers[name].array.copy()

    # ------------------------------------------------ device-side access
    def _dev_bursts(self, buf: Buffer, kind: str, engine: str,
                    tag: str) -> BurstBatch:
        """Split one device transfer into link-level bursts (§IV-C) —
        built as a column batch, not per-burst Transaction objects."""
        step = self.congestion.max_burst_bytes if self.congestion else 0
        return BurstBatch.from_transfer(self.time, engine, kind, buf.addr,
                                        buf.nbytes, tag, step)

    def _submit(self, batch: BurstBatch) -> None:
        """Route one burst batch through the link (or the fast path),
        applying any fault-plan perturbation first."""
        if self.fault_plan is not None:
            batch = self.fault_plan.perturb_batch(batch, self.log)
        if self.link is not None:
            self.time = self.link.submit_batch(batch, self.log)
        else:
            self.time = self._fast_clock(batch, self.time)
        self.counters.tick(self.time)

    def _fast_clock(self, batch: BurstBatch, t: float) -> float:
        """Congestion-free logical clock over a batch: one cycle per
        burst; a delayed burst's min-issue time still holds.  Same
        float-op order as the per-object loop it replaces."""
        times = batch.rec["time"].tolist()
        out = [0.0] * len(times)
        for i, ti in enumerate(times):
            tn = t + 1
            t = tn if tn >= ti else ti
            out[i] = t
        if times:
            batch.rec["time"] = out
            self.log.log_batch(batch)
            self.counters.inc("bytes_moved", int(batch.rec["nbytes"].sum()))
        return t

    def dev_read(self, name: str, engine: str = "dma") -> np.ndarray:
        """Accelerator-side read: transaction-logged, congestion-timed.

        With a fault plan the returned data may suffer a transient bit
        flip; the bridge detects it (ECC-style), audits the fault, and
        re-issues the burst — the retry must heal, so firmware always sees
        clean data while the protocol path is exercised.
        """
        buf = self.buffers[name]
        self._submit(self._dev_bursts(buf, "read", engine, name))
        data = buf.array.copy()
        if (self.fault_plan is not None
                and self.fault_plan.flip_read(data, name, self.log)):
            # corrupted transfer detected against ECC: audited retry
            self._submit(self._dev_bursts(buf, "read", engine, name))
            data = buf.array.copy()
        return data

    def dev_write(self, name: str, data, engine: str = "dma") -> None:
        """Accelerator-side write: transaction-logged, congestion-timed."""
        buf = self.buffers[name]
        arr = np.asarray(data, buf.array.dtype)
        if arr.shape != buf.array.shape:
            raise ValueError(
                f"dev_write to {name!r}: data shape {arr.shape} != buffer "
                f"shape {buf.array.shape} (refusing silent broadcast)")
        self._submit(self._dev_bursts(buf, "write", engine, name))
        np.copyto(buf.array, arr)

    def log_burst_list(self, txs: List[Tuple[str, str, int, int]],
                       base_time: Optional[float] = None) -> None:
        """Log a kernel's static BlockSpec-derived burst list (see
        kernels/*/ops.transactions).

        With congestion enabled the whole list is arbitrated as one batch
        through the shared link — engines named in the list contend for
        bandwidth exactly as the paper's DMA VIPs do on the AXI fabric
        (Fig. 8) — and ``self.time`` advances to the batch makespan.
        """
        t = self.time if base_time is None else base_time
        batch = BurstBatch.from_tuples(t, txs)
        if self.fault_plan is not None:
            batch = self.fault_plan.perturb_batch(batch, self.log)
        if self.link is not None:
            self.time = self.link.submit_batch(batch, self.log)
        else:
            self.time = self._fast_clock(batch, t)
        self.counters.tick(self.time)

    def congestion_stats(self) -> Optional[CongestionResult]:
        """Fig. 8 statistics accumulated by the online link so far
        (None when the bridge runs congestion-free)."""
        return self.link.result() if self.link is not None else None

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict[str, Any]:
        """Deep snapshot of the bridge at a transaction boundary
        (core/replay.py): DDR contents, the allocation cursor, the modeled
        clock, the online link arbiter, the fault-plan RNG position, and
        the transaction log.  Restoring it into a structurally identical
        bridge makes every subsequent access replay bit-identically."""
        return {
            "buffers": {n: (b.addr, b.array.copy())
                        for n, b in self.buffers.items()},
            "next": self._next,
            "time": self.time,
            "log": self.log.get_state(),
            "link": self.link.get_state() if self.link is not None else None,
            "fault_plan": (self.fault_plan.get_state()
                           if self.fault_plan is not None else None),
            "counters": self.counters.get_state(),
        }

    def set_state(self, state: Dict[str, Any]) -> None:
        self.buffers = {n: Buffer(n, addr, arr.copy())
                        for n, (addr, arr) in state["buffers"].items()}
        self._next = state["next"]
        self.time = state["time"]
        self.log.set_state(state["log"])
        if state["link"] is not None:
            self.link.set_state(state["link"])
        if state["fault_plan"] is not None:
            self.fault_plan.set_state(state["fault_plan"])
        cs = state.get("counters")
        if cs is not None:
            self.counters.set_state(cs)


class FireBridge:
    """Top-level co-verification environment: registers + memory bridge +
    switchable accelerator backends (paper Fig. 1c).

    Pass ``congestion`` to emulate interconnect contention online during
    ``launch()`` (§IV-C): stall statistics are then available from
    ``congestion_stats()`` as soon as the firmware returns.
    """

    BACKENDS = ("oracle", "interpret", "compiled")

    def __init__(self, name: str = "fb",
                 congestion: Optional[CongestionConfig] = None,
                 fault_plan: Optional["FaultPlan"] = None,
                 profile: bool = False) -> None:
        self.name = name
        self.log = TransactionLog()
        self.mem = MemoryBridge(self.log, congestion=congestion,
                                fault_plan=fault_plan, profile=profile)
        self.csr = RegisterFile(f"{name}.csr", self.log)
        self._ops: Dict[str, Dict[str, Callable]] = {}

    def register_op(self, name: str, *, oracle: Callable,
                    interpret: Optional[Callable] = None,
                    compiled: Optional[Callable] = None,
                    burst_list: Optional[Callable] = None) -> None:
        """An accelerator operation with up to three functionally-equivalent
        backends + an optional static burst-list derivation (the paper's
        golden-model / RTL-sim / deployment tiers, Fig. 1)."""
        self._ops[name] = {
            "oracle": oracle,
            "interpret": interpret or oracle,
            # callers pass an explicitly jitted fn for the compiled backend;
            # default falls back to the oracle (still XLA under the hood).
            "compiled": compiled or oracle,
            "burst_list": burst_list,
        }

    def launch(self, op: str, backend: str, in_bufs: List[str],
               out_bufs: List[str], engine: str = "accel",
               burst_list: Optional[Callable] = None, **kw) -> None:
        """Run one accelerator op against named DDR buffers, logging the
        transaction stream (paper Fig. 3 launch path).

        ``burst_list`` (here or at register_op) derives the tile-level DMA
        bursts from the kernel's BlockSpec schedule; with congestion
        enabled those bursts contend on the shared link while the op runs,
        so per-engine stalls are produced by the launch itself (Fig. 8).
        """
        assert backend in self.BACKENDS, backend
        with self.mem.mark(f"{op}@{backend}", engine):
            self._launch(op, backend, in_bufs, out_bufs, engine,
                         burst_list, kw)

    def _launch(self, op: str, backend: str, in_bufs: List[str],
                out_bufs: List[str], engine: str,
                burst_list: Optional[Callable], kw: Dict) -> None:
        fns = self._ops[op]
        args = [self.mem.dev_read(n, engine=f"{engine}_rd") for n in in_bufs]
        bl = burst_list or fns["burst_list"]
        if bl is not None:
            self.mem.log_burst_list(bl())
        outs = fns[backend](*args, **kw)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        if len(outs) != len(out_bufs):
            raise ValueError(
                f"op {op!r} ({backend}) returned {len(outs)} output(s) but "
                f"{len(out_bufs)} out_bufs were given ({out_bufs}); refusing "
                f"to silently truncate the writeback")
        for name, o in zip(out_bufs, outs):
            self.mem.dev_write(name, np.asarray(o), engine=f"{engine}_wr")

    def congestion_stats(self) -> Optional[CongestionResult]:
        """Per-engine stall/busy/utilization accumulated online (Fig. 8)."""
        return self.mem.congestion_stats()

    def counter_banks(self) -> List[CounterBank]:
        """Always-on counter banks owned by this target, in stable order
        (core/counters.py counter-diff oracle)."""
        return [self.mem.counters]

    def profiler(self, label: Optional[str] = None):
        """Off-chip data-movement profile of everything logged so far
        (core/profiler.py, §IV): exhaustive stall attribution closing to
        ``mem.time``, per-engine/per-op series, Perfetto export."""
        from repro.core.profiler import DataMovementProfiler
        return DataMovementProfiler(self, label=label or self.name)

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> Dict[str, Any]:
        """Snapshot for time-travel replay (core/replay.py).  ``mem``
        carries the shared transaction log (``self.log`` is the same
        object), so CSR state is just values + the protocol clock."""
        return {"mem": self.mem.get_state(), "csr": self.csr.get_state()}

    def set_state(self, state: Dict[str, Any]) -> None:
        self.mem.set_state(state["mem"])
        self.csr.set_state(state["csr"])
