"""The FireBridge memory bridge (paper §IV, Fig. 3).

Host-side firmware sees "DDR" as plain arrays (idiomatic-C-style pointer
access in the paper; NumPy views here).  The accelerator side — a Pallas
kernel in interpret mode ("RTL sim"), its jnp oracle ("golden model"), or
the compiled XLA executable ("deployment") — accesses the same buffers
through the bridge, which logs every burst as a Transaction.  The SAME
firmware function runs unmodified against every backend; that is the
paper's equivalence guarantee, checked by core/equivalence.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.registers import RegisterFile
from repro.core.transactions import Transaction, TransactionLog


@dataclasses.dataclass
class Buffer:
    name: str
    addr: int
    array: np.ndarray

    @property
    def nbytes(self) -> int:
        return self.array.nbytes


class MemoryBridge:
    """Host DDR pool with transaction-logged accelerator access."""

    PAGE = 4096

    def __init__(self, log: Optional[TransactionLog] = None) -> None:
        self.log = log if log is not None else TransactionLog()
        self._next = 0x1000_0000                    # DDR base
        self.buffers: Dict[str, Buffer] = {}
        self.time = 0.0

    def alloc(self, name: str, shape, dtype) -> Buffer:
        arr = np.zeros(shape, dtype)
        size = -(-arr.nbytes // self.PAGE) * self.PAGE
        buf = Buffer(name, self._next, arr)
        self._next += size
        self.buffers[name] = buf
        return buf

    # Firmware-side access: plain numpy (paper: dereferencing C pointers).
    def host_write(self, name: str, data) -> None:
        buf = self.buffers[name]
        np.copyto(buf.array, np.asarray(data, buf.array.dtype))

    def host_read(self, name: str) -> np.ndarray:
        return self.buffers[name].array.copy()

    # Accelerator-side access: transaction-logged bursts.
    def dev_read(self, name: str, engine: str = "dma") -> np.ndarray:
        buf = self.buffers[name]
        self.time += 1
        self.log.log(Transaction(self.time, engine, "read", buf.addr,
                                 buf.nbytes, tag=name))
        return buf.array.copy()

    def dev_write(self, name: str, data, engine: str = "dma") -> None:
        buf = self.buffers[name]
        self.time += 1
        self.log.log(Transaction(self.time, engine, "write", buf.addr,
                                 buf.nbytes, tag=name))
        np.copyto(buf.array, np.asarray(data, buf.array.dtype))

    def log_burst_list(self, txs: List[Tuple[str, str, int, int]],
                       base_time: Optional[float] = None) -> None:
        """Log a kernel's static BlockSpec-derived burst list (see
        kernels/systolic_matmul/ops.transactions)."""
        t = self.time if base_time is None else base_time
        for engine, kind, addr, nbytes in txs:
            t += 1
            self.log.log(Transaction(t, engine, kind, addr, nbytes))
        self.time = t


class FireBridge:
    """Top-level co-verification environment: registers + memory bridge +
    switchable accelerator backends (paper Fig. 1c)."""

    BACKENDS = ("oracle", "interpret", "compiled")

    def __init__(self, name: str = "fb") -> None:
        self.log = TransactionLog()
        self.mem = MemoryBridge(self.log)
        self.csr = RegisterFile(f"{name}.csr", self.log)
        self._ops: Dict[str, Dict[str, Callable]] = {}

    def register_op(self, name: str, *, oracle: Callable,
                    interpret: Optional[Callable] = None,
                    compiled: Optional[Callable] = None,
                    burst_list: Optional[Callable] = None) -> None:
        """An accelerator operation with up to three functionally-equivalent
        backends + an optional static burst-list derivation."""
        self._ops[name] = {
            "oracle": oracle,
            "interpret": interpret or oracle,
            # callers pass an explicitly jitted fn for the compiled backend;
            # default falls back to the oracle (still XLA under the hood).
            "compiled": compiled or oracle,
            "burst_list": burst_list,
        }

    def launch(self, op: str, backend: str, in_bufs: List[str],
               out_bufs: List[str], engine: str = "accel",
               burst_list: Optional[Callable] = None, **kw) -> None:
        """Run one accelerator op against named DDR buffers, logging the
        transaction stream.  `burst_list` (here or at register_op) derives
        the tile-level DMA bursts from the kernel's BlockSpec schedule."""
        assert backend in self.BACKENDS, backend
        fns = self._ops[op]
        args = [self.mem.dev_read(n, engine=f"{engine}_rd") for n in in_bufs]
        bl = burst_list or fns["burst_list"]
        if bl is not None:
            self.mem.log_burst_list(bl())
        outs = fns[backend](*args, **kw)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for name, o in zip(out_bufs, outs):
            self.mem.dev_write(name, np.asarray(o), engine=f"{engine}_wr")
