"""Interconnect topologies for the routed fabric (core/switch.py).

The crossbar fabric (`FabricCluster` with ``topology=None``) attaches
every device port and the host channel to one implicit zero-hop switch —
inter-device stalls never depend on *where* a device sits.  FireSim's
scaling story is the opposite: cycle-accurate simulation reaches
thousands of nodes because the interconnect is a *modeled switched
network* (``switch.cc``/``flit.h``) whose contention structure survives
scale-down.  This module provides that structure:

* a ``Topology`` — switches, directed inter-switch links, device→switch
  attachments, and **static routing tables** (per-switch next-hop maps
  computed once by deterministic BFS), with
  ``route(src_dev, dst_dev) -> tuple of link indices``;
* builders for the three classic shapes: ``ring`` (one switch per
  device, shortest-way routing, clockwise on ties), ``torus2d``
  (near-square grid with wraparound, x-before-y dimension-order
  preference), and ``fat_tree`` (leaf switches holding ``leaf_width``
  devices under ``spines`` spine switches, static spine selection
  rotated per leaf so uplink load spreads without adaptive routing).

Topologies are pure descriptions — no queues, no clocks.  The modeled
switch state (per-port flit arbitration, credit windows) lives in
``core/switch.py``; ``core/fabric.py`` turns transfer legs into
multi-hop journeys along ``route()``.

The host staging DDR attaches to switch ``host_attach`` (switch 0 by
default), so scatter/gather traffic is placement-dependent exactly like
device-to-device traffic.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Tuple

__all__ = ["Topology", "ring", "torus2d", "fat_tree", "build_topology",
           "TOPOLOGY_KINDS"]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A switched-interconnect shape: pure routing structure, no state.

    ``edges[k] = (a, b)`` is the k-th directed inter-switch link (one
    modeled switch egress port, ``core/switch.py``).  ``attach[i]`` is
    the switch device ``i`` hangs off.  ``flit_bytes`` is the framing
    granularity switch hops re-burst payloads at; ``credits`` is the
    per-port ingress-buffer depth for credit-based flow control.
    """
    kind: str
    n_devices: int
    n_switches: int
    attach: Tuple[int, ...]
    edges: Tuple[Tuple[int, int], ...]
    host_attach: int = 0
    flit_bytes: int = 256
    credits: int = 4

    def __post_init__(self):
        if len(self.attach) != self.n_devices:
            raise ValueError(
                f"attach maps {len(self.attach)} devices, topology has "
                f"{self.n_devices}")
        for s in (*self.attach, self.host_attach,
                  *(x for e in self.edges for x in e)):
            if not 0 <= s < self.n_switches:
                raise ValueError(f"switch id {s} out of range "
                                 f"[0, {self.n_switches})")
        # static routing tables: hop[s][t] = first link index on the
        # s -> t path, from one BFS per source switch.  Adjacency is
        # walked in link-declaration order, so builders control the
        # tie-break (clockwise for rings, x-before-y for tori, rotated
        # spine choice for fat trees) and routes are deterministic.
        adj: Dict[int, List[Tuple[int, int]]] = {
            s: [] for s in range(self.n_switches)}
        for k, (a, b) in enumerate(self.edges):
            adj[a].append((k, b))
        tables: List[Dict[int, int]] = []
        for src in range(self.n_switches):
            first: Dict[int, int] = {}
            q = deque([src])
            seen = {src}
            while q:
                s = q.popleft()
                for k, b in adj[s]:
                    if b in seen:
                        continue
                    seen.add(b)
                    # the first hop toward b is inherited from s (or is
                    # the link itself when s is the source)
                    first[b] = first.get(s, k)
                    q.append(b)
            tables.append(first)
        object.__setattr__(self, "_first_hop", tuple(tables))
        object.__setattr__(self, "_edge_by_pair",
                           {e: k for k, e in enumerate(self.edges)})

    # -------------------------------------------------------------- routing
    def route_switches(self, src_sw: int, dst_sw: int) -> Tuple[int, ...]:
        """Link indices along the static route between two switches
        (empty when they are the same switch)."""
        hops: List[int] = []
        s = src_sw
        while s != dst_sw:
            k = self._first_hop[s].get(dst_sw)
            if k is None:
                raise ValueError(
                    f"no route from switch {src_sw} to {dst_sw} "
                    f"({self.kind} topology is disconnected)")
            hops.append(k)
            s = self.edges[k][1]
        return tuple(hops)

    def route(self, src_dev: int, dst_dev: int) -> Tuple[int, ...]:
        """Link indices a device→device journey traverses (the hop list;
        empty when both devices share a switch)."""
        return self.route_switches(self.attach[src_dev],
                                   self.attach[dst_dev])

    def n_hops(self, src_dev: int, dst_dev: int) -> int:
        return len(self.route(src_dev, dst_dev))

    def groups(self) -> List[List[int]]:
        """Devices grouped by attachment switch (locality domains for the
        hierarchical all_reduce), in switch order, members sorted."""
        by_sw: Dict[int, List[int]] = {}
        for dev, sw in enumerate(self.attach):
            by_sw.setdefault(sw, []).append(dev)
        return [sorted(by_sw[sw]) for sw in sorted(by_sw)]

    def edge_label(self, k: int) -> str:
        a, b = self.edges[k]
        return f"sw{a}->sw{b}"


# ----------------------------------------------------------------- builders
def ring(n_devices: int, *, flit_bytes: int = 256,
         credits: int = 4) -> Topology:
    """One switch per device on a bidirectional ring.  Routing takes the
    shorter way around; on the even-ring tie the clockwise link is
    declared first, so ties break clockwise."""
    if n_devices < 1:
        raise ValueError(f"need at least one device, got {n_devices}")
    edges: List[Tuple[int, int]] = []
    if n_devices > 1:
        for i in range(n_devices):
            edges.append((i, (i + 1) % n_devices))          # clockwise
            edges.append((i, (i - 1) % n_devices))          # counter
    return Topology("ring", n_devices, n_devices,
                    tuple(range(n_devices)), tuple(dict.fromkeys(edges)),
                    flit_bytes=flit_bytes, credits=credits)


def _grid(n: int) -> Tuple[int, int]:
    """Near-square rows x cols factorization of ``n`` (rows <= cols)."""
    r = int(n ** 0.5)
    while r > 1 and n % r:
        r -= 1
    return r, n // r


def torus2d(n_devices: int, *, rows: int = 0, flit_bytes: int = 256,
            credits: int = 4) -> Topology:
    """One switch per device on a 2D torus (near-square grid with
    wraparound links).  Per-switch link order is +x, -x, +y, -y, so the
    BFS routing tables prefer x-first dimension-order routes."""
    if n_devices < 1:
        raise ValueError(f"need at least one device, got {n_devices}")
    if rows:
        if n_devices % rows:
            raise ValueError(f"{n_devices} devices do not tile into "
                             f"{rows} rows")
        r, c = rows, n_devices // rows
    else:
        r, c = _grid(n_devices)
    edges: List[Tuple[int, int]] = []
    for y in range(r):
        for x in range(c):
            s = y * c + x
            for nb in (y * c + (x + 1) % c, y * c + (x - 1) % c,
                       ((y + 1) % r) * c + x, ((y - 1) % r) * c + x):
                if nb != s and (s, nb) not in edges:
                    edges.append((s, nb))
    return Topology("torus2d", n_devices, n_devices,
                    tuple(range(n_devices)), tuple(edges),
                    flit_bytes=flit_bytes, credits=credits)


def fat_tree(n_devices: int, *, leaf_width: int = 4, spines: int = 2,
             flit_bytes: int = 256, credits: int = 4) -> Topology:
    """Two-level fat tree: ``ceil(n/leaf_width)`` leaf switches each
    holding up to ``leaf_width`` devices, every leaf linked to every
    spine.  Leaf ``l`` declares its uplinks starting at spine
    ``l % spines``, so the static tables spread uplink load across
    spines by source leaf (FireSim-style static multi-root routing —
    no adaptive state)."""
    if n_devices < 1:
        raise ValueError(f"need at least one device, got {n_devices}")
    leaf_width = max(1, leaf_width)
    n_leaves = -(-n_devices // leaf_width)
    spines = max(1, min(spines, n_leaves)) if n_leaves > 1 else 0
    attach = tuple(i // leaf_width for i in range(n_devices))
    edges: List[Tuple[int, int]] = []
    for leaf in range(n_leaves):
        for j in range(spines):
            sp = n_leaves + (leaf + j) % spines
            edges.append((leaf, sp))
            edges.append((sp, leaf))
    return Topology("fat_tree", n_devices, n_leaves + spines, attach,
                    tuple(dict.fromkeys(edges)),
                    flit_bytes=flit_bytes, credits=credits)


_BUILDERS = {"ring": ring, "torus2d": torus2d, "fat_tree": fat_tree}
TOPOLOGY_KINDS = tuple(_BUILDERS)


def build_topology(kind: str, n_devices: int, **kw) -> Topology:
    """Topology by name — the sweep-axis entry point
    (``CoVerifySession.add_sweep(..., topologies=("torus2d",))``)."""
    builder = _BUILDERS.get(kind)
    if builder is None:
        raise ValueError(f"unknown topology kind {kind!r} "
                         f"(known: {sorted(_BUILDERS)})")
    return builder(n_devices, **kw)
