import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402

from repro.configs import (SHAPES, applicable_shapes, get_config,  # noqa: E402
                           list_archs, non_embedding_params)
from repro.core import hlo_profiler  # noqa: E402
from repro.launch.mesh import make_ctx, make_production_mesh  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.models.transformer import (RunFlags, make_decode_fn,  # noqa: E402
                                      make_loss_fn, make_prefill_fn)

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS per the assignment: 6·N·D train (N active for MoE),
    2·N·D forward-only (prefill), 2·N per token (decode)."""
    n = non_embedding_params(cfg, active_only=cfg.moe is not None)
    if kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token per seq


def build_lowered(cfg, shape, mesh, ctx, flags: RunFlags,
                  zero_level: int = -1):
    kind = shape.kind
    if kind == "train":
        if zero_level < 0:      # auto: FSDP masters when ZeRO-1 won't fit
            zero_level = 1
            if steps_lib.train_state_bytes_per_device(cfg, mesh, 1) > 6e9:
                zero_level = 3
        # auto grad accumulation: bound activation live-set per microbatch
        # to ~4096 tokens/device (1M-token global batches always accumulate)
        ax = dict(zip(mesh.axis_names, mesh.devices.shape))
        dsize = mesh.devices.size // ax["model"]
        tok_dev = shape.global_batch * shape.seq_len // dsize
        want_nm = max(flags.microbatches, tok_dev // 4096)
        while shape.global_batch % want_nm:
            want_nm += 1
        if want_nm != flags.microbatches:
            flags = dataclasses.replace(flags, microbatches=want_nm)
        st_shape, st_sh, b_shape, b_sh, gshard = steps_lib.train_shardings(
            cfg, shape, mesh, ctx, zero_level=zero_level)
        step = steps_lib.make_train_step(cfg, flags, ctx,
                                         grad_shardings=gshard)
        jitted = jax.jit(step, in_shardings=(st_sh, b_sh), donate_argnums=0)
        return jitted.lower(st_shape, b_shape), zero_level, flags
    if kind == "prefill":
        p_shape, p_sh, b_shape, b_sh = steps_lib.prefill_shardings(
            cfg, shape, mesh, ctx)
        step = make_prefill_fn(cfg, flags, ctx, max_len=shape.seq_len)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        return jitted.lower(p_shape, b_shape), 0, flags
    # decode
    p_shape, p_sh, c_shape, c_sh, t_shape, t_sh = steps_lib.decode_shardings(
        cfg, shape, mesh, ctx)
    step = make_decode_fn(cfg, flags, ctx)
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh), donate_argnums=1)
    return jitted.lower(p_shape, c_shape, t_shape), 0, flags


def mem_fields(compiled, text=None):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                out[f] = int(v)
    except Exception as e:  # CPU backend may not support all fields
        out["error"] = str(e)
    if text is not None:
        out["cpu_f32_convert_artifact_bytes"] = _convert_artifacts(text)
    return out


def _convert_artifacts(text: str) -> int:
    """XLA-CPU rewrites bf16 dot operands as (often loop-hoisted) f32
    conversions — a backend emitter detail; TPU feeds bf16 to the MXU
    natively.  Sum the distinct large f32 buffers that have a bf16 twin of
    the same shape in the module so the HBM fit can be reported both raw
    and TPU-corrected (EXPERIMENTS.md §Dry-run caveat)."""
    import re as _re
    total = 0
    seen = set()
    for m in _re.finditer(r"= f32\[([\d,]+)\]", text):
        dims = m.group(1)
        if dims in seen:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 < 256e6:
            continue
        if f"bf16[{dims}]" in text:
            seen.add(dims)
            total += n * 4
    return int(total)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             flags: RunFlags, tag: str = "baseline",
             save_text: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh)
    world = mesh.devices.size

    t0 = time.time()
    lowered, zero_level, flags = build_lowered(cfg, shape, mesh, ctx, flags)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = {}
    try:
        ca = compiled.cost_analysis()
        cost = {k: float(ca[k]) for k in ("flops", "bytes accessed") if k in ca}
    except Exception as e:
        cost = {"error": str(e)}

    text = compiled.as_text()
    prof = hlo_profiler.profile_hlo(text, world)
    mf = model_flops(cfg, shape, shape.kind) / world
    rl = hlo_profiler.roofline(prof, mf)
    mem = mem_fields(compiled, text)

    rec = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "world": world,
        "tag": tag, "zero_level": zero_level,
        "flags": dataclasses.asdict(flags),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis_raw": cost,
        "profile": {
            "hlo_flops_per_dev": prof.flops,
            "hbm_traffic_bytes_per_dev": prof.traffic_bytes,
            "collective_bytes_per_dev": prof.collective_bytes,
            "dot_count": prof.dot_count,
            "collective_summary": {k: {"count": c, "bytes": b}
                                   for k, (c, b) in
                                   prof.collective_summary().items()},
            "warnings": prof.warnings[:20],
        },
        "roofline": {
            "compute_s": rl.compute_s,
            "memory_s": rl.memory_s,
            "collective_s": rl.collective_s,
            "dominant": rl.dominant,
            "model_flops_per_dev": mf,
            "useful_ratio": rl.useful_ratio,
            "roofline_fraction": rl.roofline_fraction,
        },
    }
    ART_DIR.mkdir(parents=True, exist_ok=True)
    pods = "2pod" if multi_pod else "1pod"
    out = ART_DIR / f"{arch}__{shape_name}__{pods}__{tag}.json"
    out.write_text(json.dumps(rec, indent=1))
    if save_text:
        (ART_DIR / f"{arch}__{shape_name}__{pods}__{tag}.hlo.txt").write_text(text)
    return rec


def flags_from_args(args) -> RunFlags:
    return RunFlags(
        attn_impl="chunked",
        q_chunk=args.q_chunk, kv_chunk=args.kv_chunk,
        skip_masked_tiles=args.skip_tiles,
        microbatches=args.microbatches,
        remat=not args.no_remat,
        moe_mode=args.moe_mode,
        wkv_chunk=args.wkv_chunk,
        remat_policy=args.remat_policy,
        sequence_parallel=args.seq_parallel,
    )


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run driver")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--kv-chunk", type=int, default=512)
    ap.add_argument("--skip-tiles", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--moe-mode", default="pjit")
    ap.add_argument("--wkv-chunk", type=int, default=16)
    ap.add_argument("--remat-policy", default="full")
    ap.add_argument("--seq-parallel", action="store_true")
    args = ap.parse_args()
    flags = flags_from_args(args)

    cells = []
    archs = [args.arch] if args.arch else list(list_archs())
    for a in archs:
        cfg = get_config(a)
        app = applicable_shapes(cfg)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for s in shapes:
            if app[s] != "OK":
                print(f"SKIP  {a:24s} {s:12s} {app[s]}")
                continue
            cells.append((a, s))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    n_ok = n_fail = 0
    for a, s in cells:
        for mp in meshes:
            name = f"{a:24s} {s:12s} {'2x16x16' if mp else '16x16'}"
            try:
                rec = run_cell(a, s, mp, flags, tag=args.tag,
                               save_text=args.save_hlo)
                rl = rec["roofline"]
                print(f"OK    {name} compile={rec['compile_s']:7.1f}s "
                      f"dom={rl['dominant']:10s} "
                      f"comp={rl['compute_s']:.3e}s mem={rl['memory_s']:.3e}s "
                      f"coll={rl['collective_s']:.3e}s "
                      f"useful={rl['useful_ratio']:.2f}", flush=True)
                n_ok += 1
            except Exception as e:
                print(f"FAIL  {name} {type(e).__name__}: {e}", flush=True)
                traceback.print_exc(limit=4)
                n_fail += 1
    print(f"\n{n_ok} OK, {n_fail} FAIL")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
