"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (required so tests/benches see 1 CPU device while only
dryrun.py forces 512 host devices).
"""
from __future__ import annotations

import jax

from repro.models.transformer import ShardCtx


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (and make_mesh's axis_types param) only exist
    # on newer jax; Auto is the default there, so omit on older versions.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many devices exist (tests / single host)."""
    return _make_mesh(shape, axes)


def make_ctx(mesh) -> ShardCtx:
    axes = mesh.axis_names
    data_axes = tuple(a for a in axes if a in ("pod", "data"))
    return ShardCtx(mesh=mesh, data_axes=data_axes, model_axis="model")
