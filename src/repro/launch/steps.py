"""Step factories: train_step (fwd+bwd+AdamW, microbatched), prefill_step,
decode_step — with full sharding wiring for jit/lower.

These are the exact programs the dry-run lowers and the trainer/server
executes; there is no separate "dry-run model".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import inputs as inputs_lib
from repro.models.transformer import (RunFlags, ShardCtx, init_cache,
                                      init_params, make_decode_fn,
                                      make_loss_fn, make_prefill_fn)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.sharding.specs import (batch_specs, cache_specs, param_specs,
                                  zero_specs)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_train_state(cfg: ModelConfig, key) -> dict:
    params = init_params(cfg, key, dtype=jnp.float32)
    opt = adamw_init(params)
    return {"params": params, **opt}


def train_state_shape(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda: make_train_state(cfg, jax.random.PRNGKey(0)))


def make_train_step(cfg: ModelConfig, flags: RunFlags,
                    ctx: Optional[ShardCtx],
                    opt_cfg: AdamWConfig = AdamWConfig(),
                    grad_shardings: Any = None):
    """grad_shardings: optional pytree of NamedShardings for the gradient
    accumulator (ZeRO: data-axis sharded).  GSPMD then reduce-scatters the
    data-parallel gradient sum instead of all-reducing it."""
    loss_fn = make_loss_fn(cfg, flags, ctx)
    nm = flags.microbatches

    def train_step(state, batch):
        params = state["params"]

        def grads_of(b):
            (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
            g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
            if grad_shardings is not None:
                g = jax.lax.with_sharding_constraint(g, grad_shardings)
            return l, g

        if nm == 1:
            loss, grads = grads_of(batch)
        else:
            def resh(a):
                a = a.reshape((nm, a.shape[0] // nm) + a.shape[1:])
                if ctx is not None:
                    a = jax.lax.with_sharding_constraint(
                        a, NamedSharding(ctx.mesh, P(None, ctx.data_spec)))
                return a

            mb = jax.tree.map(resh, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def body(carry, b):
                ls, gs = carry
                l, g = grads_of(b)
                gs = jax.tree.map(jnp.add, gs, g)
                return (ls + l, gs), None

            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), g0), mb)
            loss = loss / nm
            grads = jax.tree.map(lambda g: g / nm, grads)

        new_params, opt, info = adamw_update(
            opt_cfg, params, grads,
            {"m": state["m"], "v": state["v"], "step": state["step"]})
        metrics = {"loss": loss, **info}
        return {"params": new_params, **opt}, metrics

    return train_step


def train_state_bytes_per_device(cfg: ModelConfig, mesh, zero_level: int) -> float:
    """Rough fit estimate: masters f32 + m/v f32 (+ bf16 cast transient)."""
    st = train_state_shape(cfg)
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = ax["model"]
    world = mesh.devices.size
    pbytes = sum(l.size * 4 for l in jax.tree.leaves(st["params"]))
    mv = 2 * pbytes / world if zero_level >= 1 else 2 * pbytes / msize
    masters = pbytes / world if zero_level >= 3 else pbytes / msize
    grads = pbytes / world if zero_level >= 1 else pbytes / msize
    return masters + mv + grads


def train_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    ctx: ShardCtx, zero_level: int = 1):
    """zero_level: 0 = params/opt sharded on model only; 1 = moments + grad
    accumulators additionally sharded over data (ZeRO-1); 3 = master params
    too (GSPMD-FSDP)."""
    st_shape = train_state_shape(cfg)
    pspec = param_specs(cfg, st_shape["params"], mesh)
    zspec = zero_specs(pspec, st_shape["params"], mesh, ctx.data_axes)
    st_spec = {"params": zspec if zero_level >= 3 else pspec,
               "m": zspec if zero_level >= 1 else pspec,
               "v": zspec if zero_level >= 1 else pspec,
               "step": P()}
    b_shape = inputs_lib.train_input_specs(cfg, shape)
    b_spec = batch_specs(cfg, b_shape, mesh, data_axes=ctx.data_axes)
    sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    gshard = sh(zspec) if zero_level >= 1 else None
    return st_shape, sh(st_spec), b_shape, sh(b_spec), gshard


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def serve_params_shape(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16))


def prefill_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      ctx: ShardCtx):
    p_shape = serve_params_shape(cfg)
    p_spec = param_specs(cfg, p_shape, mesh)
    b_shape = inputs_lib.prefill_input_specs(cfg, shape)
    b_spec = batch_specs(cfg, b_shape, mesh, data_axes=ctx.data_axes)
    sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return p_shape, sh(p_spec), b_shape, sh(b_spec)


def decode_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     ctx: ShardCtx):
    p_shape = serve_params_shape(cfg)
    p_spec = param_specs(cfg, p_shape, mesh)
    c_shape = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    c_spec = cache_specs(cfg, c_shape, mesh, data_axes=ctx.data_axes)
    t_shape = inputs_lib.decode_token_specs(cfg, shape)
    dsize = 1
    for a in ctx.data_axes:
        dsize *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    t_spec = P(ctx.data_spec) if shape.global_batch % dsize == 0 and \
        shape.global_batch >= dsize else P(None)
    sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return (p_shape, sh(p_spec), c_shape, sh(c_spec), t_shape,
            NamedSharding(mesh, t_spec))
