"""Deterministic synthetic LM data with learnable structure.

Mixture of (a) Zipfian unigrams, (b) copy/induction spans (the sequence
repeats a randomly chosen earlier window), so a real model's loss drops
well below the unigram entropy — used by the end-to-end training example
and the loss-decreases integration test.  Fully seeded: restart-safe (the
pipeline can be fast-forwarded to any step for checkpoint/restart).
"""
from __future__ import annotations

import numpy as np


class SyntheticLMDataset:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, copy_frac: float = 0.5):
        self.V = vocab_size
        self.S = seq_len
        self.B = global_batch
        self.seed = seed
        self.copy_frac = copy_frac
        # Zipf weights over a head of the vocab
        head = min(self.V, 4096)
        w = 1.0 / np.arange(1, head + 1) ** 1.1
        self._p = w / w.sum()
        self._head = head

    def batch(self, step: int) -> dict:
        """Batch for a given step index (stateless -> restartable)."""
        rng = np.random.default_rng((self.seed, step))
        toks = rng.choice(self._head, size=(self.B, self.S + 1), p=self._p)
        # induction spans: copy an earlier window forward
        n_copy = int(self.B * self.copy_frac)
        for b in range(n_copy):
            span = rng.integers(8, max(9, self.S // 4))
            src = rng.integers(0, self.S - 2 * span)
            dst = rng.integers(src + span, self.S - span)
            toks[b, dst:dst + span] = toks[b, src:src + span]
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
