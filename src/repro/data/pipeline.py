"""Host data pipeline: background prefetch + device placement.

Double-buffers batches on a worker thread (host-side "DMA engine"); every
produced batch is transaction-logged when a bridge is attached, so data-path
stalls show up in the same Fig. 8-style profile as accelerator traffic.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional

import jax

from repro.core.transactions import Transaction, TransactionLog


class DataPipeline:
    def __init__(self, dataset, start_step: int = 0, prefetch: int = 2,
                 shardings: Any = None,
                 log: Optional[TransactionLog] = None):
        self.dataset = dataset
        self.shardings = shardings
        self.log = log
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch(step)
            if self.shardings is not None:
                batch = jax.device_put(batch, self.shardings)
            try:
                self._q.put((step, batch), timeout=1.0)
            except queue.Full:
                if self._stop.is_set():
                    return
                continue
            step += 1

    def next(self):
        step, batch = self._q.get()
        if self.log is not None:
            nbytes = sum(v.nbytes for v in jax.tree.leaves(batch))
            self.log.log(Transaction(float(step), "host_data", "read", 0,
                                     nbytes, tag=f"step{step}"))
        return step, batch

    def stop(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
