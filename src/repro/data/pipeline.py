"""Host data pipeline: background prefetch + device placement.

Double-buffers batches on a worker thread (host-side "DMA engine"); every
produced batch is transaction-logged when a bridge is attached, so data-path
stalls show up in the same Fig. 8-style profile as accelerator traffic.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional

import jax

from repro.core.transactions import Transaction, TransactionLog


_WORKER_ERROR = object()        # queue sentinel: worker died with an error


class DataPipeline:
    def __init__(self, dataset, start_step: int = 0, prefetch: int = 2,
                 shardings: Any = None,
                 log: Optional[TransactionLog] = None):
        self.dataset = dataset
        self.shardings = shardings
        self.log = log
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        # An exception anywhere in the produce path (dataset.batch,
        # device_put) used to kill this thread silently: prefetch just
        # ended and the consumer's next() blocked forever.  Now the error
        # is parked on the pipeline and a sentinel is queued so the
        # consumer re-raises it on its next get.
        step = self._step
        try:
            while not self._stop.is_set():
                batch = self.dataset.batch(step)
                if self.shardings is not None:
                    batch = jax.device_put(batch, self.shardings)
                try:
                    self._q.put((step, batch), timeout=1.0)
                except queue.Full:
                    if self._stop.is_set():
                        return
                    continue
                step += 1
        except BaseException as e:
            self._error = e
            while not self._stop.is_set():
                try:
                    self._q.put((_WORKER_ERROR, None), timeout=1.0)
                    return
                except queue.Full:
                    continue

    def next(self):
        step, batch = self._q.get()
        if step is _WORKER_ERROR:
            # put the sentinel back so every subsequent next() also raises
            # instead of hanging on the dead worker
            try:
                self._q.put_nowait((_WORKER_ERROR, None))
            except queue.Full:
                pass
            raise RuntimeError(
                "data pipeline worker failed") from self._error
        if self.log is not None:
            nbytes = sum(v.nbytes for v in jax.tree.leaves(batch))
            self.log.log(Transaction(float(step), "host_data", "read", 0,
                                     nbytes, tag=f"step{step}"))
        return step, batch

    def stop(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
