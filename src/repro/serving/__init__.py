from repro.serving.cluster import ClusterServingEngine
from repro.serving.engine import Request, ServingEngine

__all__ = ["ClusterServingEngine", "Request", "ServingEngine"]
