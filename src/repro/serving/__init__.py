from repro.serving.arrivals import (Arrival, ArrivalTrace, build_trace,
                                    bursty_trace, poisson_trace,
                                    replayed_trace, run_open_loop)
from repro.serving.cluster import ClusterServingEngine
from repro.serving.engine import Request, ServingEngine
from repro.serving.kvpool import KVPool
from repro.serving.slo import RequestStats, SLOReport

__all__ = [
    "Arrival", "ArrivalTrace", "ClusterServingEngine", "KVPool", "Request",
    "RequestStats", "SLOReport", "ServingEngine", "build_trace",
    "bursty_trace", "poisson_trace", "replayed_trace", "run_open_loop",
]
