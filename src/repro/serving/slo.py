"""Per-run latency-SLO report for open-loop serving (modeled cycles).

After an open-loop run (``serving/arrivals.py``) every completed request
carries its lifecycle timestamps on the engine's modeled clock:

  arrival  — when the open-loop source emitted it (from the trace)
  submit   — when the doorbell rang (>= arrival; equal unless the driver
             was busy stepping)
  admit    — when admission control granted a slot + KV pages (queueing
             delay = admit - arrival: the oversubscription signal)
  first    — when prefill emitted the first token (TTFT = first - arrival)
  done     — when the last token retired

``SLOReport.from_run`` collects them into per-request rows plus the SLO
summary: p50/p99 time-to-first-token, p50/p99 inter-token latency, and
tokens per kilocycle over the run horizon.  Everything is deterministic
(modeled cycles, not wall clock), so reports digest:

* ``digest()`` — full witness over rows AND token streams: identical
  across backends and across reruns of one configuration;
* ``tokens_digest()`` — token streams only: additionally identical across
  1/2/4-device scales, where modeled *timing* legitimately differs but
  generated tokens must not (the cross-scale tier in
  tests/test_serving_slo.py).

``benchmarks/bench_serving.py`` gates the committed ``BENCH_serving.json``
trajectory on these numbers; ``CoVerifySession.to_rows`` surfaces the
summary columns per sweep cell.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["RequestStats", "SLOReport", "percentile"]


def percentile(xs: List[float], q: float) -> float:
    """Deterministic linear-interpolation percentile (numpy's default
    method, implemented locally so the report never drifts with numpy
    versions).  Empty input -> 0.0."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = (len(s) - 1) * q / 100.0
    f = math.floor(k)
    c = min(f + 1, len(s) - 1)
    return s[f] + (s[c] - s[f]) * (k - f)


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """One completed request's lifecycle on the modeled clock."""
    rid: int
    t_arrival: float
    t_submit: float
    t_admit: float
    t_first: float
    t_done: float
    tokens: Tuple[int, ...]

    @property
    def ttft(self) -> float:
        """Time to first token, measured from *arrival* — queueing delay
        under load is part of the user-visible latency."""
        return self.t_first - self.t_arrival

    @property
    def queueing(self) -> float:
        return self.t_admit - self.t_arrival

    @property
    def itl(self) -> float:
        """Mean inter-token latency (0 for single-token requests)."""
        n = len(self.tokens)
        return (self.t_done - self.t_first) / (n - 1) if n > 1 else 0.0


@dataclasses.dataclass
class SLOReport:
    """Per-cell SLO readout of one open-loop run."""
    stats: List[RequestStats]
    horizon: float                      # final modeled clock
    deferrals: int                      # pool admission denials (retries)
    rejected: int                       # doorbell-time protocol rejections
    label: str = "serving"

    @classmethod
    def from_run(cls, trace: Any, target: Any,
                 label: str = "serving") -> "SLOReport":
        """Collect the report from a drained engine/cluster plus the
        arrival trace that drove it (the trace carries arrival times; the
        engine carries the admission/first/done stamps)."""
        t_arrival = {a.rid: a.time for a in trace.arrivals}
        stats = []
        for rid, req in sorted(target.requests.items()):
            if not req.done:
                continue
            stats.append(RequestStats(
                rid, t_arrival.get(rid, req.t_submit), req.t_submit,
                req.t_admit, req.t_first, req.t_done,
                tuple(int(t) for t in req.out_tokens)))
        engines = getattr(target, "engines", None) or [target]
        deferrals = sum(e.kv_pool.deferrals for e in engines
                        if e.kv_pool is not None)
        n_violations = len(target.violations) if hasattr(
            target, "violations") else len(target.mem.log.violations)
        return cls(stats, float(target.clock), deferrals, n_violations,
                   label=label)

    # ------------------------------------------------------------- metrics
    @property
    def completed(self) -> int:
        return len(self.stats)

    @property
    def total_tokens(self) -> int:
        return sum(len(s.tokens) for s in self.stats)

    def p50_ttft(self) -> float:
        return percentile([s.ttft for s in self.stats], 50.0)

    def p99_ttft(self) -> float:
        return percentile([s.ttft for s in self.stats], 99.0)

    def p50_itl(self) -> float:
        return percentile([s.itl for s in self.stats if len(s.tokens) > 1],
                          50.0)

    def p99_itl(self) -> float:
        return percentile([s.itl for s in self.stats if len(s.tokens) > 1],
                          99.0)

    def tokens_per_kcycle(self) -> float:
        """Throughput over the run horizon, tokens per 1000 modeled
        cycles."""
        return (self.total_tokens / self.horizon * 1000.0
                if self.horizon > 0 else 0.0)

    # ---------------------------------------------------------------- rows
    def to_rows(self) -> List[str]:
        """Per-request CSV rows (sorted by rid) + one summary row —
        the SLO table schema documented in docs/serving.md."""
        rows = ["rid,t_arrival,t_admit,t_first,t_done,"
                "queue_cycles,ttft_cycles,itl_cycles,tokens"]
        for s in self.stats:
            rows.append(f"{s.rid},{s.t_arrival:.1f},{s.t_admit:.1f},"
                        f"{s.t_first:.1f},{s.t_done:.1f},"
                        f"{s.queueing:.1f},{s.ttft:.1f},{s.itl:.1f},"
                        f"{len(s.tokens)}")
        rows.append(self.summary_row())
        return rows

    def summary_row(self) -> str:
        return (f"summary,completed={self.completed},"
                f"deferrals={self.deferrals},rejected={self.rejected},"
                f"p50_ttft={self.p50_ttft():.1f},"
                f"p99_ttft={self.p99_ttft():.1f},"
                f"p50_itl={self.p50_itl():.1f},"
                f"p99_itl={self.p99_itl():.1f},"
                f"tok_per_kcyc={self.tokens_per_kcycle():.3f}")

    def summary(self) -> Dict[str, Any]:
        return {
            "label": self.label,
            "completed": self.completed,
            "total_tokens": self.total_tokens,
            "horizon": round(self.horizon, 1),
            "deferrals": self.deferrals,
            "rejected": self.rejected,
            "p50_ttft": round(self.p50_ttft(), 1),
            "p99_ttft": round(self.p99_ttft(), 1),
            "p50_itl": round(self.p50_itl(), 1),
            "p99_itl": round(self.p99_itl(), 1),
            "tokens_per_kcycle": round(self.tokens_per_kcycle(), 3),
        }

    # ------------------------------------------------------------- digests
    def digest(self) -> str:
        """Full determinism witness: SLO rows + token streams.  Identical
        across backends (oracle/interpret/compiled) and reruns of one
        configuration; NOT across device counts (modeled timing differs
        per scale — use ``tokens_digest`` there)."""
        h = hashlib.sha256()
        for row in self.to_rows():
            h.update(row.encode())
            h.update(b"\n")
        h.update(self.tokens_digest().encode())
        return h.hexdigest()

    def tokens_digest(self) -> str:
        """Cross-scale witness: generated token streams only (rid order).
        Identical across 1/2/4 devices AND all backends for one seed."""
        h = hashlib.sha256()
        for s in self.stats:
            h.update(f"{s.rid}:{','.join(map(str, s.tokens))}\n".encode())
        return h.hexdigest()
