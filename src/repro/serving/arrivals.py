"""Seeded open-loop arrival processes + the open-loop serving driver.

Closed-loop storms (``run_until_done`` after a burst of submissions) only
exercise the engine at its own pace.  Open-loop load — the traffic shape
of millions of users — keeps arriving whether or not the engine kept up,
so queueing delay, deferred admission, and SLO percentiles become
observable.  This module provides the stimulus side:

* ``poisson_trace`` / ``bursty_trace`` / ``replayed_trace`` build an
  ``ArrivalTrace``: request ids, arrival times (modeled cycles), prompts,
  and token budgets, all a **pure function of the seed** (numpy
  ``default_rng``) — same seed, same trace, on any machine at any worker
  count.  ``fork()`` derives child traces by the same sha256 construction
  as ``FaultPlan.fork`` / ``runfarm.units.fork_seed``, so run-farm
  campaigns can shard arrival-trace sweeps without coordination.
* ``drive_open_loop`` is THE open-loop decision loop, shared verbatim by
  the live driver (``run_open_loop``) and the replay recorder
  (``replay.open_loop_program``): at each scheduler tick it submits every
  arrival whose time has come through the CSR protocol (prompt poke,
  SUBMIT_*, DOORBELL), steps the engine, and fast-forwards the modeled
  clock over idle gaps.  Submission instants depend only on the engine's
  deterministic clock, so the emitted event sequence is itself
  deterministic — which is what lets a recorded open-loop run replay
  bit-identically.

Works against a ``ServingEngine`` or ``ClusterServingEngine`` in
continuous-batching mode (both expose ``clock`` / ``advance_clock``).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Arrival", "ArrivalTrace", "fork_seed", "poisson_trace",
    "bursty_trace", "replayed_trace", "build_trace", "ARRIVAL_KINDS",
    "drive_open_loop", "run_open_loop",
]


def fork_seed(seed: int, label: str) -> int:
    """Deterministic child seed — identical construction to
    ``FaultPlan.fork`` (core/fuzz.py) and ``runfarm.units.fork_seed``,
    so arrival-trace lineages are order- and process-independent."""
    return int.from_bytes(
        hashlib.sha256(f"{seed}/{label}".encode()).digest()[:8], "little")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop request: arrives at ``time`` (modeled cycles),
    carries its prompt tokens and decode budget."""
    rid: int
    time: float
    prompt: Tuple[int, ...]
    max_new_tokens: int


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """A seed-closed arrival process realization.  ``kind``/``seed``/
    ``params`` fully determine ``arrivals`` for the generated kinds, so
    the trace ships as three JSON-friendly fields (runfarm unit params)
    and regenerates anywhere."""
    kind: str
    seed: int
    params: Tuple[Tuple[str, Any], ...]
    arrivals: Tuple[Arrival, ...]

    @property
    def label(self) -> str:
        return f"{self.kind}/s{self.seed}/n{len(self.arrivals)}"

    def digest(self) -> str:
        """sha256 over the canonical arrival lines (stimulus witness)."""
        h = hashlib.sha256()
        h.update(f"{self.kind}/{self.seed}".encode())
        for a in self.arrivals:
            h.update(f"{a.rid},{a.time:.6f},{a.max_new_tokens},"
                     f"{','.join(map(str, a.prompt))}\n".encode())
        return h.hexdigest()

    def fork(self, label: str) -> "ArrivalTrace":
        """Child trace: same process shape, seed forked by ``label``
        (sha256 — worker/order independent).  Generated kinds only."""
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(f"cannot fork a {self.kind!r} trace "
                             f"(explicit arrivals carry no seed)")
        return build_trace(self.kind, fork_seed(self.seed, label),
                           **dict(self.params))

    def total_tokens(self) -> int:
        return sum(a.max_new_tokens for a in self.arrivals)


def _mk_arrivals(times: np.ndarray, rng: np.random.Generator, *,
                 prompt_lens: Tuple[int, int], max_new: Tuple[int, int],
                 vocab: int, rid_base: int) -> Tuple[Arrival, ...]:
    out = []
    for i, t in enumerate(times):
        ln = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        mx = int(rng.integers(max_new[0], max_new[1] + 1))
        prompt = tuple(int(x) for x in rng.integers(1, vocab, size=ln))
        out.append(Arrival(rid_base + i, float(round(t, 6)), prompt, mx))
    return tuple(out)


def poisson_trace(seed: int, *, n_requests: int = 8,
                  mean_gap: float = 200.0,
                  prompt_lens: Tuple[int, int] = (3, 12),
                  max_new: Tuple[int, int] = (1, 6),
                  vocab: int = 512, rid_base: int = 0) -> ArrivalTrace:
    """Poisson process: exponential inter-arrival gaps with mean
    ``mean_gap`` modeled cycles."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(mean_gap, size=n_requests))
    params = (("n_requests", n_requests), ("mean_gap", mean_gap),
              ("prompt_lens", tuple(prompt_lens)),
              ("max_new", tuple(max_new)), ("vocab", vocab),
              ("rid_base", rid_base))
    return ArrivalTrace("poisson", seed, params,
                        _mk_arrivals(times, rng, prompt_lens=prompt_lens,
                                     max_new=max_new, vocab=vocab,
                                     rid_base=rid_base))


def bursty_trace(seed: int, *, n_requests: int = 8,
                 burst_size: int = 4, gap_in_burst: float = 10.0,
                 gap_between: float = 1500.0,
                 prompt_lens: Tuple[int, int] = (3, 12),
                 max_new: Tuple[int, int] = (1, 6),
                 vocab: int = 512, rid_base: int = 0) -> ArrivalTrace:
    """ON-OFF (bursty) process: bursts of up to ``burst_size`` requests
    ``gap_in_burst`` cycles apart, separated by exponential OFF periods
    with mean ``gap_between`` — the hostile shape where a whole burst
    lands on a drained engine at once."""
    rng = np.random.default_rng(seed)
    times: List[float] = []
    t = 0.0
    while len(times) < n_requests:
        t += float(rng.exponential(gap_between))
        n = int(rng.integers(1, burst_size + 1))
        for j in range(min(n, n_requests - len(times))):
            times.append(t + j * gap_in_burst)
    params = (("n_requests", n_requests), ("burst_size", burst_size),
              ("gap_in_burst", gap_in_burst), ("gap_between", gap_between),
              ("prompt_lens", tuple(prompt_lens)),
              ("max_new", tuple(max_new)), ("vocab", vocab),
              ("rid_base", rid_base))
    return ArrivalTrace("bursty", seed, params,
                        _mk_arrivals(np.asarray(times), rng,
                                     prompt_lens=prompt_lens,
                                     max_new=max_new, vocab=vocab,
                                     rid_base=rid_base))


def replayed_trace(entries: Sequence[Tuple[int, float, Sequence[int], int]]
                   ) -> ArrivalTrace:
    """Explicit (replayed) arrival trace from ``(rid, time, prompt,
    max_new_tokens)`` entries — captured production traffic, a fuzz
    scenario's hostile stream, or a hand-written regression case.
    Entries are sorted by (time, rid) into canonical arrival order."""
    arrivals = tuple(sorted(
        (Arrival(int(rid), float(t), tuple(int(x) for x in prompt),
                 int(mx)) for rid, t, prompt, mx in entries),
        key=lambda a: (a.time, a.rid)))
    return ArrivalTrace("replay", 0, (("n_requests", len(arrivals)),),
                        arrivals)


ARRIVAL_KINDS: Dict[str, Callable[..., ArrivalTrace]] = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
}


def build_trace(kind: str, seed: int, **params: Any) -> ArrivalTrace:
    """Registry entry point (runfarm units / fuzz scenarios build traces
    from JSON params through here)."""
    try:
        builder = ARRIVAL_KINDS[kind]
    except KeyError:
        raise KeyError(f"unknown arrival kind {kind!r} "
                       f"(known: {sorted(ARRIVAL_KINDS)})") from None
    return builder(seed, **params)


# ------------------------------------------------------------- the driver
def drive_open_loop(do: Callable[..., Any], target: Any,
                    trace: ArrivalTrace, max_ticks: int = 200_000) -> int:
    """THE open-loop decision loop, parameterized by the event sink:
    ``do(kind, *args)`` either applies directly (``run_open_loop``) or
    records + applies (``replay.open_loop_program``) — one loop, so the
    live and recorded stimulus cannot drift.

    Per iteration: submit every arrival due at the target's current
    modeled clock through the CSR protocol, then either step the
    scheduler (work pending/active) or fast-forward the clock to the next
    arrival (idle).  Returns the number of scheduler ticks driven.
    """
    pending = (target._n_pending if hasattr(target, "engines")
               else (lambda: len(target.pending)))
    arrivals = sorted(trace.arrivals, key=lambda a: (a.time, a.rid))
    i, ticks = 0, 0
    while i < len(arrivals) or pending() or target._n_active():
        now = target.clock
        while i < len(arrivals) and arrivals[i].time <= now:
            a = arrivals[i]
            i += 1
            do("host_poke", "prompt_in", np.asarray(a.prompt, np.int32))
            do("csr_write", "SUBMIT_ID", int(a.rid))
            do("csr_write", "SUBMIT_LEN", len(a.prompt))
            do("csr_write", "SUBMIT_MAXNEW", int(a.max_new_tokens))
            do("csr_write", "DOORBELL", 1)
        if not pending() and not target._n_active():
            if i >= len(arrivals):
                # every arrival submitted, none admitted still in flight
                # (the tail was rejected at the doorbell): drained
                break
            # drained with arrivals still ahead: fast-forward the modeled
            # clock over the idle gap (the open-loop source keeps its own
            # time — the engine does not get to slow it down)
            do("advance", float(arrivals[i].time))
            continue
        do("step")
        ticks += 1
        if ticks > max_ticks:
            raise RuntimeError(
                f"open-loop run did not drain within {max_ticks} ticks "
                f"({pending()} pending, {target._n_active()} active)")
    return ticks


def run_open_loop(target: Any, trace: ArrivalTrace,
                  max_ticks: int = 200_000) -> int:
    """Drive ``trace`` against a live engine/cluster (continuous-batching
    mode) without recording; returns the scheduler-tick count.  Events
    are funneled through ``replay.apply_event`` — the exact executor a
    recorded run replays through."""
    from repro.core.replay import TimelineEvent, apply_event

    def do(kind: str, *args: Any) -> Any:
        return apply_event(target, TimelineEvent(kind, args))

    return drive_open_loop(do, target, trace, max_ticks)
