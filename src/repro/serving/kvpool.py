"""Paged KV-cache pool with admission control (vLLM-style block manager
scaled down to the modeled engine).

``ServingEngine`` in continuous-batching mode reserves a request's whole
worst-case KV footprint — padded prompt plus ``max_new_tokens - 1`` decode
entries — from a fixed page pool **at admission**.  A request whose
reservation cannot be satisfied is *deferred*: it stays at the head of the
FIFO pending queue and is retried on a later scheduler tick, so
oversubscription degrades into queueing instead of a doorbell rejection.
Pages return to the free pool when the request retires (the eviction
policy: retire-time release, never mid-flight preemption — an admitted
request always runs to completion).

Reserve-on-admission makes the invariants the regression tier checks
trivially monotone:

* an admitted request can never run out of pages mid-decode, so it always
  retires with exactly ``max_new_tokens`` tokens;
* after a drained run every page is back in the free pool (no leaks);
* admission order is FIFO with no head-of-line bypass, so the admitted
  set is a pure function of the arrival trace and the pool geometry —
  deterministic at any worker/device count.

The free list is a LIFO stack popped from a fixed initial order, so the
page ids a request holds are themselves deterministic and live in the
replay fingerprints (``get_state``/``set_state``).

``leak_every`` is a fault-injection knob for the replay-bisect tier: every
``leak_every``-th release silently drops one page (a late-firing paging
bug — the run behaves until enough requests have retired), which
``tests/test_serving_slo.py`` localizes to its transaction via
``bisect_divergence``.
"""
from __future__ import annotations

from typing import Dict, List


class KVPool:
    """Fixed pool of ``n_pages`` KV pages, ``page_size`` cache entries
    (token positions) each, with per-request page lists."""

    def __init__(self, n_pages: int, page_size: int = 16,
                 leak_every: int = 0) -> None:
        if n_pages < 1 or page_size < 1:
            raise ValueError(f"bad pool geometry: {n_pages} pages x "
                             f"{page_size} entries")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.leak_every = int(leak_every)
        self.reset()

    def reset(self) -> None:
        """Fresh pool: all pages free, counters cleared."""
        # LIFO stack; popping from the end yields pages in 0,1,2,... order
        self.free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self.pages: Dict[int, List[int]] = {}   # rid -> held page ids
        self.deferrals = 0      # admission attempts denied for lack of pages
        self.releases = 0
        self.leaked = 0
        self.peak_in_use = 0

    # -------------------------------------------------------------- policy
    def pages_for(self, n_entries: int) -> int:
        """Pages covering ``n_entries`` KV positions (ceil division)."""
        return -(-max(0, int(n_entries)) // self.page_size)

    def fits(self, n_entries: int) -> bool:
        """Whether ``n_entries`` could EVER be admitted (whole-pool bound —
        the doorbell-time rejection test for impossible requests)."""
        return self.pages_for(n_entries) <= self.n_pages

    def reserve(self, rid: int, n_entries: int) -> bool:
        """Reserve the full footprint for ``rid`` or defer: returns False
        (and counts a deferral) without partial allocation when the free
        list is short."""
        if rid in self.pages:
            raise ValueError(f"request {rid} already holds pages")
        need = self.pages_for(n_entries)
        if need > len(self.free):
            self.deferrals += 1
            return False
        self.pages[rid] = [self.free.pop() for _ in range(need)]
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return True

    def release(self, rid: int) -> None:
        """Return ``rid``'s pages (retire-time eviction).  With the
        ``leak_every`` bug knob armed, every ``leak_every``-th release
        drops its last page on the floor."""
        held = self.pages.pop(rid)
        self.releases += 1
        if self.leak_every and self.releases % self.leak_every == 0 \
                and held:
            held = held[:-1]
            self.leaked += 1
        # reverse-order push keeps the free list a true LIFO stack: the
        # most recently used pages are reissued first, deterministically
        self.free.extend(reversed(held))

    # ------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free)

    def held_by(self, rid: int) -> List[int]:
        return list(self.pages.get(rid, ()))

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> dict:
        return {"free": list(self.free),
                "pages": {rid: list(p) for rid, p in self.pages.items()},
                "deferrals": self.deferrals,
                "releases": self.releases,
                "leaked": self.leaked,
                "peak_in_use": self.peak_in_use}

    def set_state(self, state: dict) -> None:
        self.free = list(state["free"])
        self.pages = {rid: list(p) for rid, p in state["pages"].items()}
        self.deferrals = state["deferrals"]
        self.releases = state["releases"]
        self.leaked = state["leaked"]
        self.peak_in_use = state["peak_in_use"]

    def __repr__(self) -> str:
        return (f"KVPool({self.in_use}/{self.n_pages} pages in use, "
                f"page_size={self.page_size}, "
                f"deferrals={self.deferrals})")
