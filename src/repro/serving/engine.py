"""Continuous-batching serving engine driven through a FireBridge
register-file control plane (paper §IV-A adapted to an inference server).

Hardware-style interface: firmware submits a request by writing its prompt
into a bridge DDR buffer, programming SUBMIT_* CSRs, and ringing the
DOORBELL; it polls STATUS/COMPLETED and reads generated tokens back from
DDR.  Internally the engine runs batched prefill/decode with slot-based
continuous batching over a shared KV/state cache (cache_insert).

The CSR protocol (and its violation audit) is what the register-protocol
fuzz tests exercise — serving *is* the paper's "accelerator with
memory-mapped configuration registers", deployed as a first-class feature.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bridge import MemoryBridge
from repro.core.congestion import CongestionConfig, CongestionResult
from repro.core.counters import CounterBank, CounterSpec
from repro.core.registers import RO, RegisterFile
from repro.models.transformer import (RunFlags, ShardCtx, cache_insert,
                                      init_cache, make_decode_fn,
                                      make_prefill_fn)
from repro.serving.kvpool import KVPool

CTRL, STATUS, DOORBELL = 0x00, 0x04, 0x08
SUBMIT_ID, SUBMIT_LEN, SUBMIT_MAXNEW = 0x0C, 0x10, 0x14
COMPLETED, ACTIVE = 0x18, 0x1C


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (len,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle stamps on the engine's modeled clock (continuous-batching
    # mode; -1.0 = not reached).  serving/slo.py reads them into the SLO
    # report: queueing = admit - arrival, TTFT = first - arrival.
    t_submit: float = -1.0
    t_admit: float = -1.0
    t_first: float = -1.0
    t_done: float = -1.0


def _copy_request(r: "Request") -> "Request":
    return Request(r.rid, r.prompt.copy(), r.max_new_tokens,
                   list(r.out_tokens), r.done, r.t_submit, r.t_admit,
                   r.t_first, r.t_done)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_len: int = 256,
                 flags: RunFlags = RunFlags(microbatches=1),
                 ctx: Optional[ShardCtx] = None,
                 prompt_pad: int = 16,
                 congestion: Optional[CongestionConfig] = None,
                 fault_plan=None,
                 jit_fns=None,
                 profile: bool = False,
                 batching: str = "storm",
                 kv_pages: Optional[int] = None,
                 kv_page_size: int = 16,
                 kv_leak_every: int = 0,
                 step_cycles: float = 64.0):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.flags = flags
        self.prompt_pad = prompt_pad
        self.congestion = congestion
        self.profile = profile
        # scheduling mode: "storm" is the closed-loop legacy tick (admit
        # ONE request or decode — the committed golden traces);
        # "continuous" is the open-loop tick (admit as many as slots AND
        # KV pages allow, then decode the whole batch) with a modeled
        # clock advanced by per-step costs — serving/arrivals.py drives it
        if batching not in ("storm", "continuous"):
            raise ValueError(f"unknown batching mode {batching!r}")
        self.batching = batching
        # KV paging (serving/kvpool.py): kv_pages=None runs unpaged;
        # kv_leak_every is the planted late-firing paging bug for the
        # replay-bisect tier
        self.kv_pages = kv_pages
        self.kv_page_size = kv_page_size
        self.kv_leak_every = kv_leak_every
        # modeled cost of one decode step (and of one prompt bucket of
        # prefill) on the engine clock, in cycles
        self.step_cycles = float(step_cycles)

        # `jit_fns` shares one (prefill, decode) executable pair across
        # device-local engines of a ClusterServingEngine — N devices, one
        # compilation (the FireSim "build once, run many" economy).
        if jit_fns is not None:
            self._prefill, self._decode = jit_fns
        else:
            self._prefill = jax.jit(make_prefill_fn(cfg, flags, ctx,
                                                    max_len))
            self._decode = jax.jit(make_decode_fn(cfg, flags, ctx))
        self.reset(fault_plan=fault_plan)

    @property
    def jit_fns(self):
        """The shareable (prefill, decode) executable pair."""
        return (self._prefill, self._decode)

    def reset(self, fault_plan=None, **overrides) -> None:
        """Restore fresh-engine state (cache, slots, queues, control plane,
        KV page pool, modeled clock) while keeping the jitted prefill/
        decode executables — used by the fuzz harness (core/fuzz.py) to
        run many randomized submit streams at warm-cache cost.
        ``fault_plan`` routes the engine's prompt/token DMA through
        bridge-level fault injection.  ``overrides`` reconfigures the
        scheduling axes for the rerun: ``batching``, ``kv_pages``,
        ``kv_page_size``, ``kv_leak_every``, ``step_cycles``."""
        for key in ("batching", "kv_pages", "kv_page_size",
                    "kv_leak_every", "step_cycles"):
            if key in overrides:
                setattr(self, key, overrides.pop(key))
        if overrides:
            raise TypeError(f"unknown reset overrides: {sorted(overrides)}")
        self.cache = init_cache(self.cfg, self.max_slots, self.max_len)
        self.slots: List[Optional[Request]] = [None] * self.max_slots
        self.pending: deque[Request] = deque()
        self.requests: Dict[int, Request] = {}
        self.completed = 0
        self.clock = 0.0
        self.kv_pool: Optional[KVPool] = (
            KVPool(self.kv_pages, self.kv_page_size,
                   leak_every=self.kv_leak_every)
            if self.kv_pages is not None else None)

        # control plane; with `congestion` the prompt/token DMA traffic is
        # arbitrated online through the shared-link model (paper §IV-C)
        self.mem = MemoryBridge(congestion=self.congestion,
                                fault_plan=fault_plan,
                                profile=self.profile)
        self.csr = RegisterFile("serve.csr", self.mem.log)
        self.csr.define("CTRL", CTRL)
        self.csr.define("STATUS", STATUS, access=RO)
        self.csr.define("DOORBELL", DOORBELL, on_write=self._on_doorbell)
        self.csr.define("SUBMIT_ID", SUBMIT_ID)
        self.csr.define("SUBMIT_LEN", SUBMIT_LEN)
        self.csr.define("SUBMIT_MAXNEW", SUBMIT_MAXNEW)
        self.csr.define("COMPLETED", COMPLETED, access=RO)
        self.csr.define("ACTIVE", ACTIVE, access=RO)
        self.mem.alloc("prompt_in", (self.max_len,), np.int32)
        self.mem.alloc("tokens_out", (self.max_slots, self.max_len),
                       np.int32)

        # always-on sampled counters (core/counters.py).  Functional-
        # scope counters (doorbells, requests/tokens retired) have
        # cumulative totals invariant across 1/2/4 devices — the
        # cross-scale side of the counter-diff oracle; the KV gauges are
        # per-engine timing-scope.  Rebuilt here because the pool and
        # bridge the probes read are rebuilt on every reset.
        self.counters = CounterBank("serving")
        self.counters.register(
            CounterSpec("doorbells", "events", scope="functional"))
        self.counters.register(
            CounterSpec("requests_retired", "events", scope="functional"))
        self.counters.register(
            CounterSpec("tokens_retired", "tokens", scope="functional"))
        if self.kv_pool is not None:
            pool = self.kv_pool
            self.counters.register(
                CounterSpec("kv_pages_in_use", "pages", monotone=False),
                lambda: pool.in_use)
            self.counters.register(CounterSpec("kv_peak_pages", "pages"),
                                   lambda: pool.peak_in_use)
            self.counters.register(CounterSpec("kv_deferrals", "events"),
                                   lambda: pool.deferrals)
            self.counters.register(CounterSpec("kv_releases", "events"),
                                   lambda: pool.releases)

    # -------------------------------------------------- register protocol
    def _on_doorbell(self, _data: int) -> None:
        self.counters.inc("doorbells")
        rid = self.csr.hw_get("SUBMIT_ID")
        ln = self.csr.hw_get("SUBMIT_LEN")
        mx = self.csr.hw_get("SUBMIT_MAXNEW")
        if ln <= 0 or ln > self.max_len:
            self.csr.log.violation(f"SUBMIT_LEN out of range: {ln}")
            return
        if self.batching == "continuous":
            # keep the DMA time domain and the engine clock in lockstep:
            # the prompt upload happens "now" on the modeled clock, and the
            # clock absorbs whatever the (possibly congested/faulted) link
            # charged for it
            self.mem.time = max(self.mem.time, self.clock)
        prompt = self.mem.dev_read("prompt_in", engine="serve_dma")[:ln]
        if self.batching == "continuous":
            self.clock = max(self.clock, self.mem.time)
        self.submit(Request(rid, prompt.astype(np.int32), mx))

    # ---------------------------------------------------------- scheduler
    def submit(self, req: Request) -> None:
        """Enqueue one request; rejects (with a logged violation, never a
        silent overwrite) non-positive token budgets and duplicate ids."""
        if req.max_new_tokens <= 0:
            self.csr.log.violation(
                f"SUBMIT_MAXNEW must be positive: {req.max_new_tokens} "
                f"(request {req.rid})")
            return
        # ids may be recycled once their request retired (bounded-width
        # SUBMIT_ID CSR); only an in-flight duplicate is a violation
        existing = self.requests.get(req.rid)
        if existing is not None and not existing.done:
            self.csr.log.violation(
                f"duplicate SUBMIT_ID {req.rid}: request still in flight")
            return
        # KV-cache capacity: prefill occupies the padded prompt bucket and
        # each decode step appends one entry — past max_len the cache
        # scatter would be silently dropped and generations corrupted
        pl = self._pad_len(len(req.prompt))
        if (len(req.prompt) > self.max_len
                or pl + req.max_new_tokens - 1 > self.max_len):
            self.csr.log.violation(
                f"request {req.rid} exceeds KV capacity: padded prompt "
                f"{pl} + {req.max_new_tokens} new tokens > max_len "
                f"{self.max_len}")
            return
        # page-pool feasibility: a request whose worst-case footprint
        # exceeds the WHOLE pool could never be admitted — deferring it
        # would livelock the FIFO, so it is rejected at the doorbell
        if self.kv_pool is not None and \
                not self.kv_pool.fits(pl + req.max_new_tokens - 1):
            self.csr.log.violation(
                f"request {req.rid} exceeds KV page pool: "
                f"{self.kv_pool.pages_for(pl + req.max_new_tokens - 1)} "
                f"pages needed > {self.kv_pool.n_pages} total")
            return
        req.t_submit = self.clock
        self.pending.append(req)
        self.requests[req.rid] = req

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _pad_len(self, n: int) -> int:
        p = self.prompt_pad
        return min(self.max_len, -(-n // p) * p)

    def step(self) -> int:
        """One scheduler tick.  Storm (legacy closed-loop) mode: admit one
        pending request (prefill+insert) OR run one batched decode step —
        the committed golden traces.  Continuous (open-loop) mode: admit
        as many pending requests as free slots and KV pages allow, then
        decode the whole batch, advancing the modeled clock by per-step
        costs.  Returns number of active slots."""
        n = (self._step_continuous() if self.batching == "continuous"
             else self._step_storm())
        # sample after the tick's state updates, on the front of the two
        # time domains (storm mode never advances self.clock; the DMA
        # clock still does)
        self.counters.tick(max(self.clock, self.mem.time))
        return n

    def _step_storm(self) -> int:
        slot = self._free_slot()
        if self.pending and slot is not None:
            req = self.pending.popleft()
            self._prefill_admit(slot, req)
            self.csr.hw_set("ACTIVE", self._n_active())
            return self._n_active()

        if self._n_active():
            self._decode_step()
            self.csr.hw_set("ACTIVE", self._n_active())
        return self._n_active()

    def _prefill_admit(self, slot: int, req: Request) -> None:
        """Prefill ``req`` into ``slot``: bucket-padded prefill, cache
        insert, first-token emit (shared by the storm and continuous
        schedulers; bit-exact with the legacy tick)."""
        # Left-pad to the prefill bucket; pad keys are masked out below.
        # RoPE scores depend only on position deltas, so the constant
        # offset is exact for attention families; for SSM/hybrid the
        # leading pad tokens perturb the state unless the prompt length
        # is already a bucket multiple (documented in the class doc).
        pl = self._pad_len(len(req.prompt))
        pad_n = pl - len(req.prompt)
        toks = np.zeros((1, pl), np.int32)
        toks[0, pad_n:] = req.prompt
        logits, single = self._prefill(
            self.params, self._batchify({"tokens": jnp.asarray(toks)}))
        self.cache = cache_insert(self.cache, single, slot)
        if pad_n and "kv_pos" in self.cache:
            self.cache["kv_pos"] = \
                self.cache["kv_pos"].at[slot, :pad_n].set(-1)
        self.slots[slot] = req
        first = int(jnp.argmax(logits[0]))
        req.out_tokens.append(first)
        # the prefill itself emits one token: a max_new_tokens=1
        # request is complete right here, not after a decode step
        if len(req.out_tokens) >= req.max_new_tokens:
            self._retire(slot)

    def _decode_step(self) -> None:
        """One batched decode step over all occupied slots (shared by the
        storm and continuous schedulers; bit-exact with the legacy tick)."""
        toks = np.zeros((self.max_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                toks[i] = s.out_tokens[-1] % self.cfg.vocab_size
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.out_tokens.append(int(nxt[i]))
            if len(s.out_tokens) >= s.max_new_tokens:
                self._retire(i)

    def _step_continuous(self) -> int:
        """Continuous-batching tick: FIFO admission (no head-of-line
        bypass — the admitted set stays a pure function of arrival order
        and pool geometry) up to slot/page limits, then one batched decode
        over everything resident.  The modeled clock pays
        ``step_cycles`` per prompt bucket of prefill and per decode step."""
        admitted = 0
        while self.pending:
            slot = self._free_slot()
            if slot is None:
                break
            req = self.pending[0]
            pl = self._pad_len(len(req.prompt))
            if self.kv_pool is not None and not self.kv_pool.reserve(
                    req.rid, pl + req.max_new_tokens - 1):
                break       # FIFO: deferred head blocks the queue
            self.pending.popleft()
            req.t_admit = self.clock
            self.clock += self.step_cycles * max(1, pl // self.prompt_pad)
            req.t_first = self.clock
            self._prefill_admit(slot, req)
            admitted += 1
        if self._n_active():
            self.clock += self.step_cycles
            self._decode_step()
        elif not admitted and self.pending:
            # nothing runnable (pages short of the FIFO head — only
            # reachable under an injected leak): modeled time must still
            # progress so the open-loop driver's max_ticks bound fires
            # instead of freezing the clock
            self.clock += self.step_cycles
        self.csr.hw_set("ACTIVE", self._n_active())
        return self._n_active()

    def advance_clock(self, t: float) -> None:
        """Fast-forward the modeled clock to ``t`` (idle-gap skip by the
        open-loop driver; never moves time backwards)."""
        self.clock = max(self.clock, float(t))
        self.counters.tick(max(self.clock, self.mem.time))

    def _retire(self, i: int) -> None:
        """Complete slot i: tokens_out DMA writeback, slot free,
        COMPLETED CSR update (shared by the prefill and decode paths)."""
        s = self.slots[i]
        s.done = True
        s.t_done = self.clock
        self.counters.inc("requests_retired")
        self.counters.inc("tokens_retired", len(s.out_tokens))
        if self.kv_pool is not None:
            self.kv_pool.release(s.rid)
        # row-sized DMA writeback: only slot i's tokens move
        buf = self.mem.buffers["tokens_out"]
        buf.array[i, :len(s.out_tokens)] = s.out_tokens
        row = buf.array[i]
        if self.batching == "continuous":
            # writeback is issued at the engine clock; the clock then
            # absorbs the link's makespan (congestion/faults show up as
            # inter-token latency, not just log entries)
            self.mem.log_burst_list(
                [("serve_dma", "write",
                  buf.addr + i * row.nbytes, row.nbytes)],
                base_time=max(self.mem.time, self.clock))
            self.clock = max(self.clock, self.mem.time)
        else:
            self.mem.log_burst_list(
                [("serve_dma", "write",
                  buf.addr + i * row.nbytes, row.nbytes)])
        self.slots[i] = None
        self.completed += 1
        self.csr.hw_set("COMPLETED", self.completed)

    def _batchify(self, batch):
        if self.cfg.frontend == "tokens+patches":
            B, M = 1, self.cfg.n_media_tokens
            batch["patches"] = jnp.zeros((B, M, self.cfg.d_model), jnp.float32)
        return batch

    def _n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def congestion_stats(self) -> Optional[CongestionResult]:
        """Fig. 8 stall statistics of the serving DMA traffic (None when
        the engine runs congestion-free)."""
        return self.mem.congestion_stats()

    def counter_banks(self):
        """All counter banks owned by this engine (core/counters.py):
        the serving-lifecycle bank plus the DMA bridge's link bank."""
        return [self.counters, self.mem.counters]

    def profiler(self, label: str = "serving"):
        """Data-movement profile of the serving DMA traffic
        (core/profiler.py): prompt-upload vs token-writeback attribution
        rides on the ``serve_dma`` read/write split
        (``DataMovementProfiler.serving_rows``)."""
        from repro.core.profiler import DataMovementProfiler
        return DataMovementProfiler(self, label=label)

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> dict:
        """Engine snapshot at a scheduler-tick boundary (core/replay.py):
        KV/state cache, request table, slot map, pending queue, and the
        control plane (bridge DDR + CSR values + transaction log).  The
        jitted prefill/decode executables are structure, not state — a
        restored engine reuses the live ones, so restore is warm-jit cheap.

        Requests are copied by rid so the slots/pending/requests aliasing
        (one object, three views) survives the round-trip."""
        reqs = {rid: _copy_request(r) for rid, r in self.requests.items()}
        return {
            "cache": dict(self.cache),      # jax arrays are immutable
            "requests": reqs,
            "slots": [s.rid if s is not None else None for s in self.slots],
            "pending": [r.rid for r in self.pending],
            "completed": self.completed,
            "clock": self.clock,
            "kv_pool": (self.kv_pool.get_state()
                        if self.kv_pool is not None else None),
            "mem": self.mem.get_state(),    # includes the shared log
            "csr": self.csr.get_state(),
            "counters": self.counters.get_state(),
        }

    def set_state(self, state: dict) -> None:
        self.cache = dict(state["cache"])
        self.requests = {rid: _copy_request(r)
                         for rid, r in state["requests"].items()}
        self.slots = [self.requests[rid] if rid is not None else None
                      for rid in state["slots"]]
        self.pending = deque(self.requests[rid] for rid in state["pending"])
        self.completed = state["completed"]
        # pre-paging checkpoints (storm-mode recordings) carry neither key
        self.clock = state.get("clock", 0.0)
        pool_state = state.get("kv_pool")
        if pool_state is not None and self.kv_pool is not None:
            self.kv_pool.set_state(pool_state)
        self.mem.set_state(state["mem"])
        self.csr.set_state(state["csr"])
        cs = state.get("counters")
        if cs is not None:
            self.counters.set_state(cs)

    def run_until_done(self, max_ticks: int = 10_000) -> None:
        self.csr.hw_set("STATUS", 1)
        for _ in range(max_ticks):
            if not self.pending and self._n_active() == 0:
                break
            self.step()
        self.csr.hw_set("STATUS", 2)
