"""Cluster-aware serving: N device-local ServingEngines behind ONE CSR
control plane, with prompt and token DMA contending on a modeled
host↔fabric channel (paper §IV-A at FireSim scale; core/fabric.py is the
same interconnect model under the co-verification sweeps).

Firmware talks to the cluster exactly as it talks to a single engine —
write the prompt into ``prompt_in``, program SUBMIT_*, ring DOORBELL,
poll COMPLETED — and the front control plane round-robins request slots
across the device-local engines.  Every prompt upload crosses the shared
host channel before it reaches the target device, and every retired
request's token row crosses it back, so cluster serving traffic contends
on the fabric the way the paper's DMA VIPs contend on the AXI
interconnect (Fig. 8 statistics from ``fabric_stats()``).

Compiled executables are shared: the first engine jits prefill/decode
once and its ``jit_fns`` seed the other devices.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.bridge import MemoryBridge
from repro.core.congestion import (CongestionConfig, CongestionResult,
                                   LinkModel)
from repro.core.counters import (CounterBank, CounterSpec,
                                 register_link_counters,
                                 register_switch_port_counters)
from repro.core.fabric import FABRIC_LINK
from repro.core.registers import RO, RegisterFile
from repro.core.switch import SwitchFabric
from repro.core.topology import build_topology
from repro.core.transactions import BurstBatch, TransactionLog
# the front-end mirrors the single engine's CSR map exactly (firmware
# drives either interchangeably); only NDEV is cluster-specific
from repro.serving.engine import (ACTIVE, COMPLETED, CTRL, DOORBELL, STATUS,
                                  SUBMIT_ID, SUBMIT_LEN, SUBMIT_MAXNEW,
                                  Request, ServingEngine)

NDEV = 0x20


class ClusterServingEngine:
    """One CSR front-end, N device-local engines, one contended fabric."""

    def __init__(self, cfg, params, *, n_devices: int = 2,
                 max_slots: int = 2, max_len: int = 256,
                 flags=None, prompt_pad: int = 16,
                 congestion: Optional[CongestionConfig] = None,
                 link_config: Optional[CongestionConfig] = None,
                 fault_plan=None, topology=None,
                 batching: str = "storm",
                 kv_pages: Optional[int] = None,
                 kv_page_size: int = 16,
                 kv_leak_every: int = 0,
                 step_cycles: float = 64.0):
        if n_devices < 1:
            raise ValueError(f"need at least one device, got {n_devices}")
        self.n = n_devices
        self.max_slots = max_slots          # per device
        self.max_len = max_len
        # scheduling mode + per-device KV paging forwarded to every
        # device-local engine (see ServingEngine; each device owns its own
        # page pool — admission control is local to the routed engine)
        if batching not in ("storm", "continuous"):
            raise ValueError(f"unknown batching mode {batching!r}")
        self.batching = batching
        self._serve_kw = dict(batching=batching, kv_pages=kv_pages,
                              kv_page_size=kv_page_size,
                              kv_leak_every=kv_leak_every,
                              step_cycles=step_cycles)
        self.link_config = link_config if link_config is not None \
            else FABRIC_LINK
        self._fault_plan = fault_plan
        # optional switched interconnect (core/topology.py): prompt
        # uploads and token writebacks then additionally cross the switch
        # hops between the host attachment and the engine's switch, so
        # writeback contention becomes placement-dependent
        if isinstance(topology, str):
            topology = build_topology(topology, n_devices)
        if topology is not None and topology.n_devices != n_devices:
            raise ValueError(
                f"topology {topology.kind!r} describes "
                f"{topology.n_devices} devices, cluster has {n_devices}")
        self._topology = topology

        def _child_plan(plan, i):
            return plan.fork(f"cluster/e{i}") if plan is not None else None

        def _kw(i):
            # per-device DDR links get distinct DoS seeds (engine 0 keeps
            # the caller's seed), matching FabricCluster's decorrelation
            kw = dict(max_slots=max_slots, max_len=max_len,
                      prompt_pad=prompt_pad,
                      congestion=(dataclasses.replace(
                          congestion, seed=congestion.seed + i)
                          if congestion is not None else None),
                      **self._serve_kw)
            if flags is not None:
                kw["flags"] = flags
            return kw

        first = ServingEngine(cfg, params,
                              fault_plan=_child_plan(fault_plan, 0),
                              **_kw(0))
        self.engines: List[ServingEngine] = [first] + [
            ServingEngine(cfg, params, jit_fns=first.jit_fns,
                          fault_plan=_child_plan(fault_plan, i), **_kw(i))
            for i in range(1, n_devices)]
        self._init_control_plane(fault_plan)

    def _init_control_plane(self, fault_plan) -> None:
        self.log = TransactionLog()
        self.host_link = LinkModel(self.link_config)
        # host-channel traffic is fault-plan-aware like every other fabric
        # link (a forked child, so the cluster reproduces from one seed)
        self.link_plan = (fault_plan.fork("cluster/links")
                          if fault_plan is not None else None)
        # fresh switch state per control-plane (re)init, so reset() also
        # resets flit arbitration and credit windows
        self.switch = (SwitchFabric(self._topology, self.link_config)
                       if self._topology is not None else None)
        self.time = 0.0
        self.mem = MemoryBridge(self.log)       # host staging DDR
        self.mem.alloc("prompt_in", (self.max_len,), np.int32)
        self.rows = self.n * self.max_slots
        self.mem.alloc("tokens_out", (self.rows, self.max_len), np.int32)
        self.csr = RegisterFile("cluster.csr", self.log)
        self.csr.define("CTRL", CTRL)
        self.csr.define("STATUS", STATUS, access=RO)
        self.csr.define("DOORBELL", DOORBELL, on_write=self._on_doorbell)
        self.csr.define("SUBMIT_ID", SUBMIT_ID)
        self.csr.define("SUBMIT_LEN", SUBMIT_LEN)
        self.csr.define("SUBMIT_MAXNEW", SUBMIT_MAXNEW)
        self.csr.define("COMPLETED", COMPLETED, access=RO)
        self.csr.define("ACTIVE", ACTIVE, access=RO)
        self.csr.define("NDEV", NDEV, access=RO, reset=self.n)
        self._rr = 0                            # round-robin pointer
        self.completed = 0
        self._written: Set[Tuple[int, int]] = set()   # (engine, rid) done
        self.placement: Dict[int, int] = {}     # rid -> engine index
        # front-side counter banks (core/counters.py): the shared host
        # channel plus one bank per switch port when a topology is routed
        hb = CounterBank("cluster/host")
        register_link_counters(hb, self.host_link)
        hb.register(CounterSpec("transactions", "events"),
                    probe=lambda: self.log.n_txs)
        self._counter_banks: List[CounterBank] = [hb]
        if self.switch is not None:
            for sp in self.switch.ports:
                sb = CounterBank(f"cluster/sw:{sp.label}")
                register_switch_port_counters(sb, sp)
                self._counter_banks.append(sb)

    def reset(self, fault_plan=None) -> None:
        """Fresh cluster state at warm-jit cost (mirrors
        ServingEngine.reset, including its semantics: ``fault_plan=None``
        CLEARS any installed plan; pass a plan to fault-inject the rerun).
        Used by fuzz/storm reruns."""
        self._fault_plan = fault_plan
        for i, eng in enumerate(self.engines):
            eng.reset(fault_plan=(fault_plan.fork(f"cluster/e{i}")
                                  if fault_plan is not None else None))
        self._init_control_plane(fault_plan)

    # ----------------------------------------------------------- fabric DMA
    def _dma(self, engine: str, kind: str, addr: int, nbytes: int,
             tag: str, at: Optional[float] = None,
             dev: Optional[int] = None) -> float:
        """One transfer over the shared host↔fabric channel, burst-split
        (BurstBatch.from_transfer — same splitter as the fabric links),
        fault-perturbed, and congestion-arbitrated (this is where cluster
        prompt uploads and token writebacks contend).  ``at`` sets the
        min-issue time — transfers sharing one scheduler tick issue
        together and therefore contend, instead of serializing in program
        order.

        With a topology installed and ``dev`` given, the transfer is a
        store-and-forward journey: outbound (``h->e*``) crosses the host
        channel then the flit-framed, credit-flow-controlled switch hops
        toward the engine's switch; inbound (``e*->h``) crosses the
        switch hops first.  ``dev=None`` (or no topology) keeps the
        single-channel crossbar path bit-exactly."""
        t = self.time if at is None else at
        hops = [(self.host_link, self.link_config.max_burst_bytes, None)]
        if self.switch is not None and dev is not None:
            outbound = engine.startswith("h->")
            ports = (self.switch.route_ports("h", dev) if outbound
                     else self.switch.route_ports(dev, "h"))
            sw = [(p.link, self._topology.flit_bytes, p) for p in ports]
            hops = hops + sw if outbound else sw + hops
        for link, step, port in hops:
            if port is not None:
                t = port.acquire(t)
            batch = BurstBatch.from_transfer(t, engine, kind, addr,
                                             nbytes, tag, step)
            if self.link_plan is not None:
                batch = self.link_plan.perturb_batch(batch, self.log)
            t = link.submit_batch(batch, self.log)
            if port is not None:
                port.release(batch.rec["complete"].tolist())
        self.time = max(self.time, t)
        self._tick_counters(self.time)
        return t

    def _tick_counters(self, now: float) -> None:
        for b in self._counter_banks:
            b.tick(now)

    # ------------------------------------------------------ front protocol
    def _on_doorbell(self, _data: int) -> None:
        rid = self.csr.hw_get("SUBMIT_ID")
        ln = self.csr.hw_get("SUBMIT_LEN")
        mx = self.csr.hw_get("SUBMIT_MAXNEW")
        # cluster-wide in-flight duplicate check: the per-engine check
        # cannot see a duplicate that round-robin routed to a DIFFERENT
        # engine, so the front-end must enforce the same no-silent-
        # overwrite guarantee the single engine gives
        holder = next((e for e in self.engines if rid in e.requests), None)
        if holder is not None and not holder.requests[rid].done:
            self.csr.log.violation(
                f"duplicate SUBMIT_ID {rid}: request still in flight")
            return
        i = self._rr % self.n
        eng = self.engines[i]
        # prompt DMA: host staging buffer -> device-local prompt_in over
        # the shared channel (a bad request still paid for its upload).
        # In continuous mode the upload issues at the cluster clock and
        # the routed engine's clock absorbs its completion, so queueing
        # behind a congested host channel is visible in TTFT.
        src = self.mem.buffers["prompt_in"]
        at = max(self.time, self.clock) if self.batching == "continuous" \
            else None
        t_up = self._dma(f"h->e{i}", "write", src.addr, src.nbytes,
                         "prompt_in", at=at, dev=i)
        if self.batching == "continuous":
            eng.advance_clock(t_up)
        np.copyto(eng.mem.buffers["prompt_in"].array, src.array)
        # forward the submission through the device-local CSR protocol;
        # remaining validation (bad length, KV budget) happens there and
        # violations land in the device log — see `violations`
        before = eng.requests.get(rid)
        eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_ID"), rid)
        eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_LEN"), ln)
        eng.csr.fb_write_32(eng.csr.addr_of("SUBMIT_MAXNEW"), mx)
        eng.csr.fb_write_32(eng.csr.addr_of("DOORBELL"), 1)
        after = eng.requests.get(rid)
        if after is not None and after is not before:   # accepted
            # the round-robin pointer advances only on acceptance, so a
            # storm of rejected submissions cannot skew live slots onto
            # one engine
            self._rr += 1
            self.placement[rid] = i
            # recycling a retired id must re-arm its writeback (a stale
            # _written marker would suppress the new request's token DMA
            # and COMPLETED update forever)
            self._written.discard((i, rid))
            # ...and drop a retired request left on another engine, so
            # the merged `requests` view stays unambiguous (ids recycle
            # only after retirement, as in the single engine)
            for j, other in enumerate(self.engines):
                if other is not eng and rid in other.requests:
                    del other.requests[rid]
                    self._written.discard((j, rid))

    # ------------------------------------------------------------ schedule
    def step(self) -> int:
        """One cluster tick: every engine steps once; newly retired
        requests stream their token rows back over the shared channel,
        all issuing at the tick boundary so concurrent retirements from
        different devices contend for channel bandwidth."""
        tick = self.time
        for i, eng in enumerate(self.engines):
            eng.step()
            # continuous mode: the retired row leaves when the engine
            # retired it (its modeled clock), not at the cluster tick base
            self._writeback(i, eng,
                            eng.clock if self.batching == "continuous"
                            else tick)
        active = self._n_active()
        self.csr.hw_set("ACTIVE", active)
        self._tick_counters(self.clock)
        return active

    def _writeback(self, i: int, eng: ServingEngine, tick: float) -> None:
        out = self.mem.buffers["tokens_out"]
        row_bytes = out.array[0].nbytes
        for rid in sorted(r for r, req in eng.requests.items()
                          if req.done and (i, r) not in self._written):
            self._written.add((i, rid))
            row = self.completed % self.rows
            toks = eng.requests[rid].out_tokens
            out.array[row, :] = 0
            out.array[row, :len(toks)] = toks
            self._dma(f"e{i}->h", "write", out.addr + row * row_bytes,
                      row_bytes, f"tokens[{rid}]", at=tick, dev=i)
            self.completed += 1
            self.csr.hw_set("COMPLETED", self.completed & 0xFFFFFFFF)

    def _n_active(self) -> int:
        return sum(e._n_active() for e in self.engines)

    def _n_pending(self) -> int:
        return sum(len(e.pending) for e in self.engines)

    # ------------------------------------------------------- modeled clock
    @property
    def clock(self) -> float:
        """Cluster-level modeled clock: the front of all time domains
        (host channel + every device-local engine clock).  The open-loop
        driver (serving/arrivals.py) reads this to decide which arrivals
        are due."""
        return max([self.time] + [e.clock for e in self.engines])

    def advance_clock(self, t: float) -> None:
        """Fast-forward every device-local clock to ``t`` (idle-gap skip
        by the open-loop driver; never moves time backwards)."""
        for e in self.engines:
            e.advance_clock(t)

    def run_until_done(self, max_ticks: int = 10_000) -> None:
        self.csr.hw_set("STATUS", 1)
        for _ in range(max_ticks):
            if not self._n_pending() and not self._n_active():
                break
            self.step()
        self.csr.hw_set("STATUS", 2)

    # --------------------------------------------- checkpoint/restore hooks
    def get_state(self) -> dict:
        """Whole-cluster snapshot at a tick boundary (core/replay.py):
        every device-local engine, the front control plane (staging DDR +
        CSR + front log, which ``mem`` carries), the shared host channel,
        the channel fault plan, and the placement bookkeeping."""
        return {
            "engines": [e.get_state() for e in self.engines],
            "mem": self.mem.get_state(),    # front staging DDR + self.log
            "csr": self.csr.get_state(),
            "host_link": self.host_link.get_state(),
            "switch": (self.switch.get_state()
                       if self.switch is not None else None),
            "link_plan": (self.link_plan.get_state()
                          if self.link_plan is not None else None),
            "time": self.time,
            "rr": self._rr,
            "completed": self.completed,
            "written": set(self._written),
            "placement": dict(self.placement),
            "counters": [b.get_state() for b in self._counter_banks],
        }

    def set_state(self, state: dict) -> None:
        for e, s in zip(self.engines, state["engines"]):
            e.set_state(s)
        self.mem.set_state(state["mem"])
        self.csr.set_state(state["csr"])
        self.host_link.set_state(state["host_link"])
        if self.switch is not None and state.get("switch") is not None:
            self.switch.set_state(state["switch"])
        if state["link_plan"] is not None:
            self.link_plan.set_state(state["link_plan"])
        self.time = state["time"]
        self._rr = state["rr"]
        self.completed = state["completed"]
        self._written = set(state["written"])
        self.placement = dict(state["placement"])
        for b, s in zip(self._counter_banks, state.get("counters") or []):
            b.set_state(s)

    # ---------------------------------------------------------- inspection
    @property
    def requests(self) -> Dict[int, Request]:
        """Merged rid -> Request view across the device-local engines."""
        out: Dict[int, Request] = {}
        for eng in self.engines:
            out.update(eng.requests)
        return out

    @property
    def violations(self) -> List[str]:
        out = list(self.csr.log.violations)
        for i, eng in enumerate(self.engines):
            out += [f"[e{i}] {v}" for v in eng.csr.log.violations]
        return out

    def fabric_stats(self) -> CongestionResult:
        """Fig. 8 stall statistics of the shared host↔fabric channel
        (prompt uploads + token writebacks, all engines contending)."""
        return self.host_link.result()

    def profiler(self, label: str = "cluster"):
        """Data-movement profile of the cluster (core/profiler.py): the
        shared host channel (where ``h->e*`` prompt uploads contend with
        ``e*->h`` token writebacks — ``serving_rows`` splits them) plus
        every device-local engine's DDR/CSR channels."""
        from repro.core.profiler import DataMovementProfiler
        return DataMovementProfiler(self, label=label)

    def congestion_stats(self) -> CongestionResult:
        return self.fabric_stats()

    def counter_banks(self) -> List[CounterBank]:
        """All cluster counter banks: front (host channel + switch ports)
        followed by every device-local engine's banks, engine order."""
        out = list(self._counter_banks)
        for eng in self.engines:
            out.extend(eng.counter_banks())
        return out

    def digest(self) -> str:
        """Reproducibility witness over the front log and device logs."""
        import hashlib
        h = hashlib.sha256()
        h.update(self.log.digest().encode())
        for eng in self.engines:
            h.update(eng.mem.log.digest().encode())
        return h.hexdigest()
