"""Shared neural-net building blocks (pure functions over param pytrees).

Conventions:
  * params are plain nested dicts of jnp arrays; layer stacks carry a leading
    (L, ...) axis and are consumed by ``lax.scan``.
  * compute dtype is bf16 with f32 accumulation for softmax/norm/loss;
    master params may be f32 (training) or bf16 (serving).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key: Array, d_in: int, d_out: int, dtype, scale: float = 1.0,
               shape_prefix: Tuple[int, ...] = ()) -> Array:
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, shape_prefix + (d_in, d_out), jnp.float32)
            * std).astype(dtype)


def embed_init(key: Array, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: Array, w: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def head_rms_norm(x: Array, w: Array, eps: float = 1e-5) -> Array:
    """Per-head RMS norm; x: (..., H, K), w: (H, K)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, fraction: str, theta: float = 10_000.0) -> Array:
    """Inverse frequencies for the rotary slice of the head dim.

    fraction: "full" -> rotate the whole head_dim; "half" -> rotate the first
    half only (chatglm-style 2D RoPE); "none" handled by callers.
    """
    rot = head_dim if fraction == "full" else head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: Array, positions: Array, fraction: str,
               theta: float = 10_000.0) -> Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    if fraction == "none":
        return x
    d = x.shape[-1]
    rot = d if fraction == "full" else d // 2
    inv = rope_freqs(d, fraction, theta)                       # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv       # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]                          # (B, S, 1, rot/2)
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    if rot == d:
        return yr.astype(x.dtype)
    return jnp.concatenate([yr.astype(x.dtype), x[..., rot:]], axis=-1)


def sinusoidal_positions(positions: Array, d_model: int) -> Array:
    """Fixed sin-cos position encoding; positions (B, S) -> (B, S, d_model)."""
    half = d_model // 2
    inv = 1.0 / (10_000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key: Array, d_model: int, d_ff: int, mlp_type: str, dtype,
             shape_prefix: Tuple[int, ...] = ()) -> dict:
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype, shape_prefix=shape_prefix),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype, shape_prefix=shape_prefix),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype, shape_prefix=shape_prefix),
        }
    return {
        "w_in": dense_init(ks[0], d_model, d_ff, dtype, shape_prefix=shape_prefix),
        "w_out": dense_init(ks[1], d_ff, d_model, dtype, shape_prefix=shape_prefix),
    }


def mlp_apply(w: dict, x: Array, mlp_type: str) -> Array:
    if mlp_type == "swiglu":
        g = x @ w["w_gate"]
        u = x @ w["w_up"]
        return (jax.nn.silu(g) * u) @ w["w_down"]
    h = jax.nn.gelu(x @ w["w_in"])
    return h @ w["w_out"]


# ---------------------------------------------------------------------------
# Cross-entropy that never materialises one-hot (B, S, V) and stays correct
# when V is sharded (compare+select fuses into the reduction).
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: Array, labels: Array,
                          mask: Optional[Array] = None,
                          z_loss: float = 0.0) -> Tuple[Array, Array]:
    """logits (..., V) bf16/f32; labels (...) int32.  Returns (mean_loss, aux).

    Label logit extracted via iota-compare fused reduction -> no (.., V)
    one-hot tensor and no cross-shard gather when V is model-sharded.
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], lf, 0.0), axis=-1)
    nll = lse - picked
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll * mask) / denom
    else:
        loss = jnp.mean(nll)
    return loss, lse


def take_embedding(table: Array, ids: Array) -> Array:
    """Embedding lookup.  For vocab-sharded tables the caller wraps this in a
    shard_map vocab-parallel lookup (see models/transformer.py); this plain
    version is the single-shard body."""
    return jnp.take(table, ids, axis=0)
