"""Mamba-2 (SSD) layer: chunked state-space dual form + O(1) decode step.

Chunked SSD is numerically safe everywhere: every exponent is a difference
cum_i - cum_j with i >= j of a cumulative sum of dA = dt * A <= 0, so all
exp() arguments are <= 0 (contrast RWKV-6, see rwkv6.py).

Projections use separate matrices per component (z, x, B, C, dt) instead of
one fused in_proj so each output dim shards cleanly on the "model" axis
(d_inner divisible by 16; N and H handled by replication when small).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Array = jax.Array


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    return d_in, H, s.head_dim, s.d_state


def mamba2_init(key: Array, cfg: ModelConfig, dtype, shape_prefix=()) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    d_in, H, P, N = dims(cfg)
    ks = jax.random.split(key, 12)
    pre = shape_prefix
    f32 = jnp.float32
    return {
        "w_z": layers.dense_init(ks[0], d, d_in, dtype, shape_prefix=pre),
        "w_x": layers.dense_init(ks[1], d, d_in, dtype, shape_prefix=pre),
        "w_B": layers.dense_init(ks[2], d, N, dtype, shape_prefix=pre),
        "w_C": layers.dense_init(ks[3], d, N, dtype, shape_prefix=pre),
        "w_dt": layers.dense_init(ks[4], d, H, dtype, shape_prefix=pre),
        "conv_x": (jax.random.normal(ks[5], pre + (s.conv_width, d_in), f32) * 0.1).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], pre + (s.conv_width, N), f32) * 0.1).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], pre + (s.conv_width, N), f32) * 0.1).astype(dtype),
        "A_log": jnp.zeros(pre + (H,), f32),
        "D": jnp.ones(pre + (H,), f32),
        "dt_bias": jnp.full(pre + (H,), -1.0, f32),
        "norm": jnp.ones(pre + (d_in,), f32),
        "w_out": layers.dense_init(ks[8], d_in, d, dtype, shape_prefix=pre),
    }


def _causal_conv(x: Array, w: Array, tail: Array | None = None):
    """Depthwise causal conv along time.  x (B,L,C), w (cw,C).
    tail (B,cw-1,C) continues a previous segment.  Returns (y, new_tail)."""
    cw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(cw))
    return jax.nn.silu(y), xp[:, -(cw - 1):]


def _ssd_chunk(state, xs, dt, A, B_, C_):
    """One SSD chunk.  state (B,H,P,N); xs (B,c,H,P); dt (B,c,H) f32;
    A (H,) f32 (negative); B_/C_ (B,c,N).  Returns (state', y (B,c,H,P))."""
    dA = dt * A                                            # (B,c,H) <= 0
    cum = jnp.cumsum(dA, axis=1)                           # (B,c,H)
    # intra-chunk
    CB = jnp.einsum("bin,bjn->bij", C_.astype(jnp.float32),
                    B_.astype(jnp.float32))                # (B,c,c)
    seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,c,c,H) i,j
    c = xs.shape[1]
    causal = jnp.tril(jnp.ones((c, c), bool))
    M = CB[..., None] * jnp.exp(jnp.where(causal[None, :, :, None], seg, -jnp.inf))
    M = M * dt[:, None, :, :]                              # weight by dt_j
    y = jnp.einsum("bijh,bjhp->bihp", M, xs.astype(jnp.float32))
    # inter-chunk (contribution of incoming state)
    y = y + jnp.einsum("bin,bhpn->bihp", C_.astype(jnp.float32),
                       state) * jnp.exp(cum)[..., None]
    # state update
    decay_to_end = jnp.exp(cum[:, -1:, :] - cum)           # (B,c,H) <= 1
    wx = xs.astype(jnp.float32) * (dt * decay_to_end)[..., None]
    state = state * jnp.exp(cum[:, -1])[..., None, None] + \
        jnp.einsum("bjn,bjhp->bhpn", B_.astype(jnp.float32), wx)
    return state, y


def mamba2_forward(w: dict, x: Array, cfg: ModelConfig,
                   state=None, conv_tails=None):
    """x (B,L,d) -> (y (B,L,d), (final_state, conv_tails)).  L % chunk == 0."""
    B, L, d = x.shape
    s = cfg.ssm
    d_in, H, P, N = dims(cfg)
    z = x @ w["w_z"]
    xs = x @ w["w_x"]
    B_ = x @ w["w_B"]
    C_ = x @ w["w_C"]
    dt = jax.nn.softplus((x @ w["w_dt"]).astype(jnp.float32) + w["dt_bias"])
    t_x, t_B, t_C = conv_tails if conv_tails is not None else (None, None, None)
    xs, t_x = _causal_conv(xs, w["conv_x"], t_x)
    B_, t_B = _causal_conv(B_, w["conv_B"], t_B)
    C_, t_C = _causal_conv(C_, w["conv_C"], t_C)
    A = -jnp.exp(w["A_log"])

    cl = min(s.chunk, L)
    assert L % cl == 0, (L, cl)
    nc = L // cl
    xs_c = xs.reshape(B, nc, cl, H, P).transpose(1, 0, 2, 3, 4)
    dt_c = dt.reshape(B, nc, cl, H).transpose(1, 0, 2, 3)
    B_c = B_.reshape(B, nc, cl, N).transpose(1, 0, 2, 3)
    C_c = C_.reshape(B, nc, cl, N).transpose(1, 0, 2, 3)

    if state is None:
        state = jnp.zeros((B, H, P, N), jnp.float32)

    def body(st, inp):
        xs_i, dt_i, B_i, C_i = inp
        st, y = _ssd_chunk(st, xs_i, dt_i, A, B_i, C_i)
        return st, y

    state, ys = jax.lax.scan(body, state, (xs_c, dt_c, B_c, C_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, L, H, P)
    y = y + w["D"][None, None, :, None] * xs.reshape(B, L, H, P).astype(jnp.float32)
    y = y.reshape(B, L, d_in).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), w["norm"], cfg.norm_eps)
    return y @ w["w_out"], (state, (t_x, t_B, t_C))


def mamba2_decode(w: dict, x: Array, cfg: ModelConfig, state, conv_tails):
    """x (B,1,d) single-token step. state (B,H,P,N) f32;
    conv_tails: 3 tensors (B,cw-1,C)."""
    B = x.shape[0]
    d_in, H, P, N = dims(cfg)
    z = x @ w["w_z"]
    xs = x @ w["w_x"]
    B_ = x @ w["w_B"]
    C_ = x @ w["w_C"]
    dt = jax.nn.softplus((x @ w["w_dt"]).astype(jnp.float32) + w["dt_bias"])[:, 0]
    t_x, t_B, t_C = conv_tails
    xs, t_x = _causal_conv(xs, w["conv_x"], t_x)
    B_, t_B = _causal_conv(B_, w["conv_B"], t_B)
    C_, t_C = _causal_conv(C_, w["conv_C"], t_C)
    A = -jnp.exp(w["A_log"])

    xs1 = xs[:, 0].reshape(B, H, P).astype(jnp.float32)
    B1 = B_[:, 0].astype(jnp.float32)                       # (B,N)
    C1 = C_[:, 0].astype(jnp.float32)
    dA = jnp.exp(dt * A)                                    # (B,H)
    state = state * dA[..., None, None] + \
        jnp.einsum("bn,bhp->bhpn", B1, xs1 * dt[..., None])
    y = jnp.einsum("bn,bhpn->bhp", C1, state)
    y = y + w["D"][None, :, None] * xs1
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), w["norm"], cfg.norm_eps)
    return y @ w["w_out"], (state, (t_x, t_B, t_C))
