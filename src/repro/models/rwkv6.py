"""RWKV-6 ("Finch") layer: data-dependent-decay time-mix + channel-mix.

Numerical strategy: RWKV-6 decays are per-channel (K-dim), so the Mamba-2
segsum trick would need a (c, c, K) tensor and the linear-attention q/k decay
factorisation overflows (exp(-cum_j) grows without bound for fast-decaying
channels).  We therefore run an outer scan over chunks of CHUNK=16 steps and
an exact unrolled recurrence inside the chunk: zero overflow risk, 16x fewer
scan iterations than a per-token scan, and the structure maps directly onto
the Pallas kernel in repro/kernels/rwkv6_wkv (grid = chunks, VMEM-resident
state).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Array = jax.Array

CHUNK = 16
LORA_MIX = 32
LORA_DECAY = 64


def rwkv6_init(key: Array, cfg: ModelConfig, dtype, shape_prefix=()) -> dict:
    d = cfg.d_model
    H, K = cfg.n_heads, cfg.rwkv.head_size
    ks = jax.random.split(key, 16)
    pre = shape_prefix
    f32 = jnp.float32
    nrm = lambda k_, sh, sc: (jax.random.normal(k_, pre + sh, f32) * sc).astype(f32)
    return {
        "tmix": {
            "maa_x": jnp.zeros(pre + (d,), f32),
            "maa": nrm(ks[0], (5, d), 0.1),
            "maa_A": nrm(ks[1], (d, 5 * LORA_MIX), 0.01),
            "maa_B": nrm(ks[2], (5, LORA_MIX, d), 0.01),
            "decay_w": nrm(ks[3], (H * K,), 0.5),
            "decay_A": nrm(ks[4], (d, LORA_DECAY), 0.01),
            "decay_B": nrm(ks[5], (LORA_DECAY, H * K), 0.01),
            "u": nrm(ks[6], (H, K), 0.5),
            "w_r": layers.dense_init(ks[7], d, d, dtype, shape_prefix=pre),
            "w_k": layers.dense_init(ks[8], d, d, dtype, shape_prefix=pre),
            "w_v": layers.dense_init(ks[9], d, d, dtype, shape_prefix=pre),
            "w_g": layers.dense_init(ks[10], d, d, dtype, shape_prefix=pre),
            "w_o": layers.dense_init(ks[11], d, d, dtype, shape_prefix=pre),
            "ln": jnp.ones(pre + (H, K), f32),
        },
        "cmix": {
            "maa_k": jnp.zeros(pre + (d,), f32),
            "maa_r": jnp.zeros(pre + (d,), f32),
            "w_k": layers.dense_init(ks[12], d, cfg.d_ff, dtype, shape_prefix=pre),
            "w_v": layers.dense_init(ks[13], cfg.d_ff, d, dtype, shape_prefix=pre),
            "w_r": layers.dense_init(ks[14], d, d, dtype, shape_prefix=pre),
        },
    }


def _shift(x: Array, prev: Array) -> Array:
    """Token shift: y_t = x_{t-1}; prev (B,1,d) seeds t=0."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(x, xprev, maa_x, maa, maa_A, maa_B):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    dx = xprev - x                                          # (B,L,d)
    xxx = x + dx * maa_x
    lo = jnp.tanh(xxx @ maa_A)                              # (B,L,5*32)
    B, L, _ = x.shape
    lo = lo.reshape(B, L, 5, LORA_MIX)
    mix = jnp.einsum("blfr,frd->blfd", lo, maa_B)           # (B,L,5,d)
    out = x[:, :, None, :] + dx[:, :, None, :] * (maa[None, None] + mix)
    return [out[:, :, i] for i in range(5)]                 # w,k,v,r,g


def _wkv_chunk(state, r, k, v, decay, u):
    """Exact WKV-6 recurrence over one chunk.
    state (B,H,K,V) f32; r/k/decay (B,c,H,K) f32; v (B,c,H,V) f32; u (H,K).

    The bonus term is factored as (r.u.k) v — a (B,H) scalar times v — so no
    (B,H,K,V) ``state + u*kv`` temporary is materialised (§Perf-1 lever:
    drops per-token HBM-bound temps from ~3 to 1 in the lax twin)."""
    outs = []
    for t in range(r.shape[1]):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], decay[:, t]
        out = jnp.einsum("bhk,bhkv->bhv", rt, state) + \
            jnp.sum(rt * u[None] * kt, axis=-1)[..., None] * vt
        state = wt[..., None] * state + kt[..., None] * vt[:, :, None, :]
        outs.append(out)
    return state, jnp.stack(outs, axis=1)                   # (B,c,H,V)


def time_mix(w: dict, x: Array, cfg: ModelConfig, shift_prev, state,
             chunk: int = CHUNK):
    """x (B,L,d); shift_prev (B,1,d); state (B,H,K,V) f32."""
    B, L, d = x.shape
    H, K = cfg.n_heads, cfg.rwkv.head_size
    xprev = _shift(x, shift_prev)
    xw, xk, xv, xr, xg = _ddlerp(x, xprev, w["maa_x"], w["maa"],
                                 w["maa_A"], w["maa_B"])
    r = (xr @ w["w_r"]).reshape(B, L, H, K).astype(jnp.float32)
    k = (xk @ w["w_k"]).reshape(B, L, H, K).astype(jnp.float32)
    v = (xv @ w["w_v"]).reshape(B, L, H, K).astype(jnp.float32)
    g = jax.nn.silu(xg @ w["w_g"])
    w_raw = w["decay_w"] + jnp.tanh(xw.astype(jnp.float32) @ w["decay_A"]) @ w["decay_B"]
    decay = jnp.exp(-jnp.exp(w_raw.reshape(B, L, H, K)))    # in (0,1)

    cl = min(chunk, L)
    while L % cl:
        cl -= 1
    nc = L // cl
    rs = r.reshape(B, nc, cl, H, K).transpose(1, 0, 2, 3, 4)
    ks_ = k.reshape(B, nc, cl, H, K).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nc, cl, H, K).transpose(1, 0, 2, 3, 4)
    ws = decay.reshape(B, nc, cl, H, K).transpose(1, 0, 2, 3, 4)

    def body(st, inp):
        ri, ki, vi, wi = inp
        st, y = _wkv_chunk(st, ri, ki, vi, wi, w["u"])
        return st, y

    state, ys = jax.lax.scan(body, state, (rs, ks_, vs, ws))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, L, H, K)
    y = layers.head_rms_norm(y, w["ln"], cfg.norm_eps)
    y = (y.reshape(B, L, d) * g).astype(x.dtype)
    return y @ w["w_o"], x[:, -1:], state


def channel_mix(w: dict, x: Array, shift_prev):
    xprev = _shift(x, shift_prev)
    dx = xprev - x
    xk = x + dx * w["maa_k"]
    xr = x + dx * w["maa_r"]
    kk = jnp.square(jax.nn.relu(xk @ w["w_k"]))
    out = jax.nn.sigmoid(xr @ w["w_r"]) * (kk @ w["w_v"])
    return out, x[:, -1:]
