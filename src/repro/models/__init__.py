from repro.models.transformer import (
    RunFlags,
    ShardCtx,
    init_cache,
    init_params,
    make_decode_fn,
    make_loss_fn,
    make_prefill_fn,
    padded_vocab,
)

__all__ = [
    "RunFlags", "ShardCtx", "init_cache", "init_params", "make_decode_fn",
    "make_loss_fn", "make_prefill_fn", "padded_vocab",
]
