"""Mixture-of-Experts layer with sort-based capacity dispatch.

Why sort-based: the one-hot-matmul (GShard) dispatch costs O(T * E * C * d)
FLOPs which poisons the useful-compute ratio; sorting + scatter keeps the
dispatch at gather/scatter cost so HLO FLOPs stay ~= active-expert FLOPs.

Baseline sharding: tokens on "data", experts on "model" (expert parallelism);
GSPMD inserts the cross-axis traffic.  The hillclimbed explicit all-to-all EP
path lives in repro/sharding/ep.py (shard_map).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Array = jax.Array


def moe_init(key: Array, cfg: ModelConfig, n_layers: int, dtype) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 4)
    pre = (n_layers, m.n_experts)
    if cfg.mlp_type == "swiglu":
        w = {
            "w_gate": layers.dense_init(ks[0], cfg.d_model, m.expert_d_ff, dtype, shape_prefix=pre),
            "w_up": layers.dense_init(ks[1], cfg.d_model, m.expert_d_ff, dtype, shape_prefix=pre),
            "w_down": layers.dense_init(ks[2], m.expert_d_ff, cfg.d_model, dtype, shape_prefix=pre),
        }
    else:
        w = {
            "w_in": layers.dense_init(ks[0], cfg.d_model, m.expert_d_ff, dtype, shape_prefix=pre),
            "w_out": layers.dense_init(ks[1], m.expert_d_ff, cfg.d_model, dtype, shape_prefix=pre),
        }
    w["router"] = layers.dense_init(ks[3], cfg.d_model, m.n_experts, jnp.float32,
                                    scale=0.1, shape_prefix=(n_layers,))
    return w


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def route(router_w: Array, x: Array, top_k: int) -> Tuple[Array, Array, Array]:
    """x (T, d) -> (topk idx (T,k), combine weights (T,k) f32, aux loss)."""
    logits = x.astype(jnp.float32) @ router_w                    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss.
    E = logits.shape[-1]
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
    return idx, w, aux


def moe_apply(w: dict, x: Array, cfg: ModelConfig, layer_idx=None) -> Tuple[Array, Array]:
    """x (T, d) -> (out (T, d), aux loss).  Sort-based capacity dispatch."""
    m = cfg.moe
    T, d = x.shape
    C = capacity(cfg, T)
    E = m.n_experts
    k = m.top_k

    router_w = w["router"] if layer_idx is None else w["router"]
    idx, cw, aux = route(router_w, x, k)                         # (T,k)

    e_flat = idx.reshape(-1)                                     # (T*k,)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)       # (T*k,)
    w_flat = cw.reshape(-1)

    order = jnp.argsort(e_flat)                                  # stable
    se, st, sw = e_flat[order], t_flat[order], w_flat[order]
    # position of each routed token within its expert segment
    counts = jnp.bincount(e_flat, length=E)                      # (E,)
    seg_start = jnp.cumsum(counts) - counts                      # exclusive
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - seg_start[se]
    keep = pos_in_e < C
    dest = jnp.where(keep, se * C + pos_in_e, E * C)             # OOB -> drop

    xt = jnp.take(x, st, axis=0)                                 # (T*k, d)
    buf = jnp.zeros((E * C, d), x.dtype).at[dest].set(
        xt * keep[:, None].astype(x.dtype), mode="drop")
    buf = buf.reshape(E, C, d)

    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, w["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, w["w_up"])
        h = jax.nn.silu(g) * u
        y = jnp.einsum("ecf,efd->ecd", h, w["w_down"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, w["w_in"]))
        y = jnp.einsum("ecf,efd->ecd", h, w["w_out"])
    y = y.reshape(E * C, d)

    yt = jnp.take(y, jnp.where(keep, dest, 0), axis=0)
    yt = yt * (sw * keep).astype(y.dtype)[:, None]
    out = jnp.zeros((T, d), y.dtype).at[st].add(yt)
    return out, aux
